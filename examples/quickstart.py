"""Quickstart: train a tiny LM for 50 steps, then generate from it.

    PYTHONPATH=src python examples/quickstart.py

Shows the three public API layers: configs (ArchConfig), train (TrainConfig +
fit), and serve (generate).
"""
import jax.numpy as jnp

from repro.configs import SURVEY_DEMO, reduced
from repro.data import DataPipeline
from repro.models import Runtime
from repro.optim import get as get_opt
from repro.train import TrainConfig, fit, generate

# a ~3M-param llama-style model (same family as the demo config)
cfg = reduced(SURVEY_DEMO, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
              d_ff=512, vocab_size=2048)

tc = TrainConfig(optimizer="adamw", lr=1e-3, log_every=10)
data = DataPipeline(cfg, batch_size=16, seq_len=128, seed=0)
try:
    state, history = fit(cfg, tc, data, steps=50, opt=get_opt(tc.optimizer, tc.lr))
finally:
    data.close()

print(f"\nloss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")

# batched greedy generation from the trained model
prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None, :].repeat(4, 0)}
tokens, _ = generate(cfg, state["params"], prompt, Runtime(dtype=jnp.float32),
                     max_new_tokens=16)
print("generated:", tokens[0].tolist())
assert history[-1]["loss"] < history[0]["loss"], "training must reduce loss"
print("quickstart OK")
