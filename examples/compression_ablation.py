"""Gradient-compression convergence ablation (survey §4.3, Fig-style).

Trains the same tiny LM with dense vs compressed gradient sync (loopback
compression — the approximation error is what matters for convergence) and
prints a loss-vs-bytes table: the survey's communication/quality trade-off,
measured.

    PYTHONPATH=src python examples/compression_ablation.py --steps 120
"""
import argparse

from repro.configs import SURVEY_DEMO, reduced
from repro.core.compression import PowerSGD, QSGD, SignEF, TopK
from repro.data import DataPipeline
from repro.optim import get as get_opt
from repro.train import TrainConfig, fit

CFG = reduced(SURVEY_DEMO, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
              d_ff=512, vocab_size=2048)

METHODS = {
    "dense": None,
    "topk@1%": TopK(0.01),
    "topk@10%": TopK(0.1),
    "qsgd-8bit": QSGD(8),
    "qsgd-4bit": QSGD(4),
    "sign+EF": SignEF(),
    "powersgd-r4": PowerSGD(4),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    results = {}
    for name, method in METHODS.items():
        tc = TrainConfig(lr=1e-3, compression=method, log_every=args.steps // 6)
        data = DataPipeline(CFG, 16, 128, seed=0)
        try:
            _, hist = fit(CFG, tc, data, args.steps, get_opt("adamw", 1e-3),
                          log=lambda s: None)
        finally:
            data.close()
        results[name] = hist

    dense_final = results["dense"][-1]["loss"]
    print(f"\n{'method':<14s} {'final loss':>10s} {'vs dense':>9s} {'wire bytes/step':>16s}")
    for name, hist in results.items():
        wire = hist[-1]["wire_bytes"]
        print(f"{name:<14s} {hist[-1]['loss']:>10.4f} "
              f"{hist[-1]['loss'] - dense_final:>+9.4f} {wire:>16.3g}")


if __name__ == "__main__":
    main()
