"""ZeRO/FSDP demo on 8 simulated devices (survey §4.1).

Spawns a subprocess with 8 fake CPU devices (so the parent process keeps its
single-device view), builds the distributed trainer at every ZeRO stage on a
(4 data x 2 model) mesh, runs REAL steps, and prints per-device memory +
collective traffic per stage.

    PYTHONPATH=src python examples/zero_fsdp_demo.py
"""
import os
import subprocess
import sys
import textwrap

def _subprocess_env():
    """Inherit the environment (JAX_PLATFORMS etc. — a bare env hangs jax
    backend probing on CPU containers); scripts set their own XLA_FLAGS."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return env



SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_reduced, ShapeSpec
    import repro.configs.registry as registry
    from repro.launch.train import build_train
    from repro.train import TrainConfig
    from repro.data import DataPipeline
    from repro.roofline.analysis import collective_bytes

    cfg = get_reduced("granite-8b")
    registry.ARCHITECTURES[cfg.name] = cfg
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    shape = ShapeSpec("demo", 64, 16, "train")

    data = DataPipeline(cfg, shape.global_batch, shape.seq_len, seed=0)
    raw = next(data); data.close()

    for stage in (0, 1, 2, 3):
        tc = TrainConfig(precision="f32", zero_stage=stage, log_every=1)
        jitted, (s_struct, b_struct) = build_train(cfg.name, mesh, tc, shape)

        # materialize real sharded state from the structs
        from repro.optim import get as get_opt
        from repro.train import make_state
        state = make_state(cfg, get_opt(tc.optimizer, tc.lr), tc)
        state = jax.tree.map(
            lambda x, st: jax.device_put(x, st.sharding), state, s_struct)
        batch = jax.tree.map(
            lambda v, st: jax.device_put(jnp.asarray(v), st.sharding),
            dict(raw), b_struct)

        compiled = jitted.lower(s_struct, b_struct).compile()
        mem = compiled.memory_analysis()
        wire = collective_bytes(compiled.as_text(), 8, cfg.n_layers).total_bytes
        losses = []
        for i in range(3):
            state, metrics = jitted(state, batch)
            losses.append(float(metrics["loss"]))
        print(f"stage{stage}: args={float(mem.argument_size_in_bytes)/2**20:8.1f}MiB "
              f"wire={wire/2**20:8.1f}MiB losses={[round(l,3) for l in losses]}")
    print("ZERO_DEMO_OK")
    """
)


def main() -> None:
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], text=True, timeout=1800,
        env=_subprocess_env(),
        cwd=".",
    )
    assert r.returncode == 0


if __name__ == "__main__":
    main()
