"""Serving example: continuous batching + paged KV pool across families.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b
    PYTHONPATH=src python examples/serve_decode.py --arch falcon-mamba-7b

Uses the REDUCED variant of the chosen architecture (CPU container). For
KV-cache attention families the requests run through the paged serve engine
(variable-length prompts, fixed decode slots, block-table page pool) and one
request is cross-checked token-for-token against running it alone on the
dense path. Recurrent / enc-dec families exercise the dense fallback.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_reduced
from repro.models import Runtime, init_params
from repro.serve import EngineConfig, ServeEngine, paged_supported
from repro.train import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=ASSIGNED)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rt = Runtime(dtype=jnp.float32, chunk_q=32)
    rng = np.random.RandomState(0)

    paged = paged_supported(cfg)
    eng = ServeEngine(
        cfg, params, rt,
        EngineConfig.capacity(
            args.prompt_len + cfg.frontend_tokens, args.new_tokens,
            slots=2, page_size=8, headroom=2.0,
        ).engine(inner_steps=4),
        paged=paged,
    )

    reqs = []
    for _ in range(args.requests):
        plen = rng.randint(max(args.prompt_len // 2, 2), args.prompt_len + 1)
        tokens = rng.randint(0, cfg.vocab_size, (plen,)).astype(np.int32)
        fe = (
            rng.randn(cfg.frontend_tokens, cfg.d_model).astype(np.float32)
            if cfg.frontend is not None else None
        )
        reqs.append((eng.submit(tokens, args.new_tokens, frontend_embeds=fe),
                     tokens, fe))

    t0 = time.perf_counter()
    out = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"arch={cfg.name} family={cfg.family} paged={eng.paged}")
    print(f"served {len(reqs)} requests / {toks} tokens in {dt:.1f}s "
          f"({eng.stats['tokens_per_s']:.1f} tok/s incl. compile)")
    for rid, _, _ in reqs[:2]:
        print(f"  req[{rid}]: {out[rid][:12].tolist()}...")

    # cross-check one request against its isolated dense run (greedy)
    rid, tokens, fe = reqs[0]
    batch = {"tokens": jnp.asarray(tokens[None])}
    if fe is not None:
        batch["frontend_embeds"] = jnp.asarray(fe[None])
    alone, _ = generate(cfg, params, batch, rt, args.new_tokens)
    assert np.array_equal(out[rid], np.asarray(alone[0])), "batched != alone"
    assert all(
        v.min() >= 0 and v.max() < cfg.vocab_padded for v in out.values()
    )
    print("serve_decode OK (batched == alone)")


if __name__ == "__main__":
    main()
