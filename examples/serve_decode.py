"""Serving example: batched prefill + decode across architecture families.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b
    PYTHONPATH=src python examples/serve_decode.py --arch falcon-mamba-7b

Uses the REDUCED variant of the chosen architecture (CPU container), which
still exercises that family's real decode path: ring-buffer kv caches with
sliding windows (gemma3), recurrent states (mamba/recurrentgemma), cross-
attention caches (seamless), image-prefix decode (phi-3-vision).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_reduced
from repro.models import Runtime, init_params
from repro.train import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=ASSIGNED)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rt = Runtime(dtype=jnp.float32, chunk_q=32)

    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
        )
    }
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )

    t0 = time.perf_counter()
    tokens, state = generate(
        cfg, params, batch, rt, max_new_tokens=args.new_tokens,
        temperature=args.temperature,
    )
    dt = time.perf_counter() - t0
    toks = int(tokens.size)
    print(f"arch={cfg.name} family={cfg.family}")
    print(f"generated {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    for b in range(min(2, args.batch)):
        print(f"  seq[{b}]: {tokens[b, :16].tolist()}...")
    assert bool(jnp.all(tokens >= 0)) and bool(jnp.all(tokens < cfg.vocab_padded))
    print("serve_decode OK")


if __name__ == "__main__":
    main()
