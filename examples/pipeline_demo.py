"""Pipeline-parallelism demo (survey §3): planner -> simulator -> execution.

1. Partition granite-8b's 36 layers into 4 stages (dyn-prog vs heuristic).
2. Simulate every Table-4 schedule on that partition.
3. Run ``dp_pp_search`` (batch-capped, uniform stages) to pick an
   executable ParallelPlan for 4 devices.
4. Execute that plan end-to-end as a REAL 1F1B pipeline on 4 simulated
   devices (subprocess): `build_train_pipeline` streams microbatches
   through the tick-table runner and the loss matches the single-device
   step on the same batch.

    PYTHONPATH=src python examples/pipeline_demo.py [--stash fp8]
"""
import argparse
import os
import subprocess
import sys
import textwrap

from repro.configs import get_config, get_reduced
from repro.core.partitioner import (
    auto_plan, dp_pp_search, dynprog_partition, heuristic_partition,
    layer_costs_from_config,
)
from repro.core.pipeline import SCHEDULES, simulate, tick_table

def _subprocess_env():
    """Inherit the environment (JAX_PLATFORMS etc. — a bare env hangs jax
    backend probing on CPU containers); scripts set their own XLA_FLAGS."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return env




def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stash", default="raw", choices=("raw", "int8", "fp8"),
                    help="activation-slot storage for the executable run "
                         "(core.stash; quantized slots loosen the "
                         "single-device loss match)")
    args = ap.parse_args()
    cfg = get_config("granite-8b")
    costs = layer_costs_from_config(cfg)
    P, M = 4, 16
    dp = dynprog_partition(costs, P)
    he = heuristic_partition(costs, P)
    print(f"partitioning {cfg.name} ({cfg.n_layers} layers) into {P} stages:")
    print(f"  dynprog  : bounds={dp.boundaries} bottleneck={dp.bottleneck:.3g}")
    print(f"  heuristic: bounds={he.boundaries} bottleneck={he.bottleneck:.3g}")

    choice = dp_pp_search(costs, n_devices=16, microbatches=M)
    print(f"  best (dp, pp) on 16 devices @ M={M}: ({choice.dp}, {choice.pp})")

    print(f"\nschedules @ P={P}, M={M} (t_bwd = 2 t_fwd):")
    for name in SCHEDULES:
        r = simulate(name, P, M)
        sync = "sync " if r.synchronous else f"async(stale<={r.max_staleness})"
        print(f"  {name:14s} bubble={r.bubble_fraction:.3f} "
              f"peak_act={r.peak_activations:3d} wcopies={r.weight_versions} {sync}")

    # planner -> executable plan for the 4 simulated devices below; the
    # batch cap (dp <= batch/microbatches) is what pushes devices into pp
    tiny = get_reduced("granite-8b")
    plan = auto_plan(tiny, 4, microbatches=4, schedule="1f1b", max_dp=2,
                     stash=args.stash)
    tt = tick_table(plan.schedule, plan.pp, plan.microbatches)
    print(f"\nauto plan for 4 devices (batch-capped dp<=2): {plan.describe()}")
    print(f"  1f1b act slots/device: {tt.n_act_slots} "
          f"(gpipe would hold {plan.microbatches})")
    rep = plan.stash_report(tiny, global_batch=8, seq_len=64, itemsize=4)
    print(f"  stash={rep['backend']}: {rep['bytes_per_slot']} B/slot "
          f"(raw {rep['raw_bytes_per_slot']} B), "
          f"capacity {rep['capacity_factor']:.2f}x raw")

    print("\nexecutable 1F1B on 4 simulated devices (plan above):")
    r = subprocess.run(
        [sys.executable, "-c", _RUNNER.format(
            dp=plan.dp, tp=plan.tp, pp=plan.pp, M=plan.microbatches,
            stash=plan.stash, rtol=2e-3 if plan.stash == "raw" else 5e-2)],
        text=True, timeout=900,
        env=_subprocess_env(),
    )
    assert r.returncode == 0
    print("pipeline_demo OK")


_RUNNER = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ShapeSpec, get_reduced
    import repro.configs.registry as registry
    from repro.core.partitioner import ParallelPlan
    from repro.data import DataPipeline
    from repro.launch.mesh import make_train_mesh
    from repro.launch.train import build_train_pipeline
    from repro.optim import get as get_opt
    from repro.train import TrainConfig, make_state, make_train_step

    cfg = get_reduced("granite-8b")
    registry.ARCHITECTURES[cfg.name] = cfg
    B, SEQ = 8, 64
    plan = ParallelPlan(dp={dp}, tp={tp}, pp={pp}, microbatches={M},
                        schedule="1f1b", stash="{stash}").validate(cfg)
    tc = TrainConfig(precision="f32", log_every=1)
    opt = get_opt(tc.optimizer, tc.lr)
    data = DataPipeline(cfg, batch_size=B, seq_len=SEQ, seed=0)
    batch_np = {{k: np.asarray(v) for k, v in dict(next(data)).items()}}
    data.close()

    mesh = make_train_mesh(plan.dp, plan.tp, plan.pp)
    jitted, (s_struct, b_struct) = build_train_pipeline(
        cfg.name, mesh, plan, tc, ShapeSpec("t", SEQ, B, "train"))
    state = jax.tree.map(lambda x, st: jax.device_put(x, st.sharding),
                         make_state(cfg, opt, tc), s_struct)
    batch = jax.tree.map(
        lambda v, st: jax.device_put(jnp.asarray(v), st.sharding),
        batch_np, b_struct)
    _, m3d = jitted(state, batch)

    step1 = make_train_step(cfg, opt, tc)
    _, m1 = step1(make_state(cfg, opt, tc),
                  {{k: jnp.asarray(v) for k, v in batch_np.items()}})
    l3d, l1 = float(m3d["loss"]), float(m1["loss"])
    assert abs(l3d - l1) < {rtol} * abs(l1), (l3d, l1)
    print(f"  1F1B on {{plan.describe()}}: loss={{l3d:.4f}} "
          f"(single-device: {{l1:.4f}})")
    """
)

if __name__ == "__main__":
    main()
