"""Pipeline-parallelism demo (survey §3): planner -> simulator -> execution.

1. Partition granite-8b's 36 layers into 4 stages (dyn-prog vs heuristic).
2. Simulate every Table-4 schedule on that partition.
3. Execute a real GPipe pipeline on 4 simulated devices (subprocess) and
   check it against the sequential model.

    PYTHONPATH=src python examples/pipeline_demo.py
"""
import os
import subprocess
import sys
import textwrap

from repro.configs import get_config
from repro.core.partitioner import (
    dp_pp_search, dynprog_partition, heuristic_partition, layer_costs_from_config,
)
from repro.core.pipeline import SCHEDULES, simulate

def _subprocess_env():
    """Inherit the environment (JAX_PLATFORMS etc. — a bare env hangs jax
    backend probing on CPU containers); scripts set their own XLA_FLAGS."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return env




def main() -> None:
    cfg = get_config("granite-8b")
    costs = layer_costs_from_config(cfg)
    P, M = 4, 16
    dp = dynprog_partition(costs, P)
    he = heuristic_partition(costs, P)
    print(f"partitioning {cfg.name} ({cfg.n_layers} layers) into {P} stages:")
    print(f"  dynprog  : bounds={dp.boundaries} bottleneck={dp.bottleneck:.3g}")
    print(f"  heuristic: bounds={he.boundaries} bottleneck={he.bottleneck:.3g}")

    choice = dp_pp_search(costs, n_devices=16, microbatches=M)
    print(f"  best (dp, pp) on 16 devices @ M={M}: ({choice.dp}, {choice.pp})")

    print(f"\nschedules @ P={P}, M={M} (t_bwd = 2 t_fwd):")
    for name in SCHEDULES:
        r = simulate(name, P, M)
        sync = "sync " if r.synchronous else f"async(stale<={r.max_staleness})"
        print(f"  {name:14s} bubble={r.bubble_fraction:.3f} "
              f"peak_act={r.peak_activations:3d} wcopies={r.weight_versions} {sync}")

    print("\nexecutable GPipe on 4 simulated devices:")
    r = subprocess.run(
        [sys.executable, "-c", _RUNNER], text=True, timeout=900,
        env=_subprocess_env(),
    )
    assert r.returncode == 0
    print("pipeline_demo OK")


_RUNNER = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.pipeline import pipeline_apply
    P, M, D, B = 4, 8, 64, 4
    mesh = jax.make_mesh((P,), ("pipe",))
    rng = np.random.RandomState(0)
    sp = {"w": jnp.asarray(rng.randn(P, D, D) * 0.2, jnp.float32)}
    mbs = jnp.asarray(rng.randn(M, B, D), jnp.float32)
    fn = lambda p, x: jnp.tanh(x @ p["w"])
    out = pipeline_apply(fn, sp, mbs, mesh=mesh)
    ref = mbs
    for s in range(P):
        ref = jax.vmap(lambda x: fn({"w": sp["w"][s]}, x))(ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    print("  pipelined output == sequential reference (8 microbatches, 4 stages)")
    """
)

if __name__ == "__main__":
    main()
