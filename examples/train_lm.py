"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --preset 20m --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300 \
        --optimizer lamb --remat full --precision bf16

All survey features are reachable from the CLI: optimizer (incl. lamb/lars/
adam8bit), remat policy, precision, gradient compression, checkpointing.
The 100m preset IS the survey-demo config; 20m is its reduced sibling for
CPU-friendly runs (the default here — 100m on this 1-core container is
~30 s/step).
"""
import argparse

from repro.configs import SURVEY_DEMO, reduced
from repro.core.compression import PowerSGD, QSGD, SignEF, TopK
from repro.data import DataPipeline
from repro.optim import Schedule, get as get_opt
from repro.train import TrainConfig, fit

PRESETS = {
    "100m": SURVEY_DEMO,  # 12L d768 12H, ~124M params
    "20m": reduced(
        SURVEY_DEMO, n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
        d_ff=1024, vocab_size=8192, name="survey-demo-20m",
    ),
    "3m": reduced(
        SURVEY_DEMO, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_ff=512, vocab_size=2048, name="survey-demo-3m",
    ),
}
COMPRESSORS = {
    "none": None, "topk": TopK(0.01), "qsgd": QSGD(8),
    "sign": SignEF(), "powersgd": PowerSGD(4),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd", "lars", "lamb", "adam8bit"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--precision", default="f32", choices=["f32", "bf16", "fp16"])
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--compression", default="none", choices=sorted(COMPRESSORS))
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n = cfg.param_count()["total"]
    print(f"model: {cfg.name} ({n/1e6:.1f}M params), "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    sched = Schedule(base_lr=args.lr, warmup_steps=min(50, args.steps // 4),
                     total_steps=args.steps, kind="cosine")
    tc = TrainConfig(
        optimizer=args.optimizer, lr=sched, precision=args.precision,
        remat=args.remat, compression=COMPRESSORS[args.compression],
        log_every=10, ckpt_dir=args.ckpt_dir or None,
        ckpt_every=100 if args.ckpt_dir else 0,
    )
    data = DataPipeline(cfg, args.batch, args.seq, seed=0)
    try:
        state, hist = fit(cfg, tc, data, args.steps, get_opt(args.optimizer, sched))
    finally:
        data.close()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first else 'WARN: did not decrease'})")


if __name__ == "__main__":
    main()
