"""Bench-regression gate: compare fresh ``benchmarks.run --json`` snapshots
against their committed references.

    python -m benchmarks.check_regression --ref BENCH_serve.json \
        --fresh BENCH_serve.fresh.json [--tolerance 20]

``--ref``/``--fresh`` repeat pairwise, so one invocation gates every
snapshot (kernels, serve, serve_sharded, serve_prefix, serve_quant,
serve_trace, train_pipeline):

    python -m benchmarks.check_regression \
        --ref BENCH_serve.json --fresh BENCH_serve.fresh.json \
        --ref BENCH_serve_prefix.json --fresh BENCH_serve_prefix.fresh.json

Rules
-----
* The fresh snapshot must contain exactly the reference's row names — a
  silently dropped (or renamed) benchmark is a failure, not a pass.
* Rows whose reference ``us_per_call`` is 0.0 are *accounting* rows
  (memory factors, byte counts): their ``derived`` string must match
  exactly — these are hardware-independent claims and any drift is a real
  behavior change.
* Timed rows gate on slowdown only: ``fresh <= ref * tolerance``. The
  tolerance is deliberately loose (CI runners vs the snapshot machine,
  interpret-mode CPU noise); the gate exists to catch catastrophic
  regressions — an accidental per-token retrace shows up as 100x, not 2x.
"""
from __future__ import annotations

import argparse
import json
import sys


def load(path: str):
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)["rows"]}


def compare(ref: dict, fresh: dict, tolerance: float) -> list:
    errors = []
    missing = sorted(set(ref) - set(fresh))
    extra = sorted(set(fresh) - set(ref))
    if missing:
        errors.append(f"rows missing from fresh run: {missing}")
    if extra:
        errors.append(
            f"rows absent from the committed snapshot: {extra} "
            "(regenerate and commit the BENCH_*.json)"
        )
    for name in sorted(set(ref) & set(fresh)):
        r, f = ref[name], fresh[name]
        if r["us_per_call"] == 0.0:
            if f["derived"] != r["derived"]:
                errors.append(
                    f"{name}: accounting drift\n  ref:   {r['derived']}"
                    f"\n  fresh: {f['derived']}"
                )
        elif f["us_per_call"] > r["us_per_call"] * tolerance:
            errors.append(
                f"{name}: {f['us_per_call']:.1f}us vs ref "
                f"{r['us_per_call']:.1f}us (> {tolerance:g}x tolerance)"
            )
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref", action="append", required=True,
                    help="committed snapshot (repeatable, pairs with --fresh)")
    ap.add_argument("--fresh", action="append", required=True,
                    help="snapshot from this run (repeatable)")
    ap.add_argument("--tolerance", type=float, default=20.0,
                    help="max allowed slowdown ratio for timed rows")
    args = ap.parse_args()
    if len(args.ref) != len(args.fresh):
        ap.error("--ref and --fresh must pair up")
    failed = False
    for ref_path, fresh_path in zip(args.ref, args.fresh):
        errors = compare(load(ref_path), load(fresh_path), args.tolerance)
        if errors:
            failed = True
            print(f"BENCH REGRESSION ({ref_path}):", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
        else:
            n = len(load(ref_path))
            print(
                f"bench gate OK: {n} rows within {args.tolerance:g}x of "
                f"{ref_path}"
            )
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
