"""Speculative-decoding benchmark: multi-token commit over the paged engine.

One timed claim, honestly framed. A spec tick replaces ``inner_steps``
sequential decode forwards with a single batched T=k+1 verify forward, so
tokens/s scales with the accepted-run length — and the accepted-run length
is a property of the WORKLOAD: prompt-lookup drafting pays off exactly when
the continuation is predictable (copied spans, boilerplate, cycles — the
regime real LM output lives in much of the time). Random-init reduced
models emit near-incompressible streams over a 1024-token alphabet, where
acceptance is ~0 (reported below, unasserted) — so the anchored scenario
shrinks the alphabet to 2 via the same config registry, which drives the
greedy stream into short cycles the drafter can actually hit: acceptance
~0.87 at k=8, in the range prompt-lookup papers report on summarization.

* **Timed** (gated on slowdown only): paged decode tokens/s at batch 1 and
  batch 4, spec-on (ngram drafter, k=8) vs spec-off (inner_steps=4 fused
  scan) on the anchored scenario; the in-bench assert is the tentpole
  claim — >= 1.5x at BOTH batch sizes. Best-of-3 walls, and spec-on
  output is asserted token-identical to spec-off first (greedy acceptance
  commits only the target's own argmax chain, so drafting buys speed,
  never tokens). Interpret-mode CPU timings are NOT TPU perf claims
  (EXPERIMENTS.md) — but note the mechanism is the same one that wins on
  real accelerators: fewer sequential forwards per committed token.
* **Exact** (accounting row, gated verbatim): acceptance counters on the
  anchored scenario — verify calls, drafted/accepted tokens, acceptance
  rate, mean accepted-per-verify. Deterministic greedy argmax facts, same
  anchored-seed caveat as the quantized-pool bench: the seed is one whose
  argmax margins clear accumulation noise.
* **Incompressible control** (timed, no speedup assert): the same engines
  on a full-vocab random prompt, where acceptance is ~0 and every tick
  commits ~1 token — the floor case: spec decode degenerates toward
  per-token verify and must stay within dispatch-overhead distance of
  plain decode, not fall off a cliff.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, header

K = 8
MAX_NEW = 128


def _drive(cfg, params, rt, prompts, max_new, k, slots):
    import jax.numpy as jnp  # noqa: F401  (jax must be initialized)

    from repro.serve import EngineConfig, ServeEngine

    ecfg = EngineConfig.capacity(
        16, max_new, slots=slots, page_size=8, headroom=1.0,
    ).engine(inner_steps=4, spec_tokens=k)
    eng = ServeEngine(cfg, params, rt, ecfg)
    rids = [eng.submit(p, max_new) for p in prompts]
    t0 = time.perf_counter()
    out = eng.run()
    wall = time.perf_counter() - t0
    outs = [np.asarray(out[r]) for r in rids]
    return eng, sum(len(o) for o in outs) / wall, outs


def _best(cfg, params, rt, prompts, max_new, k, reps=3):
    """Best-of-N tokens/s (compile-warmed): engine ticks are host-driven,
    so a single wall is noisier than time_fn's jitted medians."""
    slots = min(len(prompts), 4)
    _drive(cfg, params, rt, prompts, max_new, k, slots)      # warm compiles
    runs = [
        _drive(cfg, params, rt, prompts, max_new, k, slots)
        for _ in range(reps)
    ]
    return max(runs, key=lambda r: r[1])


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.configs.base import reduced
    from repro.models import Runtime, init_params

    header("Speculative decoding (ngram drafter, paged k=8 verify)")
    rt = Runtime(dtype=jnp.float32, chunk_q=32)
    base = get_reduced("granite-8b")

    # anchored scenario: binary alphabet -> the greedy stream cycles, the
    # prompt-lookup drafter hits, and the accepted-run length is large.
    # Seed 7 is a measured anchor whose argmax margins clear noise.
    cfg = reduced(base, name="granite-8b-bin", vocab_size=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.RandomState(7).randint(0, 2, (12,)).astype(np.int32)

    spec_stats = None
    for B in (1, 4):
        prompts = [prompt] * B
        _, off_tps, off_out = _best(cfg, params, rt, prompts, MAX_NEW, 0)
        eng, on_tps, on_out = _best(cfg, params, rt, prompts, MAX_NEW, K)
        for a, b in zip(off_out, on_out):
            # greedy acceptance == the target's own argmax chain
            assert np.array_equal(a, b), "spec-on diverged from spec-off"
        ratio = on_tps / off_tps
        emit(
            f"serve_spec/decode_b{B}_off",
            1e6 / off_tps,
            f"tokens_per_s={off_tps:.1f} (inner_steps=4 fused decode scan)",
        )
        emit(
            f"serve_spec/decode_b{B}_spec",
            1e6 / on_tps,
            f"tokens_per_s={on_tps:.1f}; speedup_vs_off={ratio:.2f}x "
            f"(>=1.5x gated in-bench); "
            f"accepted_per_verify="
            f"{eng.stats['spec_accepted_per_verify']:.2f}",
        )
        assert ratio >= 1.5, (B, ratio, on_tps, off_tps)
        spec_stats = eng.stats

    s = spec_stats
    emit(
        "serve_spec/acceptance",
        0.0,
        f"k={K} drafter=ngram batch=4: "
        f"verify_calls={s['spec_verify_calls']} "
        f"drafted={s['spec_drafted_tokens']} "
        f"accepted={s['spec_accepted_tokens']} "
        f"accept_rate={s['spec_accept_rate']:.3f} "
        f"accepted_per_verify={s['spec_accepted_per_verify']:.3f}",
    )

    # control: full-vocab random stream — near-zero acceptance, spec ticks
    # commit ~1 token each; must stay in the same cost range as plain
    # decode (the timed gate's 20x tolerance catches a cliff), and stay
    # token-identical (junk drafts are rejected, never committed)
    pfull = np.random.RandomState(0).randint(
        0, base.vocab_size, (12,)
    ).astype(np.int32)
    params_full = init_params(base, jax.random.PRNGKey(0))
    _, off_tps, off_out = _best(base, params_full, rt, [pfull], 48, 0, reps=2)
    eng, on_tps, on_out = _best(base, params_full, rt, [pfull], 48, K, reps=2)
    assert np.array_equal(off_out[0], on_out[0])
    emit(
        "serve_spec/incompressible_control",
        1e6 / on_tps,
        f"tokens_per_s={on_tps:.1f} vs off={off_tps:.1f} "
        f"(ratio={on_tps / off_tps:.2f}x); "
        f"accept_rate={eng.stats['spec_accept_rate']:.3f} — random-init "
        f"full-vocab stream: prompt lookup has ~nothing to hit",
    )


if __name__ == "__main__":
    main()
