"""SLO-grade traffic-trace serving benchmark (the async front-end harness).

Replays a Poisson and a bursty arrival trace — mixed prompt/output lengths,
a shared-prefix population, a QoS mix, and client cancels — against two
engine configs through ``serve.frontend.replay_trace``, and reports the
latency distribution instead of a single-drain mean:

* timed rows (``serve_trace/<trace>_<cfg>``): p50/p99 TTFT (submit ->
  first token, queue wait included — see the TTFT-origin fix in
  ``serve.engine``) and p50/p99 time-per-output-token, in wall-clock ms.
  us_per_call is the p99 TTFT, so the regression gate bounds tail latency.
* accounting rows (``..._slo``, us=0.0): SLO goodput plus cancel /
  preemption / backpressure-deferral / completion counts. Trace arrivals
  and cancels are keyed to engine TICKS (virtual time), so these counts
  are machine-independent and gate EXACTLY in CI — scheduling drift is a
  behavior change even when wall-clock noise hides it.

Engine configs: ``reserve`` (full-horizon reservation, ample pool — no
preemption by construction) and ``tight_optimistic`` (optimistic admission
into a pool small enough that decode growth forces recompute-style
preemptions) — the two ends of the admission-policy trade the scheduler
implements. Interpret-mode CPU timings are NOT TPU perf claims
(EXPERIMENTS.md); the accounting rows carry the hardware-independent
claims.
"""
from __future__ import annotations

import asyncio

import numpy as np

from benchmarks.common import emit, header

SLO_TICKS = 10          # first token due within this many ticks of arrival
N_REQUESTS = 12
PAGE = 8


def _traces(vocab: int):
    """Both traces from one seeded RandomState each — fully deterministic.
    Shared prefix is 8 tokens (one full page) so the prefix population is
    meaningful to a page-granular cache."""

    def kw(rng):
        return dict(
            vocab=vocab,
            prompt_range=(4, 8),
            new_range=(10, 14),
            qos_batch_frac=0.25,
            shared_prefix=rng.randint(0, vocab, (PAGE,)).astype(np.int32),
            shared_frac=0.5,
            cancel_frac=0.3,
            cancel_after=2,
        )

    from repro.serve import bursty_trace, poisson_trace

    rng_p = np.random.RandomState(7)
    poisson = poisson_trace(rng_p, N_REQUESTS, rate=1.0, **kw(rng_p))
    rng_b = np.random.RandomState(11)
    bursty = bursty_trace(rng_b, N_REQUESTS, burst=6, gap=12, **kw(rng_b))
    return {"poisson": poisson, "bursty": bursty}


def _pcts(vals):
    if not vals:
        return 0.0, 0.0
    return (
        float(np.percentile(vals, 50)), float(np.percentile(vals, 99))
    )


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import Runtime, init_params
    from repro.serve import EngineConfig, ServeEngine, goodput, replay_trace

    header("Traffic-trace serving (async front-end; p50/p99 vs SLO)")
    cfg = get_reduced("granite-8b")
    rt = Runtime(dtype=jnp.float32, chunk_q=32)
    params = init_params(cfg, jax.random.PRNGKey(0))

    # prompt (<=16+8 shared) + max_new (<=12) - 1 <= 35 -> max_len 40.
    # "reserve" runs the chunked-prefill + prefix-cache admission path
    # (fixed chunk shapes — no per-prompt-length compiles — and the
    # shared-prefix population actually hits the radix tree); the tight
    # config runs the legacy bucketed whole-prompt prefill under optimistic
    # admission with a pool small enough that decode growth preempts.
    engine_cfgs = {
        "reserve": EngineConfig(
            max_slots=2, page_size=PAGE, num_pages=21, max_len=40,
            inner_steps=4, policy="reserve", max_queue=3,
            prefix_cache=True, prefill_chunk=PAGE,
        ),
        "tight_optimistic": EngineConfig(
            max_slots=2, page_size=PAGE, num_pages=7, max_len=40,
            inner_steps=4, policy="optimistic", max_queue=3,
            prefill_bucket=PAGE,
        ),
    }
    traces = _traces(cfg.vocab_size)

    for cfg_name, ecfg in engine_cfgs.items():
        # warm the compile caches so the measured replay times steady-state
        # serving, not XLA compilation (every bucketed prefill length, the
        # chunked fused/prefill-only programs, and the decode chunk)
        warm = ServeEngine(cfg, params, rt, ecfg)
        for n in (4, 12, 20):
            warm.submit(np.arange(n, dtype=np.int32) + 1, 4)
        warm.run()

        for trace_name, trace in traces.items():
            eng = ServeEngine(cfg, params, rt, ecfg)
            records, fe = asyncio.run(replay_trace(eng, trace))
            ttfts = [r["ttft_s"] for r in records if r["ttft_s"] is not None]
            tpots = [r["tpot_s"] for r in records if r["tpot_s"] is not None]
            t50, t99 = _pcts(ttfts)
            o50, o99 = _pcts(tpots)
            emit(
                f"serve_trace/{trace_name}_{cfg_name}",
                t99 * 1e6,
                f"ttft_p50_ms={t50*1e3:.1f}; ttft_p99_ms={t99*1e3:.1f}; "
                f"tpot_p50_ms={o50*1e3:.2f}; tpot_p99_ms={o99*1e3:.2f}; "
                f"tokens_per_s={eng.stats['tokens_per_s']:.1f}",
            )
            met, total = goodput(records, SLO_TICKS)
            completed = sum(
                1 for r in records if r["status"] == "complete"
            )
            cancelled = sum(
                1 for r in records if r["status"] == "cancelled"
            )
            deferred = sum(r["deferred_ticks"] for r in records)
            emit(
                f"serve_trace/{trace_name}_{cfg_name}_slo",
                0.0,
                f"goodput={met}/{total} (slo={SLO_TICKS}t); "
                f"completed={completed}; cancelled={cancelled}; "
                f"preemptions={eng.stats.get('evictions', 0)}; "
                f"deferred_ticks={deferred}; ticks={fe.ticks}",
            )


if __name__ == "__main__":
    main()
