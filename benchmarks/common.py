"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row)


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time in microseconds (jit-compiled fns; blocks on ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def header(title: str) -> None:
    print(f"\n# === {title} ===")
    print("name,us_per_call,derived")


def subprocess_env():
    """Inherit the environment (JAX_PLATFORMS etc. — a bare env hangs jax
    backend probing on CPU containers); scripts set their own XLA_FLAGS."""
    import os

    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return env
