"""Executable pipeline training bench: single vs GPipe vs 1F1B.

Accounting rows (us = 0.0, exact — gated by check_regression):
  * simulator-vs-executable bubble fraction per schedule: the tick table IS
    the simulator schedule, so these must agree exactly.
  * per-device activation-slot budgets and peak live activation bytes —
    the survey's 1F1B memory argument as a hard number: O(P) slots vs
    GPipe's O(M), strictly smaller at M >= 2P (asserted, not just printed).

Timed rows (subprocess on 4 forced host devices): measured step time for
the single-device step and the executable GPipe / 1F1B plans at equal
microbatch count on the same reduced model.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap

from benchmarks.common import emit, header, subprocess_env
from repro.core.pipeline import simulate, tick_table

P, M = 4, 8          # M = 2P: the memory-gap regime the acceptance bar names
ACT_BYTES = 8 * 64 * 128 * 4   # bench microbatch activation (B, S, d) f32


def _accounting() -> None:
    for sched in ("gpipe", "1f1b"):
        t = tick_table(sched, P, M)
        sim = simulate(sched, P, M, t_fwd=1.0, t_bwd=1.0)
        assert abs(t.bubble_fraction - sim.bubble_fraction) < 1e-12
        emit(
            f"train_pipe/bubble@{sched}_P{P}M{M}", 0.0,
            f"sim={sim.bubble_fraction:.4f} exec={t.bubble_fraction:.4f} "
            "exact_match=True",
        )
        emit(
            f"train_pipe/act_slots@{sched}_P{P}M{M}", 0.0,
            f"act={t.n_act_slots} cot={t.n_cot_slots} "
            f"peak_bytes={t.peak_activation_bytes(ACT_BYTES)}",
        )
    f, g = tick_table("1f1b", P, M), tick_table("gpipe", P, M)
    assert f.peak_activation_bytes(ACT_BYTES) < g.peak_activation_bytes(ACT_BYTES)
    emit(
        f"train_pipe/memory_factor@P{P}M{M}", 0.0,
        f"gpipe_slots={g.n_act_slots} 1f1b_slots={f.n_act_slots} "
        f"factor={g.n_act_slots / f.n_act_slots:.2f}x "
        f"(1f1b strictly below gpipe at M>=2P)",
    )


SCRIPT = textwrap.dedent(
    """
    import os, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import SURVEY_DEMO, ShapeSpec, reduced
    import repro.configs.registry as registry
    from repro.core.partitioner import ParallelPlan
    from repro.data import DataPipeline
    from repro.launch.mesh import make_train_mesh
    from repro.launch.train import build_train_pipeline
    from repro.optim import get as get_opt
    from repro.train import TrainConfig, make_state, make_train_step

    TINY = reduced(SURVEY_DEMO, n_layers=4, d_model=128, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=512)
    registry.ARCHITECTURES[TINY.name] = TINY
    B, SEQ, P, M = 8, 64, 4, 8
    tc = TrainConfig(precision="f32", log_every=1)
    opt = get_opt(tc.optimizer, tc.lr)
    data = DataPipeline(TINY, batch_size=B, seq_len=SEQ, seed=0)
    batch_np = {k: np.asarray(v) for k, v in dict(next(data)).items()}
    data.close()

    def time_step(fn, state, batch, iters=5):
        state, m = fn(state, batch)          # compile + warm
        jax.block_until_ready(m)
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = fn(state, batch)
            jax.block_until_ready(m)
        return (time.perf_counter() - t0) / iters * 1e6, float(m["loss"])

    step1 = make_train_step(TINY, opt, tc)
    us, loss1 = time_step(
        step1, make_state(TINY, opt, tc),
        {k: jnp.asarray(v) for k, v in batch_np.items()})
    print(f"ROW single {us:.1f} loss={loss1:.4f}")

    for sched in ("gpipe", "1f1b"):
        plan = ParallelPlan(dp=1, tp=1, pp=P, microbatches=M,
                            schedule=sched).validate(TINY)
        mesh = make_train_mesh(1, 1, P)
        jitted, (s_struct, b_struct) = build_train_pipeline(
            TINY.name, mesh, plan, tc, ShapeSpec("t", SEQ, B, "train"))
        state = jax.tree.map(
            lambda x, st: jax.device_put(x, st.sharding),
            make_state(TINY, opt, tc), s_struct)
        batch = jax.tree.map(
            lambda v, st: jax.device_put(jnp.asarray(v), st.sharding),
            batch_np, b_struct)
        us, loss = time_step(jitted, state, batch)
        print(f"ROW {sched} {us:.1f} loss={loss:.4f}")
    """
)


def _executable() -> None:
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=900, env=subprocess_env(),
    )
    rows = {}
    for ln in r.stdout.splitlines():
        if ln.startswith("ROW "):
            _, name, us, extra = ln.split(maxsplit=3)
            rows[name] = (float(us), extra)
    for name in ("single", "gpipe", "1f1b"):
        us, extra = rows.get(name, (0.0, f"FAILED rc={r.returncode}"))
        emit(
            f"train_pipe/step@{name}_P{P}M{M}", us,
            f"{extra} B=8 seq=64 4-layer tiny",
        )
    assert r.returncode == 0, r.stderr[-2000:]


def main() -> None:
    header("Train pipeline: executable 1F1B vs GPipe vs single device")
    _accounting()
    _executable()


if __name__ == "__main__":
    main()
