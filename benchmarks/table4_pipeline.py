"""Survey Table 4 — pipeline-parallel schedules.

Simulator: bubble fraction, peak in-flight activations, weight versions and
staleness per schedule (the columns of Table 4). Executable: the shard_map
GPipe runner timed on 4 fake devices (subprocess keeps this process at 1
device).
"""
from __future__ import annotations

import os

import subprocess
import sys
import textwrap

from benchmarks.common import emit, header, subprocess_env
from repro.core.pipeline import SCHEDULES, simulate



def main() -> None:
    header("Table 4: model/pipeline parallelism strategies")
    P = 8
    for M in (8, 32):
        for name in SCHEDULES:
            r = simulate(name, P, M, v=2)
            emit(
                f"table4/{name}@P{P}M{M}", r.makespan * 1e3,
                f"bubble={r.bubble_fraction:.3f} peak_act={r.peak_activations} "
                f"wcopies={r.weight_versions} "
                f"{'sync' if r.synchronous else f'async(stale<={r.max_staleness})'}",
            )
    _executable()


SCRIPT = textwrap.dedent(
    """
    import os, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.pipeline import pipeline_apply
    P, M, D, B = 4, 16, 256, 8
    mesh = jax.make_mesh((P,), ("pipe",))
    rng = np.random.RandomState(0)
    sp = {"w": jnp.asarray(rng.randn(P, D, D) * 0.1, jnp.float32)}
    mbs = jnp.asarray(rng.randn(M, B, D), jnp.float32)
    fn = jax.jit(lambda sp, mbs: pipeline_apply(
        lambda p, x: jnp.tanh(x @ p["w"]), sp, mbs, mesh=mesh))
    out = fn(sp, mbs); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(fn(sp, mbs))
    print(f"USPC {(time.perf_counter()-t0)/5*1e6:.1f}")
    """
)


def _executable() -> None:
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600, env=subprocess_env(),
        cwd="/root/repo",
    )
    us = 0.0
    for ln in r.stdout.splitlines():
        if ln.startswith("USPC"):
            us = float(ln.split()[1])
    emit("table4/executable_gpipe_4stage", us,
         f"shard_map+ppermute runner rc={r.returncode}")


if __name__ == "__main__":
    main()
