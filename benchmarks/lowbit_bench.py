"""Low-precision optimizers (survey §4.2): state bytes + update fidelity."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, time_fn
from repro.optim import adam8bit, adamw, apply_updates
from repro.optim.lowbit import state_bytes


def main() -> None:
    header("Low-precision optimizers (survey s4.2)")
    rng = np.random.RandomState(0)
    params = {
        "w1": jnp.asarray(rng.randn(2048, 2048) * 0.02, jnp.float32),
        "w2": jnp.asarray(rng.randn(8192, 512) * 0.02, jnp.float32),
    }
    grads = jax.tree.map(lambda p: jnp.asarray(rng.randn(*p.shape), jnp.float32) * 0.01, params)

    o32, o8 = adamw(1e-3), adam8bit(1e-3)
    s32, s8 = o32.init(params), o8.init(params)
    b32 = state_bytes({"m": s32["m"], "v": s32["v"]})
    b8 = state_bytes(s8["slots"])
    emit("lowbit/state_bytes_f32", 0.0, f"{b32:.4g}B")
    emit("lowbit/state_bytes_8bit", 0.0, f"{b8:.4g}B ratio={b8/b32:.3f}")

    @jax.jit
    def step32(p, s, g):
        u, s = o32.update(g, s, p)
        return apply_updates(p, u), s

    @jax.jit
    def step8(p, s, g):
        u, s = o8.update(g, s, p)
        return apply_updates(p, u), s

    p32, p8 = params, params
    for i in range(10):
        p32, s32 = step32(p32, s32, grads)
        p8, s8 = step8(p8, s8, grads)
    drift = np.mean(
        [
            np.linalg.norm(np.asarray(a - b)) / np.linalg.norm(np.asarray(b))
            for a, b in zip(jax.tree.leaves(p8), jax.tree.leaves(p32))
        ]
    )
    us32 = time_fn(step32, params, o32.init(params), grads, iters=3)
    us8 = time_fn(step8, params, o8.init(params), grads, iters=3)
    emit("lowbit/adamw_f32_step", us32, "")
    emit("lowbit/adam8bit_step", us8, f"param_drift_vs_f32@10steps={drift:.4f}")


if __name__ == "__main__":
    main()
