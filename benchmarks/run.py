"""Benchmark driver: one module per survey table + framework benches.

``python -m benchmarks.run [--only table1,table4,...] [--json out.json]``
Each module prints ``name,us_per_call,derived`` CSV rows; ``--json`` also
records the collected rows as a structured snapshot (e.g.
``--only kernels --json BENCH_kernels.json``).
"""
from __future__ import annotations

import argparse
import importlib
import json
import traceback

MODULES = [
    "benchmarks.table1_methods",
    "benchmarks.table2_remat",
    "benchmarks.table3_offload",
    "benchmarks.table4_pipeline",
    "benchmarks.zero_stages",
    "benchmarks.compression_bench",
    "benchmarks.lowbit_bench",
    "benchmarks.kernels_bench",
    "benchmarks.serve_bench",
    "benchmarks.serve_prefix_bench",
    "benchmarks.serve_quant_bench",
    "benchmarks.serve_spec_bench",
    "benchmarks.serve_trace_bench",
    "benchmarks.train_pipeline_bench",
    "benchmarks.train_stash_bench",
    "benchmarks.roofline_report",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="", help="write collected rows to this path")
    args = ap.parse_args()
    wanted = [w.strip() for w in args.only.split(",") if w.strip()]
    failures = []
    for mod_name in MODULES:
        short = mod_name.split(".")[-1]
        if wanted and not any(w in short for w in wanted):
            continue
        try:
            importlib.import_module(mod_name).main()
        except Exception as e:  # noqa: BLE001
            failures.append((short, repr(e)))
            traceback.print_exc()
    if args.json:
        from benchmarks.common import ROWS

        rows = []
        for row in ROWS:
            name, us, derived = row.split(",", 2)
            rows.append(
                {"name": name, "us_per_call": float(us), "derived": derived}
            )
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {len(rows)} rows to {args.json}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
