"""Survey Table 3 — offloading strategies under the TPU host-link model.

Simulated makespan + peak device memory for each planner on a 36-segment
granite-8b-like activation profile, at several memory budgets. The "what to
offload" column of Table 3 becomes measurable policy differences.

Dtype-aware: per-segment bytes come from the roofline stash arithmetic
(``stash_bytes_per_slot``) at the activation's storage format instead of a
hard-wired f32 constant — an fp8-stashed segment both fits more budget
without offloading and pays proportionally less link time per offload. The
``roofline_reconcile`` accounting row asserts the planner's counted link
traffic equals segments x the roofline's predicted bytes per segment.
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core.offload import (
    LinkModel,
    dynprog_joint,
    greedy_planner,
    lifetime_planner,
    simulate_schedule,
)
from repro.roofline.analysis import stash_bytes_per_slot

# granite-8b-ish: 36 blocks, ~0.8 GB f32 activations each at the dry-run
# batch, forward ~6 ms per block on v5e; host link 50 GB/s.
N = 36
N_ELEMS = int(0.8e9) // 4            # elements per segment (f32 baseline)
T_FWD = [6e-3] * N
LINK = LinkModel(bandwidth=50e9, latency=5e-6)

# (label, stash format, native itemsize) -> per-segment stored bytes
DTYPES = [("f32", "raw", 4), ("bf16", "raw", 2), ("fp8", "fp8", 2)]


def _seg_bytes(stash: str, itemsize: int) -> float:
    return float(stash_bytes_per_slot(N_ELEMS, stash, itemsize))


def main() -> None:
    header("Table 3: offloading strategies")
    a_f32 = [_seg_bytes("raw", 4)] * N
    base_t, base_peak = simulate_schedule(T_FWD, a_f32, ["keep"] * N, LINK)
    emit("table3/keep_all", base_t * 1e6, f"peak={base_peak/2**30:.1f}GiB")
    for frac in (0.5, 0.25):
        budget = base_peak * frac
        for name, planner in [
            ("lifetime_tflms", lifetime_planner),
            ("greedy_beaumont20", greedy_planner),
            ("dynprog_joint_beaumont21", dynprog_joint),
        ]:
            plan = planner(T_FWD, a_f32, budget, LINK)
            n_off = sum(1 for x in plan.actions if x == "offload")
            n_rec = sum(1 for x in plan.actions if x == "recompute")
            emit(
                f"table3/{name}@{frac}",
                plan.est_time * 1e6,
                f"peak={plan.peak_memory/2**30:.2f}GiB(budget {budget/2**30:.2f}) "
                f"offloaded={n_off} recomputed={n_rec} "
                f"slowdown={plan.est_time/base_t:.3f}x",
            )

    # dtype sweep at a FIXED absolute budget (25% of the f32 peak): narrower
    # storage lowers both the peak and the per-offload link time, so the
    # planner offloads less and the makespan approaches keep-all
    budget = base_peak * 0.25
    for label, stash, itemsize in DTYPES:
        a = [_seg_bytes(stash, itemsize)] * N
        plan = greedy_planner(T_FWD, a, budget, LINK)
        n_off = sum(1 for x in plan.actions if x == "offload")
        emit(
            f"table3/greedy@{label}_budget0.25f32",
            plan.est_time * 1e6,
            f"seg_bytes={a[0]/2**20:.1f}MiB peak={plan.peak_memory/2**30:.2f}GiB "
            f"offloaded={n_off} slowdown={plan.est_time/base_t:.3f}x",
        )
        predicted = n_off * _seg_bytes(stash, itemsize)
        assert plan.offloaded_bytes == predicted, (label, plan.offloaded_bytes)
    emit(
        "table3/roofline_reconcile@fp8", 0.0,
        f"per_seg_predicted={int(_seg_bytes('fp8', 2))} == planner-counted "
        "link bytes / offloaded segments (exact, all dtypes asserted)",
    )


if __name__ == "__main__":
    main()
