"""Survey Table 3 — offloading strategies under the TPU host-link model.

Simulated makespan + peak device memory for each planner on a 36-segment
granite-8b-like activation profile, at several memory budgets. The "what to
offload" column of Table 3 becomes measurable policy differences.
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core.offload import (
    LinkModel,
    dynprog_joint,
    greedy_planner,
    lifetime_planner,
    simulate_schedule,
)

# granite-8b-ish: 36 blocks, ~0.8 GB activations each at the dry-run batch,
# forward ~6 ms per block on v5e; host link 50 GB/s.
N = 36
T_FWD = [6e-3] * N
A_BYTES = [0.8e9] * N
LINK = LinkModel(bandwidth=50e9, latency=5e-6)


def main() -> None:
    header("Table 3: offloading strategies")
    base_t, base_peak = simulate_schedule(T_FWD, A_BYTES, ["keep"] * N, LINK)
    emit("table3/keep_all", base_t * 1e6, f"peak={base_peak/2**30:.1f}GiB")
    for frac in (0.5, 0.25):
        budget = base_peak * frac
        for name, planner in [
            ("lifetime_tflms", lifetime_planner),
            ("greedy_beaumont20", greedy_planner),
            ("dynprog_joint_beaumont21", dynprog_joint),
        ]:
            plan = planner(T_FWD, A_BYTES, budget, LINK)
            n_off = sum(1 for x in plan.actions if x == "offload")
            n_rec = sum(1 for x in plan.actions if x == "recompute")
            emit(
                f"table3/{name}@{frac}",
                plan.est_time * 1e6,
                f"peak={plan.peak_memory/2**30:.2f}GiB(budget {budget/2**30:.2f}) "
                f"offloaded={n_off} recomputed={n_rec} "
                f"slowdown={plan.est_time/base_t:.3f}x",
            )


if __name__ == "__main__":
    main()
