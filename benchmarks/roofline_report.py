"""Roofline report: aggregates experiments/dryrun/*.json into the §Roofline
table (one row per arch x shape x mesh) — run after the dry-run matrix."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, header


def main() -> None:
    header("Roofline (from dry-run artifacts; see EXPERIMENTS.md)")
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    if not files:
        emit("roofline/no_dryrun_artifacts", 0.0,
             "run: python -m repro.launch.dryrun --all")
        return
    from repro.roofline.analysis import derive_terms

    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        tag = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("tag"):
            tag += f"/{r['tag']}"
        d = derive_terms(r)
        emit(
            f"roofline/{tag}", d["bound_step_time"] * 1e6,
            f"t_c={d['t_compute']*1e3:.2f}ms "
            f"t_m=[{d['t_memory_lb']*1e3:.2f},{d['t_memory_ub']*1e3:.2f}]ms "
            f"t_x={d['t_collective']*1e3:.2f}ms dom={d['dominant_lb']} "
            f"roofline_frac={d['roofline_fraction']:.2f} "
            f"useful={r['useful_ratio']:.2f} "
            f"temp={r['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.1f}GiB",
        )


if __name__ == "__main__":
    main()
