"""Survey Table 2 — rematerialization strategies.

Two halves:
 (a) planner comparison on an 88-segment heterogeneous chain (granite-34b
     layer profile): periodic vs binomial vs dyn-prog vs DTR scores —
     recompute overhead at equal memory budget (the Table-2 "guarantees"
     column, quantified).
 (b) executed jax.checkpoint policies on the demo model: measured peak temp
     memory + step time from the compiled artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, time_fn
from repro.configs import SURVEY_DEMO, reduced
from repro.core.remat_solver import binomial, dtr_scores, dynprog_het, periodic, simulate
from repro.data import DataPipeline
from repro.optim import get as get_opt
from repro.train import TrainConfig, make_state, make_train_step


def planners() -> None:
    n = 88
    # heterogeneous profile: attention-heavy early, MoE-ish spikes
    t = [1.0 + 0.5 * ((i % 7) == 3) for i in range(n)]
    a = [1.0 + 1.0 * ((i % 5) == 0) for i in range(n)]
    full_mem = simulate(n, range(n), t, a)[1]
    budget = full_mem / 4
    for name, plan in [
        ("periodic_chen16", periodic(n, int(budget))),
        ("binomial_revolve", binomial(n, int(budget))),
        ("dynprog_het_beaumont19", dynprog_het(t, a, budget)),
        ("dtr_scores_kirisame20", dtr_scores(t, a, int(budget))),
    ]:
        emit(
            f"table2/plan/{name}", 0.0,
            f"peak={plan.peak_memory:.1f}/{budget:.1f} "
            f"extra_fwd={plan.extra_forwards} "
            f"overhead={plan.recompute_overhead:.2f}x n_ckpt={len(plan.checkpoints)}",
        )


CFG = reduced(SURVEY_DEMO, n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
              d_ff=1024, vocab_size=2048)


def executed() -> None:
    data = DataPipeline(CFG, 8, 256, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    data.close()
    for name in ["none", "full", "dots"]:
        tc = TrainConfig(remat=name)
        opt = get_opt("adamw", 1e-3)
        state = make_state(CFG, opt, tc)
        step = make_train_step(CFG, opt, tc)
        compiled = step.lower(state, batch).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        us = time_fn(step, state, batch)
        emit(
            f"table2/exec/remat_{name}", us,
            f"temp={float(mem.temp_size_in_bytes)/2**20:.1f}MiB "
            f"flops={float(cost.get('flops', 0)):.3g}",
        )


def main() -> None:
    header("Table 2: rematerialization strategies")
    planners()
    executed()


if __name__ == "__main__":
    main()
