"""Gradient compression (survey §4.3): wire bytes + quality per method."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, time_fn
from repro.core.compression import (
    PowerSGD, QSGD, SignEF, TopK, init_state, sync, wire_bytes_dense,
)


def main() -> None:
    header("Gradient compression (survey s4.3)")
    rng = np.random.RandomState(0)
    grads = {
        "w1": jnp.asarray(rng.randn(1024, 1024), jnp.float32),
        "w2": jnp.asarray(rng.randn(4096, 256), jnp.float32),
        "b": jnp.asarray(rng.randn(64), jnp.float32),
    }
    dense = wire_bytes_dense(grads)
    emit("compress/dense_allreduce", 0.0, f"wire={dense:.3g}B ratio=1.0")
    for m in [TopK(0.01), TopK(0.1), QSGD(8), QSGD(4), SignEF(), PowerSGD(4),
              PowerSGD(16)]:
        st = init_state(m, grads)
        ghat, _, nbytes = sync(m, grads, st, axis_name=None)
        errs = []
        for k in ("w1", "w2"):
            a, b = np.asarray(ghat[k]), np.asarray(grads[k])
            errs.append(np.linalg.norm(a - b) / np.linalg.norm(b))
        us = time_fn(lambda g: sync(m, g, st, axis_name=None)[0], grads, iters=3)
        label = f"{m.name}" + (
            f"@{getattr(m, 'ratio', getattr(m, 'bits', getattr(m, 'rank', '')))}"
        )
        emit(
            f"compress/{label}", us,
            f"wire={float(nbytes):.3g}B ratio={float(nbytes)/dense:.4f} "
            f"relerr={np.mean(errs):.3f}",
        )


if __name__ == "__main__":
    main()
