"""Pallas kernels vs pure-jnp references (interpret-mode correctness timing
is NOT a TPU perf claim — see EXPERIMENTS.md; derived fields carry the
roofline-relevant arithmetic intensities and peak-activation estimates
instead). Backward entries time jax.grad through the reference paths; the
fused Pallas backwards are validated against those same paths in
tests/test_kernels_backward.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, time_fn
from repro.kernels.blockwise_quant import quantize
from repro.kernels.chunked_ce import chunked_ce
from repro.kernels.chunked_ce.ref import chunked_ce_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _mb(nbytes: float) -> str:
    return f"{nbytes / 2**20:.1f}MB"


def main() -> None:
    header("Kernels (refs timed on CPU; kernels validated in interpret mode)")
    rng = np.random.RandomState(0)

    # ---------------------------------------------------- attention fwd/bwd
    B, S, Kv, G, hd = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.randn(B, S, Kv, G, hd), jnp.float32) * hd**-0.5
    k = jnp.asarray(rng.randn(B, S, Kv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Kv, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = time_fn(fa, q, k, v, iters=3)
    flops = 4 * B * S * S * Kv * G * hd / 2  # causal half
    emit("kernel/attention_ref_1k", us, f"arith_intensity~{flops/(q.size*4*3):.0f}")

    fa_bwd = jax.jit(
        jax.grad(
            lambda q, k, v: jnp.sum(flash_attention_ref(q, k, v, causal=True)),
            argnums=(0, 1, 2),
        )
    )
    us = time_fn(fa_bwd, q, k, v, iters=3)
    # backward ~2.5x fwd FLOPs (dq, dk, dv + score recompute); fused kernel
    # reads q/k/v/o/do + (m,l) stats once per tile pair, never (S, S)
    bwd_flops = 2.5 * flops
    bwd_bytes = (q.size * 3 + k.size * 2 + v.size * 2) * 4
    emit(
        "kernel/attention_bwd_ref_1k", us,
        f"arith_intensity~{bwd_flops/bwd_bytes:.0f}; "
        f"saved_stats={_mb(2 * B * Kv * G * S * 4)} vs "
        f"scores={_mb(B * Kv * G * S * S * 4)}",
    )

    # ------------------------------------------------------ rmsnorm fwd/bwd
    x = jnp.asarray(rng.randn(4096, 2048), jnp.float32)
    s = jnp.ones(2048)
    rn = jax.jit(lambda x, s: rmsnorm_ref(x, s))
    emit("kernel/rmsnorm_ref_4kx2k", time_fn(rn, x, s, iters=3),
         "memory-bound: AI~0.5 flop/byte")

    rn_bwd = jax.jit(
        jax.grad(lambda x, s: jnp.sum(rmsnorm_ref(x, s)), argnums=(0, 1))
    )
    us = time_fn(rn_bwd, x, s, iters=3)
    # fused bwd: one pass reads x+g, writes dx and a VMEM-accumulated dscale
    emit(
        "kernel/rmsnorm_bwd_4kx2k", us,
        f"memory-bound: AI~0.7 flop/byte; fused reads={_mb(x.size * 2 * 4)} "
        f"vs unfused={_mb(x.size * 4 * 4)}",
    )

    # ------------------------------------------------------- blockwise quant
    g = jnp.asarray(rng.randn(256 * 256), jnp.float32)
    qz = jax.jit(lambda g: quantize(g, backend="ref")[0])
    emit("kernel/blockwise_quant_ref_64k", time_fn(qz, g, iters=3),
         "VPU-bound: 256-way codebook compare")

    # --------------------------------------------------- chunked-CE head
    Bc, Sc, d, V, C = 2, 512, 128, 32768, 2048
    xh = jnp.asarray(rng.randn(Bc, Sc, d), jnp.float32)
    wh = jnp.asarray(rng.randn(V, d), jnp.float32) * 0.05
    labels = jnp.asarray(rng.randint(0, V, (Bc, Sc)), jnp.int32)

    def _loss(ce):
        def f(x_, w_):
            ll, logz = ce(x_, w_)
            return jnp.mean(logz - ll)

        return f

    dense = jax.jit(
        jax.grad(_loss(lambda x_, w_: chunked_ce_ref(x_, w_, labels)),
                 argnums=(0, 1))
    )
    chunked = jax.jit(
        jax.grad(_loss(lambda x_, w_: chunked_ce(x_, w_, labels, C)),
                 argnums=(0, 1))
    )
    peak_dense = Bc * Sc * V * 4 * 2      # logits + dlogits, f32
    peak_chunk = Bc * Sc * C * 4          # one (B, S, chunk) tile live
    emit("kernel/ce_dense_grad_32kvocab", time_fn(dense, xh, wh, iters=3),
         f"peak_logits_act={_mb(peak_dense)}")
    emit(
        "kernel/ce_chunked_grad_32kvocab", time_fn(chunked, xh, wh, iters=3),
        f"peak_logits_act={_mb(peak_chunk)} ({peak_dense // peak_chunk}x "
        f"smaller); AI~{2 * d:.0f} flop/byte on the head matmul",
    )


if __name__ == "__main__":
    main()
