"""Pallas kernels vs pure-jnp references (interpret-mode correctness timing
is NOT a TPU perf claim — see EXPERIMENTS.md; derived fields carry the
roofline-relevant arithmetic intensities instead)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, time_fn
from repro.kernels.blockwise_quant import quantize
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def main() -> None:
    header("Kernels (refs timed on CPU; kernels validated in interpret mode)")
    rng = np.random.RandomState(0)

    B, S, Kv, G, hd = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.randn(B, S, Kv, G, hd), jnp.float32) * hd**-0.5
    k = jnp.asarray(rng.randn(B, S, Kv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Kv, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = time_fn(fa, q, k, v, iters=3)
    flops = 4 * B * S * S * Kv * G * hd / 2  # causal half
    emit("kernel/attention_ref_1k", us, f"arith_intensity~{flops/(q.size*4*3):.0f}")

    x = jnp.asarray(rng.randn(4096, 2048), jnp.float32)
    s = jnp.ones(2048)
    rn = jax.jit(lambda x, s: rmsnorm_ref(x, s))
    emit("kernel/rmsnorm_ref_4kx2k", time_fn(rn, x, s, iters=3),
         "memory-bound: AI~0.5 flop/byte")

    g = jnp.asarray(rng.randn(256 * 256), jnp.float32)
    qz = jax.jit(lambda g: quantize(g, backend="ref")[0])
    emit("kernel/blockwise_quant_ref_64k", time_fn(qz, g, iters=3),
         "VPU-bound: 256-way codebook compare")


if __name__ == "__main__":
    main()
