"""ZeRO stages (survey §4.1): per-device memory + collective bytes by stage.

Analytic table for the assigned archs on the production mesh (the survey's
"Partitioning: optim state / + gradients / + parameters" rows), plus a
compiled small-mesh (2x2, subprocess) measurement showing the collective
pattern change: stage 0 all-reduces grads; stage 3 adds per-layer
all-gathers of params (ZeRO's documented comm overhead).
"""
from __future__ import annotations

import os

import subprocess
import sys
import textwrap

from benchmarks.common import emit, header, subprocess_env
from repro.configs import get_config



def analytic() -> None:
    dp, tp = 16, 16
    for arch in ("granite-8b", "granite-34b", "arctic-480b"):
        cfg = get_config(arch)
        n = cfg.param_count()["total"]
        for stage in range(4):
            p = n * 4 / tp / (dp if stage >= 3 else 1)
            g = n * 4 / tp / (dp if stage >= 2 else 1)
            o = n * 8 / tp / (dp if stage >= 1 else 1)
            emit(
                f"zero/analytic/{arch}/stage{stage}", 0.0,
                f"params={p/2**30:.2f}GiB grads={g/2**30:.2f}GiB "
                f"opt={o/2**30:.2f}GiB total={(p+g+o)/2**30:.2f}GiB",
            )


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    from repro.configs import get_reduced, ShapeSpec
    from repro.launch.train import build_train
    from repro.train import TrainConfig
    from repro.roofline.analysis import collective_bytes

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    shape = ShapeSpec("bench", 128, 8, "train")
    cfg_name = "granite-8b"
    import repro.launch.train as LT
    import repro.configs as C
    cfg = C.get_reduced(cfg_name)
    C.registry.ARCHITECTURES[cfg.name] = cfg
    for stage in (0, 1, 2, 3):
        tc = TrainConfig(precision="bf16", remat="none", zero_stage=stage)
        jitted, (s, b) = build_train(cfg.name, mesh, tc, shape)
        compiled = jitted.lower(s, b).compile()
        stats = collective_bytes(compiled.as_text(), 4, trip_hint=cfg.n_layers)
        per = {k: int(v) for k, v in stats.bytes_by_kind.items() if v}
        print(f"STAGE {stage} wire={int(stats.total_bytes)} {per}")
    """
)


def compiled_small_mesh() -> None:
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=900, env=subprocess_env(),
        cwd="/root/repo",
    )
    for ln in r.stdout.splitlines():
        if ln.startswith("STAGE"):
            parts = ln.split(maxsplit=3)
            emit(f"zero/compiled_2x2/stage{parts[1]}", 0.0,
                 f"wire={parts[2].split('=')[1]}B {parts[3]}")
    if r.returncode != 0:
        emit("zero/compiled_2x2/FAILED", 0.0, r.stderr.strip()[-200:])


def main() -> None:
    header("ZeRO stages (survey s4.1)")
    analytic()
    compiled_small_mesh()


if __name__ == "__main__":
    main()
