"""Activation-stash bench: capacity accounting + codec + pipeline timings.

Accounting rows (us = 0.0, exact — gated by check_regression):
  * fp8-vs-bf16 bytes per activation slot: blockwise codes + per-block f32
    scales must be >= 1.8x smaller than the native bf16 slot (asserted).
  * 1F1B slot high-water at P=4, M=8: min(P, M) slots per device — the
    quantity the stash multiplies.
  * predicted-vs-measured: the roofline closed form for stash state bytes
    must equal the byte size of the buffers ``StashBackend.init``
    actually allocates (eval_shape), per backend.
  * host byte split: HostStash device-window vs host-spill bytes match the
    roofline closed forms (device window raw-width, spill beyond it).
  * host overlap: on a deterministic toy pipeline, the prefetching runner
    (lookahead=2) converts the eager runner's stalled gets into prefetch
    hits — counters are exact functions of (schedule, window, lookahead).
  * plan unlock / remat trade: a ParallelPlan whose total activation state
    (slots + within-stage transient) fails ``.validate()`` at stash=raw
    fits at stash=fp8, and ``auto_plan`` walks the (stash, remat) ladder —
    compression first, per-stage full remat only when compression alone
    does not fit.

Timed rows:
  * codec roundtrip (in-process): the jnp reference vs the Pallas kernels
    in interpret mode (the CPU validation path; on TPU ``fused_stash``
    routes to the compiled kernels, on CPU it resolves to the jnp codec —
    see kernels.blockwise_quant.ops.fused_codec_backend).
  * 1F1B step time (subprocess, 4 forced host devices) at stash raw /
    int8 / fp8 and with ``fused_stash=True`` (must stay ~1x raw), plus the
    host-driven runner eager (lookahead=0) vs prefetching (lookahead=2)
    with measured stall fractions.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, subprocess_env, time_fn
from repro.core.pipeline import tick_table
from repro.core.stash import get_backend
from repro.roofline.analysis import (
    predicted_stash_capacity_factor,
    predicted_stash_host_bytes,
    stash_bytes_per_slot,
)

P, M = 4, 8
B, SEQ, D = 8, 64, 128          # bench microbatch: (B/M, SEQ, D) slots
N_ELEMS = (B // M) * SEQ * D


def _struct_bytes(struct) -> int:
    total = 0
    for leaf in jax.tree.leaves(struct):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def _accounting() -> None:
    raw_b = stash_bytes_per_slot(N_ELEMS, "raw", native_itemsize=2)
    fp8_b = stash_bytes_per_slot(N_ELEMS, "fp8", native_itemsize=2)
    factor = predicted_stash_capacity_factor(N_ELEMS, "fp8", native_itemsize=2)
    assert factor >= 1.8, (raw_b, fp8_b, factor)
    emit(
        "train_stash/bytes_per_slot@fp8_vs_bf16", 0.0,
        f"bf16={raw_b} fp8={fp8_b} factor={factor:.3f}x (>=1.8x)",
    )

    t = tick_table("1f1b", P, M)
    assert t.n_act_slots == min(P, M), t.n_act_slots
    emit(
        f"train_stash/slot_high_water@1f1b_P{P}M{M}", 0.0,
        f"act_slots={t.n_act_slots} == min(P,M) cot_slots={t.n_cot_slots}",
    )

    # predicted (roofline closed form) vs measured (buffers init allocates);
    # the runner's buffer carries one extra trash slot for -1 table entries
    x_struct = jax.ShapeDtypeStruct((B // M, SEQ, D), jnp.bfloat16)
    n_slots = t.n_act_slots + 1
    for name in ("raw", "int8", "fp8"):
        backend = get_backend(name)
        predicted = n_slots * stash_bytes_per_slot(
            N_ELEMS, name, native_itemsize=2
        )
        measured = _struct_bytes(
            jax.eval_shape(lambda: backend.init(n_slots, x_struct))
        )
        assert predicted == measured, (name, predicted, measured)
        emit(
            f"train_stash/predicted_vs_measured@{name}", 0.0,
            f"predicted={predicted} measured={measured} exact_match=True "
            f"({n_slots} slots incl. trash)",
        )

    # host stash byte split: gpipe holds M slots, the window keeps 2 on
    # device, everything beyond it spills to host RAM at raw width
    tg = tick_table("gpipe", P, M)
    host = get_backend("host", host_window=2)
    dev = host.device_bytes(tg.n_act_slots, x_struct)
    spill = host.host_bytes(tg.n_act_slots, x_struct)
    predicted_spill = predicted_stash_host_bytes(
        N_ELEMS, tg.n_act_slots, "host", native_itemsize=2, host_window=2
    )
    assert spill == predicted_spill, (spill, predicted_spill)
    assert dev == 2 * stash_bytes_per_slot(N_ELEMS, "raw", 2)
    emit(
        f"train_stash/host_bytes_split@gpipe_P{P}M{M}", 0.0,
        f"slots={tg.n_act_slots} device={dev} (window=2) host={spill} "
        f"roofline_match=True",
    )


def _toy_pipeline(P_, M_, L, d, seed=0):
    rng = np.random.RandomState(seed)
    stage_params = {"w": jnp.asarray(rng.randn(L, d, d).astype(np.float32) * 0.3)}
    shared = {"emb": jnp.asarray(rng.randn(d, d).astype(np.float32) * 0.3)}
    mbs = jnp.asarray(rng.randn(M_, 2, d).astype(np.float32))

    def first_fn(sh, mb):
        return mb @ sh["emb"]

    def stage_fn(sp, x):
        def body(h, w):
            return jnp.tanh(h @ w), jnp.zeros((), jnp.float32)
        y, aux = jax.lax.scan(body, x, sp["w"])
        return y, jnp.sum(aux)

    def last_fn(sh, y, mb):
        loss = jnp.sum((y - mb) ** 2)
        return loss, {"xent": loss}

    return stage_params, shared, mbs, first_fn, stage_fn, last_fn


def _host_overlap() -> None:
    """Deterministic overlap counters: eager vs prefetching host runner on
    a toy pipeline with window=1 (every backward read is off-window)."""
    from repro.core.pipeline import pipeline_grads_host

    P_, M_, L, d = 2, 4, 4, 8
    stage_params, shared, mbs, first_fn, stage_fn, last_fn = _toy_pipeline(
        P_, M_, L, d
    )
    table = tick_table("1f1b", P_, M_)
    kw = dict(
        table=table,
        x_struct=jax.ShapeDtypeStruct((2, d), jnp.float32),
        metrics_struct={"xent": jax.ShapeDtypeStruct((), jnp.float32)},
    )
    outs, stats = {}, {}
    for la in (0, 2):
        backend = get_backend("host", host_window=1)
        outs[la] = pipeline_grads_host(
            first_fn, stage_fn, last_fn, stage_params, shared, mbs,
            stash=backend, lookahead=la, **kw,
        )
        stats[la] = backend.stats()
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[2])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    e, o = stats[0], stats[2]
    assert o["gets"] == e["gets"] and o["host_hits"] == e["host_hits"]
    assert e["prefetch_hits"] == 0 and e["stalled_gets"] == e["host_hits"]
    assert o["stalled_gets"] < e["stalled_gets"], (e, o)
    hit_rate = o["prefetch_hits"] / max(o["host_hits"], 1)
    emit(
        f"train_stash/host_overlap@1f1b_P{P_}M{M_}", 0.0,
        f"window=1 off_window_gets={e['host_hits']} "
        f"stalls eager={e['stalled_gets']} prefetch={o['stalled_gets']} "
        f"hit_rate={hit_rate:.2f} bitwise_equal=True",
    )


def _plan_unlock() -> None:
    from repro.configs import SURVEY_DEMO, reduced
    from repro.core.partitioner import ParallelPlan, auto_plan

    tiny = reduced(SURVEY_DEMO, n_layers=4, d_model=D, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=512)
    base = ParallelPlan(dp=1, tp=1, pp=P, microbatches=M, schedule="1f1b")
    kw = dict(global_batch=B, seq_len=SEQ, itemsize=4)
    raw_rep = base.stash_report(tiny, **kw)
    import dataclasses

    fp8 = dataclasses.replace(base, stash="fp8")
    fp8_rep = fp8.stash_report(tiny, **kw)
    budget = (fp8_rep["total_bytes"] + raw_rep["total_bytes"]) // 2
    try:
        base.validate(tiny, act_budget=budget, **kw)
        raise AssertionError("raw plan should exceed the budget")
    except ValueError:
        pass
    fp8.validate(tiny, act_budget=budget, **kw)
    emit(
        f"train_stash/plan_unlock@fp8_P{P}M{M}", 0.0,
        f"budget={budget} raw_total={raw_rep['total_bytes']} (fails) "
        f"fp8_total={fp8_rep['total_bytes']} (fits) "
        f"capacity={fp8_rep['capacity_factor']:.3f}x",
    )

    # remat-vs-compression ladder at pp=2 (2 layers/stage, so full remat
    # actually shrinks the within-stage transient): a mid budget escalates
    # to fp8+cot compression WITHOUT paying remat; only a tighter one adds
    # per-stage full remat on top
    base2 = ParallelPlan(dp=1, tp=1, pp=2, microbatches=4, schedule="1f1b")
    fp8c = dataclasses.replace(base2, stash="fp8", stash_cot=True)
    fp8c_full = dataclasses.replace(fp8c, remat="full")
    t_raw = base2.stash_report(tiny, **kw)["total_bytes"]
    t_fp8c = fp8c.stash_report(tiny, **kw)["total_bytes"]
    t_full = fp8c_full.stash_report(tiny, **kw)["total_bytes"]
    assert t_full < t_fp8c < t_raw, (t_full, t_fp8c, t_raw)
    ap_kw = dict(microbatches=4, tp=1, max_dp=1, stash="raw",
                 global_batch=B, seq_len=SEQ, itemsize=4)
    mid = auto_plan(tiny, 2, act_budget=(t_raw + t_fp8c) // 2, **ap_kw)
    assert (mid.stash, mid.stash_cot, mid.remat) == ("fp8", True, "none")
    tight = auto_plan(tiny, 2, act_budget=(t_fp8c + t_full) // 2, **ap_kw)
    assert (tight.stash, tight.stash_cot, tight.remat) == ("fp8", True, "full")
    emit(
        "train_stash/remat_trade@1f1b_P2M4", 0.0,
        f"totals raw={t_raw} fp8+cot={t_fp8c} fp8+cot+remat={t_full}; "
        f"mid budget -> stash=fp8 remat=none, tight -> stash=fp8 remat=full",
    )


def _codec_timing() -> None:
    """Codec roundtrip: jnp reference vs the Pallas kernels (interpret mode
    on CPU — the validation path; compiled on TPU). Both jitted."""
    from repro.kernels.blockwise_quant.ops import (
        stash_dequantize, stash_quantize,
    )

    x = jnp.asarray(
        np.random.RandomState(0).randn(64, SEQ, D).astype(np.float32) / 3,
        jnp.bfloat16,
    )

    def roundtrip(v, storage, backend):
        c, s = stash_quantize(v, storage, backend=backend)
        return stash_dequantize(c, s, v.shape, v.dtype, backend=backend)

    for storage in ("int8", "fp8"):
        for backend, label in (("ref", "jnp"), ("pallas", "pallas_interp")):
            fn = jax.jit(partial(roundtrip, storage=storage, backend=backend))
            us = time_fn(fn, x, iters=5)
            emit(
                f"train_stash/codec@{storage}_{label}", us,
                f"quant+dequant roundtrip {tuple(x.shape)} bf16 block=256",
            )


SCRIPT = textwrap.dedent(
    """
    import os, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import SURVEY_DEMO, ShapeSpec, reduced
    import repro.configs.registry as registry
    from repro.core.partitioner import ParallelPlan
    from repro.data import DataPipeline
    from repro.launch.mesh import make_train_mesh
    from repro.launch.train import (
        build_train_pipeline, build_train_pipeline_host)
    from repro.optim import get as get_opt
    from repro.train import TrainConfig, make_state

    TINY = reduced(SURVEY_DEMO, n_layers=4, d_model=128, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=512)
    registry.ARCHITECTURES[TINY.name] = TINY
    B, SEQ, P, M = 8, 64, 4, 8
    shape = ShapeSpec("t", SEQ, B, "train")
    opt_tc = TrainConfig(precision="f32", log_every=1)
    opt = get_opt(opt_tc.optimizer, opt_tc.lr)
    data = DataPipeline(TINY, batch_size=B, seq_len=SEQ, seed=0)
    batch_np = {k: np.asarray(v) for k, v in dict(next(data)).items()}
    data.close()

    def time_step(fn, state, batch, iters=5):
        state, m = fn(state, batch)          # compile + warm
        jax.block_until_ready(m)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            state, m = fn(state, batch)
            jax.block_until_ready(m)
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2] * 1e6, float(m["loss"])

    times = {}
    for name, stash, fused in (
        ("raw", "raw", False), ("int8", "int8", False), ("fp8", "fp8", False),
        ("int8_fused", "int8", True), ("fp8_fused", "fp8", True),
    ):
        plan = ParallelPlan(dp=1, tp=1, pp=P, microbatches=M,
                            schedule="1f1b", stash=stash).validate(TINY)
        tc = TrainConfig(precision="f32", log_every=1, stash=stash,
                         fused_stash=fused)
        mesh = make_train_mesh(1, 1, P)
        jitted, (s_struct, b_struct) = build_train_pipeline(
            TINY.name, mesh, plan, tc, shape)
        state = jax.tree.map(
            lambda x, st: jax.device_put(x, st.sharding),
            make_state(TINY, opt, tc), s_struct)
        batch = jax.tree.map(
            lambda v, st: jax.device_put(jnp.asarray(v), st.sharding),
            batch_np, b_struct)
        us, loss = time_step(jitted, state, batch, iters=8)
        times[name] = us
        ratio = us / times["raw"]
        print(f"ROW {name} {us:.1f} loss={loss:.4f} ratio_vs_raw={ratio:.2f}x")
    for name in ("int8_fused", "fp8_fused"):
        assert times[name] <= times["raw"] * 1.25, (name, times)

    for name, lookahead in (("host_eager", 0), ("host", 2)):
        plan = ParallelPlan(dp=1, tp=1, pp=P, microbatches=M,
                            schedule="1f1b", stash="host").validate(TINY)
        tc = TrainConfig(precision="f32", log_every=1, stash="host")
        step, _, backend = build_train_pipeline_host(
            TINY.name, plan, tc, shape, lookahead=lookahead)
        state = make_state(TINY, opt, tc)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        us, loss = time_step(step, state, batch, iters=1)
        st = backend.stats()
        frac = st["stalled_gets"] / max(st["host_hits"], 1)
        hits = st["prefetch_hits"] / max(st["host_hits"], 1)
        print(f"ROW {name} {us:.1f} loss={loss:.4f} "
              f"evictions={st['evictions']} host_hits={st['host_hits']} "
              f"stall_frac={frac:.2f} prefetch_hit_rate={hits:.2f}")
        if lookahead == 0:
            assert frac == 1.0, st       # eager: every off-window get stalls
        else:
            assert frac < 1.0, st        # overlap measurably removes stalls
    """
)

ROW_NAMES = ("raw", "int8", "fp8", "int8_fused", "fp8_fused",
             "host_eager", "host")


def _executable() -> None:
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=1800, env=subprocess_env(),
    )
    rows = {}
    for ln in r.stdout.splitlines():
        if ln.startswith("ROW "):
            _, name, us, extra = ln.split(maxsplit=3)
            rows[name] = (float(us), extra)
    for name in ROW_NAMES:
        us, extra = rows.get(name, (0.0, f"FAILED rc={r.returncode}"))
        emit(
            f"train_stash/step@{name}_P{P}M{M}", us,
            f"{extra} B={B} seq={SEQ} 4-layer tiny 1f1b",
        )
    assert r.returncode == 0, r.stderr[-2000:]


def main() -> None:
    header("Activation stash: accounting + codec + 1F1B step timings")
    _accounting()
    _host_overlap()
    _plan_unlock()
    _codec_timing()
    _executable()


if __name__ == "__main__":
    main()
