"""Activation-stash bench: capacity accounting + pipeline step timings.

Accounting rows (us = 0.0, exact — gated by check_regression):
  * fp8-vs-bf16 bytes per activation slot: blockwise codes + per-block f32
    scales must be >= 1.8x smaller than the native bf16 slot (asserted).
  * 1F1B slot high-water at P=4, M=8: min(P, M) slots per device — the
    quantity the stash multiplies.
  * predicted-vs-measured: the roofline closed form for stash state bytes
    must equal the byte size of the buffers ``StashBackend.init``
    actually allocates (eval_shape), per backend.
  * plan unlock: a ParallelPlan whose activation budget fails
    ``.validate()`` at stash=raw validates (and, per the timed rows,
    trains) at stash=fp8 — the capacity factor as a feasibility flip.

Timed rows (subprocess on 4 forced host devices): 1F1B step time at
stash raw / int8 / fp8 on the same reduced model, plus the host-driven
eager runner (stash=host) with its eviction stats.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, subprocess_env
from repro.core.pipeline import tick_table
from repro.core.stash import get_backend
from repro.roofline.analysis import (
    predicted_stash_capacity_factor,
    stash_bytes_per_slot,
)

P, M = 4, 8
B, SEQ, D = 8, 64, 128          # bench microbatch: (B/M, SEQ, D) slots
N_ELEMS = (B // M) * SEQ * D


def _struct_bytes(struct) -> int:
    total = 0
    for leaf in jax.tree.leaves(struct):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def _accounting() -> None:
    raw_b = stash_bytes_per_slot(N_ELEMS, "raw", native_itemsize=2)
    fp8_b = stash_bytes_per_slot(N_ELEMS, "fp8", native_itemsize=2)
    factor = predicted_stash_capacity_factor(N_ELEMS, "fp8", native_itemsize=2)
    assert factor >= 1.8, (raw_b, fp8_b, factor)
    emit(
        "train_stash/bytes_per_slot@fp8_vs_bf16", 0.0,
        f"bf16={raw_b} fp8={fp8_b} factor={factor:.3f}x (>=1.8x)",
    )

    t = tick_table("1f1b", P, M)
    assert t.n_act_slots == min(P, M), t.n_act_slots
    emit(
        f"train_stash/slot_high_water@1f1b_P{P}M{M}", 0.0,
        f"act_slots={t.n_act_slots} == min(P,M) cot_slots={t.n_cot_slots}",
    )

    # predicted (roofline closed form) vs measured (buffers init allocates);
    # the runner's buffer carries one extra trash slot for -1 table entries
    x_struct = jax.ShapeDtypeStruct((B // M, SEQ, D), jnp.bfloat16)
    n_slots = t.n_act_slots + 1
    for name in ("raw", "int8", "fp8"):
        backend = get_backend(name)
        predicted = n_slots * stash_bytes_per_slot(
            N_ELEMS, name, native_itemsize=2
        )
        measured = _struct_bytes(
            jax.eval_shape(lambda: backend.init(n_slots, x_struct))
        )
        assert predicted == measured, (name, predicted, measured)
        emit(
            f"train_stash/predicted_vs_measured@{name}", 0.0,
            f"predicted={predicted} measured={measured} exact_match=True "
            f"({n_slots} slots incl. trash)",
        )


def _plan_unlock() -> None:
    from repro.configs import SURVEY_DEMO, reduced
    from repro.core.partitioner import ParallelPlan

    tiny = reduced(SURVEY_DEMO, n_layers=4, d_model=D, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=512)
    base = ParallelPlan(dp=1, tp=1, pp=P, microbatches=M, schedule="1f1b")
    kw = dict(global_batch=B, seq_len=SEQ, itemsize=4)
    raw_rep = base.stash_report(tiny, **kw)
    import dataclasses

    fp8 = dataclasses.replace(base, stash="fp8")
    fp8_rep = fp8.stash_report(tiny, **kw)
    budget = (fp8_rep["act_bytes"] + raw_rep["act_bytes"]) // 2
    try:
        base.validate(tiny, act_budget=budget, **kw)
        raise AssertionError("raw plan should exceed the budget")
    except ValueError:
        pass
    fp8.validate(tiny, act_budget=budget, **kw)
    emit(
        f"train_stash/plan_unlock@fp8_P{P}M{M}", 0.0,
        f"budget={budget} raw={raw_rep['act_bytes']} (fails) "
        f"fp8={fp8_rep['act_bytes']} (fits) "
        f"capacity={fp8_rep['capacity_factor']:.3f}x",
    )


SCRIPT = textwrap.dedent(
    """
    import os, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import SURVEY_DEMO, ShapeSpec, reduced
    import repro.configs.registry as registry
    from repro.core.partitioner import ParallelPlan
    from repro.data import DataPipeline
    from repro.launch.mesh import make_train_mesh
    from repro.launch.train import (
        build_train_pipeline, build_train_pipeline_host)
    from repro.optim import get as get_opt
    from repro.train import TrainConfig, make_state

    TINY = reduced(SURVEY_DEMO, n_layers=4, d_model=128, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=512)
    registry.ARCHITECTURES[TINY.name] = TINY
    B, SEQ, P, M = 8, 64, 4, 8
    shape = ShapeSpec("t", SEQ, B, "train")
    opt_tc = TrainConfig(precision="f32", log_every=1)
    opt = get_opt(opt_tc.optimizer, opt_tc.lr)
    data = DataPipeline(TINY, batch_size=B, seq_len=SEQ, seed=0)
    batch_np = {k: np.asarray(v) for k, v in dict(next(data)).items()}
    data.close()

    def time_step(fn, state, batch, iters=5):
        state, m = fn(state, batch)          # compile + warm
        jax.block_until_ready(m)
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = fn(state, batch)
            jax.block_until_ready(m)
        return (time.perf_counter() - t0) / iters * 1e6, float(m["loss"])

    for stash in ("raw", "int8", "fp8"):
        plan = ParallelPlan(dp=1, tp=1, pp=P, microbatches=M,
                            schedule="1f1b", stash=stash).validate(TINY)
        tc = TrainConfig(precision="f32", log_every=1, stash=stash)
        mesh = make_train_mesh(1, 1, P)
        jitted, (s_struct, b_struct) = build_train_pipeline(
            TINY.name, mesh, plan, tc, shape)
        state = jax.tree.map(
            lambda x, st: jax.device_put(x, st.sharding),
            make_state(TINY, opt, tc), s_struct)
        batch = jax.tree.map(
            lambda v, st: jax.device_put(jnp.asarray(v), st.sharding),
            batch_np, b_struct)
        us, loss = time_step(jitted, state, batch)
        print(f"ROW {stash} {us:.1f} loss={loss:.4f}")

    plan = ParallelPlan(dp=1, tp=1, pp=P, microbatches=M,
                        schedule="1f1b", stash="host").validate(TINY)
    tc = TrainConfig(precision="f32", log_every=1, stash="host")
    step, _, backend = build_train_pipeline_host(TINY.name, plan, tc, shape)
    state = make_state(TINY, opt, tc)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    us, loss = time_step(step, state, batch, iters=2)
    st = backend.stats()
    print(f"ROW host {us:.1f} loss={loss:.4f} "
          f"evictions={st['evictions']} host_hits={st['host_hits']}")
    """
)


def _executable() -> None:
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=900, env=subprocess_env(),
    )
    rows = {}
    for ln in r.stdout.splitlines():
        if ln.startswith("ROW "):
            _, name, us, extra = ln.split(maxsplit=3)
            rows[name] = (float(us), extra)
    for name in ("raw", "int8", "fp8", "host"):
        us, extra = rows.get(name, (0.0, f"FAILED rc={r.returncode}"))
        emit(
            f"train_stash/step@{name}_P{P}M{M}", us,
            f"{extra} B={B} seq={SEQ} 4-layer tiny 1f1b",
        )
    assert r.returncode == 0, r.stderr[-2000:]


def main() -> None:
    header("Activation stash: capacity accounting + 1F1B step timings")
    _accounting()
    _plan_unlock()
    _executable()


if __name__ == "__main__":
    main()
