"""Survey Table 1 — cross-method comparison, measured.

For each method row of Table 1 we train one step of the demo transformer on
CPU and record, from the compiled HLO of that exact step:
  * peak temp memory (memory_analysis)    -> the "batch size increase?" col
  * HLO FLOPs (cost_analysis)             -> the "# FLOP per iteration" col
  * data-parallel wire bytes (loopback-measured payload for compression;
    analytic dense payload otherwise)     -> the communication cols

The derived field prints the Table-1 arrow this row reproduces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, time_fn
from repro.configs import SURVEY_DEMO, reduced
from repro.core.compression import QSGD, SignEF, TopK, wire_bytes_dense
from repro.data import DataPipeline
from repro.optim import get as get_opt
from repro.train import TrainConfig, make_state, make_train_step

CFG = reduced(
    SURVEY_DEMO, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
    d_ff=1024, vocab_size=2048,
)
BATCH, SEQ = 8, 256


def step_stats(tc: TrainConfig):
    opt = get_opt(tc.optimizer, 1e-3)
    state = make_state(CFG, opt, tc)
    data = DataPipeline(CFG, BATCH, SEQ, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    data.close()
    step = make_train_step(CFG, opt, tc)
    lowered = jax.jit(step).lower(state, batch) if not hasattr(step, "lower") else step.lower(state, batch)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    us = time_fn(step, state, batch)
    _, metrics = step(state, batch)
    return {
        "temp_gb": float(mem.temp_size_in_bytes) / 2**30,
        "flops": float(cost.get("flops", 0)),
        "wire": float(metrics["wire_bytes"]),
        "us": us,
    }


def main() -> None:
    header("Table 1: methods to train large neural networks (measured)")
    base = step_stats(TrainConfig(remat="none"))
    dense_wire = None

    def row(name, tc, note):
        s = step_stats(tc)
        emit(
            f"table1/{name}", s["us"],
            f"temp={s['temp_gb']:.3f}GiB({s['temp_gb']/max(base['temp_gb'],1e-9):.2f}x) "
            f"flops={s['flops']:.3g}({s['flops']/max(base['flops'],1):.2f}x) "
            f"wire={s['wire']:.3g}B {note}",
        )
        return s

    emit(
        "table1/baseline", base["us"],
        f"temp={base['temp_gb']:.3f}GiB flops={base['flops']:.3g} "
        f"wire={wire_bytes_dense(make_state(CFG, get_opt('adamw', 1e-3), TrainConfig())['params']):.3g}B(dense-DP)",
    )
    row("remat_full", TrainConfig(remat="full"), "Table1: remat memory v, FLOP ^")
    row("remat_dots", TrainConfig(remat="dots"), "Table1: selective remat")
    row("compress_topk", TrainConfig(compression=TopK(0.01)),
        "Table1: grad compression wire v")
    row("compress_qsgd", TrainConfig(compression=QSGD(8)), "Table1: 8-bit grads")
    row("compress_sign", TrainConfig(compression=SignEF()), "Table1: 1-bit grads")
    row("adam8bit", TrainConfig(optimizer="adam8bit"),
        "Table1/s4.2: optim state 4x v")


if __name__ == "__main__":
    main()
