"""Quantized KV-pool benchmark: int8/fp8 paged pools vs the bf16 baseline.

Four claims, separated by what can be asserted where:

* **Timed** decode attention (jitted paged-decode op over a 1024-token
  pooled context): int8 pages + fused in-gather dequant vs a bf16 pool.
  Decode is memory-bound in the KV gather, so reading 1 byte/elem + one
  f32 scale per (slot, head) row beats streaming 2-byte K/V even though
  dequant adds a multiply — the CPU measurement, with the roofline's
  dtype-aware prediction alongside (predicted-vs-measured).
* **Exact bytes** (accounting rows, hardware-independent): per-token pool
  bytes at bf16 / int8 / fp8 from the one pricing rule
  (``quant.kv_token_bytes``), and per-device pool bytes asserted from the
  engine's REAL device buffers.
* **Capacity** (accounting row): ``EngineConfig.capacity(pool_bytes=...)``
  at one fixed HBM budget — resident requests at int8 vs bf16 (>= 1.8x is the
  tentpole claim; the f32-scale overhead is why it lands under the naive
  2x).
* **Accuracy** (accounting row): greedy agreement of the int8 engine vs
  the bf16 engine on the anchored serve scenario, plus batched==alone
  token-identity at int8 (quantize-once-per-write makes pool bytes batch-
  independent, so the engine determinism guarantee survives quantization).
  Caveat on the anchor: random-init reduced models have near-degenerate
  top-2 logit margins, so greedy agreement under ANY KV rounding (bf16
  included) is a coin flip at steps whose margin sits below the noise —
  the anchored prompt seed is one where the trajectory's margins clear
  the int8 noise (most seeds do; fp8's ~2x noise does not clear them,
  which is why the gated row claims int8 only). The robust accuracy
  statement — max-logit-error tolerance vs the native pool — lives in
  tests/test_serve_engine.py, not here.

Interpret-mode CPU timings are NOT TPU perf claims (EXPERIMENTS.md); the
accounting rows carry the hardware-independent statements.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header, time_fn


def _decode_attention_section() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention import quant
    from repro.kernels.paged_attention.ref import paged_attention_ref
    from repro.roofline.analysis import predicted_decode_kv_speedup

    B, Kv, G, hd, page, P = 8, 8, 4, 128, 16, 64      # 1024-token context
    N = B * P + 1
    key = jax.random.PRNGKey(0)
    kp = jax.random.normal(key, (N, page, Kv, hd), jnp.float32)
    vp = jax.random.normal(key, (N, page, Kv, hd), jnp.float32)
    q = jax.random.normal(key, (B, Kv, G, hd), jnp.float32)
    tables = jnp.arange(1, N, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.full((B,), P * page, jnp.int32)

    f_pool = jax.jit(
        lambda q_, k_, v_: paged_attention_ref(q_, k_, v_, tables, lengths)
    )
    f_quant = jax.jit(
        lambda q_, k_, v_, ks_, vs_: paged_attention_ref(
            q_, k_, v_, tables, lengths, k_scale=ks_, v_scale=vs_
        )
    )
    kb, vb = kp.astype(jnp.bfloat16), vp.astype(jnp.bfloat16)
    kc, ks = quant.kv_quantize(kp, jnp.int8)
    vc, vs = quant.kv_quantize(vp, jnp.int8)

    t_bf16 = time_fn(f_pool, q, kb, vb, iters=9)
    t_int8 = time_fn(f_quant, q, kc, vc, ks, vs, iters=9)
    pred_bf16 = predicted_decode_kv_speedup(Kv, hd, "int8")
    emit(
        "serve_quant/paged_decode_bf16",
        t_bf16,
        f"B={B} ctx={P * page} Kv={Kv} hd={hd}; bf16 pool",
    )
    emit(
        "serve_quant/paged_decode_int8",
        t_int8,
        f"measured_speedup_vs_bf16={t_bf16 / t_int8:.2f}x "
        f"(roofline predicts {pred_bf16:.2f}x from KV-read bytes alone)",
    )
    # deterministic arithmetic only (the measured value lives on the timed
    # row above, which the gate checks for slowdown, not for drift)
    emit(
        "serve_quant/roofline_predicted",
        0.0,
        f"decode KV-read bytes/token bf16={quant.kv_token_bytes(Kv, hd, 'bf16')} "
        f"int8={quant.kv_token_bytes(Kv, hd, 'int8')} "
        f"fp8={quant.kv_token_bytes(Kv, hd, 'fp8')}; "
        f"predicted int8 decode speedup {pred_bf16:.2f}x",
    )


def _capacity_section(cfg) -> None:
    from repro.kernels.paged_attention.quant import kv_token_bytes
    from repro.serve import EngineConfig
    from repro.serve.pool import kv_page_bytes

    page, max_new, max_prompt = 8, 12, 24
    tok_bf16 = kv_token_bytes(cfg.n_kv_heads, cfg.head_dim, "bf16")
    tok_int8 = kv_token_bytes(cfg.n_kv_heads, cfg.head_dim, "int8")
    tok_fp8 = kv_token_bytes(cfg.n_kv_heads, cfg.head_dim, "fp8")
    emit(
        "serve_quant/kv_bytes_per_token",
        0.0,
        f"bf16={tok_bf16} int8={tok_int8} fp8={tok_fp8} "
        f"(codes + f32 scale per (slot, head)); "
        f"int8_byte_factor={tok_bf16 / tok_int8:.2f}x",
    )

    # equal-HBM-budget capacity: size the budget so the bf16 pool seats 8
    # worst-case requests, then ask how many the int8 pool seats
    page_b = kv_page_bytes(
        page, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers, "bf16"
    )
    max_len = -(-(max_prompt + max_new) // page) * page
    # budget covers 8 full horizons PLUS the pool's null page — the null
    # page is charged to the budget by EngineConfig.capacity, so seating 8
    # requests takes (1 + 8*pages_per_req) pages
    budget = (1 + 8 * (max_len // page)) * page_b
    c_bf16 = EngineConfig.capacity(
        max_prompt, max_new, pool_bytes=budget, cfg=cfg, page_size=page,
        kv_dtype="bf16",
    )
    c_int8 = EngineConfig.capacity(
        max_prompt, max_new, pool_bytes=budget, cfg=cfg, page_size=page,
        kv_dtype="int8",
    )
    factor = c_int8.slots / c_bf16.slots
    assert factor >= 1.8, (c_bf16.slots, c_int8.slots)
    emit(
        "serve_quant/resident_requests",
        0.0,
        f"pool_budget={budget}B horizon={max_len}: bf16_slots={c_bf16.slots} "
        f"int8_slots={c_int8.slots}; capacity_factor={factor:.3f}x (>=1.8x)",
    )


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import Runtime, init_params
    from repro.serve import EngineConfig, ServeEngine

    header("Quantized KV pool (int8/fp8 pages, fused in-gather dequant)")
    _decode_attention_section()

    cfg = get_reduced("granite-8b")
    _capacity_section(cfg)

    rt = Runtime(dtype=jnp.float32, chunk_q=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(5)    # anchored: margins clear int8 noise
    page, max_new, max_prompt = 8, 12, 24
    prompts = [
        rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
        for s in (9, 24, 14, 19)
    ]

    def run_engine(kv_dtype, reqs):
        ecfg = EngineConfig.capacity(
            max_prompt, max_new, slots=2, page_size=page, headroom=2.0,
            kv_dtype=kv_dtype,
        ).engine(inner_steps=4)
        eng = ServeEngine(cfg, params, rt, ecfg)
        rids = [eng.submit(p, max_new) for p in reqs]
        out = eng.run()
        return eng, [out[r] for r in rids]

    results = {}
    for kv_dtype in ("bf16", "int8"):
        run_engine(kv_dtype, prompts)             # warm the compile caches
        eng, outs = run_engine(kv_dtype, prompts)
        results[kv_dtype] = (eng, outs)
        s = eng.stats
        n_tokens = sum(len(o) for o in outs)
        emit(
            f"serve_quant/engine_decode_{kv_dtype}",
            s["wall_s"] / max(n_tokens, 1) * 1e6,
            f"tokens_per_s={s['tokens_per_s']:.1f}; "
            f"kv_bytes_per_req={np.mean(list(s['kv_bytes'].values())):.0f} "
            f"(toy-scale CPU engine: MLP + write-quant dominate; the "
            f"KV-bound regime is the paged_decode rows)",
        )

    # per-device pool bytes asserted from the engines' real device buffers
    # (rt.dtype is f32 on CPU, so the native pool prices at 4B/elem here;
    # the bf16 claim is the kv_bytes_per_token row above)
    b_native = results["bf16"][0].kv_pool_bytes_per_device()
    b_int8 = results["int8"][0].kv_pool_bytes_per_device()
    emit(
        "serve_quant/kv_pool_bytes_per_device",
        0.0,
        f"native(f32)={b_native} int8={b_int8} "
        f"(same page geometry; int8 = codes + f32 scales), "
        f"factor={b_native / b_int8:.2f}x",
    )

    # accuracy: greedy agreement int8 vs bf16, batched==alone at int8
    agree = float(np.mean([
        np.mean(np.asarray(b) == np.asarray(i))
        for b, i in zip(results["bf16"][1], results["int8"][1])
    ]))
    alone = [run_engine("int8", [p])[1][0] for p in prompts]
    batched_eq_alone = all(
        np.array_equal(b, a) for b, a in zip(results["int8"][1], alone)
    )
    assert agree >= 0.99 and batched_eq_alone, (agree, batched_eq_alone)
    emit(
        "serve_quant/greedy_agreement",
        0.0,
        f"int8_vs_bf16_agreement={agree:.2f} (>=0.99); "
        f"int8_batched==alone={batched_eq_alone}",
    )


if __name__ == "__main__":
    main()
