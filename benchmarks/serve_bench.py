"""Serving benchmark: continuous batching + paged KV pool vs dense batch.

Reports decode throughput (tokens/s), mean time-to-first-token (submit ->
first token, queue wait included — not just prefill compute), and KV-cache
bytes per request for (a) the paged engine over variable-length requests and
(b) the dense path over the equal-length batch it would need to serve the
same work. Interpret-mode CPU timings are NOT TPU perf claims (see
EXPERIMENTS.md); the derived fields carry the memory accounting — the
KV-bytes ratio is hardware-independent and is the point of the paged pool
(Li et al. 2021-style empirical memory pinpointing applied to serving).

With >= 2 visible devices (CI forces them via
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) a sharded section
also runs: the same engine at mesh shapes 1x2 and 2x2, reporting tokens/s
and the per-device KV-pool bytes — the deterministic
``serve/kv_bytes_per_device`` row is the hardware-independent claim (TP
shards the pool's kv-head axis, so bytes/chip shrink by the model factor).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import Runtime, init_params
    from repro.serve import EngineConfig, ServeEngine
    from repro.serve.dense import generate_dense
    from repro.serve.engine import dense_kv_bytes

    header("Serving (paged continuous batching vs dense batch; CPU interpret)")
    cfg = get_reduced("granite-8b")
    rt = Runtime(dtype=jnp.float32, chunk_q=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    page, max_new, max_prompt = 8, 12, 24
    prompt_lens = [9, 24, 14, 19]
    prompts = [
        rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
        for s in prompt_lens
    ]
    ecfg = EngineConfig.capacity(
        max_prompt, max_new, slots=2, page_size=page, headroom=2.0,
    ).engine(inner_steps=4)

    def run_engine():
        eng = ServeEngine(cfg, params, rt, ecfg)
        rids = [eng.submit(p, max_new) for p in prompts]
        out = eng.run()
        return eng, rids, out

    run_engine()                                  # warm the compile caches
    eng, rids, out = run_engine()
    s = eng.stats
    n_tokens = sum(len(v) for v in out.values())
    kv_paged = float(np.mean(list(s["kv_bytes"].values())))
    # per-run mean TTFT (submit -> first token, queue wait included).
    # stats["ttft_s"] keeps per-rid entries across runs, so averaging that
    # dict would mix warm-up runs into the number on a reused engine.
    ttft_paged = s["run_mean_ttft_s"]
    emit(
        "serve/paged_decode",
        s["wall_s"] / max(n_tokens, 1) * 1e6,
        f"tokens_per_s={s['tokens_per_s']:.1f}; ttft_ms={ttft_paged*1e3:.1f}; "
        f"kv_bytes_per_req={kv_paged:.0f}; "
        f"high_water_pages={s['pool_high_water_pages']}/{eng.pool.budget}",
    )

    # dense comparison: the equal-length batch serving the same requests
    # (prompts padded to the longest, horizon allocated for every row)
    import time

    batch = {
        "tokens": jnp.asarray(
            np.stack([
                np.pad(p, (0, max_prompt - len(p))) for p in prompts
            ]),
            jnp.int32,
        )
    }
    generate_dense(cfg, params, batch, rt, max_new)      # warm
    t0 = time.perf_counter()
    tokens, _, ttft_dense = generate_dense(cfg, params, batch, rt, max_new)
    tokens.block_until_ready()
    wall = time.perf_counter() - t0
    n_dense = int(tokens.size)
    # same accounting the engine reports for its own dense fallback
    # (per-spec cache_len: window-truncated local layers, recurrent share)
    kv_dense = dense_kv_bytes(cfg, rt, max_prompt + max_new)
    emit(
        "serve/dense_decode",
        wall / max(n_dense, 1) * 1e6,
        f"tokens_per_s={n_dense/max(wall, 1e-9):.1f}; "
        f"ttft_ms={ttft_dense*1e3:.1f}; kv_bytes_per_req={kv_dense:.0f}",
    )
    emit(
        "serve/kv_bytes_ratio",
        0.0,
        f"dense/paged={kv_dense/max(kv_paged, 1):.2f}x "
        f"(paged pays only used pages; dense pays the full "
        f"(max_prompt+max_new) extent per row)",
    )

    if len(jax.devices()) >= 2:
        sharded_section()


def sharded_section() -> None:
    """Tensor-parallel + replicated serving over forced host devices."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.launch.mesh import make_serve_mesh
    from repro.models import Runtime, init_params
    from repro.serve import EngineConfig, ReplicatedServeEngine, ServeEngine

    header("Sharded serving (paged pool over the (data, model) mesh)")
    cfg = get_reduced("moonshot-v1-16b-a3b")   # GQA: 4 kv heads shard TP<=4
    rt = Runtime(dtype=jnp.float32, chunk_q=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    max_new = 8
    prompts = [
        rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
        for s in (9, 16, 12, 14)
    ]
    ecfg = EngineConfig.capacity(
        16, max_new, slots=2, page_size=8, headroom=2.0,
    ).engine(inner_steps=4)
    kv_per_dev = {}
    n_dev = len(jax.devices())
    shapes = [(1, 1), (1, 2)] + ([(2, 2)] if n_dev >= 4 else [])
    for data_par, model_par in shapes:
        mesh = make_serve_mesh(data_par, model_par)
        tag = f"{data_par}x{model_par}"

        def run():
            if data_par > 1:
                eng = ReplicatedServeEngine(cfg, params, rt, ecfg, mesh=mesh)
            else:
                from repro.launch.mesh import replica_submeshes

                eng = ServeEngine(
                    cfg, params, rt.replace(mesh=replica_submeshes(mesh)[0]),
                    ecfg,
                )
            rids = [eng.submit(p, max_new) for p in prompts]
            out = eng.run()
            return eng, sum(len(v) for v in out.values())

        run()                                 # warm the compile caches
        eng, n_tokens = run()
        s = eng.stats
        kv = s["kv_pool_bytes_per_device"] if data_par > 1 else (
            eng.kv_pool_bytes_per_device()
        )
        kv_per_dev[tag] = kv
        emit(
            f"serve/paged_mesh_{tag}",
            s["wall_s"] / max(n_tokens, 1) * 1e6,
            f"tokens_per_s={s['tokens_per_s']:.1f}; "
            f"kv_pool_bytes_per_device={kv}",
        )
    factor = kv_per_dev["1x1"] / max(kv_per_dev.get("1x2", 1), 1)
    emit(
        "serve/kv_bytes_per_device",
        0.0,
        "; ".join(f"{k}={v}" for k, v in sorted(kv_per_dev.items()))
        + f"; tp2_factor={factor:.2f}x",
    )


if __name__ == "__main__":
    main()
