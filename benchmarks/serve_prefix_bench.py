"""Shared-prefix serving benchmark: radix prefix cache + chunked prefill.

The workload the prefix cache exists for: N requests sharing one system
prompt (the "millions of users, one template" pattern), with short unique
tails. Reported:

* mean TTFT with the cache off (every request prefills its full prompt)
  vs on+warm (every request adopts the shared prefix and prefills only its
  tail) — the timed claim, `ttft_ratio` recorded in the derived string.
  TTFT spans submit -> first token (queue wait included), so the
  `batch_ttft_ms` numbers count waiting behind co-submitted requests; the
  headline sequential numbers submit one at a time into an idle engine, so
  for them the two origins coincide;
* `serve_prefix/savings` — an exact accounting row: hit rate, cached-token
  fraction, and prefill FLOPs saved (cached tokens x 2 x param count, the
  standard matmul-dominated estimate). These are scheduling facts, not
  timings, so the regression gate matches them exactly;
* decode tokens/s with chunked prefill on vs off in the derived strings —
  interleaving prefill chunks with the decode batch must not cost decode
  throughput (CPU-interpret numbers; see EXPERIMENTS note in serve_bench).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models import Runtime, init_params
    from repro.serve import EngineConfig, ServeEngine

    header("Shared-prefix serving (radix prefix cache + chunked prefill)")
    cfg = get_reduced("granite-8b")
    rt = Runtime(dtype=jnp.float32, chunk_q=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    # the shared prefix must be long enough that its prefill FLOPs
    # dominate the engine-step dispatch overhead even on the CPU-interpret
    # reduced model — 160 tokens vs <=9-token unique tails (the realistic
    # shape: a big system prompt + short user turns)
    page, max_new = 16, 16
    sys_len = 160
    sys_prompt = rng.randint(0, cfg.vocab_size, (sys_len,)).astype(np.int32)
    tails = [
        rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
        for s in (5, 9, 3, 7, 6, 4)
    ]
    prompts = [np.concatenate([sys_prompt, t]) for t in tails]
    max_prompt = max(len(p) for p in prompts)

    def make_engine(**kw):
        ecfg = EngineConfig.capacity(
            max_prompt, max_new, slots=2, page_size=page, headroom=2.0,
        ).engine(inner_steps=4, **kw)
        return ServeEngine(cfg, params, rt, ecfg)

    COUNTERS = (
        "prefix_lookups", "prefix_hits", "prefix_cached_tokens",
        "prompt_tokens",
    )

    def drive(eng):
        before = {k: eng.stats.get(k, 0) for k in COUNTERS}
        rids = [eng.submit(p, max_new) for p in prompts]
        out = eng.run()
        s = eng.stats
        return {
            "ttft_ms": float(np.mean([s["ttft_s"][r] for r in rids])) * 1e3,
            "tok_s": s["tokens_per_s"],
            "tokens": sum(len(out[r]) for r in rids),
            # per-drive counter deltas: the warm pass's own hit rate, not a
            # mix with the cold pass's compulsory misses
            "stats": {
                k: s.get(k, 0) - before[k] for k in COUNTERS
            },
        }

    def ttft_sequential(eng):
        """Mean TTFT over one-at-a-time submissions (idle engine: the
        number a single user sees, undiluted by co-batched decode work)."""
        ms = []
        for p in prompts:
            rid = eng.submit(p, max_new)
            eng.run()
            ms.append(eng.stats["ttft_s"][rid] * 1e3)
        return float(np.mean(ms))

    # cache off: every prompt prefills from scratch (legacy path)
    off_eng = make_engine()
    drive(off_eng)                               # warm the compile caches
    off = drive(off_eng)
    off_ttft = ttft_sequential(off_eng)

    # cache on + chunked prefill: first drive populates the radix tree,
    # second is the steady state (every request adopts the system prompt)
    on_eng = make_engine(prefix_cache=True, prefill_chunk=page)
    drive(on_eng)                                # cold: compiles + inserts
    on = drive(on_eng)
    on_ttft = ttft_sequential(on_eng)
    s = on["stats"]
    hit_rate = s["prefix_hits"] / max(s["prefix_lookups"], 1)
    cached_frac = s["prefix_cached_tokens"] / max(s["prompt_tokens"], 1)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    flops_saved = 2 * n_params * s["prefix_cached_tokens"]

    emit(
        "serve_prefix/ttft_cache_off",
        off_ttft * 1e3,
        f"mean_ttft_ms={off_ttft:.1f}; "
        f"batch_ttft_ms={off['ttft_ms']:.1f}; "
        f"decode_tokens_per_s={off['tok_s']:.1f}",
    )
    emit(
        "serve_prefix/ttft_cache_on",
        on_ttft * 1e3,
        f"mean_ttft_ms={on_ttft:.1f}; "
        f"batch_ttft_ms={on['ttft_ms']:.1f}; "
        f"ttft_ratio_vs_off={on_ttft / max(off_ttft, 1e-9):.2f}x; "
        f"decode_tokens_per_s={on['tok_s']:.1f} (chunked prefill on)",
    )
    emit(
        "serve_prefix/savings",
        0.0,
        f"hit_rate={s['prefix_hits']}/{s['prefix_lookups']}; "
        f"cached_token_fraction={cached_frac:.3f}; "
        f"prefill_tokens_saved={s['prefix_cached_tokens']}; "
        f"prefill_flops_saved={flops_saved:.3e} "
        f"(2 x {n_params} params x cached tokens)",
    )
    assert hit_rate == 1.0, (
        "steady-state shared-prefix workload should hit on every lookup"
    )


if __name__ == "__main__":
    main()
