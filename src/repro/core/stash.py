"""Activation-stash subsystem: pluggable storage for pipeline slot buffers.

The 1F1B/GPipe runner (core.pipeline.pipeline_grads) keeps exactly
``tick_table.n_act_slots`` live stage inputs per device — write-once /
read-once tensors whose lifetime spans the warmup gap between a
microbatch's forward and its backward. A ``StashBackend`` owns how those
slots are stored, which is the per-device activation-capacity lever
(Jin'20 error-bounded lossy compression; Rhu'16 vDNN host offload):

* ``RawStash``   — identity storage at the native dtype; bitwise-preserves
                   the pre-stash runner (the default).
* ``QuantStash`` — blockwise int8/fp8 codes + per-block f32 scales
                   (kernels.blockwise_quant.stash_quantize, which reuses
                   the paged-KV symmetric quantizer). Purely functional —
                   ``put``/``get`` are jnp ops on an explicit state pytree,
                   so the stash lives inside the runner's single
                   ``lax.scan`` carry under ``shard_map``. Every forward
                   consumes the DEQUANTIZED slot value (stage 0 via the
                   straight-through ``roundtrip``), so the vjp gradients
                   are the exact gradients of a well-defined perturbed
                   forward and same-seed runs are deterministic.
* ``HostStash``  — stateful double-buffered device->host eviction for the
                   host-driven runner (``pipeline_grads_host``) and the
                   offload-chain executor (core.offload): the newest
                   ``window`` slots stay on device; eviction STARTS the
                   device->host copy (``copy_to_host_async`` at put time)
                   but never blocks — evicted values sit in a pending
                   staging buffer until ``poll`` (called once per tick by
                   the host runner) observes the copy complete
                   (``Array.is_ready``) and materializes it, the overlap
                   path. ``prefetch`` starts the host->device load for an
                   upcoming backward's slot ahead of its get (the runner
                   reads future B-entries from the TickTable); a get that
                   finds neither the window nor a prefetched staging
                   buffer is a measured *stall*. Values round-trip
                   bit-exactly on every path.

All backends share one protocol: ``init(n_slots, struct) -> state``,
``put(state, slot, tree) -> state``, ``get(state, slot, struct) -> tree``,
``roundtrip(tree)`` (the storage perturbation as a function; identity for
lossless backends), ``prefetch``/``poll`` (overlap hooks; no-ops for
device-resident backends), plus exact byte accounting: ``slot_bytes``,
``device_bytes``/``host_bytes`` (split residency), and ``state_bytes``
(device-resident, kept as an alias of ``device_bytes``). Scan-capable
backends take traced slot indices; the host backend requires concrete
ints (its schedule is host-driven by construction).
"""
from __future__ import annotations

import collections
import functools
from typing import Any, Dict, Optional, Tuple

STASH_BACKENDS = ("raw", "int8", "fp8", "host")


def normalize_stash(stash: str) -> str:
    """Canonical backend name ('' and 'bf16'/'native' mean raw)."""
    if stash in ("", "raw", "native", "bf16"):
        return "raw"
    if stash not in STASH_BACKENDS:
        raise ValueError(f"stash {stash!r} not in {STASH_BACKENDS}")
    return stash


def _leaf_bytes(struct: Any) -> int:
    import jax
    import jax.numpy as jnp

    total = 0
    for leaf in jax.tree.leaves(struct):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


class RawStash:
    """Identity storage: slots are ``(n_slots,) + leaf.shape`` native-dtype
    buffers, put/get are dynamic slice update/read. Bitwise-preserves the
    pre-stash pipeline runner."""

    name = "raw"
    scan_capable = True

    def init(self, n_slots: int, struct: Any) -> Any:
        import jax
        import jax.numpy as jnp

        return jax.tree.map(
            lambda s: jnp.zeros((n_slots,) + tuple(s.shape), s.dtype), struct
        )

    def put(self, state: Any, slot: Any, value: Any) -> Any:
        import jax

        return jax.tree.map(lambda b, v: b.at[slot].set(v), state, value)

    def get(self, state: Any, slot: Any, struct: Any) -> Any:
        import jax

        return jax.tree.map(lambda b: b[slot], state)

    def roundtrip(self, value: Any) -> Any:
        return value

    def prefetch(self, state: Any, slot: Any) -> None:
        """Overlap hook (no-op: slots are already device-resident)."""

    def poll(self, state: Any) -> None:
        """Overlap hook (no-op: nothing is ever in flight)."""

    def slot_bytes(self, struct: Any) -> int:
        """Exact stored bytes for ONE slot (== sum of leaf nbytes)."""
        return _leaf_bytes(struct)

    def device_bytes(self, n_slots: int, struct: Any) -> int:
        return n_slots * self.slot_bytes(struct)

    def host_bytes(self, n_slots: int, struct: Any) -> int:
        return 0

    def state_bytes(self, n_slots: int, struct: Any) -> int:
        return self.device_bytes(n_slots, struct)


@functools.lru_cache(maxsize=None)
def _ste_roundtrip(storage: str, block: int, codec_backend: str = "ref"):
    """Straight-through quantize->dequantize: forward is the exact stash
    perturbation (bitwise-identical to put-then-get on the same value),
    backward is identity — so stage-0 recompute inside the runner's vjp
    sees the same activations the forward consumed while embedding grads
    still flow. Cached per (storage, block, codec_backend) so jit tracing
    sees one custom_vjp primitive per codec."""
    import jax

    from repro.kernels.blockwise_quant.ops import (
        stash_dequantize, stash_quantize,
    )

    def fwd_value(x):
        codes, scales = stash_quantize(x, storage, block, codec_backend)
        return stash_dequantize(
            codes, scales, x.shape, x.dtype, block, codec_backend
        )

    @jax.custom_vjp
    def ste(x):
        return fwd_value(x)

    ste.defvjp(lambda x: (fwd_value(x), None), lambda _, g: (g,))
    return ste


class QuantStash:
    """Blockwise int8/fp8 stash: codes at 1 byte/elem (zero-padded to the
    block multiple) + one f32 scale per block. State is an explicit
    ``{"codes": tree, "scales": tree}`` pytree mirroring the slot struct —
    pure jnp in and out, so it rides in the pipeline scan carry.

    ``codec_backend`` routes quantize-on-put / dequantize-on-get through
    the fused Pallas kernels (``"pallas"``) or the jnp reference
    (``"ref"``, the default); codes and scales are bitwise identical
    either way (tests/test_kernels_quant.py), so the routing never changes
    training numerics. ``cotangents=True`` additionally stores the
    pipeline's cotangent slots through the same codec (the runners read
    this flag)."""

    scan_capable = True

    def __init__(self, storage: str = "fp8", block: Optional[int] = None,
                 codec_backend: Optional[str] = None,
                 cotangents: bool = False):
        from repro.kernels.blockwise_quant.ops import STASH_BLOCK

        if storage not in ("int8", "fp8"):
            raise ValueError(f"QuantStash storage {storage!r}")
        if codec_backend not in (None, "ref", "pallas"):
            raise ValueError(f"codec_backend {codec_backend!r}")
        self.storage = storage
        self.block = int(block or STASH_BLOCK)
        self.codec_backend = codec_backend or "ref"
        self.cotangents = bool(cotangents)

    @property
    def name(self) -> str:
        return self.storage

    def _storage_dtype(self):
        from repro.kernels.paged_attention.quant import _QUANT

        return _QUANT[self.storage][0]

    def init(self, n_slots: int, struct: Any) -> Any:
        import jax
        import jax.numpy as jnp

        from repro.kernels.blockwise_quant.ops import stash_padded_size

        sdt = self._storage_dtype()

        def one_codes(s):
            n = 1
            for d in s.shape:
                n *= int(d)
            nb = stash_padded_size(n, self.block) // self.block
            return jnp.zeros((n_slots, nb, self.block), sdt)

        def one_scales(s):
            n = 1
            for d in s.shape:
                n *= int(d)
            nb = stash_padded_size(n, self.block) // self.block
            return jnp.zeros((n_slots, nb), jnp.float32)

        return {
            "codes": jax.tree.map(one_codes, struct),
            "scales": jax.tree.map(one_scales, struct),
        }

    def put(self, state: Any, slot: Any, value: Any) -> Any:
        import jax

        from repro.kernels.blockwise_quant.ops import stash_quantize

        flat, treedef = jax.tree.flatten(value)
        quantized = [
            stash_quantize(v, self.storage, self.block, self.codec_backend)
            for v in flat
        ]
        codes = jax.tree.unflatten(treedef, [c for c, _ in quantized])
        scales = jax.tree.unflatten(treedef, [s for _, s in quantized])
        return {
            "codes": jax.tree.map(
                lambda b, c: b.at[slot].set(c), state["codes"], codes
            ),
            "scales": jax.tree.map(
                lambda b, s: b.at[slot].set(s), state["scales"], scales
            ),
        }

    def get(self, state: Any, slot: Any, struct: Any) -> Any:
        import jax

        from repro.kernels.blockwise_quant.ops import stash_dequantize

        return jax.tree.map(
            lambda s, c, sc: stash_dequantize(
                c[slot], sc[slot], tuple(s.shape), s.dtype, self.block,
                self.codec_backend,
            ),
            struct, state["codes"], state["scales"],
        )

    def roundtrip(self, value: Any) -> Any:
        import jax

        ste = _ste_roundtrip(self.storage, self.block, self.codec_backend)
        return jax.tree.map(ste, value)

    def prefetch(self, state: Any, slot: Any) -> None:
        """Overlap hook (no-op: codes/scales are device-resident)."""

    def poll(self, state: Any) -> None:
        """Overlap hook (no-op: nothing is ever in flight)."""

    def slot_bytes(self, struct: Any) -> int:
        """Exact stored bytes per slot: padded codes + per-block f32 scales."""
        import jax

        from repro.kernels.blockwise_quant.ops import stash_padded_size
        from repro.kernels.paged_attention.quant import SCALE_BYTES

        total = 0
        for leaf in jax.tree.leaves(struct):
            n = 1
            for d in leaf.shape:
                n *= int(d)
            padded = stash_padded_size(n, self.block)
            total += padded + (padded // self.block) * SCALE_BYTES
        return total

    def device_bytes(self, n_slots: int, struct: Any) -> int:
        return n_slots * self.slot_bytes(struct)

    def host_bytes(self, n_slots: int, struct: Any) -> int:
        return 0

    def state_bytes(self, n_slots: int, struct: Any) -> int:
        return self.device_bytes(n_slots, struct)


class _HostSlotStore:
    """Mutable handle behind HostStash: four residency sets per slot —

    * ``device``  — FIFO window of the newest ``window`` slots.
    * ``pending`` — evicted slots whose device->host copy (started at put
                    time via ``copy_to_host_async``) is still in flight;
                    the device buffer stays alive here so the copy never
                    blocks the put.
    * ``host``    — landed numpy copies (``poll`` moves pending slots here
                    once ``Array.is_ready`` observes the copy complete —
                    the overlapped-eviction path).
    * ``staged``  — device arrays prefetched ahead of a backward's get
                    (``prefetch``, driven by the runner's TickTable
                    lookahead). A get served from ``staged`` is a prefetch
                    hit; a get that has to transfer inline is a *stall*.

    Values round-trip bit-exactly on every path; only the counters differ
    between the eager (lookahead=0, never poll) and overlapped runners."""

    def __init__(self, window: int):
        self.window = int(window)
        self.device: "collections.OrderedDict[int, Any]" = collections.OrderedDict()
        self.pending: Dict[int, Any] = {}
        self.host: Dict[int, Any] = {}
        self.staged: Dict[int, Any] = {}
        self.stats = {
            "puts": 0, "gets": 0, "evictions": 0, "host_hits": 0,
            "window_hits": 0, "host_bytes_high_water": 0,
            "overlapped_evictions": 0, "prefetch_issued": 0,
            "prefetch_hits": 0, "stalled_gets": 0,
        }

    def _host_bytes(self) -> int:
        """Host-destined bytes: landed copies plus in-flight evictions."""
        import jax

        total = 0
        for store in (self.host, self.pending):
            for tree in store.values():
                for leaf in jax.tree.leaves(tree):
                    total += leaf.nbytes
        return total

    def put(self, slot: int, value: Any) -> None:
        import jax

        for leaf in jax.tree.leaves(value):
            start = getattr(leaf, "copy_to_host_async", None)
            if start is not None:
                start()
        # Slot reuse drops every stale copy (host, in-flight, prefetched).
        self.host.pop(slot, None)
        self.pending.pop(slot, None)
        self.staged.pop(slot, None)
        self.device.pop(slot, None)
        self.device[slot] = value
        self.stats["puts"] += 1
        while len(self.device) > self.window:
            # Eviction never blocks: the copy was started at put time; the
            # slot parks in ``pending`` until poll/get observes completion.
            old_slot, old_val = self.device.popitem(last=False)
            self.pending[old_slot] = old_val
            self.stats["evictions"] += 1
        self.stats["host_bytes_high_water"] = max(
            self.stats["host_bytes_high_water"], self._host_bytes()
        )

    def poll(self) -> None:
        """Land every pending eviction whose async copy has completed
        (``is_ready`` on all leaves) — called once per tick by the host
        runner, so completed transfers retire without ever blocking."""
        import jax
        import numpy as np

        for slot in list(self.pending):
            val = self.pending[slot]
            if all(
                getattr(leaf, "is_ready", lambda: True)()
                for leaf in jax.tree.leaves(val)
            ):
                self.host[slot] = jax.tree.map(np.asarray, val)
                del self.pending[slot]
                self.stats["overlapped_evictions"] += 1

    def prefetch(self, slot: int) -> None:
        """Start the host->device load for an upcoming backward's slot.
        Window/staged residents are no-ops; a pending slot's device buffer
        is still alive, so staging it is free (the round trip is elided)."""
        import jax

        if slot in self.device or slot in self.staged:
            return
        if slot in self.pending:
            self.staged[slot] = self.pending[slot]
            self.stats["prefetch_issued"] += 1
            return
        if slot in self.host:
            self.staged[slot] = jax.tree.map(jax.device_put, self.host[slot])
            self.stats["prefetch_issued"] += 1

    def get(self, slot: int) -> Any:
        import jax
        import numpy as np

        self.stats["gets"] += 1
        if slot in self.device:
            self.stats["window_hits"] += 1
            return self.device[slot]
        self.stats["host_hits"] += 1
        staged = self.staged.pop(slot, None)
        if staged is not None:
            self.stats["prefetch_hits"] += 1
            return staged
        # Neither windowed nor prefetched: the get transfers inline — the
        # measured stall the lookahead exists to remove.
        self.stats["stalled_gets"] += 1
        if slot in self.pending:
            val = self.pending.pop(slot)
            self.host[slot] = jax.tree.map(np.asarray, val)
            return val
        return jax.tree.map(jax.device_put, self.host[slot])


class HostStash:
    """Double-buffered device->host slot eviction (vDNN for pipeline
    stashes). Values round-trip bit-exactly; only the newest ``window``
    slots occupy device memory. Not scan-capable: put/get need concrete
    slot ints and perform host transfers, so this backend pairs with the
    host-driven runner (``core.pipeline.pipeline_grads_host``) and the
    offload-chain executor (``core.offload.offload_chain_grads``)."""

    name = "host"
    scan_capable = False

    def __init__(self, window: int = 2):
        self.window = int(window)
        self.stores: list = []   # every store handed out (one per stage/step)

    def init(self, n_slots: int, struct: Any) -> _HostSlotStore:
        store = _HostSlotStore(self.window)
        self.stores.append(store)  # exit-stats hook (launch.train)
        return store

    def put(self, state: _HostSlotStore, slot: Any, value: Any) -> _HostSlotStore:
        state.put(int(slot), value)
        return state

    def get(self, state: _HostSlotStore, slot: Any, struct: Any) -> Any:
        return state.get(int(slot))

    def roundtrip(self, value: Any) -> Any:
        return value

    def prefetch(self, state: _HostSlotStore, slot: Any) -> None:
        """Start the host->device load for ``slot`` ahead of its get."""
        state.prefetch(int(slot))

    def poll(self, state: _HostSlotStore) -> None:
        """Retire completed async evictions (called once per tick)."""
        state.poll()

    def slot_bytes(self, struct: Any) -> int:
        """Bytes one slot occupies WHILE resident in the device window (the
        host copy is the same size; capacity accounting multiplies by the
        window, not the slot count)."""
        return _leaf_bytes(struct)

    def device_bytes(self, n_slots: int, struct: Any) -> int:
        """Only the window stays on device."""
        return min(self.window, n_slots) * self.slot_bytes(struct)

    def host_bytes(self, n_slots: int, struct: Any) -> int:
        """Everything beyond the window lands on host (steady-state high
        water; pending in-flight copies count — they are host-destined)."""
        return max(0, n_slots - self.window) * self.slot_bytes(struct)

    def state_bytes(self, n_slots: int, struct: Any) -> int:
        """Device-resident bytes (alias of ``device_bytes``)."""
        return self.device_bytes(n_slots, struct)

    def stats(self) -> Dict[str, int]:
        """Counters summed over every store this backend handed out — the
        host runner inits one store per stage, so per-stage counters (and
        multi-step runs) aggregate here."""
        out: Dict[str, int] = {}
        for store in self.stores:
            for k, v in store.stats.items():
                out[k] = out.get(k, 0) + v
        return out


def get_backend(stash: str, *, block: Optional[int] = None,
                host_window: int = 2, fused: bool = False,
                cotangents: bool = False):
    """Factory: ``raw | int8 | fp8 | host`` -> a StashBackend instance.

    ``fused=True`` routes the int8/fp8 codec through the Pallas kernels
    where they compile (``ops.fused_codec_backend`` — bitwise-identical
    output either way). ``cotangents=True`` asks the runner to store
    cotangent slots through the same codec; it is only meaningful for the
    quantized backends."""
    s = normalize_stash(stash)
    if cotangents and s not in ("int8", "fp8"):
        raise ValueError(
            f"cotangents=True needs a quantized stash, got {s!r}"
        )
    if s == "raw":
        return RawStash()
    if s in ("int8", "fp8"):
        codec = None
        if fused:
            from repro.kernels.blockwise_quant.ops import fused_codec_backend

            codec = fused_codec_backend()
        return QuantStash(
            s, block=block, codec_backend=codec, cotangents=cotangents
        )
    return HostStash(window=host_window)
