"""Rematerialization schedule solvers (survey §2.1, Table 2).

Implements the planning side of the survey's remat taxonomy for sequential
chains of L segments under a memory budget of M stored checkpoints:

* ``periodic``       — sqrt(L) heuristic of [Chen et al., 2016].
* ``binomial``       — optimal checkpoint placement for homogeneous chains
                       ([Grimm et al., 1996]; REVOLVE [Griewank & Walther,
                       2000]) via the binomial recurrence on recompute cost.
* ``dynprog_het``    — dynamic program for heterogeneous chains (per-segment
                       time and memory costs), the [Beaumont et al., 2019] /
                       Rotor setting restricted to "store-input" checkpoints.
* ``dtr_scores``     — the DTR [Kirisame et al., 2020] eviction *policy*
                       (cost / (size * staleness)) as an ahead-of-time
                       planner: XLA's static graphs replace DTR's runtime
                       eviction, so we pre-pick which segments stay resident
                       (documented hardware adaptation, DESIGN.md §3).

All solvers return which segment boundaries to checkpoint; the executable
side (jax.checkpoint over scan units) consumes them via
``repro.core.remat.apply_plan``. ``brute_force`` provides the exponential
reference used by tests to certify optimality on small chains.

Cost model: forward(i) costs t[i] and produces an activation of size a[i];
storing a checkpoint at boundary i consumes a[i] memory; the backward sweep
needs the activation of every segment, recomputing from the nearest stored
checkpoint. This is the classic AD "chain reversal" model (REVOLVE), where
total recompute = sum over segments of (#times segment re-executed).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class RematPlan:
    """checkpoints: sorted segment indices whose *inputs* are kept resident."""

    n_segments: int
    checkpoints: Tuple[int, ...]
    extra_forwards: int            # recomputed segment executions
    peak_memory: float             # activation units resident at the worst time

    @property
    def recompute_overhead(self) -> float:
        return self.extra_forwards / max(self.n_segments, 1)


# ---------------------------------------------------------------- simulation
def simulate(
    n: int,
    checkpoints: Sequence[int],
    t: Optional[Sequence[float]] = None,
    a: Optional[Sequence[float]] = None,
) -> Tuple[float, float]:
    """(extra forward time, peak memory) of a checkpoint set, by simulation.

    Strategy simulated: forward stores activations only at ``checkpoints``
    (0 is implicitly stored: the input). Backward walks segments in reverse;
    to get activation of segment i it recomputes forward from the nearest
    stored checkpoint <= i, storing every intermediate activation of that
    span (the standard segment-wise "checkpoint + replay" execution used by
    jax.checkpoint / torch.utils.checkpoint).
    """
    t = list(t) if t is not None else [1.0] * n
    a = list(a) if a is not None else [1.0] * n
    cps = sorted(set(list(checkpoints) + [0]))
    assert all(0 <= c < n for c in cps)

    extra = 0.0
    # memory during forward: stored checkpoint activations
    stored = sum(a[c] for c in cps)
    peak = stored
    # backward: process spans [cp_k, cp_{k+1}) from last to first
    spans = [(cps[i], cps[i + 1] if i + 1 < len(cps) else n) for i in range(len(cps))]
    for lo, hi in reversed(spans):
        # replay forward lo..hi-1 storing all activations of the span
        # (the span's own checkpoint a[lo] is already counted in `stored`)
        extra += sum(t[lo:hi])
        span_mem = sum(a[lo + 1 : hi])
        peak = max(peak, stored + span_mem)
        stored -= a[lo]  # checkpoint consumed after its span's backward
    return extra, peak


# ----------------------------------------------------------------- periodic
def periodic(n: int, budget: int) -> RematPlan:
    """[Chen et al., 2016]: checkpoint every ~n/budget segments."""
    budget = max(1, budget)
    k = max(1, -(-n // budget))  # ceil
    cps = tuple(range(0, n, k))
    extra, peak = simulate(n, cps)
    return RematPlan(n, cps, int(extra), peak)


# ----------------------------------------------------------------- binomial
@functools.lru_cache(maxsize=None)
def _opt_cost(l: int, m: int) -> int:
    """REVOLVE recurrence: min extra forwards to reverse a length-l chain
    with m checkpoint slots (uniform costs). opt(l, 1) = l*(l-1)/2."""
    if l <= 1:
        return 0
    if m <= 0:
        raise ValueError("need at least one checkpoint slot")
    if m == 1:
        return l * (l - 1) // 2
    best = None
    for j in range(1, l):
        c = j + _opt_cost(l - j, m - 1) + _opt_cost(j, m)
        best = c if best is None or c < best else best
    return best


def binomial(n: int, budget: int) -> RematPlan:
    """Optimal homogeneous-chain plan; checkpoint positions via the argmin
    split of the REVOLVE recurrence (flattened to the segment-replay model
    simulated by :func:`simulate` for reporting)."""
    budget = max(1, budget)
    cps: List[int] = []

    def place(lo: int, l: int, m: int):
        if l <= 1 or m <= 1:
            return
        best_j, best_c = 1, None
        for j in range(1, l):
            c = j + _opt_cost(l - j, m - 1) + _opt_cost(j, m)
            if best_c is None or c < best_c:
                best_j, best_c = j, c
        cps.append(lo + best_j)
        place(lo + best_j, l - best_j, m - 1)
        place(lo, best_j, m)

    place(0, n, budget)
    cps_t = tuple(sorted(set([0] + cps)))
    extra, peak = simulate(n, cps_t)
    return RematPlan(n, cps_t, int(extra), peak)


# ------------------------------------------------------------- heterogeneous
def dynprog_het(
    t: Sequence[float], a: Sequence[float], mem_budget: float
) -> RematPlan:
    """Heterogeneous chain (Beaumont'19-style, store-input checkpoints).

    Exact for the :func:`simulate` cost model. Key observation: when the
    backward sweep replays span [i, j), the checkpoints later than i have
    already been consumed, so the peak during that span is

        sum(a[c] for checkpoints c <= i)  +  sum(a[i+1:j])

    i.e. the constraint is a function of (i, cumulative checkpoint mass) —
    Markovian. DP state = (checkpoint position i, mass w); we keep a Pareto
    frontier of (mass, cost, checkpoint set) per position since lower mass
    and lower cost are both desirable.
    """
    n = len(t)
    assert len(a) == n
    # frontier[i]: list of (mass incl. a[i], cost, cps tuple)
    frontier: List[List[Tuple[float, float, Tuple[int, ...]]]] = [
        [] for _ in range(n)
    ]
    if a[0] <= mem_budget:
        frontier[0] = [(a[0], 0.0, (0,))]

    def pareto(items):
        items.sort()
        out: List[Tuple[float, float, Tuple[int, ...]]] = []
        best_cost = float("inf")
        for w, c, cps in items:
            if c < best_cost - 1e-12:
                out.append((w, c, cps))
                best_cost = c
        return out

    best_final: Optional[Tuple[float, Tuple[int, ...]]] = None
    for i in range(n):
        frontier[i] = pareto(frontier[i])
        for w, cost, cps in frontier[i]:
            # finish: last span is [i, n)
            span = sum(a[i + 1 : n])
            if w + span <= mem_budget + 1e-12:
                c_fin = cost + sum(t[i:n])
                if best_final is None or c_fin < best_final[0]:
                    best_final = (c_fin, cps)
            # place next checkpoint at j
            span = 0.0
            for j in range(i + 1, n):
                # span replay memory for [i, j)
                span += a[j - 1] if j - 1 > i else 0.0
                if w + span > mem_budget + 1e-12:
                    break  # monotone in j: no later j feasible either
                if w + a[j] <= mem_budget + 1e-12:
                    frontier[j].append(
                        (w + a[j], cost + sum(t[i:j]), cps + (j,))
                    )
    if best_final is None:
        cps = tuple(range(n))
        extra, peak = simulate(n, cps, t, a)
        return RematPlan(n, cps, int(extra), peak)
    cps = best_final[1]
    extra, peak = simulate(n, cps, t, a)
    return RematPlan(n, cps, int(extra), peak)


# --------------------------------------------------------------- DTR policy
def dtr_scores(
    t: Sequence[float], a: Sequence[float], keep: int
) -> RematPlan:
    """DTR-inspired static plan: keep the ``keep`` segments with the highest
    retention priority score t[i] / a[i] (cheap-to-store, expensive-to-
    recompute stay resident); staleness has no static analogue and is
    dropped — see DESIGN.md §3 on adapting runtime eviction to XLA."""
    n = len(t)
    order = sorted(range(n), key=lambda i: (t[i] / max(a[i], 1e-9)), reverse=True)
    cps = tuple(sorted(set([0] + order[: max(0, keep - 1)])))
    extra, peak = simulate(n, cps, t, a)
    return RematPlan(n, cps, int(extra), peak)


# -------------------------------------------------------------- brute force
def brute_force(
    n: int,
    budget_mem: float,
    t: Optional[Sequence[float]] = None,
    a: Optional[Sequence[float]] = None,
) -> RematPlan:
    """Exponential exact search (tests only; n <= ~12)."""
    import itertools

    t = list(t) if t is not None else [1.0] * n
    a = list(a) if a is not None else [1.0] * n
    best: Optional[RematPlan] = None
    for r in range(n):
        for combo in itertools.combinations(range(1, n), r):
            cps = (0,) + combo
            extra, peak = simulate(n, cps, t, a)
            if peak <= budget_mem:
                if best is None or extra < best.extra_forwards:
                    best = RematPlan(n, cps, int(extra), peak)
    if best is None:
        cps = tuple(range(n))
        extra, peak = simulate(n, cps, t, a)
        best = RematPlan(n, cps, int(extra), peak)
    return best
