"""Mixed-precision policy + dynamic loss scaling (survey §4.2 context).

Master params stay f32; compute runs in a lower dtype; optionally gradients
are accumulated in f32. bf16 (TPU-native) needs no loss scaling; the fp16
path implements the standard dynamic scale (double every ``growth_interval``
clean steps, halve on non-finite grads and skip the update) so the framework
is also correct on fp16-only hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    use_loss_scaling: bool = False
    init_scale: float = 2.0**15
    growth_interval: int = 2000

    @staticmethod
    def bf16() -> "PrecisionPolicy":
        return PrecisionPolicy()

    @staticmethod
    def f32() -> "PrecisionPolicy":
        return PrecisionPolicy(compute_dtype=jnp.float32)

    @staticmethod
    def fp16() -> "PrecisionPolicy":
        return PrecisionPolicy(compute_dtype=jnp.float16, use_loss_scaling=True)


def init_scale_state(policy: PrecisionPolicy) -> Dict[str, jax.Array]:
    return {
        "scale": jnp.array(policy.init_scale if policy.use_loss_scaling else 1.0,
                           jnp.float32),
        "good_steps": jnp.array(0, jnp.int32),
    }


def scale_loss(loss: jax.Array, state: Dict[str, jax.Array]) -> jax.Array:
    return loss * state["scale"]


def unscale_and_check(
    grads: Any, state: Dict[str, jax.Array], policy: PrecisionPolicy
) -> Tuple[Any, Dict[str, jax.Array], jax.Array]:
    """Unscale grads; detect non-finite; update the dynamic scale.

    Returns (grads, new_state, grads_finite). Callers skip the optimizer
    update when grads_finite is False (jnp.where on the update).
    """
    inv = 1.0 / state["scale"]
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
    finite = jnp.array(True)
    for g in jax.tree.leaves(grads):
        finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(g)))
    if not policy.use_loss_scaling:
        return grads, state, finite

    good = jnp.where(finite, state["good_steps"] + 1, 0)
    grow = good >= policy.growth_interval
    new_scale = jnp.where(
        finite,
        jnp.where(grow, state["scale"] * 2.0, state["scale"]),
        jnp.maximum(state["scale"] * 0.5, 1.0),
    )
    return grads, {"scale": new_scale, "good_steps": jnp.where(grow, 0, good)}, finite
