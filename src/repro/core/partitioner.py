"""Pipeline-stage partitioners (survey Table 4, "Partition Optimization").

Given per-layer costs, split L layers into P contiguous stages:

* ``dynprog_partition`` — minimize the bottleneck stage time (the PipeDream /
  DAPPLE planner objective): classic minimax DP, optimal, O(L^2 P).
* ``heuristic_partition`` — Megatron-style equal-count split (the survey's
  "Heuristic" rows).
* ``dp_pp_search``     — joint (data, pipeline) degree search for a device
  budget (PipeDream's outer loop / Varuna's brute force): for each (dp, pp)
  with dp*pp == N, partition with the DP and score throughput under the
  1F1B bubble model from repro.core.pipeline; returns the argmax.

Costs can come from anywhere; ``layer_costs_from_config`` derives analytic
per-layer FLOP weights from an ArchConfig (MoE/dense/mixer aware), which is
what the benchmark uses.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class Partition:
    boundaries: Tuple[int, ...]   # stage s = layers [boundaries[s], boundaries[s+1])
    stage_costs: Tuple[float, ...]
    bottleneck: float

    @property
    def n_stages(self) -> int:
        return len(self.stage_costs)


def _stage_costs(costs: Sequence[float], bounds: Sequence[int]) -> List[float]:
    return [sum(costs[bounds[i] : bounds[i + 1]]) for i in range(len(bounds) - 1)]


def heuristic_partition(costs: Sequence[float], P: int) -> Partition:
    """Equal layer-count split (Megatron heuristic)."""
    L = len(costs)
    base, rem = divmod(L, P)
    bounds = [0]
    for s in range(P):
        bounds.append(bounds[-1] + base + (1 if s < rem else 0))
    sc = _stage_costs(costs, bounds)
    return Partition(tuple(bounds), tuple(sc), max(sc))


def dynprog_partition(costs: Sequence[float], P: int) -> Partition:
    """Minimax contiguous partition via DP (optimal bottleneck)."""
    L = len(costs)
    P = min(P, L)
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)

    def span(i: int, j: int) -> float:
        return prefix[j] - prefix[i]

    INF = float("inf")
    # dp[p][j] = min bottleneck for first j layers in p stages
    dp = [[INF] * (L + 1) for _ in range(P + 1)]
    cut = [[0] * (L + 1) for _ in range(P + 1)]
    dp[0][0] = 0.0
    for p in range(1, P + 1):
        for j in range(p, L + 1):
            for i in range(p - 1, j):
                cand = max(dp[p - 1][i], span(i, j))
                if cand < dp[p][j]:
                    dp[p][j] = cand
                    cut[p][j] = i
    bounds = [L]
    p, j = P, L
    while p > 0:
        i = cut[p][j]
        bounds.append(i)
        p, j = p - 1, i
    bounds.reverse()
    sc = _stage_costs(costs, bounds)
    return Partition(tuple(bounds), tuple(sc), max(sc))


def brute_force_partition(costs: Sequence[float], P: int) -> Partition:
    """Exponential reference for tests (L <= ~14)."""
    import itertools

    L = len(costs)
    best: Optional[Partition] = None
    for combo in itertools.combinations(range(1, L), P - 1):
        bounds = (0,) + combo + (L,)
        sc = _stage_costs(costs, bounds)
        cand = Partition(bounds, tuple(sc), max(sc))
        if best is None or cand.bottleneck < best.bottleneck:
            best = cand
    assert best is not None
    return best


def layer_costs_from_config(cfg: ArchConfig) -> List[float]:
    """Analytic per-layer FLOP weights (relative; embedding/head excluded)."""
    d, dff, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    out: List[float] = []
    for kind in cfg.mixer_kinds():
        if kind in ("attn", "local"):
            mix = 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + \
                2 * cfg.n_heads * hd * d
        elif kind == "mamba":
            di = cfg.d_inner
            mix = 2 * d * 2 * di + 2 * di * d + 2 * di * (di // 16 + 2 * cfg.ssm_state)
        else:  # rglru
            w = cfg.rglru_width or d
            mix = 2 * d * 2 * w + 2 * w * d
        if cfg.ffn_kind == "dense":
            ffn = (3 if cfg.mlp_gated else 2) * 2 * d * dff
        elif cfg.ffn_kind == "moe":
            ffn = cfg.experts_top_k * (3 if cfg.mlp_gated else 2) * 2 * d * dff
            ffn += cfg.n_shared_experts * 3 * 2 * d * dff
            if cfg.dense_residual:
                ffn += 3 * 2 * d * cfg.residual_d_ff
        else:
            ffn = 0
        out.append(float(mix + ffn))
    return out


@dataclasses.dataclass(frozen=True)
class DPPPChoice:
    dp: int
    pp: int
    partition: Partition
    est_step_time: float   # bottleneck * (M + P - 1) / dp  (1F1B fill model)


def dp_pp_search(
    costs: Sequence[float], n_devices: int, microbatches: int
) -> DPPPChoice:
    """Joint (dp, pp) degree search (PipeDream / Varuna outer loop)."""
    best: Optional[DPPPChoice] = None
    for pp in range(1, min(n_devices, len(costs)) + 1):
        if n_devices % pp:
            continue
        dp = n_devices // pp
        part = dynprog_partition(costs, pp)
        t = part.bottleneck * (microbatches + pp - 1) / (microbatches * dp)
        cand = DPPPChoice(dp, pp, part, t)
        if best is None or t < best.est_step_time:
            best = cand
    assert best is not None
    return best
