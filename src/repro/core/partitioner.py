"""Pipeline partitioners + the executable ParallelPlan (survey Table 4).

Given per-layer costs, split L layers into P contiguous stages:

* ``dynprog_partition`` — minimize the bottleneck stage time (the PipeDream /
  DAPPLE planner objective): classic minimax DP, optimal, O(L^2 P).
* ``heuristic_partition`` — Megatron-style equal-count split (the survey's
  "Heuristic" rows).
* ``dp_pp_search``     — joint (data, pipeline) degree search for a device
  budget (PipeDream's outer loop / Varuna's brute force): for each (dp, pp)
  with dp*pp == N, partition with the DP and score throughput under the
  1F1B bubble model from repro.core.pipeline; returns the argmax.
  ``uniform=True`` restricts to equal-count stages, the executable-runner
  constraint (SPMD stages share one program, so stage param blocks must be
  shape-uniform).

The planner output is no longer score-only: ``ParallelPlan`` is the object
the 3D trainer executes — (dp, tp, pp) degrees over the (data, model, pipe)
mesh, microbatch count, executable schedule, stage boundaries, and the
per-stage remat policy. ``auto_plan`` runs the search on a real device
count and returns a validated plan (``launch.train --plan auto``).

Costs can come from anywhere; ``layer_costs_from_config`` derives analytic
per-layer FLOP weights from an ArchConfig (MoE/dense/mixer aware), which is
what the benchmark uses.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class Partition:
    boundaries: Tuple[int, ...]   # stage s = layers [boundaries[s], boundaries[s+1])
    stage_costs: Tuple[float, ...]
    bottleneck: float

    @property
    def n_stages(self) -> int:
        return len(self.stage_costs)


def _stage_costs(costs: Sequence[float], bounds: Sequence[int]) -> List[float]:
    return [sum(costs[bounds[i] : bounds[i + 1]]) for i in range(len(bounds) - 1)]


def heuristic_partition(costs: Sequence[float], P: int) -> Partition:
    """Equal layer-count split (Megatron heuristic)."""
    L = len(costs)
    base, rem = divmod(L, P)
    bounds = [0]
    for s in range(P):
        bounds.append(bounds[-1] + base + (1 if s < rem else 0))
    sc = _stage_costs(costs, bounds)
    return Partition(tuple(bounds), tuple(sc), max(sc))


def dynprog_partition(costs: Sequence[float], P: int) -> Partition:
    """Minimax contiguous partition via DP (optimal bottleneck)."""
    L = len(costs)
    P = min(P, L)
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)

    def span(i: int, j: int) -> float:
        return prefix[j] - prefix[i]

    INF = float("inf")
    # dp[p][j] = min bottleneck for first j layers in p stages
    dp = [[INF] * (L + 1) for _ in range(P + 1)]
    cut = [[0] * (L + 1) for _ in range(P + 1)]
    dp[0][0] = 0.0
    for p in range(1, P + 1):
        for j in range(p, L + 1):
            for i in range(p - 1, j):
                cand = max(dp[p - 1][i], span(i, j))
                if cand < dp[p][j]:
                    dp[p][j] = cand
                    cut[p][j] = i
    bounds = [L]
    p, j = P, L
    while p > 0:
        i = cut[p][j]
        bounds.append(i)
        p, j = p - 1, i
    bounds.reverse()
    sc = _stage_costs(costs, bounds)
    return Partition(tuple(bounds), tuple(sc), max(sc))


def brute_force_partition(costs: Sequence[float], P: int) -> Partition:
    """Exponential reference for tests (L <= ~14)."""
    import itertools

    L = len(costs)
    best: Optional[Partition] = None
    for combo in itertools.combinations(range(1, L), P - 1):
        bounds = (0,) + combo + (L,)
        sc = _stage_costs(costs, bounds)
        cand = Partition(bounds, tuple(sc), max(sc))
        if best is None or cand.bottleneck < best.bottleneck:
            best = cand
    assert best is not None
    return best


def layer_costs_from_config(cfg: ArchConfig) -> List[float]:
    """Analytic per-layer FLOP weights (relative; embedding/head excluded)."""
    d, dff, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    out: List[float] = []
    for kind in cfg.mixer_kinds():
        if kind in ("attn", "local"):
            mix = 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + \
                2 * cfg.n_heads * hd * d
        elif kind == "mamba":
            di = cfg.d_inner
            mix = 2 * d * 2 * di + 2 * di * d + 2 * di * (di // 16 + 2 * cfg.ssm_state)
        else:  # rglru
            w = cfg.rglru_width or d
            mix = 2 * d * 2 * w + 2 * w * d
        if cfg.ffn_kind == "dense":
            ffn = (3 if cfg.mlp_gated else 2) * 2 * d * dff
        elif cfg.ffn_kind == "moe":
            ffn = cfg.experts_top_k * (3 if cfg.mlp_gated else 2) * 2 * d * dff
            ffn += cfg.n_shared_experts * 3 * 2 * d * dff
            if cfg.dense_residual:
                ffn += 3 * 2 * d * cfg.residual_d_ff
        else:
            ffn = 0
        out.append(float(mix + ffn))
    return out


@dataclasses.dataclass(frozen=True)
class DPPPChoice:
    dp: int
    pp: int
    partition: Partition
    est_step_time: float   # bottleneck * (M + P - 1) / dp  (1F1B fill model)


def dp_pp_search(
    costs: Sequence[float],
    n_devices: int,
    microbatches: int,
    *,
    uniform: bool = False,
    max_dp: Optional[int] = None,
) -> DPPPChoice:
    """Joint (dp, pp) degree search (PipeDream / Varuna outer loop).

    ``uniform=True`` restricts candidates to equal-layer-count stages
    (pp | L, heuristic split) — the executable runner's constraint.
    ``max_dp`` caps the data-parallel degree (Varuna's batch-size limit:
    dp beyond global_batch / microbatch_size replicates idle work); under
    the cap, extra devices go to the pipeline instead.
    """
    best: Optional[DPPPChoice] = None
    for pp in range(1, min(n_devices, len(costs)) + 1):
        if n_devices % pp:
            continue
        if uniform and len(costs) % pp:
            continue
        dp = n_devices // pp
        if max_dp is not None and dp > max_dp:
            continue
        part = (
            heuristic_partition(costs, pp) if uniform
            else dynprog_partition(costs, pp)
        )
        t = part.bottleneck * (microbatches + pp - 1) / (microbatches * dp)
        cand = DPPPChoice(dp, pp, part, t)
        if best is None or t < best.est_step_time:
            best = cand
    assert best is not None, "no feasible (dp, pp) split (max_dp too tight?)"
    return best


# --------------------------------------------------------- executable plans
@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """One plan object from planner to hardware (the 3D trainer executes it).

    dp/tp/pp are the (data, model, pipe) mesh degrees; ``microbatches`` and
    ``schedule`` drive the executable pipeline (repro.core.pipeline
    tick tables); ``boundaries`` are the contiguous stage cut points over
    layers (must be equal-count — SPMD stages share one compiled program);
    ``remat`` is the remat policy applied inside every stage's layer scan
    (the per-stage knob of the §2.1 plans — the runner itself already
    recomputes each stage forward from its stored input, so this controls
    the *within-stage* transient only); ``stash`` picks the activation-slot
    storage backend (core.stash: raw | int8 | fp8 | host) — the capacity
    knob that can make an otherwise-OOM plan feasible; ``stash_cot``
    additionally stores the pipeline's cotangent slots through the same
    codec (quantized backends only — the second capacity knob
    ``auto_plan`` prices against per-stage remat).
    """
    dp: int = 1
    tp: int = 1
    pp: int = 1
    microbatches: int = 1
    schedule: str = "1f1b"
    boundaries: Tuple[int, ...] = ()
    remat: str = "none"
    stash: str = "raw"
    stash_cot: bool = False

    @property
    def n_devices(self) -> int:
        return self.dp * self.tp * self.pp

    def stage_boundaries(self, n_layers: int) -> Tuple[int, ...]:
        if self.boundaries:
            return self.boundaries
        step = n_layers // self.pp
        return tuple(range(0, n_layers + 1, step))

    def validate(
        self,
        cfg: ArchConfig,
        *,
        global_batch: Optional[int] = None,
        seq_len: Optional[int] = None,
        act_budget: Optional[int] = None,
        itemsize: int = 2,
    ) -> "ParallelPlan":
        """Check executability against ``cfg``; returns self (chainable).

        With ``act_budget`` (device bytes available for pipeline activation
        state; requires ``global_batch``/``seq_len``), also checks that the
        stash fits — the capacity constraint a compressed or host stash can
        unlock for a plan that is infeasible at raw width.
        """
        from repro.core.pipeline import EXECUTABLE_SCHEDULES
        from repro.core.stash import normalize_stash
        from repro.models.stack import pipeline_incompatibility

        normalize_stash(self.stash)
        if self.schedule not in EXECUTABLE_SCHEDULES:
            raise ValueError(
                f"schedule {self.schedule!r} is simulator-only; executable: "
                f"{EXECUTABLE_SCHEDULES} (async rows need weight versioning "
                "that SPMD JAX does not express)"
            )
        if min(self.dp, self.tp, self.pp, self.microbatches) < 1:
            raise ValueError(f"degenerate plan {self}")
        if normalize_stash(self.stash) == "host" and (self.dp, self.tp) != (1, 1):
            raise ValueError(
                "stash='host' uses the host-driven runner (single device "
                f"per stage); got dp={self.dp} tp={self.tp}"
            )
        if self.stash_cot and normalize_stash(self.stash) not in ("int8", "fp8"):
            raise ValueError(
                f"stash_cot=True needs a quantized stash, got {self.stash!r}"
            )
        if cfg.n_layers % self.pp:
            raise ValueError(
                f"{cfg.n_layers} layers not divisible into pp={self.pp} stages"
            )
        b = self.stage_boundaries(cfg.n_layers)
        sizes = {b[i + 1] - b[i] for i in range(len(b) - 1)}
        if len(b) != self.pp + 1 or len(sizes) != 1:
            raise ValueError(f"non-uniform stage boundaries {b} for pp={self.pp}")
        why = pipeline_incompatibility(cfg, self.tp)
        if why is not None:
            raise ValueError(f"plan incompatible with {cfg.name}: {why}")
        if act_budget is not None:
            if global_batch is None or seq_len is None:
                raise ValueError("act_budget check needs global_batch and seq_len")
            rep = self.stash_report(
                cfg, global_batch=global_batch, seq_len=seq_len,
                itemsize=itemsize,
            )
            if rep["total_bytes"] > act_budget:
                raise ValueError(
                    f"activation state {rep['total_bytes']} B (slots "
                    f"{rep['act_bytes']} B + within-stage transient "
                    f"{rep['transient_bytes']} B) exceeds budget "
                    f"{act_budget} B at stash={rep['backend']} "
                    f"remat={self.remat} "
                    f"(raw slots would need {rep['raw_act_bytes']} B; "
                    f"capacity factor {rep['capacity_factor']:.2f}x)"
                )
        return self

    def stash_report(
        self,
        cfg: ArchConfig,
        *,
        global_batch: int,
        seq_len: int,
        itemsize: int = 2,
    ) -> dict:
        """Predicted per-device pipeline activation-state bytes under this
        plan's stash backend (roofline.analysis closed forms; the bench
        reconciles these against measured buffer sizes).

        ``act_bytes`` (alias ``device_bytes``) is the device-resident slot
        state; ``host_bytes`` the host-RAM high water a host stash spills;
        ``transient_bytes`` the within-stage backward transient the
        ``remat`` policy controls; ``total_bytes`` = device slots +
        transient is what ``validate(act_budget=...)`` gates on."""
        from repro.core.pipeline import tick_table
        from repro.core.stash import normalize_stash
        from repro.roofline.analysis import (
            predicted_pipeline_stash_bytes,
            predicted_stage_transient_bytes,
            predicted_stash_host_bytes,
            stash_bytes_per_slot,
        )

        s = normalize_stash(self.stash)
        cot_s = s if (self.stash_cot and s in ("int8", "fp8")) else "raw"
        table = tick_table(self.schedule, self.pp, self.microbatches)
        mb = global_batch // (self.dp * self.microbatches)
        n_elems = mb * seq_len * cfg.d_model // self.tp
        raw_slot = stash_bytes_per_slot(n_elems, "raw", itemsize)
        act = predicted_pipeline_stash_bytes(
            n_elems, table.n_act_slots, table.n_cot_slots, s, itemsize,
            cot_stash=cot_s,
        )
        raw = predicted_pipeline_stash_bytes(
            n_elems, table.n_act_slots, table.n_cot_slots, "raw", itemsize
        )
        host = predicted_stash_host_bytes(
            n_elems, table.n_act_slots, s, itemsize
        )
        transient = predicted_stage_transient_bytes(
            n_elems, cfg.n_layers // self.pp, self.remat, itemsize
        )
        return {
            "backend": s,
            "remat": self.remat,
            "stash_cot": cot_s != "raw",
            "n_act_slots": table.n_act_slots,
            "n_cot_slots": table.n_cot_slots,
            "bytes_per_slot": stash_bytes_per_slot(n_elems, s, itemsize),
            "raw_bytes_per_slot": raw_slot,
            "act_bytes": act,
            "device_bytes": act,
            "host_bytes": host,
            "transient_bytes": transient,
            "total_bytes": act + transient,
            "raw_act_bytes": raw,
            "capacity_factor": raw / max(act, 1),
        }

    def describe(self) -> str:
        return (
            f"dp={self.dp} tp={self.tp} pp={self.pp} "
            f"M={self.microbatches} schedule={self.schedule} "
            f"remat={self.remat} stash={self.stash}"
        )


def auto_plan(
    cfg: ArchConfig,
    n_devices: int,
    *,
    microbatches: int = 8,
    tp: int = 1,
    schedule: str = "1f1b",
    remat: str = "none",
    max_dp: Optional[int] = None,
    stash: str = "raw",
    act_budget: Optional[int] = None,
    global_batch: Optional[int] = None,
    seq_len: Optional[int] = None,
    itemsize: int = 2,
) -> ParallelPlan:
    """Search (dp, pp) for ``n_devices`` and return an executable plan.

    tp is fixed by the caller (head-divisibility is a model property, not a
    search dimension); the remaining budget goes through ``dp_pp_search``
    with the uniform-stage constraint. ``max_dp`` typically comes from the
    global batch: dp <= batch / microbatches.

    With ``act_budget`` the plan is stash-aware AND remat-aware: if the
    throughput-optimal split does not fit the activation budget at the
    requested ``stash``/``remat``, the search walks a (stash, remat)
    ladder — slot compression first (raw -> fp8; int8 stores the same
    bytes, so fp8 is the whole compressed rung, and the compressed rungs
    also compress cotangent slots via ``stash_cot``), then per-stage
    remat ("full" collapses the within-stage transient to one layer), then
    both. Compression is tried before remat because it costs ~1x step time
    (BENCH_train_stash) while full remat recomputes every stage layer.
    The returned plan's ``stash``/``stash_cot``/``remat`` fields report
    which rung unlocked it.
    """
    if n_devices % tp:
        raise ValueError(f"{n_devices} devices not divisible by tp={tp}")
    costs = layer_costs_from_config(cfg)
    choice = dp_pp_search(
        costs, n_devices // tp, microbatches, uniform=True, max_dp=max_dp
    )
    plan = ParallelPlan(
        dp=choice.dp, tp=tp, pp=choice.pp, microbatches=microbatches,
        schedule=schedule, boundaries=choice.partition.boundaries,
        remat=remat, stash=stash,
    )
    if act_budget is None:
        return plan.validate(cfg)
    from repro.core.stash import normalize_stash

    s0 = normalize_stash(stash)
    sq = s0 if s0 in ("int8", "fp8") else "fp8"   # the compressed rung
    ladder = [(s0, False, remat), (sq, True, remat)]
    if remat != "full":
        ladder += [(s0, False, "full"), (sq, True, "full")]
    ladder = list(dict.fromkeys(ladder))
    last_err: Optional[ValueError] = None
    for rung_stash, rung_cot, rung_remat in ladder:
        cand = dataclasses.replace(
            plan, stash=rung_stash, stash_cot=rung_cot, remat=rung_remat
        )
        try:
            return cand.validate(
                cfg, global_batch=global_batch, seq_len=seq_len,
                act_budget=act_budget, itemsize=itemsize,
            )
        except ValueError as e:
            last_err = e
    assert last_err is not None
    raise ValueError(
        f"no stash/remat rung fits act_budget={act_budget}: {last_err}"
    )
