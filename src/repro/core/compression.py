"""Gradient compression for data-parallel sync (survey §4.3).

Methods (one per literature class discussed in the survey):

* ``TopK``     — sparsification [Aji & Heafield 2017; Alistarh et al. 2019],
                 with error feedback (memory) [Stich et al. 2018].
* ``QSGD``     — quantization [Alistarh et al. 2017]: per-tensor norm +
                 s-level integer levels (deterministic rounding by default;
                 pass an rng key for the paper's stochastic rounding).
* ``SignEF``   — 1-bit sign compression with error feedback
                 [Stich et al. 2018; 1-bit Adam context, Tang et al. 2021].
* ``PowerSGD`` — low-rank [Vogels et al. 2019]: rank-r power iteration with
                 a reused Q, orthogonalized P, and error feedback.

``sync`` is the drop-in replacement for the data-parallel gradient mean:
called inside shard_map over the data axis it all-gathers *compressed*
payloads (TopK/QSGD/Sign) or psums the low-rank factors (PowerSGD), so the
bytes on the wire genuinely shrink — the HLO collective parser in
``repro.roofline`` sees the reduction (Table 1's comm column, measured).
With ``axis_name=None`` it runs loopback (compress->decompress, N=1) for
single-device tests and convergence ablations.

Only leaves with >= ``min_size`` elements are compressed (ndim >= 2 for
PowerSGD); the rest ride an ordinary psum — standard practice (biases and
norms are a rounding error of the traffic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat

MIN_SIZE = 1024


# ------------------------------------------------------------------ configs
@dataclasses.dataclass(frozen=True)
class TopK:
    ratio: float = 0.01
    name: str = "topk"


@dataclasses.dataclass(frozen=True)
class QSGD:
    bits: int = 8
    name: str = "qsgd"

    @property
    def levels(self) -> int:
        return 2 ** (self.bits - 1) - 1


@dataclasses.dataclass(frozen=True)
class SignEF:
    name: str = "sign"


@dataclasses.dataclass(frozen=True)
class PowerSGD:
    rank: int = 4
    name: str = "powersgd"


Method = Any  # TopK | QSGD | SignEF | PowerSGD | None


def _compressible(leaf: jax.Array, method: Method) -> bool:
    if method is None or leaf.size < MIN_SIZE:
        return False
    if isinstance(method, PowerSGD):
        return leaf.ndim >= 2
    return True


# -------------------------------------------------------------------- state
def init_state(method: Method, params: Any, key: Optional[jax.Array] = None) -> Any:
    """Error-feedback buffers (+ PowerSGD Q factors).

    State layout: a flat LIST aligned with ``tree_leaves(params)`` order
    (None for uncompressed leaves) — robust to None-vs-subtree pytree
    ambiguities and checkpointable as-is.
    """
    if method is None or isinstance(method, QSGD):
        return None
    key = key if key is not None else jax.random.PRNGKey(17)

    def leaf_state(i: int, p):
        if not _compressible(p, method):
            return None
        st = {"ef": jnp.zeros(p.shape, jnp.float32)}
        if isinstance(method, PowerSGD):
            m = p.reshape(p.shape[0], -1)
            k = jax.random.fold_in(key, i)
            st["q"] = jax.random.normal(
                k, (m.shape[1], min(method.rank, min(m.shape))), jnp.float32
            )
        return st

    flat = jax.tree_util.tree_leaves(params)
    return {"leaves": [leaf_state(i, p) for i, p in enumerate(flat)]}


# ------------------------------------------------------------ per-leaf sync
def _psum_mean(x: jax.Array, axis_name: Optional[str]) -> jax.Array:
    if axis_name is None:
        return x
    return jax.lax.pmean(x, axis_name)


def _topk_sync(method: TopK, g: jax.Array, ef, axis_name):
    flat = (g.astype(jnp.float32) + ef["ef"]).reshape(-1)
    k = max(1, int(method.ratio * flat.size))
    mag, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    ghat_local = jnp.zeros_like(flat).at[idx].set(vals)
    new_ef = flat - ghat_local
    if axis_name is None:
        mean = ghat_local
    else:
        n = compat.axis_size(axis_name)
        all_idx = jax.lax.all_gather(idx, axis_name)       # (N, k)
        all_val = jax.lax.all_gather(vals, axis_name)
        mean = (
            jnp.zeros_like(flat)
            .at[all_idx.reshape(-1)]
            .add(all_val.reshape(-1))
            / n
        )
    bytes_ = k * (4 + 4)
    return mean.reshape(g.shape), {"ef": new_ef.reshape(g.shape)}, bytes_


def _qsgd_sync(method: QSGD, g: jax.Array, _ef, axis_name, key=None):
    # max-norm scaling (the practical QSGD variant): the L2-norm scaling of
    # the original paper leaves O(1) levels per element at these tensor sizes
    flat = g.astype(jnp.float32).reshape(-1)
    norm = jnp.max(jnp.abs(flat)) + 1e-12
    s = method.levels
    scaled = jnp.abs(flat) / norm * s
    if key is not None:
        noise = jax.random.uniform(key, flat.shape)
        q = jnp.floor(scaled + noise)
    else:
        q = jnp.round(scaled)
    q = (jnp.sign(flat) * q).astype(jnp.int8)
    dequant = q.astype(jnp.float32) * (norm / s)
    if axis_name is None:
        mean = dequant
    else:
        mean = jnp.mean(
            jax.lax.all_gather(dequant, axis_name), axis=0
        )  # payload = int8 levels + scalar norm; gather modelled on dequant
    bytes_ = flat.size * method.bits // 8 + 4
    return mean.reshape(g.shape), None, bytes_


def _sign_sync(method: SignEF, g: jax.Array, ef, axis_name):
    flat = (g.astype(jnp.float32) + ef["ef"]).reshape(-1)
    scale = jnp.mean(jnp.abs(flat))
    comp = jnp.sign(flat) * scale
    new_ef = flat - comp
    mean = _psum_mean(comp, axis_name)
    bytes_ = flat.size // 8 + 4
    return mean.reshape(g.shape), {"ef": new_ef.reshape(g.shape)}, bytes_


def _orthonormalize(p: jax.Array) -> jax.Array:
    q, _ = jnp.linalg.qr(p)
    return q


def _powersgd_sync(method: PowerSGD, g: jax.Array, st, axis_name):
    m = (g.astype(jnp.float32) + st["ef"]).reshape(g.shape[0], -1)
    q = st["q"]                                           # (n, r)
    p = _psum_mean(m @ q, axis_name)                      # (m, r) averaged
    p = _orthonormalize(p)
    q_new = _psum_mean(m.T @ p, axis_name)                # (n, r) averaged
    ghat = p @ q_new.T
    new_ef = m - ghat                                     # local residual
    bytes_ = (p.size + q_new.size) * 4
    return (
        ghat.reshape(g.shape),
        {"ef": new_ef.reshape(g.shape), "q": q_new},
        bytes_,
    )


# ---------------------------------------------------------------- tree sync
def sync(
    method: Method,
    grads: Any,
    state: Any,
    axis_name: Optional[str] = None,
    key: Optional[jax.Array] = None,
) -> Tuple[Any, Any, jax.Array]:
    """Compressed data-parallel gradient mean over ``axis_name``.

    Returns (grad_means, new_state, payload_bytes_per_device). Must be
    called where ``axis_name`` is bound (inside shard_map/pmap) unless None.
    """
    total_bytes = 0.0
    flat, treedef = jax.tree_util.tree_flatten(grads)
    st_flat = state["leaves"] if state is not None else [None] * len(flat)
    assert len(st_flat) == len(flat)

    out_leaves, out_state = [], []
    for i, (g, st) in enumerate(zip(flat, st_flat)):
        if not _compressible(g, method):
            out_leaves.append(_psum_mean(g, axis_name))
            out_state.append(st)
            total_bytes += g.size * g.dtype.itemsize
            continue
        if isinstance(method, TopK):
            ghat, nst, b = _topk_sync(method, g, st, axis_name)
        elif isinstance(method, QSGD):
            kk = None if key is None else jax.random.fold_in(key, i)
            ghat, nst, b = _qsgd_sync(method, g, st, axis_name, kk)
        elif isinstance(method, SignEF):
            ghat, nst, b = _sign_sync(method, g, st, axis_name)
        elif isinstance(method, PowerSGD):
            ghat, nst, b = _powersgd_sync(method, g, st, axis_name)
        else:
            raise ValueError(method)
        out_leaves.append(ghat.astype(g.dtype))
        out_state.append(nst)
        total_bytes += b

    new_state = {"leaves": out_state} if state is not None else None
    return (
        jax.tree_util.tree_unflatten(treedef, out_leaves),
        new_state,
        jnp.asarray(total_bytes, jnp.float32),
    )


def wire_bytes_dense(grads: Any) -> float:
    """Baseline uncompressed all-reduce payload (for the benchmark tables)."""
    return float(
        sum(g.size * g.dtype.itemsize for g in jax.tree_util.tree_leaves(grads))
    )
