"""Pipeline parallelism: Table-4 schedules + executable shard_map runner.

Two halves:

1. **Schedule generators + event-driven simulator** (pure Python) covering
   the survey's Table 4 rows: GPipe, 1F1B (DAPPLE/Megatron), interleaved
   (Megatron-LM), PipeDream (async), PipeDream-2BW, Chimera (bidirectional),
   GEMS. The simulator respects fwd/bwd dependencies and device
   serialization and reports bubble fraction, peak in-flight activations per
   device, and weight versions — the quantities Table 4 compares. Async
   schedules also report weight staleness. Interleaved/Chimera use a greedy
   ready-op scheduler over virtual stages (documented approximation).

2. **Executable GPipe** on a ``pipe`` mesh axis: microbatch stream scanned
   over ticks, stage-to-stage transfer via ``ppermute``, stage params
   sharded P('pipe', ...). The backward pipeline comes from AD through the
   ppermutes (synchronous GPipe semantics). Correctness is tested against
   the equivalent sequential model (tests/test_pipeline.py).

TPU adaptation (DESIGN.md §3): asynchronous weight versioning (PipeDream)
does not exist in SPMD-synchronous JAX; async rows are simulator +
convergence-model only, and the executable path is the synchronous family
(GPipe now, 1F1B being a scheduling/memory variant of the same math).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# =====================================================================
# Part 1: schedules + simulator
# =====================================================================

F, B = "F", "B"


@dataclasses.dataclass(frozen=True)
class Op:
    stage: int           # virtual stage index in [0, P*v)
    mb: int
    kind: str            # F | B


@dataclasses.dataclass(frozen=True)
class SimResult:
    name: str
    makespan: float
    bubble_fraction: float
    peak_activations: int        # per device, in microbatch-activation units
    weight_versions: int
    synchronous: bool
    max_staleness: int           # in optimizer steps (async only)


def _device_of(vstage: int, P: int, placement: str, v: int) -> int:
    if placement == "interleaved":
        return vstage % P
    if placement == "bidirectional":      # chimera/gems: chunk0 = s, chunk1 = P-1-s
        chunk, s = divmod(vstage, P)
        return s if chunk == 0 else P - 1 - s
    return vstage  # plain: one stage per device


def _op_order(name: str, P: int, M: int, v: int) -> Tuple[List[List[Op]], str, int]:
    """Per-DEVICE preferred op order. Returns (orders, placement, n_vstages)."""
    if name == "gpipe":
        orders = [
            [Op(s, m, F) for m in range(M)] + [Op(s, m, B) for m in range(M)]
            for s in range(P)
        ]
        return orders, "plain", P
    if name in ("1f1b", "dapple", "pipedream", "pipedream_2bw", "varuna"):
        orders = []
        for s in range(P):
            warm = min(P - s, M)
            ops: List[Op] = [Op(s, m, F) for m in range(warm)]
            nf, nb = warm, 0
            while nb < M:
                ops.append(Op(s, nb, B))
                nb += 1
                if nf < M:
                    ops.append(Op(s, nf, F))
                    nf += 1
            orders.append(ops)
        return orders, "plain", P
    if name == "interleaved":
        # derive per-device orders from a virtual 1F1B execution on P*v
        # virtual devices (one per model chunk), then merge each real
        # device's chunk streams by virtual start time
        V = P * v
        times = _virtual_1f1b_times(V, M)
        orders = [[] for _ in range(P)]
        for d in range(P):
            ops = [
                (times[(vs, m, k)], Op(vs, m, k))
                for vs in range(d, V, P)
                for m in range(M)
                for k in (F, B)
            ]
            ops.sort(key=lambda x: (x[0], x[1].kind == F, x[1].stage))
            orders[d] = [o for _, o in ops]
        return orders, "interleaved", V
    if name in ("chimera", "gems"):
        # bidirectional: 2 virtual pipelines; each device hosts vstage s and
        # vstage P + (P-1-s). Chimera splits microbatches between directions.
        V = 2 * P
        half = M // 2 if name == "chimera" else M
        orders = [[] for _ in range(P)]
        for dev in range(P):
            up, down = dev, 2 * P - 1 - dev  # wait: see _device_of mapping
            down = P + (P - 1 - dev)
            ops: List[Op] = []
            mbs_up = range(0, half)
            mbs_down = range(half, M) if name == "chimera" else range(0)
            for m_u, m_d in zip(list(mbs_up) + [None] * M, list(mbs_down) + [None] * M):
                if m_u is not None:
                    ops.append(Op(up, m_u, F))
                if m_d is not None:
                    ops.append(Op(down, m_d, F))
            for m_u, m_d in zip(list(mbs_up) + [None] * M, list(mbs_down) + [None] * M):
                if m_u is not None:
                    ops.append(Op(up, m_u, B))
                if m_d is not None:
                    ops.append(Op(down, m_d, B))
            orders[dev] = [o for o in ops if o.mb is not None]
        return orders, "bidirectional", 2 * P
    raise ValueError(f"unknown schedule {name!r}")


def _virtual_1f1b_times(V: int, M: int, tf: float = 1.0, tb: float = 2.0):
    """Start time of every (vstage, mb, kind) under 1F1B with V devices."""
    orders, _, _ = _op_order("1f1b", V, M, 1)
    ready_f = np.full((V, M), np.inf)
    ready_b = np.full((V, M), np.inf)
    ready_f[0, :] = 0.0
    done_f = np.full((V, M), np.inf)
    dev_time = np.zeros(V)
    queues = [list(o) for o in orders]
    times: Dict[Tuple[int, int, str], float] = {}
    remaining = sum(len(q) for q in queues)
    while remaining:
        for d in range(V):
            if not queues[d]:
                continue
            for qi, op in enumerate(queues[d]):
                if op.kind == F:
                    t_in, dur = ready_f[op.stage, op.mb], tf
                else:
                    t_in = (
                        done_f[op.stage, op.mb]
                        if op.stage == V - 1
                        else max(done_f[op.stage, op.mb], ready_b[op.stage, op.mb])
                    )
                    dur = tb
                if not np.isfinite(t_in):
                    continue
                start = max(dev_time[d], t_in)
                end = start + dur
                dev_time[d] = end
                times[(op.stage, op.mb, op.kind)] = start
                if op.kind == F:
                    done_f[op.stage, op.mb] = end
                    if op.stage + 1 < V:
                        ready_f[op.stage + 1, op.mb] = end
                    else:
                        ready_b[op.stage, op.mb] = end
                else:
                    if op.stage > 0:
                        ready_b[op.stage - 1, op.mb] = end
                queues[d].pop(qi)
                remaining -= 1
                break
    return times


def simulate(
    name: str,
    P: int,
    M: int,
    *,
    v: int = 2,
    t_fwd: float = 1.0,
    t_bwd: float = 2.0,
    t_comm: float = 0.0,
) -> SimResult:
    """Event-driven simulation of a pipeline schedule."""
    asynchronous = name in ("pipedream", "pipedream_2bw", "varuna")
    orders, placement, V = _op_order(name, P, M, v)
    chunks = V // P if placement != "plain" else 1
    if placement == "interleaved":
        # v chunks per device, each 1/v of the model: per-op time scales down
        t_fwd, t_bwd = t_fwd / chunks, t_bwd / chunks
    if placement == "bidirectional":
        # two half-depth pipelines: each vstage is half the per-device model
        t_fwd, t_bwd = t_fwd / 2, t_bwd / 2

    ready_f = np.full((V, M), np.inf)  # time input available
    ready_b = np.full((V, M), np.inf)
    for m in range(M):
        ready_f[0, m] = 0.0
        if placement == "bidirectional":
            ready_f[P, m] = 0.0        # reverse pipeline entry
    done_f = np.full((V, M), np.inf)
    done_b = np.full((V, M), np.inf)

    dev_time = np.zeros(P)
    queues = [list(o) for o in orders]
    executed = [[] for _ in range(P)]  # (start, end, op)

    total_ops = sum(len(q) for q in queues)
    n_exec = 0
    stall_guard = 0
    while n_exec < total_ops:
        progressed = False
        for d in range(P):
            if not queues[d]:
                continue
            # execute the first READY op in the device's preferred order
            # (greedy relaxation — exact for gpipe/1f1b whose orders are
            # dependency-consistent; documented approximation otherwise)
            pick = None
            for qi, op in enumerate(queues[d]):
                if op.kind == F:
                    t_in = ready_f[op.stage, op.mb]
                    dur = t_fwd
                else:
                    t_in = (
                        done_f[op.stage, op.mb]
                        if _is_last(op.stage, V, placement, P)
                        else max(done_f[op.stage, op.mb], ready_b[op.stage, op.mb])
                    )
                    dur = t_bwd
                if np.isfinite(t_in):
                    pick = (qi, op, t_in, dur)
                    break
            if pick is None:
                continue
            qi, op, t_in, dur = pick
            start = max(dev_time[d], t_in)
            end = start + dur
            dev_time[d] = end
            executed[d].append((start, end, op))
            if op.kind == F:
                done_f[op.stage, op.mb] = end
                nxt = _next_stage(op.stage, V, placement, P)
                if nxt is not None:
                    ready_f[nxt, op.mb] = end + t_comm
                else:
                    ready_b[op.stage, op.mb] = end  # loss -> own bwd
            else:
                done_b[op.stage, op.mb] = end
                prv = _prev_stage(op.stage, V, placement, P)
                if prv is not None:
                    ready_b[prv, op.mb] = end + t_comm
            queues[d].pop(qi)
            n_exec += 1
            progressed = True
        if not progressed:
            stall_guard += 1
            if stall_guard > total_ops * 4:
                raise RuntimeError(f"schedule {name} deadlocked")
        else:
            stall_guard = 0

    makespan = float(dev_time.max())
    work = M * (t_fwd + t_bwd) * chunks
    if placement == "bidirectional" and name == "chimera":
        work = M * (t_fwd + t_bwd)  # each direction carries M/2 microbatches
    bubble = 1.0 - work / makespan if makespan > 0 else 0.0

    # peak in-flight activations per device: fwd done, bwd not yet done
    peak = 0
    for d in range(P):
        events = []
        for (s0, e0, op) in executed[d]:
            if op.kind == F:
                events.append((e0, +1))
            else:
                events.append((e0, -1))
        cur = 0
        for _, delta in sorted(events):
            cur += delta
            peak = max(peak, cur)

    versions = {"pipedream": P, "pipedream_2bw": 2}.get(name, 1)
    staleness = {"pipedream": P - 1, "pipedream_2bw": 1}.get(name, 0)
    return SimResult(
        name=name,
        makespan=makespan,
        bubble_fraction=max(bubble, 0.0),
        peak_activations=peak,
        weight_versions=versions,
        synchronous=not asynchronous,
        max_staleness=staleness,
    )


def _is_last(vs: int, V: int, placement: str, P: int) -> bool:
    if placement == "bidirectional":
        return vs == P - 1 or vs == 2 * P - 1
    return vs == V - 1


def _next_stage(vs: int, V: int, placement: str, P: int) -> Optional[int]:
    if placement == "bidirectional":
        if vs == P - 1 or vs == 2 * P - 1:
            return None
        return vs + 1
    return vs + 1 if vs + 1 < V else None


def _prev_stage(vs: int, V: int, placement: str, P: int) -> Optional[int]:
    if placement == "bidirectional":
        if vs == 0 or vs == P:
            return None
        return vs - 1
    return vs - 1 if vs > 0 else None


SCHEDULES = (
    "gpipe", "1f1b", "interleaved", "pipedream", "pipedream_2bw",
    "chimera", "gems",
)


# =====================================================================
# Part 2: executable GPipe on a mesh axis
# =====================================================================
def pipeline_apply(
    stage_fn: Callable,
    stage_params: Any,
    microbatches: Any,
    *,
    mesh,
    axis: str = "pipe",
):
    """Run ``stage_fn`` as a GPipe pipeline over mesh axis ``axis``.

    stage_params: pytree with leading dim P (sharded over ``axis``).
    microbatches: pytree with leading dim M (replicated).
    stage_fn(params_for_stage, x) -> y, with y.shape == x.shape.

    Returns outputs with leading dim M (replicated over ``axis``). Backward
    through this function is the AD-reversed pipeline (GPipe semantics).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as Pspec

    from repro.compat import shard_map

    P_count = mesh.shape[axis]
    x0 = jax.tree.map(lambda m: m[0], microbatches)
    M = jax.tree.leaves(microbatches)[0].shape[0]
    T = M + P_count - 1

    def inner(params, mbs):
        params = jax.tree.map(lambda p: p[0], params)  # local stage params
        stage = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % P_count) for i in range(P_count)]

        def tick(carry, t):
            state = carry
            inject = jax.tree.map(
                lambda m: m[jnp.minimum(t, M - 1)], mbs
            )
            xin = jax.tree.map(
                lambda s, i: jnp.where(stage == 0, i, s), state, inject
            )
            out = stage_fn(params, xin)
            contrib = jax.tree.map(
                lambda o: jnp.where(stage == P_count - 1, o, 0.0), out
            )
            emitted = jax.tree.map(lambda c: jax.lax.psum(c, axis), contrib)
            nxt = jax.tree.map(
                lambda o: jax.lax.ppermute(o, axis, perm), out
            )
            return nxt, emitted

        zeros = jax.tree.map(jnp.zeros_like, x0)
        _, ys = jax.lax.scan(tick, zeros, jnp.arange(T))
        # output for microbatch m emerges at tick m + P - 1
        return jax.tree.map(lambda y: y[P_count - 1 :], ys)

    pspec = jax.tree.map(lambda _: Pspec(axis), stage_params)
    mspec = jax.tree.map(lambda _: Pspec(), microbatches)
    ospec = jax.tree.map(lambda _: Pspec(), microbatches)
    fn = shard_map(
        inner, mesh=mesh, in_specs=(pspec, mspec), out_specs=ospec,
        check_vma=False,
    )
    return fn(stage_params, microbatches)
