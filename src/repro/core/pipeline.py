"""Pipeline parallelism: Table-4 schedules + executable 1F1B/GPipe runners.

Three parts:

1. **Schedule generators + event-driven simulator** (pure Python) covering
   the survey's Table 4 rows: GPipe, 1F1B (DAPPLE/Megatron), interleaved
   (Megatron-LM), PipeDream (async), PipeDream-2BW, Chimera (bidirectional),
   GEMS. The simulator respects fwd/bwd dependencies and device
   serialization and reports bubble fraction, peak in-flight activations per
   device, and weight versions — the quantities Table 4 compares. Async
   schedules also report weight staleness. Interleaved/Chimera use a greedy
   ready-op scheduler over virtual stages (documented approximation).

2. **Executable GPipe via AD** (``pipeline_apply``) on a ``pipe`` mesh axis:
   microbatch stream scanned over ticks, stage transfer via ``ppermute``,
   backward from AD through the ppermutes. Simple, but AD stores every
   microbatch's activations — O(M) live memory per device.

3. **Executable manual-backward runner** (``tick_table`` +
   ``pipeline_grads``): the same event-driven simulator, run at unit op
   cost, is compiled into integer *tick tables* — per (tick, stage): which
   microbatch to forward/backward, which activation slot to read/write, and
   where arriving ppermute traffic lands. The runner streams those tables
   through one ``lax.scan`` inside a fully-manual ``shard_map`` over a
   (data, model, pipe) mesh and computes the backward itself (``jax.vjp``
   per microbatch inside the schedule, gradients accumulated as-you-go), so
   live activations are exactly the schedule's slot count: O(P) for 1F1B vs
   O(M) for GPipe at identical math. Backward recomputes each stage forward
   from its stored stage *input* — per-stage rematerialization (Chen'16,
   1604.06174) composed with the schedule by construction. GPipe and 1F1B
   run the identical per-microbatch code in the identical per-stage
   accumulation order, so their gradients are bitwise equal — asserted in
   tests/benchmarks.

TPU adaptation (DESIGN.md §3): asynchronous weight versioning (PipeDream)
does not exist in SPMD-synchronous JAX; async rows are simulator +
convergence-model only. The executable family is synchronous: GPipe and
1F1B, which share the same math and differ only in op order and peak
memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# =====================================================================
# Part 1: schedules + simulator
# =====================================================================

F, B = "F", "B"


@dataclasses.dataclass(frozen=True)
class Op:
    stage: int           # virtual stage index in [0, P*v)
    mb: int
    kind: str            # F | B


@dataclasses.dataclass(frozen=True)
class SimResult:
    name: str
    makespan: float
    bubble_fraction: float
    peak_activations: int        # per device, in microbatch-activation units
    weight_versions: int
    synchronous: bool
    max_staleness: int           # in optimizer steps (async only)


def _device_of(vstage: int, P: int, placement: str, v: int) -> int:
    if placement == "interleaved":
        return vstage % P
    if placement == "bidirectional":      # chimera/gems: chunk0 = s, chunk1 = P-1-s
        chunk, s = divmod(vstage, P)
        return s if chunk == 0 else P - 1 - s
    return vstage  # plain: one stage per device


def _op_order(name: str, P: int, M: int, v: int) -> Tuple[List[List[Op]], str, int]:
    """Per-DEVICE preferred op order. Returns (orders, placement, n_vstages)."""
    if name == "gpipe":
        orders = [
            [Op(s, m, F) for m in range(M)] + [Op(s, m, B) for m in range(M)]
            for s in range(P)
        ]
        return orders, "plain", P
    if name in ("1f1b", "dapple", "pipedream", "pipedream_2bw", "varuna"):
        orders = []
        for s in range(P):
            warm = min(P - s, M)
            ops: List[Op] = [Op(s, m, F) for m in range(warm)]
            nf, nb = warm, 0
            while nb < M:
                ops.append(Op(s, nb, B))
                nb += 1
                if nf < M:
                    ops.append(Op(s, nf, F))
                    nf += 1
            orders.append(ops)
        return orders, "plain", P
    if name == "interleaved":
        # derive per-device orders from a virtual 1F1B execution on P*v
        # virtual devices (one per model chunk), then merge each real
        # device's chunk streams by virtual start time
        V = P * v
        times = _virtual_1f1b_times(V, M)
        orders = [[] for _ in range(P)]
        for d in range(P):
            ops = [
                (times[(vs, m, k)], Op(vs, m, k))
                for vs in range(d, V, P)
                for m in range(M)
                for k in (F, B)
            ]
            ops.sort(key=lambda x: (x[0], x[1].kind == F, x[1].stage))
            orders[d] = [o for _, o in ops]
        return orders, "interleaved", V
    if name in ("chimera", "gems"):
        # bidirectional: 2 virtual pipelines; each device hosts vstage s and
        # vstage P + (P-1-s). Chimera splits microbatches between directions.
        V = 2 * P
        half = M // 2 if name == "chimera" else M
        orders = [[] for _ in range(P)]
        for dev in range(P):
            up, down = dev, 2 * P - 1 - dev  # wait: see _device_of mapping
            down = P + (P - 1 - dev)
            ops: List[Op] = []
            mbs_up = range(0, half)
            mbs_down = range(half, M) if name == "chimera" else range(0)
            for m_u, m_d in zip(list(mbs_up) + [None] * M, list(mbs_down) + [None] * M):
                if m_u is not None:
                    ops.append(Op(up, m_u, F))
                if m_d is not None:
                    ops.append(Op(down, m_d, F))
            for m_u, m_d in zip(list(mbs_up) + [None] * M, list(mbs_down) + [None] * M):
                if m_u is not None:
                    ops.append(Op(up, m_u, B))
                if m_d is not None:
                    ops.append(Op(down, m_d, B))
            orders[dev] = [o for o in ops if o.mb is not None]
        return orders, "bidirectional", 2 * P
    raise ValueError(f"unknown schedule {name!r}")


def _virtual_1f1b_times(V: int, M: int, tf: float = 1.0, tb: float = 2.0):
    """Start time of every (vstage, mb, kind) under 1F1B with V devices."""
    orders, _, _ = _op_order("1f1b", V, M, 1)
    ready_f = np.full((V, M), np.inf)
    ready_b = np.full((V, M), np.inf)
    ready_f[0, :] = 0.0
    done_f = np.full((V, M), np.inf)
    dev_time = np.zeros(V)
    queues = [list(o) for o in orders]
    times: Dict[Tuple[int, int, str], float] = {}
    remaining = sum(len(q) for q in queues)
    while remaining:
        for d in range(V):
            if not queues[d]:
                continue
            for qi, op in enumerate(queues[d]):
                if op.kind == F:
                    t_in, dur = ready_f[op.stage, op.mb], tf
                else:
                    t_in = (
                        done_f[op.stage, op.mb]
                        if op.stage == V - 1
                        else max(done_f[op.stage, op.mb], ready_b[op.stage, op.mb])
                    )
                    dur = tb
                if not np.isfinite(t_in):
                    continue
                start = max(dev_time[d], t_in)
                end = start + dur
                dev_time[d] = end
                times[(op.stage, op.mb, op.kind)] = start
                if op.kind == F:
                    done_f[op.stage, op.mb] = end
                    if op.stage + 1 < V:
                        ready_f[op.stage + 1, op.mb] = end
                    else:
                        ready_b[op.stage, op.mb] = end
                else:
                    if op.stage > 0:
                        ready_b[op.stage - 1, op.mb] = end
                queues[d].pop(qi)
                remaining -= 1
                break
    return times


def _execute_schedule(
    name: str,
    P: int,
    M: int,
    *,
    v: int = 2,
    t_fwd: float = 1.0,
    t_bwd: float = 2.0,
    t_comm: float = 0.0,
):
    """Run the event-driven scheduler; returns (executed, dev_time, placement,
    V, chunks, t_fwd, t_bwd) where ``executed[d]`` is the per-device list of
    (start, end, Op). Shared engine behind ``simulate`` and ``tick_table``."""
    orders, placement, V = _op_order(name, P, M, v)
    chunks = V // P if placement != "plain" else 1
    if placement == "interleaved":
        # v chunks per device, each 1/v of the model: per-op time scales down
        t_fwd, t_bwd = t_fwd / chunks, t_bwd / chunks
    if placement == "bidirectional":
        # two half-depth pipelines: each vstage is half the per-device model
        t_fwd, t_bwd = t_fwd / 2, t_bwd / 2

    ready_f = np.full((V, M), np.inf)  # time input available
    ready_b = np.full((V, M), np.inf)
    for m in range(M):
        ready_f[0, m] = 0.0
        if placement == "bidirectional":
            ready_f[P, m] = 0.0        # reverse pipeline entry
    done_f = np.full((V, M), np.inf)
    done_b = np.full((V, M), np.inf)

    dev_time = np.zeros(P)
    queues = [list(o) for o in orders]
    executed = [[] for _ in range(P)]  # (start, end, op)

    total_ops = sum(len(q) for q in queues)
    n_exec = 0
    stall_guard = 0
    while n_exec < total_ops:
        progressed = False
        for d in range(P):
            if not queues[d]:
                continue
            # execute the first READY op in the device's preferred order
            # (greedy relaxation — exact for gpipe/1f1b whose orders are
            # dependency-consistent; documented approximation otherwise)
            pick = None
            for qi, op in enumerate(queues[d]):
                if op.kind == F:
                    t_in = ready_f[op.stage, op.mb]
                    dur = t_fwd
                else:
                    t_in = (
                        done_f[op.stage, op.mb]
                        if _is_last(op.stage, V, placement, P)
                        else max(done_f[op.stage, op.mb], ready_b[op.stage, op.mb])
                    )
                    dur = t_bwd
                if np.isfinite(t_in):
                    pick = (qi, op, t_in, dur)
                    break
            if pick is None:
                continue
            qi, op, t_in, dur = pick
            start = max(dev_time[d], t_in)
            end = start + dur
            dev_time[d] = end
            executed[d].append((start, end, op))
            if op.kind == F:
                done_f[op.stage, op.mb] = end
                nxt = _next_stage(op.stage, V, placement, P)
                if nxt is not None:
                    ready_f[nxt, op.mb] = end + t_comm
                else:
                    ready_b[op.stage, op.mb] = end  # loss -> own bwd
            else:
                done_b[op.stage, op.mb] = end
                prv = _prev_stage(op.stage, V, placement, P)
                if prv is not None:
                    ready_b[prv, op.mb] = end + t_comm
            queues[d].pop(qi)
            n_exec += 1
            progressed = True
        if not progressed:
            stall_guard += 1
            if stall_guard > total_ops * 4:
                raise RuntimeError(f"schedule {name} deadlocked")
        else:
            stall_guard = 0

    return executed, dev_time, placement, V, chunks, t_fwd, t_bwd


def simulate(
    name: str,
    P: int,
    M: int,
    *,
    v: int = 2,
    t_fwd: float = 1.0,
    t_bwd: float = 2.0,
    t_comm: float = 0.0,
) -> SimResult:
    """Event-driven simulation of a pipeline schedule."""
    asynchronous = name in ("pipedream", "pipedream_2bw", "varuna")
    executed, dev_time, placement, V, chunks, t_fwd, t_bwd = _execute_schedule(
        name, P, M, v=v, t_fwd=t_fwd, t_bwd=t_bwd, t_comm=t_comm
    )

    makespan = float(dev_time.max())
    work = M * (t_fwd + t_bwd) * chunks
    if placement == "bidirectional" and name == "chimera":
        work = M * (t_fwd + t_bwd)  # each direction carries M/2 microbatches
    bubble = 1.0 - work / makespan if makespan > 0 else 0.0

    # peak in-flight activations per device: fwd done, bwd not yet done
    peak = 0
    for d in range(P):
        events = []
        for (s0, e0, op) in executed[d]:
            if op.kind == F:
                events.append((e0, +1))
            else:
                events.append((e0, -1))
        cur = 0
        for _, delta in sorted(events):
            cur += delta
            peak = max(peak, cur)

    versions = {"pipedream": P, "pipedream_2bw": 2}.get(name, 1)
    staleness = {"pipedream": P - 1, "pipedream_2bw": 1}.get(name, 0)
    return SimResult(
        name=name,
        makespan=makespan,
        bubble_fraction=max(bubble, 0.0),
        peak_activations=peak,
        weight_versions=versions,
        synchronous=not asynchronous,
        max_staleness=staleness,
    )


def _is_last(vs: int, V: int, placement: str, P: int) -> bool:
    if placement == "bidirectional":
        return vs == P - 1 or vs == 2 * P - 1
    return vs == V - 1


def _next_stage(vs: int, V: int, placement: str, P: int) -> Optional[int]:
    if placement == "bidirectional":
        if vs == P - 1 or vs == 2 * P - 1:
            return None
        return vs + 1
    return vs + 1 if vs + 1 < V else None


def _prev_stage(vs: int, V: int, placement: str, P: int) -> Optional[int]:
    if placement == "bidirectional":
        if vs == 0 or vs == P:
            return None
        return vs - 1
    return vs - 1 if vs > 0 else None


SCHEDULES = (
    "gpipe", "1f1b", "interleaved", "pipedream", "pipedream_2bw",
    "chimera", "gems",
)


# =====================================================================
# Part 2: executable GPipe on a mesh axis
# =====================================================================
def pipeline_apply(
    stage_fn: Callable,
    stage_params: Any,
    microbatches: Any,
    *,
    mesh,
    axis: str = "pipe",
):
    """Run ``stage_fn`` as a GPipe pipeline over mesh axis ``axis``.

    stage_params: pytree with leading dim P (sharded over ``axis``).
    microbatches: pytree with leading dim M (replicated).
    stage_fn(params_for_stage, x) -> y, with y.shape == x.shape.

    Returns outputs with leading dim M (replicated over ``axis``). Backward
    through this function is the AD-reversed pipeline (GPipe semantics).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as Pspec

    from repro.compat import shard_map

    P_count = mesh.shape[axis]
    x0 = jax.tree.map(lambda m: m[0], microbatches)
    M = jax.tree.leaves(microbatches)[0].shape[0]
    T = M + P_count - 1

    def inner(params, mbs):
        params = jax.tree.map(lambda p: p[0], params)  # local stage params
        stage = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % P_count) for i in range(P_count)]

        def tick(carry, t):
            state = carry
            inject = jax.tree.map(
                lambda m: m[jnp.minimum(t, M - 1)], mbs
            )
            xin = jax.tree.map(
                lambda s, i: jnp.where(stage == 0, i, s), state, inject
            )
            out = stage_fn(params, xin)
            contrib = jax.tree.map(
                lambda o: jnp.where(stage == P_count - 1, o, 0.0), out
            )
            emitted = jax.tree.map(lambda c: jax.lax.psum(c, axis), contrib)
            nxt = jax.tree.map(
                lambda o: jax.lax.ppermute(o, axis, perm), out
            )
            return nxt, emitted

        zeros = jax.tree.map(jnp.zeros_like, x0)
        _, ys = jax.lax.scan(tick, zeros, jnp.arange(T))
        # output for microbatch m emerges at tick m + P - 1
        return jax.tree.map(lambda y: y[P_count - 1 :], ys)

    pspec = jax.tree.map(lambda _: Pspec(axis), stage_params)
    mspec = jax.tree.map(lambda _: Pspec(), microbatches)
    ospec = jax.tree.map(lambda _: Pspec(), microbatches)
    fn = shard_map(
        inner, mesh=mesh, in_specs=(pspec, mspec), out_specs=ospec,
        check_vma=False,
    )
    return fn(stage_params, microbatches)


# =====================================================================
# Part 3: tick tables + manual-backward runner (1F1B and GPipe)
# =====================================================================
EXECUTABLE_SCHEDULES = ("gpipe", "1f1b")


@dataclasses.dataclass(frozen=True)
class TickTable:
    """Integer-tick execution tables compiled from the event simulator.

    The simulator is run at unit op cost (t_fwd = t_bwd = 1, t_comm = 0), so
    op start times are a global integer tick clock on which every device
    executes at most one op per tick and a value ppermuted at the end of
    tick ``t`` is available at tick ``t + 1``. Tables are (n_ticks, P)
    int32, entry -1 = nothing this tick:

      f_mb / b_mb    microbatch to forward / backward
      f_slot         activation-buffer slot holding (or to hold) the stage
                     INPUT of that microbatch
      b_slot         activation slot to read for the backward (same slot
                     its forward stored; freed afterwards)
      b_cot          cotangent slot carrying the arriving upstream gradient
                     (-1 on the last stage, which seeds from the loss)
      arr_f / arr_b  slot into which this tick's arriving ppermute traffic
                     (activation / cotangent) must be stored

    ``n_act_slots`` is the greedy-allocated per-device activation buffer
    depth — the executable form of Table 4's "peak in-flight activations":
    O(M) for GPipe, O(P) for 1F1B. ``bubble_fraction`` is exact for the
    executable schedule (each device computes 2M of n_ticks op slots) and
    must agree with ``simulate(name, P, M, t_fwd=1, t_bwd=1)`` — the bench
    asserts this simulator-vs-executable accounting row.
    """
    schedule: str
    n_stages: int
    n_microbatches: int
    n_ticks: int
    n_act_slots: int
    n_cot_slots: int
    f_mb: np.ndarray
    f_slot: np.ndarray
    b_mb: np.ndarray
    b_slot: np.ndarray
    b_cot: np.ndarray
    arr_f: np.ndarray
    arr_b: np.ndarray

    @property
    def bubble_fraction(self) -> float:
        return 1.0 - 2.0 * self.n_microbatches / self.n_ticks

    def peak_activation_bytes(self, act_bytes: int) -> int:
        """Live pipeline-state bytes per device for one microbatch size."""
        return (self.n_act_slots + self.n_cot_slots) * act_bytes


def _alloc_slots(avail: Dict, last_use: Dict) -> Tuple[Dict, int]:
    """Greedy per-stage interval slot allocation: a slot is live from its
    value's arrival tick through its last-use tick (inclusive; arrivals at a
    tick are stored before that tick's op reads, so reuse needs end < start)."""
    slots: Dict = {}
    depth = 0
    by_stage: Dict[int, List] = {}
    for key in avail:
        by_stage.setdefault(key[0], []).append(key)
    for s, keys in by_stage.items():
        keys.sort(key=lambda k: (avail[k], k[1]))
        busy: List[Tuple[int, int]] = []   # (last_use, slot)
        free: List[int] = []
        used = 0
        for k in keys:
            t0 = avail[k]
            free += [sl for end, sl in busy if end < t0]
            busy = [(end, sl) for end, sl in busy if end >= t0]
            free.sort()
            if free:
                sl = free.pop(0)
            else:
                sl = used
                used += 1
            slots[k] = sl
            busy.append((last_use[k], sl))
        depth = max(depth, used)
    return slots, depth


def tick_table(schedule: str, P: int, M: int) -> TickTable:
    """Compile ``schedule`` into integer tick tables (see TickTable)."""
    if schedule not in EXECUTABLE_SCHEDULES:
        raise ValueError(
            f"executable schedules are {EXECUTABLE_SCHEDULES}, got {schedule!r}"
        )
    executed, _, _, _, _, _, _ = _execute_schedule(
        schedule, P, M, v=1, t_fwd=1.0, t_bwd=1.0, t_comm=0.0
    )
    f_tick: Dict[Tuple[int, int], int] = {}
    b_tick: Dict[Tuple[int, int], int] = {}
    for evs in executed:
        for (s0, e0, op) in evs:
            t = int(round(s0))
            assert abs(s0 - t) < 1e-9 and abs(e0 - t - 1) < 1e-9, (s0, e0, op)
            (f_tick if op.kind == F else b_tick)[(op.stage, op.mb)] = t
    T = 1 + max(b_tick.values())

    # availability: when the stage input / upstream cotangent lands locally
    avail_f = {
        (s, m): (t if s == 0 else f_tick[(s - 1, m)] + 1)
        for (s, m), t in f_tick.items()
    }
    avail_b = {
        (s, m): b_tick[(s + 1, m)] + 1 for (s, m) in b_tick if s < P - 1
    }
    for k, t in f_tick.items():
        assert avail_f[k] <= t, ("fwd before input available", k)
        if k in avail_b:
            assert avail_b[k] <= b_tick[k], ("bwd before cotangent", k)

    act_slot, n_act = _alloc_slots(avail_f, b_tick)
    cot_slot, n_cot = _alloc_slots(avail_b, {k: b_tick[k] for k in avail_b})
    n_cot = max(n_cot, 1)

    tables = {
        name: np.full((T, P), -1, np.int32)
        for name in ("f_mb", "f_slot", "b_mb", "b_slot", "b_cot",
                     "arr_f", "arr_b")
    }
    for (s, m), t in f_tick.items():
        tables["f_mb"][t, s] = m
        tables["f_slot"][t, s] = act_slot[(s, m)]
        if s > 0:
            ta = avail_f[(s, m)]
            assert tables["arr_f"][ta, s] == -1, "two fwd arrivals in one tick"
            tables["arr_f"][ta, s] = act_slot[(s, m)]
    for (s, m), t in b_tick.items():
        tables["b_mb"][t, s] = m
        tables["b_slot"][t, s] = act_slot[(s, m)]
        if s < P - 1:
            tables["b_cot"][t, s] = cot_slot[(s, m)]
            ta = avail_b[(s, m)]
            assert tables["arr_b"][ta, s] == -1, "two bwd arrivals in one tick"
            tables["arr_b"][ta, s] = cot_slot[(s, m)]
    return TickTable(
        schedule=schedule, n_stages=P, n_microbatches=M, n_ticks=T,
        n_act_slots=n_act, n_cot_slots=n_cot, **tables,
    )


def pipeline_grads(
    first_fn: Callable,
    stage_fn: Callable,
    last_fn: Callable,
    stage_params: Any,
    shared_params: Any,
    microbatches: Any,
    *,
    mesh,
    table: TickTable,
    x_struct,
    metrics_struct: Any,
    stage_specs: Any,
    mb_specs: Any,
    seed=None,
    axis: str = "pipe",
    data_axis: Optional[str] = None,
    stash=None,
):
    """Run one pipelined forward+backward; returns (loss, metrics, grads).

    The schedule in ``table`` is executed tick-by-tick inside a fully-manual
    ``shard_map`` over ``mesh``; the backward is computed by this runner
    (``jax.vjp`` per microbatch, recomputing the stage forward from the
    stored stage input — per-stage remat by construction), NOT by AD through
    the scan, so live state is exactly the table's slot buffers.

    Callables (all executed per device, per microbatch):
      first_fn(shared, mb)    -> x            stage-0 input (e.g. embedding)
      stage_fn(stage_p, x)    -> (y, aux)     this stage's layers; ``aux`` is
                                              a scalar loss term (router aux)
                                              seeded on EVERY stage
      last_fn(shared, y, mb)  -> (loss, metrics)  head + loss on stage P-1

    ``stage_params`` is the canonical stacked-layer tree whose leading layer
    axis is sharded over ``axis`` per ``stage_specs`` (each device sees its
    stage's layer slice); ``shared_params`` (embedding/head/final norm) are
    replicated over ``axis`` — their grads are psum'd over it, which also
    resolves tied embeddings used at both ends. ``microbatches`` leaves are
    (M, B, ...) with specs ``mb_specs`` (batch dim over ``data_axis``).
    ``x_struct`` is the per-device inter-stage activation
    ShapeDtypeStruct; ``seed`` the loss cotangent (loss scaling /
    microbatch normalization — caller bakes in 1/(M*dp)).

    Mesh-collective safety: the per-tick op branches contain collectives
    over the ``model`` axis only (manual tensor parallelism inside
    ``stage_fn``). All devices sharing a pipe coordinate run the SAME branch
    every tick (tables depend only on (tick, stage)), so model-axis groups
    never diverge across a collective. ``ppermute`` transfers sit outside
    the branches and run every tick.

    Returns (loss_sum, metrics_sums, stage_grads, shared_grads) as global
    arrays: loss/metrics are summed over microbatches and data shards
    (caller normalizes by M*dp); grads are psum'd over ``data_axis`` (and
    ``axis`` for shared) but NOT over model — model-sharded leaves carry
    distinct shards, replicated leaves identical values.

    ``stash`` is the activation-slot storage backend (core.stash): every
    slot write/read goes through ``stash.put``/``stash.get`` on an explicit
    state carried by the scan. The default RawStash reproduces the
    pre-stash runner bitwise; QuantStash stores int8/fp8 codes + per-block
    scales. Every stage's forward consumes the DEQUANTIZED slot value —
    stage 0 writes its embedding output and reads it back, and the
    backward's stage-0 recompute applies the same perturbation via the
    straight-through ``stash.roundtrip`` — so the vjp grads are exact
    grads of the (slightly perturbed) forward that actually ran, and
    1F1B == GPipe bitwise still holds per backend. Cotangent slots stay at
    the native dtype by default (they are consumed the tick after they
    arrive — compressing them buys little capacity); a backend constructed
    with ``cotangents=True`` (``QuantStash``) routes them through the same
    codec as activation slots, which matters when interleaved schedules
    hold several cotangents live (the remat-vs-compression trade
    ``auto_plan`` prices).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as Pspec

    from repro.compat import shard_map
    from repro.core.stash import RawStash

    backend = stash if stash is not None else RawStash()
    if not backend.scan_capable:
        raise ValueError(
            f"stash backend {backend.name!r} is host-driven; use "
            "pipeline_grads_host (the in-scan runner cannot issue host "
            "transfers per slot)"
        )
    # static Python bool: picks the cotangent-buffer representation at
    # trace time (raw native-dtype buffers vs the backend's codec state)
    quant_cot = bool(getattr(backend, "cotangents", False))

    P_count = table.n_stages
    assert mesh.shape[axis] == P_count, (mesh.shape, P_count)
    fwd_perm = [(i, (i + 1) % P_count) for i in range(P_count)]
    bwd_perm = [(i, (i - 1) % P_count) for i in range(P_count)]
    Wa, Wc = table.n_act_slots, table.n_cot_slots
    rows = {
        k: jnp.asarray(getattr(table, k))
        for k in ("f_mb", "f_slot", "b_mb", "b_slot", "b_cot", "arr_f", "arr_b")
    }
    zero_metrics = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), metrics_struct
    )
    if seed is None:
        seed = jnp.ones((), jnp.float32)

    def inner(sid, sp, shared, mbs, seed_):
        stage = sid[0]
        is_first = stage == 0
        is_last = stage == P_count - 1
        x_zero = jnp.zeros(x_struct.shape, x_struct.dtype)

        def mb_slice(m):
            return jax.tree.map(lambda a: a[m], mbs)

        def full_fn(sp_, sh_, xs_, m):
            mb = mb_slice(m)
            # stage 0 recomputes its input from first_fn; the roundtrip STE
            # re-applies the stash perturbation so recompute matches the
            # put-then-get forward bitwise (identity for RawStash)
            x = jax.lax.cond(
                is_first,
                lambda: backend.roundtrip(
                    first_fn(sh_, mb).astype(x_struct.dtype)
                ),
                lambda: xs_,
            )
            y, aux = stage_fn(sp_, x)
            tail, metrics = jax.lax.cond(
                is_last,
                lambda: last_fn(sh_, y, mb),
                lambda: (jnp.zeros((), jnp.float32), zero_metrics),
            )
            return (y, aux.astype(jnp.float32) + tail), metrics

        def tick(carry, row):
            act, cot, gacc, sacc, lacc, macc, fwd_in, bwd_in = carry
            g = {k: row[k][stage] for k in rows}
            # arrivals land before this tick's op reads the buffers (slot
            # writes route through the stash backend; -1 -> trash slot Wa)
            act = backend.put(
                act, jnp.where(g["arr_f"] >= 0, g["arr_f"], Wa), fwd_in
            )
            cot_w = jnp.where(g["arr_b"] >= 0, g["arr_b"], Wc)
            if quant_cot:
                cot = backend.put(cot, cot_w, bwd_in)
            else:
                cot = cot.at[cot_w].set(bwd_in)
            opk = jnp.where(g["f_mb"] >= 0, 1, jnp.where(g["b_mb"] >= 0, 2, 0))

            def idle_op(act, cot, gacc, sacc, lacc, macc):
                return act, cot, gacc, sacc, lacc, macc, x_zero, x_zero

            def f_op(act, cot, gacc, sacc, lacc, macc):
                m = g["f_mb"]
                slot = jnp.where(g["f_slot"] >= 0, g["f_slot"], Wa)
                # stage 0 stashes its own first_fn output (other stages'
                # slots were filled by the ppermute arrival above); ALL
                # stages then compute on the slot's stored value, so the
                # forward consumes exactly what the backward will read
                act = jax.lax.cond(
                    is_first,
                    lambda a: backend.put(
                        a, slot,
                        first_fn(shared, mb_slice(m)).astype(x_struct.dtype),
                    ),
                    lambda a: a,
                    act,
                )
                x_in = backend.get(act, slot, x_struct)
                y, _ = stage_fn(sp, x_in)
                return act, cot, gacc, sacc, lacc, macc, y, x_zero

            def b_op(act, cot, gacc, sacc, lacc, macc):
                m = g["b_mb"]
                x_saved = backend.get(
                    act, jnp.where(g["b_slot"] >= 0, g["b_slot"], Wa), x_struct
                )
                cot_r = jnp.where(g["b_cot"] >= 0, g["b_cot"], Wc)
                if quant_cot:
                    cot_in = backend.get(cot, cot_r, x_struct)
                else:
                    cot_in = cot[cot_r]
                (y, loss), vjp_fn, metrics = jax.vjp(
                    lambda sp_, sh_, xs_: full_fn(sp_, sh_, xs_, m),
                    sp, shared, x_saved, has_aux=True,
                )
                y_cot = jnp.where(is_last, jnp.zeros_like(y), cot_in)
                d_sp, d_sh, dx = vjp_fn((y_cot, seed_))
                gacc = jax.tree.map(jnp.add, gacc, d_sp)
                sacc = jax.tree.map(jnp.add, sacc, d_sh)
                macc = jax.tree.map(jnp.add, macc, metrics)
                return act, cot, gacc, sacc, lacc + loss, macc, x_zero, dx

            act, cot, gacc, sacc, lacc, macc, y_send, dx_send = jax.lax.switch(
                opk, (idle_op, f_op, b_op), act, cot, gacc, sacc, lacc, macc
            )
            fwd_nxt = jax.lax.ppermute(y_send, axis, fwd_perm)
            bwd_nxt = jax.lax.ppermute(dx_send, axis, bwd_perm)
            return (act, cot, gacc, sacc, lacc, macc, fwd_nxt, bwd_nxt), None

        zeros_like_tree = lambda t: jax.tree.map(
            lambda a: jnp.zeros(a.shape, a.dtype), t
        )
        carry0 = (
            backend.init(Wa + 1, x_struct),
            backend.init(Wc + 1, x_struct) if quant_cot
            else jnp.zeros((Wc + 1,) + x_struct.shape, x_struct.dtype),
            zeros_like_tree(sp),
            zeros_like_tree(shared),
            jnp.zeros((), jnp.float32),
            zero_metrics,
            x_zero,
            x_zero,
        )
        carry, _ = jax.lax.scan(tick, carry0, rows)
        _, _, gacc, sacc, lacc, macc, _, _ = carry

        red = (axis,) + ((data_axis,) if data_axis else ())
        sacc = jax.tree.map(lambda a: jax.lax.psum(a, red), sacc)
        lacc = jax.lax.psum(lacc, red)
        macc = jax.tree.map(lambda a: jax.lax.psum(a, red), macc)
        if data_axis:
            gacc = jax.tree.map(lambda a: jax.lax.psum(a, data_axis), gacc)
        return lacc, macc, gacc, sacc

    repl = lambda t: jax.tree.map(lambda _: Pspec(), t)
    sid = jnp.arange(P_count, dtype=jnp.int32)
    fn = shard_map(
        inner,
        mesh=mesh,
        in_specs=(Pspec(axis), stage_specs, repl(shared_params), mb_specs,
                  Pspec()),
        out_specs=(Pspec(), repl(metrics_struct), stage_specs,
                   repl(shared_params)),
        check_vma=False,
    )
    return fn(sid, stage_params, shared_params, microbatches, seed)


def pipeline_grads_host(
    first_fn: Callable,
    stage_fn: Callable,
    last_fn: Callable,
    stage_params: Any,
    shared_params: Any,
    microbatches: Any,
    *,
    table: TickTable,
    x_struct,
    metrics_struct: Any,
    seed=None,
    stash=None,
    lookahead: int = 2,
):
    """Host-driven twin of :func:`pipeline_grads`: the same tick tables,
    executed as a Python loop on ONE device (dp = tp = 1), with all P
    stages' ops issued sequentially per tick and ppermute traffic emulated
    by per-stage wire buffers (a value sent at tick t arrives at t+1,
    exactly the table's ``avail`` contract).

    This is the execution mode where a stateful stash backend becomes
    legal: ``HostStash`` evicts activation slots to host RAM between a
    microbatch's forward and backward (vDNN applied to the 1F1B stash), so
    a pipeline whose min(P, M) raw slots exceed device memory still trains
    — slot indices are concrete ints here, and put/get may block on
    transfers. Math is identical to the in-scan runner per backend (same
    per-stage op order and grad accumulation), minus cross-device psum
    reduction order, so losses agree to float tolerance.

    Overlap: each tick first ``poll``s every stage's store (retiring
    completed async evictions), then reads the next ``lookahead`` ticks'
    B-entries from the table and ``prefetch``es their slots so host->device
    loads run under this tick's compute. A get neither windowed nor
    prefetched is a counted stall (``HostStash.stats``). ``lookahead=0``
    is the eager baseline. Prefetching is a pure residency hint — puts
    invalidate staged copies, so the result is bitwise-equal to the eager
    runner for every backend and lookahead.

    ``stage_params`` is the FULL stacked-layer tree (leading layer axis
    unsharded); returns (loss_sum, metrics_sums, stage_grads, shared_grads)
    with stage_grads matching ``stage_params``'s full shapes.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.stash import RawStash

    backend = stash if stash is not None else RawStash()
    quant_cot = bool(getattr(backend, "cotangents", False))
    P_count, M = table.n_stages, table.n_microbatches
    L = jax.tree.leaves(stage_params)[0].shape[0]
    assert L % P_count == 0, (L, P_count)
    k = L // P_count
    Wa, Wc = table.n_act_slots, table.n_cot_slots
    zero_metrics = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), metrics_struct
    )
    if seed is None:
        seed = jnp.ones((), jnp.float32)

    def sp_slice(s):
        return jax.tree.map(lambda a: a[s * k:(s + 1) * k], stage_params)

    def mb_slice(m):
        return jax.tree.map(lambda a: a[m], microbatches)

    def full_fn(s, m):
        is_first, is_last = s == 0, s == P_count - 1

        def fn(sp_, sh_, xs_):
            mb = mb_slice(m)
            if is_first:
                x = backend.roundtrip(first_fn(sh_, mb).astype(x_struct.dtype))
            else:
                x = xs_
            y, aux = stage_fn(sp_, x)
            if is_last:
                tail, metrics = last_fn(sh_, y, mb)
            else:
                tail, metrics = jnp.zeros((), jnp.float32), zero_metrics
            return (y, aux.astype(jnp.float32) + tail), metrics

        return fn

    acts = [backend.init(Wa, x_struct) for _ in range(P_count)]
    cots: List[List[Any]] = [[None] * max(Wc, 1) for _ in range(P_count)]
    gacc = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), stage_params)
    sacc = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), shared_params)
    lacc = jnp.zeros((), jnp.float32)
    macc = zero_metrics
    fwd_wire: List[Any] = [None] * P_count
    bwd_wire: List[Any] = [None] * P_count

    for t in range(table.n_ticks):
        # arrivals land before this tick's ops read the buffers
        for s in range(P_count):
            af = int(table.arr_f[t, s])
            if af >= 0:
                acts[s] = backend.put(acts[s], af, fwd_wire[s])
                fwd_wire[s] = None
            ab = int(table.arr_b[t, s])
            if ab >= 0:
                # quantized cotangent storage: the codec roundtrip value is
                # bitwise what the in-scan runner's put-then-get produces
                cots[s][ab] = (
                    backend.roundtrip(bwd_wire[s]) if quant_cot
                    else bwd_wire[s]
                )
                bwd_wire[s] = None
        # overlap pass: retire completed evictions, then start host->device
        # loads for the next ticks' backward reads (no-ops for RawStash &c)
        for s in range(P_count):
            backend.poll(acts[s])
        for dt in range(1, lookahead + 1):
            if t + dt >= table.n_ticks:
                break
            for s in range(P_count):
                if int(table.b_mb[t + dt, s]) >= 0:
                    backend.prefetch(acts[s], int(table.b_slot[t + dt, s]))
        next_fwd: List[Any] = [None] * P_count
        next_bwd: List[Any] = [None] * P_count
        for s in range(P_count):
            fm, bm = int(table.f_mb[t, s]), int(table.b_mb[t, s])
            if fm >= 0:
                slot = int(table.f_slot[t, s])
                if s == 0:
                    acts[0] = backend.put(
                        acts[0], slot,
                        first_fn(shared_params, mb_slice(fm)).astype(
                            x_struct.dtype
                        ),
                    )
                x_in = backend.get(acts[s], slot, x_struct)
                y, _ = stage_fn(sp_slice(s), x_in)
                if s + 1 < P_count:
                    next_fwd[s + 1] = y
            elif bm >= 0:
                slot = int(table.b_slot[t, s])
                x_saved = backend.get(acts[s], slot, x_struct)
                (y, loss), vjp_fn, metrics = jax.vjp(
                    full_fn(s, bm), sp_slice(s), shared_params, x_saved,
                    has_aux=True,
                )
                if s == P_count - 1:
                    y_cot = jnp.zeros_like(y)
                else:
                    y_cot = cots[s][int(table.b_cot[t, s])]
                d_sp, d_sh, dx = vjp_fn((y_cot, seed))
                lo, hi = s * k, (s + 1) * k
                gacc = jax.tree.map(
                    lambda g, d: g.at[lo:hi].add(d), gacc, d_sp
                )
                sacc = jax.tree.map(jnp.add, sacc, d_sh)
                macc = jax.tree.map(jnp.add, macc, metrics)
                lacc = lacc + loss
                if s > 0:
                    next_bwd[s - 1] = dx
        fwd_wire, bwd_wire = next_fwd, next_bwd
    return lacc, macc, gacc, sacc
