"""The survey's taxonomy as composable training features (see DESIGN.md)."""
from repro.core import (  # noqa: F401
    compression,
    partitioner,
    offload,
    pipeline,
    precision,
    remat,
    remat_solver,
    zero,
)
