"""Executable rematerialization policies (survey §2.1).

The planning side lives in ``repro.core.remat_solver``; this module maps a
plan / named policy onto the executable JAX mechanisms:

* ``"none"``    — store everything (baseline row of Table 1).
* ``"full"``    — jax.checkpoint(nothing_saveable) on every scan unit:
                  activations of a unit are recomputed during backward.
* ``"dots"``    — checkpoint_dots: keep matmul outputs, recompute the rest
                  (the "selective" policy used by Megatron-style frameworks).
* ``"offload"`` — save activations to host memory instead of recomputing
                  (survey §2.2 executed through the remat machinery:
                  offload_dot_with_no_batch_dims device->pinned_host).
* ``plan:k``    — periodic plan from the solver: checkpoint every k-th unit,
                  recompute the rest (Chen'16 executed exactly).

``policy_for`` returns a transform applied to the scan-unit body inside
``repro.models.stack.stack_forward`` (which honours Runtime.remat for the
simple names); ``wrap_units`` is used by the trainer for plan-based remat
where different units get different treatment.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from repro.core.remat_solver import RematPlan

BodyFn = Callable


def policy_for(name: str) -> Optional[Callable]:
    if name in ("none", ""):
        return None
    if name == "full":
        return lambda f: jax.checkpoint(f, prevent_cse=False)
    if name == "dots":
        return lambda f: jax.checkpoint(
            f, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    if name == "offload":
        return lambda f: jax.checkpoint(
            f, prevent_cse=False,
            policy=jax.checkpoint_policies.offload_dot_with_no_batch_dims(
                "device", "pinned_host"
            ),
        )
    raise ValueError(f"unknown remat policy {name!r}")


def wrap_units(body: BodyFn, plan: RematPlan, unit_index: int) -> BodyFn:
    """Plan-based remat: units at checkpoint boundaries store activations,
    others recompute (jax.checkpoint)."""
    if unit_index in plan.checkpoints:
        return body
    return jax.checkpoint(body, prevent_cse=False)


def period_from_plan(plan: RematPlan) -> int:
    """Executable granularity for a periodic-style plan: with checkpoints
    every k units, set Runtime.remat_period = k and remat="full" — the scan
    then stores one carry per k layers and recomputes within the group,
    exactly the plan's memory/recompute profile."""
    cps = sorted(plan.checkpoints)
    if len(cps) < 2:
        return plan.n_segments
    gaps = [b - a for a, b in zip(cps, cps[1:])]
    return max(1, min(gaps))
