"""Activation/weight offloading: planners + host-memory execution (survey §2.2-2.3).

Planning (GPU->CPU PCIe in the survey; HBM->host link on TPU — constants
adapted, algorithms preserved):

* ``lifetime_planner``  — TFLMS/SwapAdvisor-style: offload the activations
  with the longest lifetime (time between production in forward and
  consumption in backward) that fit the link-bandwidth budget.
* ``greedy_planner``    — [Beaumont et al., 2020] greedy: walk segments in
  forward order, offload while the transfer can hide under compute.
* ``dynprog_joint``     — joint offload+remat dyn-prog in the spirit of
  [Beaumont et al., 2021a]: each segment's activation is kept, offloaded,
  or recomputed; exact for the chain model below.

``simulate_schedule`` scores a plan under a simple overlap model: transfers
overlap compute but serialize on the link; a prefetch must complete before
its backward segment starts. This produces the Table-3 benchmark numbers.

Execution: ``repro.core.remat.policy_for("offload")`` routes saved dots to
``pinned_host`` via jax.checkpoint policies (XLA host-offload machinery),
which is the TPU-native execution of these plans.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

ACTION_KEEP = "keep"
ACTION_OFFLOAD = "offload"
ACTION_RECOMPUTE = "recompute"


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    actions: Tuple[str, ...]             # per segment
    est_time: float                      # simulated wall time (fwd+bwd)
    peak_memory: float                   # device activation bytes at peak
    offloaded_bytes: float


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Transfer model. TPU v5e defaults: ~50 GB/s host link vs 819 GB/s HBM."""

    bandwidth: float = 50e9              # bytes/s each direction
    latency: float = 5e-6


def simulate_schedule(
    t_fwd: Sequence[float],
    a_bytes: Sequence[float],
    actions: Sequence[str],
    link: LinkModel,
    t_bwd: Optional[Sequence[float]] = None,
) -> Tuple[float, float]:
    """(total time, peak device memory) under compute/transfer overlap.

    Forward: segment i runs for t_fwd[i]; if offloaded, its activation is
    enqueued on the link (serialized FIFO). Backward (reverse order):
    recomputed segments re-run their forward; offloaded ones must finish
    prefetching (link FIFO again, earliest-needed-first) before B_i starts.
    """
    n = len(t_fwd)
    t_bwd = list(t_bwd) if t_bwd is not None else [2.0 * x for x in t_fwd]

    # ---- forward sweep
    time = 0.0
    link_free = 0.0
    resident = 0.0
    peak = 0.0
    done_offload = {}
    for i in range(n):
        time += t_fwd[i]
        resident += a_bytes[i]
        peak = max(peak, resident)
        if actions[i] == ACTION_OFFLOAD:
            start = max(time, link_free)
            link_free = start + link.latency + a_bytes[i] / link.bandwidth
            done_offload[i] = link_free
            resident -= a_bytes[i]
        elif actions[i] == ACTION_RECOMPUTE:
            resident -= a_bytes[i]
    time = max(time, link_free)  # drain pending stores before bwd of last seg

    # ---- backward sweep (prefetch next-needed while computing)
    link_free = time
    for i in reversed(range(n)):
        if actions[i] == ACTION_OFFLOAD:
            start = max(time, link_free)
            ready = start + link.latency + a_bytes[i] / link.bandwidth
            link_free = ready
            time = max(time, ready)
            resident += a_bytes[i]
        elif actions[i] == ACTION_RECOMPUTE:
            time += t_fwd[i]          # replay forward
            resident += a_bytes[i]
        peak = max(peak, resident)
        time += t_bwd[i]
        resident -= a_bytes[i]
    return time, peak


def _finish(t_fwd, a_bytes, actions, link) -> OffloadPlan:
    est, peak = simulate_schedule(t_fwd, a_bytes, actions, link)
    off = sum(b for b, act in zip(a_bytes, actions) if act == ACTION_OFFLOAD)
    return OffloadPlan(tuple(actions), est, peak, off)


def lifetime_planner(
    t_fwd: Sequence[float], a_bytes: Sequence[float], mem_budget: float,
    link: LinkModel = LinkModel(),
) -> OffloadPlan:
    """Offload longest-lifetime activations first until under budget."""
    n = len(t_fwd)
    total_t = sum(t_fwd)
    # lifetime of activation i ~ time from end of F_i to start of B_i
    lifetime = [2.0 * (total_t - sum(t_fwd[: i + 1])) + total_t for i in range(n)]
    order = sorted(range(n), key=lambda i: lifetime[i], reverse=True)
    actions = [ACTION_KEEP] * n
    for i in order:
        _, peak = simulate_schedule(t_fwd, a_bytes, actions, link)
        if peak <= mem_budget:
            break
        actions[i] = ACTION_OFFLOAD
    return _finish(t_fwd, a_bytes, actions, link)


def greedy_planner(
    t_fwd: Sequence[float], a_bytes: Sequence[float], mem_budget: float,
    link: LinkModel = LinkModel(),
) -> OffloadPlan:
    """[Beaumont'20]-style greedy: offload while the transfer hides under
    downstream forward compute; then force-offload to meet the budget."""
    n = len(t_fwd)
    actions = [ACTION_KEEP] * n
    link_backlog = 0.0
    for i in range(n):
        transfer = a_bytes[i] / link.bandwidth + link.latency
        downstream = sum(t_fwd[i + 1 :])
        if link_backlog + transfer <= downstream:
            actions[i] = ACTION_OFFLOAD
            link_backlog += transfer
    # budget enforcement: offload largest remaining activations
    for i in sorted(range(n), key=lambda i: a_bytes[i], reverse=True):
        _, peak = simulate_schedule(t_fwd, a_bytes, actions, link)
        if peak <= mem_budget:
            break
        actions[i] = ACTION_OFFLOAD
    return _finish(t_fwd, a_bytes, actions, link)


def dynprog_joint(
    t_fwd: Sequence[float], a_bytes: Sequence[float], mem_budget: float,
    link: LinkModel = LinkModel(),
) -> OffloadPlan:
    """Joint offload/remat/keep via exhaustive DP on small n, beam otherwise.

    Exact per-segment action choice against :func:`simulate_schedule`
    (itertools product for n <= 12; beam search width 64 beyond), in the
    spirit of [Beaumont et al., 2021a]'s optimal combination result.
    """
    n = len(t_fwd)
    choices = (ACTION_KEEP, ACTION_OFFLOAD, ACTION_RECOMPUTE)
    best: Optional[OffloadPlan] = None
    if n <= 12:
        import itertools

        for combo in itertools.product(choices, repeat=n):
            est, peak = simulate_schedule(t_fwd, a_bytes, combo, link)
            if peak <= mem_budget and (best is None or est < best.est_time):
                off = sum(
                    b for b, a in zip(a_bytes, combo) if a == ACTION_OFFLOAD
                )
                best = OffloadPlan(tuple(combo), est, peak, off)
    else:
        beam: List[Tuple[str, ...]] = [()]
        for i in range(n):
            cand = [p + (c,) for p in beam for c in choices]

            def score(prefix: Tuple[str, ...]) -> float:
                pad = prefix + (ACTION_RECOMPUTE,) * (n - len(prefix))
                est, peak = simulate_schedule(t_fwd, a_bytes, pad, link)
                return est + (1e12 if peak > mem_budget else 0.0)

            beam = sorted(cand, key=score)[:64]
        for combo in beam:
            est, peak = simulate_schedule(t_fwd, a_bytes, combo, link)
            if peak <= mem_budget and (best is None or est < best.est_time):
                off = sum(b for b, a in zip(a_bytes, combo) if a == ACTION_OFFLOAD)
                best = OffloadPlan(tuple(combo), est, peak, off)
    if best is None:  # infeasible: recompute everything
        combo = tuple([ACTION_RECOMPUTE] * n)
        return _finish(t_fwd, a_bytes, list(combo), link)
    return best


def offload_chain_grads(
    seg_fns: Sequence,
    seg_params: Sequence,
    x0,
    actions: Sequence[str],
    loss_fn,
    *,
    host_window: int = 2,
):
    """EXECUTE an offload plan's per-segment actions for real.

    The planners above only score action vectors; this runs one
    forward+backward over the segment chain ``x_{i+1} = seg_fns[i](p_i,
    x_i)`` with each segment input stored per its action:

      keep      -> stays on device (plain reference)
      offload   -> core.stash.HostStash — device->host copy started at
                   store time, double-buffered window, fetched back
                   bit-exactly for the backward
      recompute -> stored nowhere; the backward replays forward from the
                   nearest stored (or initial) input

    Backward is ``jax.vjp`` per segment in reverse order, seeded by
    ``loss_fn(x_n)``. Returns (loss, per-segment param grads, dx0, stats)
    where stats merges the HostStash counters with ``replayed_segments`` —
    the recompute cost the dynprog planner trades against link time.
    """
    import jax

    from repro.core.stash import HostStash

    n = len(seg_fns)
    assert len(seg_params) == n and len(actions) == n, (n, actions)
    host = HostStash(window=host_window)
    hstate = host.init(n, None)
    kept = {}

    x = x0
    inputs_stored = [False] * n
    for i in range(n):
        if actions[i] == ACTION_OFFLOAD:
            hstate = host.put(hstate, i, x)
            inputs_stored[i] = True
        elif actions[i] == ACTION_KEEP:
            kept[i] = x
            inputs_stored[i] = True
        x = seg_fns[i](seg_params[i], x)
    y = x

    replays = 0

    def load_input(i):
        nonlocal replays
        if actions[i] == ACTION_OFFLOAD:
            return host.get(hstate, i, None)
        if actions[i] == ACTION_KEEP:
            return kept[i]
        j = i
        while j > 0 and not inputs_stored[j]:
            j -= 1
        xx = x0 if j == 0 and not inputs_stored[0] else load_input(j)
        for t in range(j, i):
            xx = seg_fns[t](seg_params[t], xx)
            replays += 1
        return xx

    loss, pull = jax.vjp(loss_fn, y)
    (cot,) = pull(jax.numpy.ones_like(loss))
    grads = [None] * n
    for i in reversed(range(n)):
        x_i = load_input(i)
        _, vjp_fn = jax.vjp(seg_fns[i], seg_params[i], x_i)
        d_p, cot = vjp_fn(cot)
        grads[i] = d_p
    stats = dict(host.stats(), replayed_segments=replays)
    return loss, grads, cot, stats
