"""ZeRO stages 1-3 as partition-spec overlays (survey §4.1).

In SPMD JAX, ZeRO's "partition X across data-parallel ranks" translates to:
take X's tensor-parallel PartitionSpec and additionally shard one eligible
dimension over the data axis. XLA then inserts exactly the collectives the
ZeRO paper describes:

  stage 1  opt state sharded over data  -> all-gather of updates (or
           reduce-scatter(grad) + local update + all-gather(param delta))
  stage 2  + gradients sharded          -> psum becomes reduce-scatter
  stage 3  + parameters sharded (FSDP)  -> per-layer all-gather on use

``overlay`` is pure spec algebra: it never touches arrays, so the same
function drives the trainer, the dry-run, and the Table-1/ZeRO benchmarks.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def add_axis_to_spec(
    spec: P, shape: Tuple[int, ...], mesh, axis="data"
) -> P:
    """Shard the first eligible dim of ``shape`` over ``axis`` (ZeRO overlay).

    Eligible: not already sharded in ``spec`` and divisible by the axis size.
    Returns ``spec`` unchanged if nothing is eligible (e.g. tiny scalars —
    they stay replicated, which matches ZeRO implementations that keep small
    tensors unpartitioned).
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    size = _axis_size(mesh, axis)
    best = -1
    for i, (dim, cur) in enumerate(zip(shape, entries)):
        if cur is None and dim % size == 0 and dim >= size:
            # prefer the largest dim: fewer padding pathologies, better balance
            if best < 0 or shape[i] > shape[best]:
                best = i
    if best < 0:
        return spec
    entries[best] = axis
    return P(*entries)


def overlay(
    stage: int,
    param_specs: Any,
    param_shapes: Any,
    mesh,
    data_axis="data",
) -> Tuple[Any, Any, Any]:
    """Returns (param_specs, grad_specs, opt_state_specs_fn) for a ZeRO stage.

    ``opt_state_specs_fn(param_spec_tree)`` maps a per-param spec tree to the
    spec for each optimizer-state slot shaped like the param (Adam m/v).
    """
    assert stage in (0, 1, 2, 3), stage

    def add(spec, shape):
        return add_axis_to_spec(spec, shape.shape if hasattr(shape, "shape") else shape,
                                mesh, data_axis)

    shapes = jax.tree.map(lambda s: s.shape if hasattr(s, "shape") else s, param_shapes)

    sharded = jax.tree.map(add, param_specs, shapes,
                           is_leaf=lambda x: isinstance(x, P))

    p_specs = sharded if stage >= 3 else param_specs
    g_specs = sharded if stage >= 2 else param_specs
    o_specs = sharded if stage >= 1 else param_specs
    return p_specs, g_specs, o_specs


def memory_per_device(
    n_params: int, mesh, stage: int, tp_shard: int = 1,
    bytes_param: int = 4, bytes_grad: int = 4, bytes_opt: int = 8,
    data_axis="data",
) -> dict:
    """Analytic per-device bytes for the ZeRO benchmark (Table 1 / §4.1).

    ``tp_shard``: tensor-parallel factor already dividing everything.
    """
    dp = _axis_size(mesh, data_axis)
    base = n_params / tp_shard
    return {
        "params": base * bytes_param / (dp if stage >= 3 else 1),
        "grads": base * bytes_grad / (dp if stage >= 2 else 1),
        "opt": base * bytes_opt / (dp if stage >= 1 else 1),
    }
