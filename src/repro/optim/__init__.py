from repro.optim.base import (  # noqa: F401
    Optimizer,
    Schedule,
    apply_updates,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.lowbit import adam8bit, state_bytes  # noqa: F401
from repro.optim.optimizers import adamw, get, lamb, lars, sgd  # noqa: F401
from repro.optim.lowbit4 import adam4bit  # noqa: F401
from repro.optim.onebit import onebit_adam  # noqa: F401
