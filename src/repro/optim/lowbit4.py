"""4-bit optimizer states with adaptive Gradient Scaling (survey §4.2,
[Sun et al. 2020] "Ultra-Low Precision 4-bit Training").

The 4-bit regime's failure mode is range/resolution: a 16-entry code map
cannot cover both the large and small quantiles of Adam moments. Two
mitigations from the paper's toolbox, adapted:

* **blockwise scales** (as in the 8-bit path) shrink the dynamic range each
  code map must cover;
* **GradScale**: gradients are pre-scaled per tensor so their RMS sits in
  the code map's sweet spot before the moment update, and the update is
  un-scaled afterwards — mitigating "insufficient range and resolution".

The 4-bit map is the signed dynamic construction with 3 exponent levels
(7 positive codes + mirror + {0, 1.0} = 16). First moment only — the second
moment's square range is kept in 8-bit (mixed 4/8, the paper's stable
recipe); tests assert parity-within-tolerance vs f32 Adam.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.blockwise_quant import dequantize, quantize
from repro.optim.base import Optimizer
from repro.optim.optimizers import LR, _lr_at

MIN_SIZE = 4096
BLOCK = 256


@functools.lru_cache(maxsize=None)
def dynamic_map_4bit() -> np.ndarray:
    """16 signed codes: 3 exponent decades x linear fractions + {0, 1}."""
    pos = []
    for i in range(3):
        boundaries = np.linspace(0.1, 1.0, 2**i + 1)
        means = (boundaries[:-1] + boundaries[1:]) / 2.0
        pos += (10.0 ** (i - 2) * means).tolist()
    assert len(pos) == 7
    data = pos + [-v for v in pos] + [0.0, 1.0]
    data.sort()
    out = np.asarray(data, dtype=np.float32)
    assert out.shape == (16,)
    return out


def quantize4(x: jax.Array, block: int = BLOCK):
    """(codes uint8 [0..15], scales) — reuses the blockwise scaffold."""
    codes = jnp.asarray(dynamic_map_4bit())
    xb = x.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    safe = jnp.where(scale > 0, scale, 1.0)
    normed = xb / safe
    mid = (codes[1:] + codes[:-1]) / 2.0
    idx = jnp.searchsorted(mid, normed, side="right").astype(jnp.uint8)
    return idx.reshape(-1), scale[:, 0]


def dequantize4(idx: jax.Array, scale: jax.Array, block: int = BLOCK):
    codes = jnp.asarray(dynamic_map_4bit())
    vals = jnp.take(codes, idx.astype(jnp.int32)).reshape(-1, block)
    return (vals * scale[:, None]).reshape(-1)


def grad_scale(g: jax.Array, target_rms: float = 0.3) -> jax.Array:
    """Adaptive Gradient Scaling: per-tensor scale putting the RMS of the
    normalized gradient near the map's high-resolution region."""
    rms = jnp.sqrt(jnp.mean(jnp.square(g))) + 1e-12
    return target_rms / rms


def _pad_to_block(x: jax.Array) -> jax.Array:
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)) if pad else x.reshape(-1)


def adam4bit(
    lr: LR = 3e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """Adam with 4-bit first moment (+GradScale) and 8-bit second moment."""

    def big(p) -> bool:
        return p.size >= MIN_SIZE

    def init(params):
        def leaf(p):
            if big(p):
                z = _pad_to_block(jnp.zeros(p.size, jnp.float32))
                c4, s4 = quantize4(z)
                c8, s8, _ = quantize(z)
                return {"m4": {"codes": c4, "scales": s4},
                        "v8": {"codes": c8, "scales": s8},
                        "gs": jnp.ones((), jnp.float32)}
            return {"m": jnp.zeros(p.shape, jnp.float32),
                    "v": jnp.zeros(p.shape, jnp.float32)}

        return {"slots": jax.tree.map(leaf, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = _lr_at(lr, state["step"])

        def leaf(slot, g, p):
            gf = g.astype(jnp.float32)
            if big(p):
                padded = _pad_to_block(jnp.zeros(p.size, jnp.float32)).size
                scale_prev = slot["gs"]
                m = dequantize4(slot["m4"]["codes"], slot["m4"]["scales"])[
                    : p.size
                ].reshape(p.shape) / scale_prev
                v = dequantize(slot["v8"]["codes"], slot["v8"]["scales"],
                               padded, (padded,))[: p.size].reshape(p.shape)
            else:
                m, v = slot["m"], slot["v"]
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if big(p):
                gs = grad_scale(m)               # scale the MOMENT stream
                c4, s4 = quantize4(_pad_to_block(m * gs))
                c8, s8, _ = quantize(_pad_to_block(v))
                new_slot = {"m4": {"codes": c4, "scales": s4},
                            "v8": {"codes": c8, "scales": s8}, "gs": gs}
            else:
                new_slot = {"m": m, "v": v}
            return new_slot, u

        flat_p, td = jax.tree_util.tree_flatten(params)
        flat_s = jax.tree_util.tree_flatten(
            state["slots"],
            is_leaf=lambda x: isinstance(x, dict) and ("m" in x or "m4" in x),
        )[0]
        flat_g = jax.tree.leaves(grads)
        pairs = [leaf(s, g, p) for s, g, p in zip(flat_s, flat_g, flat_p)]
        slots = jax.tree_util.tree_unflatten(td, [a for a, _ in pairs])
        updates = jax.tree_util.tree_unflatten(td, [b for _, b in pairs])
        return updates, {"slots": slots, "step": step}

    return Optimizer(init, update)
