"""1-bit Adam (survey §4.3, [Tang et al. 2021]).

Two-phase distributed Adam: a full-precision WARMUP (variance v is still
moving), then a COMPRESSION phase where v is frozen and only the momentum is
synchronized — sign-compressed with error feedback (the paper's key insight:
Adam's nonlinearity lives in v; once v is stable, the update is linear in m
and tolerates biased 1-bit compression + EF).

``axis_name`` is the data-parallel shard_map axis for real multi-device
sync; None = loopback (the compression error still applies — used by tests
to check convergence parity and by the benchmark for bytes accounting).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer
from repro.optim.optimizers import LR, _lr_at

MIN_SIZE = 1024


def onebit_adam(
    lr: LR = 3e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    warmup_steps: int = 20,
    axis_name: Optional[str] = None,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "ef": jax.tree.map(z, params),     # error feedback (compress phase)
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        in_warmup = step <= warmup_steps
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = _lr_at(lr, state["step"])

        def mean_dp(x):
            return jax.lax.pmean(x, axis_name) if axis_name else x

        def leaf(m, v, ef, g):
            gf = mean_dp(g.astype(jnp.float32)) if True else g
            # NOTE: warmup syncs raw grads (full precision)
            m_new = b1 * m + (1 - b1) * gf
            v_new = jnp.where(in_warmup, b2 * v + (1 - b2) * jnp.square(gf), v)

            if g.size >= MIN_SIZE:
                # compression phase: 1-bit momentum sync with error feedback
                t = m_new + ef
                scale = jnp.mean(jnp.abs(t))
                comp = jnp.sign(t) * scale
                comp = mean_dp(comp)
                ef_new = t - comp
                m_comm = jnp.where(in_warmup, m_new, comp)
                ef_out = jnp.where(in_warmup, ef, ef_new)
            else:
                m_comm, ef_out = m_new, ef

            u = -lr_t * (m_comm / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            return (m_comm, v_new, ef_out, u)

        flat_g, td = jax.tree_util.tree_flatten(grads)
        outs = [
            leaf(m, v, ef, g)
            for m, v, ef, g in zip(
                jax.tree.leaves(state["m"]), jax.tree.leaves(state["v"]),
                jax.tree.leaves(state["ef"]), flat_g,
            )
        ]
        unf = lambda i: jax.tree_util.tree_unflatten(td, [o[i] for o in outs])
        return unf(3), {"m": unf(0), "v": unf(1), "ef": unf(2), "step": step}

    return Optimizer(init, update)
