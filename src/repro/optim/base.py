"""Composable optimizer API (optax-like, self-contained).

An Optimizer is (init, update):
    state          = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params         = apply_updates(params, updates)

All states are pytrees -> they shard with ZeRO overlays (repro.core.zero)
and checkpoint with repro.checkpoint for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]   # (grads, state, params) -> (updates, state)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ------------------------------------------------------------------ schedules
@dataclasses.dataclass(frozen=True)
class Schedule:
    """Warmup + {constant, cosine, linear} decay, with the linear-scaling rule
    [Goyal et al. 2017]: lr = base_lr * (global_batch / base_batch)."""

    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    kind: str = "cosine"             # cosine | linear | constant
    base_batch: int = 0              # 0 = linear-scaling rule off
    global_batch: int = 0
    min_ratio: float = 0.1

    def __call__(self, step: jax.Array) -> jax.Array:
        lr = self.base_lr
        if self.base_batch and self.global_batch:
            lr = lr * self.global_batch / self.base_batch
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        frac = jnp.clip(
            (step - self.warmup_steps)
            / max(self.total_steps - self.warmup_steps, 1),
            0.0, 1.0,
        )
        if self.kind == "cosine":
            decay = self.min_ratio + (1 - self.min_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac)
            )
        elif self.kind == "linear":
            decay = 1.0 - (1 - self.min_ratio) * frac
        else:
            decay = 1.0
        return lr * warm * decay
