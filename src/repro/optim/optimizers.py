"""SGD / Adam(W) / LARS / LAMB (survey §4.3 large-batch training).

LARS [You et al. 2017] and LAMB [You et al. 2019] apply a per-layer trust
ratio ||w|| / ||update|| on top of SGD-momentum / AdamW respectively — the
survey's answer to large-batch generalization loss beyond the linear scaling
rule (which lives in ``repro.optim.base.Schedule``).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer

LR = Union[float, Callable]


def _lr_at(lr: LR, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def _norm(x: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


def _trust_ratio(p: jax.Array, u: jax.Array, eps: float = 1e-9) -> jax.Array:
    """phi(||p||) / ||u|| with the standard guard: 1.0 when either norm is 0."""
    pn, un = _norm(p), _norm(u)
    ratio = jnp.where((pn > 0) & (un > 0), pn / (un + eps), 1.0)
    return ratio


def sgd(lr: LR = 1e-2, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        step = state["step"]
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
        )
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), mu, grads
            )
        else:
            upd = mu
        lr_t = _lr_at(lr, step)
        updates = jax.tree.map(lambda u: -lr_t * u, upd)
        return updates, {"mu": mu, "step": step + 1}

    return Optimizer(init, update)


def adamw(
    lr: LR = 3e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = _lr_at(lr, state["step"])

        def upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and params is not None:
                u = u + weight_decay * p.astype(jnp.float32)
            return -lr_t * u

        updates = jax.tree.map(upd, m, v, params if params is not None else m)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def lars(
    lr: LR = 1e-2,
    momentum: float = 0.9,
    weight_decay: float = 1e-4,
    trust_coef: float = 1e-3,
) -> Optimizer:
    """Layer-wise Adaptive Rate Scaling over SGD-momentum."""
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"]
        lr_t = _lr_at(lr, step)

        def leaf(m, g, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            local = trust_coef * _trust_ratio(p, g)
            m_new = momentum * m + local * g
            return m_new, -lr_t * m_new

        flat_p, td = jax.tree_util.tree_flatten(params)
        flat_m = jax.tree.leaves(state["mu"])
        flat_g = jax.tree.leaves(grads)
        pairs = [leaf(m, g, p) for m, g, p in zip(flat_m, flat_g, flat_p)]
        mu = jax.tree_util.tree_unflatten(td, [a for a, _ in pairs])
        updates = jax.tree_util.tree_unflatten(td, [b for _, b in pairs])
        return updates, {"mu": mu, "step": step + 1}

    return Optimizer(init, update)


def lamb(
    lr: LR = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
) -> Optimizer:
    """LAMB: AdamW direction rescaled by the per-layer trust ratio."""
    inner = adamw(1.0, b1, b2, eps, 0.0)  # unit-lr Adam direction

    def init(params):
        return inner.init(params)

    def update(grads, state, params):
        dirs, new_state = inner.update(grads, state, params)
        lr_t = _lr_at(lr, state["step"])

        def leaf(u, p):
            r = -u  # inner returned -1.0 * direction
            if weight_decay:
                r = r + weight_decay * p.astype(jnp.float32)
            return -lr_t * _trust_ratio(p, r) * r

        updates = jax.tree.map(leaf, dirs, params)
        return updates, new_state

    return Optimizer(init, update)


def get(name: str, lr: LR, **kw) -> Optimizer:
    table = {"sgd": sgd, "adamw": adamw, "adam": adamw, "lars": lars, "lamb": lamb}
    if name == "adam8bit":
        from repro.optim.lowbit import adam8bit

        return adam8bit(lr, **kw)
    if name == "adam4bit":
        from repro.optim.lowbit4 import adam4bit

        return adam4bit(lr, **kw)
    if name == "onebit_adam":
        from repro.optim.onebit import onebit_adam

        return onebit_adam(lr, **kw)
    return table[name](lr, **kw)
