"""8-bit Adam via blockwise dynamic quantization (survey §4.2, Dettmers'21).

Optimizer moments are stored as (uint8 codes, f32 per-block scales): 4x less
state memory than f32 Adam (the survey's headline for low-precision
optimizers). Each update dequantizes m/v, performs exact f32 Adam math, and
requantizes — matching the paper's stateless-kernel formulation. The
second moment is non-negative, but we reuse the signed dynamic map for both
(the positive half provides 7-bit resolution; parity is verified in
tests/test_lowbit.py against f32 Adam).

Leaves smaller than ``min_size`` stay f32 (Dettmers keeps <4096-element
tensors in 32-bit for stability — same here).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.kernels.blockwise_quant import dequantize, quantize
from repro.optim.base import Optimizer
from repro.optim.optimizers import LR, _lr_at

MIN_SIZE = 4096


def _q(x: jax.Array, backend: str) -> Dict[str, Any]:
    codes, scales, n = quantize(x, backend=backend)
    return {"codes": codes, "scales": scales}


def _dq(q: Dict[str, Any], shape, backend: str) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return dequantize(q["codes"], q["scales"], n, shape, backend=backend)


def adam8bit(
    lr: LR = 3e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    backend: str = "ref",
) -> Optimizer:
    def leaf_big(p) -> bool:
        return p.size >= MIN_SIZE

    def init(params):
        def leaf(p):
            if leaf_big(p):
                z = jnp.zeros(p.size, jnp.float32)
                return {"m": _q(z, backend), "v": _q(z, backend)}
            return {
                "m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32),
            }

        return {
            "slots": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = _lr_at(lr, state["step"])

        def leaf(slot, g, p):
            gf = g.astype(jnp.float32)
            if leaf_big(p):
                m = _dq(slot["m"], (p.size,), backend).reshape(p.shape)
                v = _dq(slot["v"], (p.size,), backend).reshape(p.shape)
            else:
                m, v = slot["m"], slot["v"]
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            if leaf_big(p):
                new_slot = {
                    "m": _q(m.reshape(-1), backend),
                    "v": _q(v.reshape(-1), backend),
                }
            else:
                new_slot = {"m": m, "v": v}
            return new_slot, -lr_t * u

        flat_p, td = jax.tree_util.tree_flatten(params)
        flat_s = jax.tree_util.tree_flatten(
            state["slots"], is_leaf=lambda x: isinstance(x, dict) and "m" in x
        )[0]
        flat_g = jax.tree.leaves(grads)
        pairs = [leaf(s, g, p) for s, g, p in zip(flat_s, flat_g, flat_p)]
        slots = jax.tree_util.tree_unflatten(td, [a for a, _ in pairs])
        updates = jax.tree_util.tree_unflatten(td, [b for _, b in pairs])
        return updates, {"slots": slots, "step": step}

    return Optimizer(init, update)


def state_bytes(state: Any) -> float:
    """Total optimizer-state bytes (for the §4.2 memory benchmark)."""
    return float(
        sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(state))
    )
