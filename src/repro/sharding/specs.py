"""PartitionSpec rules: tensor parallelism + batch/cache shardings per arch.

Rules (Megatron-style row/column splits, adapted per family):

* attention: wq/wk/wv column-split over head width (only when the head count
  divides the TP size — gemma3's 4 heads and recurrentgemma's 10 stay
  replicated), wo row-split.
* MLP: up/gate column-split on d_ff, down row-split.
* MoE: experts sharded over ``model`` (expert parallelism); router replicated.
* Mamba: column-split on d_inner for in/conv/dt, row-split for x_proj and
  out_proj (the scan is elementwise over d_inner, so it stays local).
* RG-LRU: column-split on the recurrence width; block-diag gates replicated.
* vocab: embedding row-split / head column-split over the padded vocab.

Stacked layer params (leaves under ``stack``/``enc_stack``) carry a leading
scan axis that is never sharded. ZeRO overlays (repro.core.zero) add the
``data`` axis on top of these specs.

Every rule checks divisibility against the mesh and falls back to
replication — a config/mesh combination can therefore always lower, and the
roofline report shows what that fallback costs (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# leaf-name classification (see module docstring)
_COL = {"wq", "wk", "wv", "w_up", "w_gate", "in_proj", "dt_proj", "conv_w"}
_ROW = {"wo", "w_down", "out_proj", "w_out", "x_proj"}
_DIM0 = {"A_log", "D", "dt_bias", "lam", "bias_a", "bias_x", "conv_b"}
_REPLICATE = {"scale", "bias", "router", "gate_a", "gate_x"}


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def leaf_param_spec(
    path: Tuple[str, ...], shape: Tuple[int, ...], cfg: ArchConfig, tp: int
) -> P:
    name = path[-1]
    stacked = "stack" in path  # leading scan axis
    dims: list = [None] * len(shape)
    body = shape[1:] if stacked else shape
    off = 1 if stacked else 0
    if not body:
        return P(*dims)

    is_moe_leaf = len(body) == 3 and name in ("w_up", "w_gate", "w_down")
    if is_moe_leaf:
        if _div(body[0], tp):
            dims[off] = "model"  # expert parallelism
        return P(*dims)

    if name in _REPLICATE:
        return P(*dims)
    if name == "table":  # embedding (V, d)
        if _div(body[0], tp):
            dims[off] = "model"
        return P(*dims)
    if name == "w" and path[-2] == "head":  # (d, V)
        if _div(body[1], tp):
            dims[off + 1] = "model"
        return P(*dims)

    # head-count guard for attention projections
    if name in ("wq", "wo") and not _div(cfg.n_heads, tp):
        return P(*dims)
    if name in ("wk", "wv") and not _div(cfg.n_kv_heads, tp):
        return P(*dims)

    if name in _COL and len(body) >= 2:
        if _div(body[-1], tp):
            dims[off + len(body) - 1] = "model"
        return P(*dims)
    if name in _ROW and len(body) >= 2:
        if _div(body[0], tp):
            dims[off] = "model"
        return P(*dims)
    if name in _DIM0 or len(body) == 1:
        if _div(body[0], tp):
            dims[off] = "model"
        return P(*dims)
    return P(*dims)


def param_specs(cfg: ArchConfig, params_shape: Any, mesh) -> Any:
    """Spec tree for a params(-shaped) pytree.

    On a 3D training mesh (one with a ``pipe`` axis), stacked layer params
    additionally shard their leading layer axis over ``pipe`` — contiguous
    equal-count stages, exactly the executable ParallelPlan layout: each
    pipe shard holds its stage's layer slice, TP dims unchanged.
    """
    tp = mesh.shape["model"] if "model" in mesh.shape else 1
    pp = mesh.shape["pipe"] if "pipe" in mesh.shape else 1

    def one(path, leaf):
        keys = tuple(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        spec = leaf_param_spec(keys, tuple(leaf.shape), cfg, tp)
        if (
            pp > 1 and "stack" in keys and leaf.shape
            and _div(leaf.shape[0], pp)
        ):
            dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
            assert dims[0] is None, (keys, spec)
            dims[0] = "pipe"
            spec = P(*dims)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_axes(mesh, global_batch: int) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) that divides the global batch."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    out = []
    size = 1
    for a in axes:
        if global_batch % (size * mesh.shape[a]) == 0:
            out.append(a)
            size *= mesh.shape[a]
    return tuple(out)


def batch_specs(batch_shape: Any, mesh, global_batch: int) -> Any:
    ba = batch_axes(mesh, global_batch)
    bspec = tuple(ba) if ba else None

    def one(leaf):
        dims = [bspec] + [None] * (len(leaf.shape) - 1)
        return P(*dims)

    return jax.tree.map(one, batch_shape)


def microbatch_specs(mb_shape: Any, mesh, mb_batch: int) -> Any:
    """Specs for microbatched arrays (M, B, ...): leading M replicated,
    per-microbatch batch dim over the data axes when divisible."""
    ba = batch_axes(mesh, mb_batch)
    bspec = tuple(ba) if ba else None

    def one(leaf):
        dims = [None, bspec] + [None] * (len(leaf.shape) - 2)
        return P(*dims)

    return jax.tree.map(one, mb_shape)


def cache_specs(cfg: ArchConfig, cache_shape: Any, mesh, global_batch: int) -> Any:
    """Decode-cache shardings.

    kv caches (R, B, S, kv, hd): batch over data axes when divisible; the
    cache SEQUENCE axis shards over ``model`` when kv-heads cannot (MQA) —
    sequence-parallel attention for decode (Pope et al.-style), which is what
    lets a 500k-token cache fit. SSM/RG-LRU states shard their channel dim.
    """
    tp = mesh.shape["model"] if "model" in mesh.shape else 1
    ba = batch_axes(mesh, global_batch)
    bspec = tuple(ba) if ba else None

    def one(path, leaf):
        keys = tuple(
            str(p.key) if hasattr(p, "key") else "" for p in path
        )
        name = keys[-1] if keys else ""
        shape = leaf.shape
        if name == "pos":
            return P(*([None] * len(shape)))
        if name in ("k", "v", "ck", "cv") and len(shape) >= 4:
            # (R, B, S, kv, hd) or (B, S, kv, hd)
            off = len(shape) - 4
            dims = [None] * len(shape)
            if off:
                dims[off] = bspec  # B
            else:
                dims[0] = bspec
            if _div(shape[off + 2], tp):
                dims[off + 2] = "model"       # kv heads
            elif _div(shape[off + 1], tp) and shape[off + 1] >= tp:
                dims[off + 1] = "model"       # sequence-parallel cache
            return P(*dims)
        if name in ("conv", "ssm", "h"):
            # (R, B, *, C) / (R, B, C, s) / (R, B, C): channel dim -> model
            dims = [None] * len(shape)
            dims[1] = bspec
            cdim = 2 if name == "h" else (2 if name == "ssm" else len(shape) - 1)
            if len(shape) > cdim and _div(shape[cdim], tp):
                dims[cdim] = "model"
            return P(*dims)
        if name == "memory" or (len(shape) == 3 and name == ""):
            dims = [bspec] + [None] * (len(shape) - 1)
            return P(*dims)
        dims = [None] * len(shape)
        if shape and bspec and _div(shape[0], _size(mesh, ba)):
            dims[0] = bspec
        return P(*dims)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def _size(mesh, axes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def pool_kv_spec(cfg: ArchConfig, ndim: int, tp: int) -> P:
    """Spec for one paged KV pool leaf ``(..., num_pages, page, Kv, hd)``.

    The pool shards over ``model`` on the KV-HEAD axis (dim -2) — the
    Megatron head split applied to serving memory: each chip stores
    ``Kv / tp`` heads of every page, so KV bytes per chip shrink by the TP
    factor while page ids stay globally valid (the block table is
    replicated). Falls back to replication when the head count doesn't
    divide (same guard as the wk/wv param rule above).
    """
    dims: list = [None] * ndim
    if _div(cfg.n_kv_heads, tp) and tp > 1:
        dims[-2] = "model"
    return P(*dims)


def pool_scale_spec(cfg: ArchConfig, ndim: int, tp: int) -> P:
    """Spec for a quantized pool's scale leaf ``(..., num_pages, page, Kv)``.

    Scales carry no head_dim axis, so the KV-head axis is the LAST dim;
    it shards over ``model`` under the same divisibility guard as
    :func:`pool_kv_spec` — each chip stores the scales for exactly the
    head slice of pages it holds.
    """
    dims: list = [None] * ndim
    if _div(cfg.n_kv_heads, tp) and tp > 1:
        dims[-1] = "model"
    return P(*dims)


def paged_state_specs(cfg: ArchConfig, state_shape: Any, mesh) -> Any:
    """Spec tree for the paged decode state (``models.lm.init_paged_state``).

    ``caches`` leaves are page pools (head-sharded, see ``pool_kv_spec``)
    plus, under a quantized ``kv_dtype``, their scale buffers (``ksc`` /
    ``vsc``, head-sharded on the last dim); ``tables``/``lengths`` (and any
    other host-updated slot arrays) are replicated — every chip addresses
    the same page ids.
    """
    tp = mesh.shape["model"] if "model" in mesh.shape else 1

    def one(path, leaf):
        keys = tuple(
            str(p.key) if hasattr(p, "key") else "" for p in path
        )
        if keys[-1] in ("kp", "vp"):
            return pool_kv_spec(cfg, len(leaf.shape), tp)
        if keys[-1] in ("ksc", "vsc"):
            return pool_scale_spec(cfg, len(leaf.shape), tp)
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, state_shape)


def stash_state_specs(state_shape: Any, mesh) -> Any:
    """Spec tree for a materialized activation-stash state (core.stash).

    Inside the pipeline runner the stash rides the shard_map scan carry and
    needs no specs; this helper covers the stash OUTSIDE shard_map — e.g. a
    stacked per-stage view with a leading ``pipe``-degree axis (checkpoint
    dumps, the bench's buffer measurement). The rule mirrors the quantized
    KV pool (PR 6): a leading axis equal to the pipe degree shards over
    ``pipe`` — and codes + scales shard together since both carry it —
    everything else (slot axis, blocks) is replicated.
    """
    pp = mesh.shape["pipe"] if "pipe" in mesh.shape else 1

    def one(leaf):
        dims: list = [None] * len(leaf.shape)
        if pp > 1 and len(leaf.shape) > 0 and leaf.shape[0] == pp:
            dims[0] = "pipe"
        return P(*dims)

    return jax.tree.map(one, state_shape)


def with_sharding(mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
