"""GQA attention: chunked (flash-structured) full-sequence path + KV-cache decode.

Design notes
------------
* The full-sequence path scans over query chunks so the (S, S) score matrix is
  never materialized — this is the pure-JAX baseline of flash attention; the
  Pallas kernel in ``repro.kernels.flash_attention`` is its TPU-tiled version.
  ``use_kernel=True`` is kernel-fused in both directions: the custom_vjp
  backward runs the Pallas dq/dkv kernels from saved (lse) stats.
* ``window > 0`` means sliding-window (local) attention; the chunked path then
  only reads the (window + chunk) key band per query chunk, so local-attention
  prefill is O(S * window) not O(S^2).
* Decode keeps a ring-buffer cache of size ``cache_len`` (= window for local
  layers) with an explicit position array, so sliding-window decode at 500k
  context holds only ``window`` entries.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, rope_apply, subkey

NEG_INF = -1e30
INVALID_POS = -(2**30)


def init_attention(
    key: jax.Array, d: int, n_heads: int, n_kv: int, head_dim: int, cross: bool = False
) -> Params:
    p = {
        "wq": dense_init(subkey(key, "wq"), d, n_heads * head_dim),
        "wk": dense_init(subkey(key, "wk"), d, n_kv * head_dim),
        "wv": dense_init(subkey(key, "wv"), d, n_kv * head_dim),
        "wo": dense_init(subkey(key, "wo"), n_heads * head_dim, d),
    }
    return p


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,Kv,G,hd), k: (B,Sk,Kv,hd) -> (B,Kv,G,Sq,Sk) in f32."""
    return jnp.einsum("bskgh,btkh->bkgst", q, k, preferred_element_type=jnp.float32)


def _gqa_combine(w: jax.Array, v: jax.Array, dtype) -> jax.Array:
    """w: (B,Kv,G,Sq,Sk), v: (B,Sk,Kv,hd) -> (B,Sq,Kv,G,hd)."""
    return jnp.einsum("bkgst,btkh->bskgh", w.astype(dtype), v)


def _softmax(scores: jax.Array, mask: jax.Array) -> jax.Array:
    scores = jnp.where(mask, scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


def attention_apply(
    p: Params,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    window: int = 0,
    causal: bool = True,
    positions: Optional[jax.Array] = None,
    memory: Optional[jax.Array] = None,
    memory_positions: Optional[jax.Array] = None,
    chunk_q: int = 512,
    collect_kv: bool = False,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full-sequence attention (train / prefill).

    x: (B, S, d). memory: (B, T, d) enables cross-attention (no causal mask,
    no rope on q/k per enc-dec convention here we do rope self-attn only).
    Returns (out (B,S,d), kv or None) where kv = roped k/v for cache prefill.
    """
    B, S, _ = x.shape
    dtype = x.dtype
    G = n_heads // n_kv
    q = _split_heads(x @ p["wq"].astype(dtype), n_heads, head_dim)
    if memory is None:
        src = x
    else:
        src = memory
    k = _split_heads(src @ p["wk"].astype(dtype), n_kv, head_dim)
    v = _split_heads(src @ p["wv"].astype(dtype), n_kv, head_dim)

    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    if memory is None:
        q = rope_apply(q, positions, theta)
        k = rope_apply(k, positions, theta)
        k_positions = positions
    else:
        if memory_positions is None:
            k_positions = jnp.arange(src.shape[1], dtype=jnp.int32)[None, :].repeat(B, 0)
        else:
            k_positions = memory_positions

    q = q.reshape(B, S, n_kv, G, head_dim) * (head_dim ** -0.5)

    if use_kernel and memory is None:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal, window)
        out = out.reshape(B, S, n_heads * head_dim)
        kv = {"k": k, "v": v} if collect_kv else None
        return out @ p["wo"].astype(dtype), kv

    def chunk_attn(q_chunk: jax.Array, qpos: jax.Array) -> jax.Array:
        # q_chunk: (B, C, Kv, G, hd); qpos: (B, C)
        if window > 0 and memory is None:
            # only the trailing (window + C) key band can be visible
            Sq = q_chunk.shape[1]
            band = min(k.shape[1], window + Sq)
            start = jnp.clip(qpos[0, 0] + Sq - band, 0, k.shape[1] - band)
            k_band = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            v_band = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpos = jax.lax.dynamic_slice_in_dim(k_positions, start, band, axis=1)
        else:
            k_band, v_band, kpos = k, v, k_positions
        scores = _gqa_scores(q_chunk, k_band)
        mask = jnp.ones(scores.shape[-2:], bool)[None, None, None]
        if causal and memory is None:
            mask = qpos[:, None, None, :, None] >= kpos[:, None, None, None, :]
        if window > 0 and memory is None:
            in_win = qpos[:, None, None, :, None] - kpos[:, None, None, None, :] < window
            mask = jnp.logical_and(mask, in_win)
        w = _softmax(scores, mask)
        return _gqa_combine(w, v_band, dtype)

    n_chunks = S // chunk_q if (S % chunk_q == 0 and S > chunk_q) else 1
    if n_chunks > 1:
        qs = q.reshape(B, n_chunks, chunk_q, n_kv, G, head_dim).swapaxes(0, 1)
        ps = positions.reshape(B, n_chunks, chunk_q).swapaxes(0, 1)
        out = jax.lax.map(lambda args: chunk_attn(*args), (qs, ps))
        out = out.swapaxes(0, 1).reshape(B, S, n_heads * head_dim)
    else:
        out = chunk_attn(q, positions).reshape(B, S, n_heads * head_dim)

    kv = {"k": k, "v": v} if collect_kv else None
    return out @ p["wo"].astype(dtype), kv


# ------------------------------------------------------------------- caching
def init_kv_cache(
    B: int, cache_len: int, n_kv: int, head_dim: int, dtype
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((B, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((B, cache_len, n_kv, head_dim), dtype),
        "pos": jnp.full((cache_len,), INVALID_POS, jnp.int32),
    }


def fill_kv_cache(
    cache: Dict[str, jax.Array], k: jax.Array, v: jax.Array, positions: jax.Array
) -> Dict[str, jax.Array]:
    """Populate a cache from prefill kv (keeps the trailing ``cache_len``)."""
    S_c = cache["k"].shape[1]
    S = k.shape[1]
    if S >= S_c:
        sel = slice(S - S_c, S)
        return {"k": k[:, sel], "v": v[:, sel], "pos": positions[0, sel]}
    pad = S_c - S
    return {
        "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "pos": jnp.concatenate(
            [positions[0], jnp.full((pad,), INVALID_POS, jnp.int32)]
        ),
    }


def attention_decode(
    p: Params,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    t: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    window: int = 0,
    memory: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, d); t: scalar int32 position of this token.

    Self-attention writes the roped k/v into the ring slot ``t % cache_len``
    and attends over all valid cache entries (window-masked via the explicit
    position array). Cross-attention (memory != None) attends over the full
    encoder output and leaves the cache untouched.
    """
    B = x.shape[0]
    dtype = x.dtype
    G = n_heads // n_kv
    q = _split_heads(x @ p["wq"].astype(dtype), n_heads, head_dim)

    if memory is not None:
        k = _split_heads(memory @ p["wk"].astype(dtype), n_kv, head_dim)
        v = _split_heads(memory @ p["wv"].astype(dtype), n_kv, head_dim)
        q = q.reshape(B, 1, n_kv, G, head_dim)[:, 0] * (head_dim ** -0.5)
        scores = jnp.einsum("bkgh,bskh->bkgs", q, k, preferred_element_type=jnp.float32)
        w = jax.nn.softmax(scores, axis=-1).astype(dtype)
        out = jnp.einsum("bkgs,bskh->bkgh", w, v).reshape(B, 1, n_heads * head_dim)
        return out @ p["wo"].astype(dtype), cache

    pos = jnp.full((B, 1), t, jnp.int32)
    q = rope_apply(q, pos, theta)
    k_new = rope_apply(_split_heads(x @ p["wk"].astype(dtype), n_kv, head_dim), pos, theta)
    v_new = _split_heads(x @ p["wv"].astype(dtype), n_kv, head_dim)

    S_c = cache["k"].shape[1]
    slot = (t % S_c).astype(jnp.int32)
    k_c = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v_c = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    pos_c = jax.lax.dynamic_update_slice(cache["pos"], t[None].astype(jnp.int32), (slot,))

    q = q.reshape(B, n_kv, G, head_dim) * (head_dim ** -0.5)
    scores = jnp.einsum("bkgh,bskh->bkgs", q, k_c, preferred_element_type=jnp.float32)
    valid = (pos_c >= 0) & (pos_c <= t)
    if window > 0:
        valid = valid & (pos_c > t - window)
    w = _softmax(scores, valid[None, None, None, :]).astype(dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v_c).reshape(B, 1, n_heads * head_dim)
    return out @ p["wo"].astype(dtype), {"k": k_c, "v": v_c, "pos": pos_c}


# ------------------------------------------------------------- paged caching
def init_paged_kv_cache(
    num_pages: int, page_size: int, n_kv: int, head_dim: int, dtype,
    kv_dtype: str = "",
) -> Dict[str, jax.Array]:
    """Per-layer KV page pool. Page 0 is the reserved null/trash page: block
    table padding and inactive-slot writes are routed there, and reads of it
    are always masked (or discarded with the slot's output).

    A quantized ``kv_dtype`` ("int8"/"fp8") stores the pools in the storage
    dtype and adds per-(page-slot, kv-head) f32 scale buffers ``ksc``/``vsc``
    (see ``kernels.paged_attention.quant``); the zero-initialized scales
    dequantize the null page to exact zeros."""
    from repro.kernels.paged_attention import quant

    store = quant.kv_storage_dtype(kv_dtype, dtype)
    pool = {
        "kp": jnp.zeros((num_pages, page_size, n_kv, head_dim), store),
        "vp": jnp.zeros((num_pages, page_size, n_kv, head_dim), store),
    }
    if quant.is_quantized(kv_dtype):
        pool["ksc"] = jnp.zeros((num_pages, page_size, n_kv), jnp.float32)
        pool["vsc"] = jnp.zeros((num_pages, page_size, n_kv), jnp.float32)
    return pool


def attention_prefill_paged(
    p: Params,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    tables: jax.Array,
    start: jax.Array,
    q_len: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    window: int = 0,
    use_kernel: bool = False,
    mesh=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One prefill *chunk* per slot against the paged KV pool.

    x: (B, T, d) chunk hidden states; cache: {"kp","vp"} (N, page, Kv, hd);
    tables: (B, P) int32; start: (B,) absolute position of x[:, 0]; q_len:
    (B,) valid rows (rows >= q_len are right-padding: their KV goes to the
    null page and their output is zeroed so downstream per-row compute
    stays deterministic).

    Write-then-attend: the chunk's roped K/V land in their block-table
    pages first, then every row attends with the absolute-position causal
    mask ``kpos <= start + t`` — which covers both the cached prefix (pages
    adopted from the radix cache or written by earlier chunks) and
    earlier-in-chunk positions, and never reads allocated-but-unwritten
    pages. ``attention_decode_paged`` is the T=1 special case of this.
    """
    from repro.kernels.paged_attention import paged_prefill_attention

    B, T, _ = x.shape
    dtype = x.dtype
    G = n_heads // n_kv
    page = cache["kp"].shape[1]

    pos = start[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # (B, T)
    q = rope_apply(_split_heads(x @ p["wq"].astype(dtype), n_heads, head_dim),
                   pos, theta)
    k_new = rope_apply(_split_heads(x @ p["wk"].astype(dtype), n_kv, head_dim),
                       pos, theta)
    v_new = _split_heads(x @ p["wv"].astype(dtype), n_kv, head_dim)

    valid = jnp.arange(T, dtype=jnp.int32)[None, :] < q_len[:, None]
    page_idx = pos // page
    ok = valid & (page_idx < tables.shape[1])
    pid = jnp.where(
        ok,
        jnp.take_along_axis(
            tables, jnp.clip(page_idx, 0, tables.shape[1] - 1), axis=1
        ),
        0,
    )
    slot = jnp.where(ok, pos % page, 0)
    new_cache = dict(cache)
    if "ksc" in cache:
        # quantize-once at write time: each token row's codes + scale depend
        # only on its own values, so pool bytes are batch-independent
        from repro.kernels.paged_attention import quant

        k_codes, k_sc = quant.kv_quantize(k_new, cache["kp"].dtype)
        v_codes, v_sc = quant.kv_quantize(v_new, cache["vp"].dtype)
        new_cache["kp"] = cache["kp"].at[pid, slot].set(k_codes)
        new_cache["vp"] = cache["vp"].at[pid, slot].set(v_codes)
        new_cache["ksc"] = cache["ksc"].at[pid, slot].set(k_sc)
        new_cache["vsc"] = cache["vsc"].at[pid, slot].set(v_sc)
        scales = {"k_scale": new_cache["ksc"], "v_scale": new_cache["vsc"]}
    else:
        new_cache["kp"] = cache["kp"].at[pid, slot].set(k_new)
        new_cache["vp"] = cache["vp"].at[pid, slot].set(v_new)
        scales = {}

    q = q.reshape(B, T, n_kv, G, head_dim) * (head_dim ** -0.5)
    out = paged_prefill_attention(
        q, new_cache["kp"], new_cache["vp"], tables, start, q_len,
        window=window, use_kernel=use_kernel, mesh=mesh, **scales,
    )
    out = jnp.where(valid[:, :, None, None, None], out, 0)
    out = out.astype(dtype).reshape(B, T, n_heads * head_dim)
    return out @ p["wo"].astype(dtype), new_cache


def attention_decode_paged(
    p: Params,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    tables: jax.Array,
    lengths: jax.Array,
    active: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    window: int = 0,
    use_kernel: bool = False,
    mesh=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode token per slot against the paged KV pool.

    x: (B, 1, d); cache: {"kp","vp"} (N, page, Kv, hd); tables: (B, P) int32;
    lengths: (B,) int32 tokens already cached per slot (the new token's
    position); active: (B,) bool — inactive slots write to the null page and
    their output is garbage by contract (the serve engine discards it).

    Unlike ``attention_decode``'s ring buffer, every slot here has its own
    position, so continuous batching can mix requests at different depths.
    With ``mesh`` set the attention itself runs shard_map'd over the
    ``model`` axis on per-shard head slices (see ``kernels.paged_attention``)
    — the tensor-parallel serving path.
    """
    from repro.kernels.paged_attention import paged_attention

    B = x.shape[0]
    dtype = x.dtype
    G = n_heads // n_kv
    page = cache["kp"].shape[1]

    pos = lengths[:, None].astype(jnp.int32)                   # (B, 1)
    q = rope_apply(_split_heads(x @ p["wq"].astype(dtype), n_heads, head_dim),
                   pos, theta)
    k_new = rope_apply(_split_heads(x @ p["wk"].astype(dtype), n_kv, head_dim),
                       pos, theta)
    v_new = _split_heads(x @ p["wv"].astype(dtype), n_kv, head_dim)

    page_idx = lengths // page
    in_range = page_idx < tables.shape[1]      # horizon overflow -> null page
    page_ids = jnp.take_along_axis(
        tables, jnp.clip(page_idx, 0, tables.shape[1] - 1)[:, None], axis=1
    )[:, 0]
    page_ids = jnp.where(active & in_range, page_ids, 0)
    slot = jnp.where(active & in_range, lengths % page, 0)
    new_cache = dict(cache)
    if "ksc" in cache:
        from repro.kernels.paged_attention import quant

        k_codes, k_sc = quant.kv_quantize(k_new[:, 0], cache["kp"].dtype)
        v_codes, v_sc = quant.kv_quantize(v_new[:, 0], cache["vp"].dtype)
        new_cache["kp"] = cache["kp"].at[page_ids, slot].set(k_codes)
        new_cache["vp"] = cache["vp"].at[page_ids, slot].set(v_codes)
        new_cache["ksc"] = cache["ksc"].at[page_ids, slot].set(k_sc)
        new_cache["vsc"] = cache["vsc"].at[page_ids, slot].set(v_sc)
        scales = {"k_scale": new_cache["ksc"], "v_scale": new_cache["vsc"]}
    else:
        new_cache["kp"] = cache["kp"].at[page_ids, slot].set(k_new[:, 0])
        new_cache["vp"] = cache["vp"].at[page_ids, slot].set(v_new[:, 0])
        scales = {}

    q = q.reshape(B, n_kv, G, head_dim) * (head_dim ** -0.5)
    out = paged_attention(
        q, new_cache["kp"], new_cache["vp"], tables, lengths + 1,
        window=window, use_kernel=use_kernel, mesh=mesh, **scales,
    )
    out = out.astype(dtype).reshape(B, 1, n_heads * head_dim)
    return out @ p["wo"].astype(dtype), new_cache
