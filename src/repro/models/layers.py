"""Shared primitive layers: init helpers, norms, RoPE, MLPs.

All modules are pure functions: ``init_*`` returns a params pytree (f32
masters), ``*_apply`` consumes params + activations. Compute dtype is passed
explicitly (mixed-precision policy lives in ``repro.core.precision``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# --------------------------------------------------------------------- init
def dense_init(key: jax.Array, d_in: int, d_out: int, scale: float = 1.0) -> jax.Array:
    """Truncated-normal fan-in init (f32 master weights)."""
    std = scale / np.sqrt(d_in)
    return std * jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out), jnp.float32)


def subkey(key: jax.Array, tag: str) -> jax.Array:
    """Deterministic named subkey (stable across processes — crc32, not hash())."""
    import zlib

    return jax.random.fold_in(key, zlib.crc32(tag.encode()) % (2**31))


# --------------------------------------------------------------------- norms
def init_norm(norm: str, d: int) -> Params:
    p: Params = {"scale": jnp.ones((d,), jnp.float32)}
    if norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(
    p: Params, x: jax.Array, norm: str, eps: float = 1e-6, fused: bool = False
) -> jax.Array:
    """RMSNorm / LayerNorm in f32, result cast back to x.dtype.

    ``fused=True`` routes RMSNorm through the Pallas kernel whose custom_vjp
    computes dx/dscale in one fused pass (repro.kernels.rmsnorm); LayerNorm
    has no fused path and falls through to the jnp implementation.
    """
    if fused and norm == "rmsnorm":
        from repro.kernels.rmsnorm import rmsnorm

        return rmsnorm(x, p["scale"], eps)
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if norm == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * p["scale"]
    if "bias" in p:
        out = out + p["bias"]
    return out.astype(dtype)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_apply(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                     # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- MLP
def init_mlp(key: jax.Array, d: int, hidden: int, gated: bool) -> Params:
    p: Params = {
        "w_up": dense_init(subkey(key, "up"), d, hidden),
        "w_down": dense_init(subkey(key, "down"), hidden, d),
    }
    if gated:
        p["w_gate"] = dense_init(subkey(key, "gate"), d, hidden)
    return p


def mlp_apply(p: Params, x: jax.Array, gated: bool, act: str = "silu") -> jax.Array:
    dtype = x.dtype
    up = x @ p["w_up"].astype(dtype)
    a = getattr(jax.nn, act)
    if gated:
        h = a(x @ p["w_gate"].astype(dtype)) * up
    else:
        h = a(up)
    return h @ p["w_down"].astype(dtype)


# ----------------------------------------------------------------- embedding
def init_embed(key: jax.Array, vocab: int, d: int) -> Params:
    return {"table": 0.02 * jax.random.normal(subkey(key, "embed"), (vocab, d), jnp.float32)}


def embed_apply(p: Params, ids: jax.Array, dtype: jnp.dtype) -> jax.Array:
    return jnp.take(p["table"].astype(dtype), ids, axis=0)


def logits_apply(
    head: Optional[Params], embed: Params, x: jax.Array, tied: bool
) -> jax.Array:
    """Final projection to (padded) vocab. Logits in f32 for a stable softmax."""
    xf = x.astype(jnp.float32)
    if tied:
        return xf @ embed["table"].astype(jnp.float32).T
    assert head is not None
    return xf @ head["w"].astype(jnp.float32)
