"""Mamba-1 selective SSM block (Falcon-Mamba architecture).

Train/prefill path uses an associative scan over time (O(log S) depth) or a
chunked scan (sequential over chunks, associative within — lower peak memory);
decode is a single recurrent step on an O(1) state.

State-space recurrence (per channel c, state s):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.compat import shard_map

from repro.models.layers import Params, dense_init, subkey


def dt_rank(d_inner: int) -> int:
    return max(1, d_inner // 16)


def init_mamba(key: jax.Array, d: int, d_inner: int, state: int, conv: int) -> Params:
    r = dt_rank(d_inner)
    # S4D-real A init: A[c, s] = -(s + 1)
    A = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32)[None, :], (d_inner, 1))
    dt_init = jax.random.uniform(
        subkey(key, "dtb"), (d_inner,), jnp.float32, 1e-3, 1e-1
    )
    return {
        "in_proj": dense_init(subkey(key, "in"), d, 2 * d_inner),
        "conv_w": 0.1 * jax.random.normal(subkey(key, "cw"), (conv, d_inner), jnp.float32),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "x_proj": dense_init(subkey(key, "xp"), d_inner, r + 2 * state),
        "dt_proj": dense_init(subkey(key, "dtp"), r, d_inner, scale=r**0.5),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),  # softplus^-1
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(subkey(key, "out"), d_inner, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is tiny (4); unrolled adds beat a grouped conv here
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b.astype(x.dtype)


def _ssm_coeffs(p: Params, x_conv: jax.Array) -> Tuple[jax.Array, ...]:
    """Input-dependent discretized (a, b) and readout C for the recurrence."""
    dtype = x_conv.dtype
    r = p["dt_proj"].shape[0]
    state = (p["x_proj"].shape[1] - r) // 2
    dbc = x_conv @ p["x_proj"].astype(dtype)
    dt_lo, B_ssm, C_ssm = jnp.split(dbc, [r, r + state], axis=-1)
    dt = jax.nn.softplus(
        (dt_lo @ p["dt_proj"].astype(dtype)).astype(jnp.float32) + p["dt_bias"]
    )                                                        # (B,S,di) f32
    A = -jnp.exp(p["A_log"])                                  # (di, s) f32
    a = jnp.exp(dt[..., None] * A)                            # (B,S,di,s)
    b = (dt * x_conv.astype(jnp.float32))[..., None] * B_ssm.astype(jnp.float32)[
        ..., None, :
    ]                                                         # (B,S,di,s)
    return a, b, C_ssm


def _readout(p: Params, h: jax.Array, C_ssm: jax.Array, x_conv: jax.Array) -> jax.Array:
    y = jnp.einsum("...ds,...s->...d", h, C_ssm.astype(jnp.float32))
    return (y + p["D"] * x_conv.astype(jnp.float32)).astype(x_conv.dtype)


def mamba_apply(
    p: Params,
    x: jax.Array,
    *,
    scan_mode: str = "assoc",
    chunk: int = 256,
    collect_state: bool = False,
):
    """Full-sequence Mamba. x: (B, S, d) -> (B, S, d) [+ final (conv,ssm) state]."""
    dtype = x.dtype
    d_inner = p["in_proj"].shape[1] // 2
    xz = x @ p["in_proj"].astype(dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_causal_conv(x_in, p["conv_w"].astype(dtype), p["conv_b"]))

    a, b, C_ssm = _ssm_coeffs(p, x_conv)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    if scan_mode == "assoc":
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    else:
        B, S = a.shape[:2]
        n = S // chunk
        assert S % chunk == 0, (S, chunk)
        ar = a.reshape(B, n, chunk, *a.shape[2:]).swapaxes(0, 1)
        br = b.reshape(B, n, chunk, *b.shape[2:]).swapaxes(0, 1)

        def step(h0, ab):
            ac, bc = ab
            A_c, Bh = jax.lax.associative_scan(combine, (ac, bc), axis=1)
            h_chunk = Bh + A_c * h0[:, None]
            return h_chunk[:, -1], h_chunk

        h0 = jnp.zeros((B,) + a.shape[2:], a.dtype)
        _, hs = jax.lax.scan(step, h0, (ar, br))
        h = hs.swapaxes(0, 1).reshape(a.shape)

    y = _readout(p, h, C_ssm, x_conv)
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(dtype)

    if collect_state:
        K = p["conv_w"].shape[0]
        S = x.shape[1]
        if S >= K - 1:
            conv_state = x_in[:, S - (K - 1) :, :]
        else:
            conv_state = jnp.pad(x_in, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"conv": conv_state, "ssm": h[:, -1]}
    return out


def mamba_apply_seqpar(
    p: Params,
    x: jax.Array,
    *,
    mesh,
    batch_axes,
    axis: str = "model",
):
    """Sequence-parallel Mamba: distribute the selective scan over ``axis``.

    Beyond-paper optimization (EXPERIMENTS.md §Perf pair 3): instead of
    tensor-parallel weights (whose per-layer all-reduces on (B, S, d_inner)
    dominate the collective roofline), shard the SEQUENCE over the model
    axis. Each device scans its chunk locally; only the O(B x d_inner x s)
    chunk summaries (a-product, boundary state) and a (K-1)-token conv halo
    cross the ICI — megabytes instead of gigabytes per layer. Weights are
    replicated inside the region (ZeRO-3 storage + one gather per layer).

    x: (B, S, d) global. Returns (B, S, d) global.
    """
    import jax.sharding as jsh

    P = jsh.PartitionSpec
    b = tuple(batch_axes) if batch_axes else None
    xspec = P(b, axis, None)
    pspec = jax.tree.map(lambda _: P(), p)

    def inner(p_, x_):
        dtype = x_.dtype
        n = compat.axis_size(axis)
        idx = jax.lax.axis_index(axis)
        d_inner = p_["in_proj"].shape[1] // 2
        xz = x_ @ p_["in_proj"].astype(dtype)
        x_in, z = jnp.split(xz, 2, axis=-1)

        # conv halo: previous chunk's last K-1 inputs from the left neighbor
        K = p_["conv_w"].shape[0]
        tail = x_in[:, -(K - 1) :, :]
        halo = jax.lax.ppermute(
            tail, axis, [(i, (i + 1) % n) for i in range(n)]
        )
        halo = jnp.where(idx == 0, jnp.zeros_like(halo), halo)
        x_ext = jnp.concatenate([halo, x_in], axis=1)
        x_conv = jax.nn.silu(
            _causal_conv(x_ext, p_["conv_w"].astype(dtype), p_["conv_b"])[
                :, K - 1 :, :
            ]
        )

        a, bb, C_ssm = _ssm_coeffs(p_, x_conv)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        A_cum, h_loc = jax.lax.associative_scan(combine, (a, bb), axis=1)

        # chunk summary -> exclusive prefix across devices (tiny collective)
        summ = (A_cum[:, -1], h_loc[:, -1])              # (B, di, s) x2
        all_A = jax.lax.all_gather(summ[0], axis)        # (n, B, di, s)
        all_h = jax.lax.all_gather(summ[1], axis)
        _, h_pref = jax.lax.associative_scan(combine, (all_A, all_h), axis=0)
        h0 = jnp.take(h_pref, jnp.maximum(idx - 1, 0), axis=0)
        h0 = jnp.where(idx == 0, jnp.zeros_like(h0), h0)

        h = h_loc + A_cum * h0[:, None]
        y = _readout(p_, h, C_ssm, x_conv)
        return (y * jax.nn.silu(z)) @ p_["out_proj"].astype(dtype)

    # default check_vma=True: replicated param in_specs then transpose to a
    # proper psum of the cotangents in the backward pass
    fn = shard_map(inner, mesh=mesh, in_specs=(pspec, xspec), out_specs=xspec)
    return fn(p, x)


def init_mamba_state(p: Params, B: int, dtype) -> Dict[str, jax.Array]:
    d_inner = p["in_proj"].shape[1] // 2
    K = p["conv_w"].shape[0]
    r = p["dt_proj"].shape[0]
    state = (p["x_proj"].shape[1] - r) // 2
    return {
        "conv": jnp.zeros((B, K - 1, d_inner), dtype),
        "ssm": jnp.zeros((B, d_inner, state), jnp.float32),
    }


def mamba_decode(
    p: Params, x: jax.Array, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token recurrent step. x: (B, 1, d)."""
    dtype = x.dtype
    xz = x[:, 0] @ p["in_proj"].astype(dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)                       # (B, di)

    w = p["conv_w"].astype(dtype)                             # (K, di)
    hist = jnp.concatenate([cache["conv"], x_in[:, None]], axis=1)  # (B, K, di)
    x_conv = jax.nn.silu(jnp.einsum("bkd,kd->bd", hist, w) + p["conv_b"].astype(dtype))

    a, b, C_ssm = _ssm_coeffs(p, x_conv[:, None])             # (B,1,di,s)
    h = a[:, 0] * cache["ssm"] + b[:, 0]
    y = _readout(p, h, C_ssm[:, 0], x_conv)
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(dtype)
    return out[:, None], {"conv": hist[:, 1:], "ssm": h}
