"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Two execution modes share one inner implementation:

* **local** — all experts on the current device (single-device smoke tests,
  or pure data-parallel runs).
* **expert-parallel** — wrapped in ``jax.shard_map`` over the ``model`` mesh
  axis by the distributed runtime (see ``repro.sharding.specs``): activations
  arrive replicated over ``model``; each device routes *all* local tokens,
  keeps the slots destined for its E/ep local experts, computes, and a final
  ``psum`` over ``model`` re-combines. No gshard one-hot dispatch einsums are
  used — their O(T*E*C*d) mask matmuls would dominate (and falsify) the
  HLO FLOP roofline; sort-based dispatch costs only the real expert FLOPs
  plus an O(T k log(T k)) sort.

Capacity: each expert processes at most C = ceil(cf * T_local * k / E)
tokens; overflow tokens are dropped (their combine weight contribution is 0)
per standard capacity-factor routing.

Shared experts / Arctic's dense-residual path are mathematically folded into
one always-on gated MLP (concatenating independent gated MLPs' hidden units
is exact) handled in the block, not here.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat

from repro.models.layers import Params, dense_init, subkey


def init_moe(
    key: jax.Array, d: int, d_ff: int, n_experts: int, gated: bool
) -> Params:
    def stack(tag: str, d_in: int, d_out: int) -> jax.Array:
        keys = jax.random.split(subkey(key, tag), n_experts)
        return jax.vmap(lambda k: dense_init(k, d_in, d_out))(keys)

    p: Params = {
        "router": dense_init(subkey(key, "router"), d, n_experts),
        "w_up": stack("up", d, d_ff),
        "w_down": stack("down", d_ff, d),
    }
    if gated:
        p["w_gate"] = stack("gate", d, d_ff)
    return p


def capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    return max(1, math.ceil(cf * n_tokens * top_k / n_experts))


def moe_apply(
    p: Params,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float,
    gated: bool,
    axis_name: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    When ``axis_name`` is set, this function runs *inside* shard_map: the
    expert leaves of ``p`` are the local E/ep shard and the output is psum'd.
    """
    B, S, d = x.shape
    dtype = x.dtype
    T = B * S
    xt = x.reshape(T, d)

    E_local = p["w_up"].shape[0]
    if axis_name is None:
        E_total, e0 = E_local, 0
    else:
        ep = compat.axis_size(axis_name)
        E_total = E_local * ep
        e0 = jax.lax.axis_index(axis_name) * E_local

    # ---- routing (identical on every model-shard: router is replicated) ----
    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, top_k)                 # (T, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # load-balance aux loss (Switch/GShard form), from the full router view
    frac_routed = jnp.mean(
        jax.nn.one_hot(gate_idx, E_total, dtype=jnp.float32), axis=(0, 1)
    )
    aux = E_total * jnp.sum(frac_routed * jnp.mean(probs, axis=0))

    # ---- slot bookkeeping: one slot per (token, choice) --------------------
    n_slots = T * top_k
    slot_expert = gate_idx.reshape(n_slots)                       # global ids
    slot_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    slot_w = gate_w.reshape(n_slots)

    local = (slot_expert >= e0) & (slot_expert < e0 + E_local)
    le = jnp.where(local, slot_expert - e0, E_local)              # E_local = trash
    order = jnp.argsort(le, stable=True)
    le_s = le[order]
    tok_s = slot_token[order]
    w_s = slot_w[order]

    # position of each sorted slot within its expert group
    group_start = jnp.searchsorted(le_s, jnp.arange(E_local + 1, dtype=le_s.dtype))
    pos = jnp.arange(n_slots, dtype=jnp.int32) - group_start[
        jnp.clip(le_s, 0, E_local)
    ].astype(jnp.int32)

    C = capacity(T, top_k, E_total, capacity_factor)
    keep = (le_s < E_local) & (pos < C)

    dest = jnp.where(keep, le_s.astype(jnp.int32) * C + pos, E_local * C)
    tok_for_slot = jnp.full((E_local * C + 1,), -1, jnp.int32).at[dest].set(
        jnp.where(keep, tok_s, -1)
    )[:-1]
    w_for_slot = jnp.zeros((E_local * C + 1,), jnp.float32).at[dest].set(
        jnp.where(keep, w_s, 0.0)
    )[:-1]

    # ---- gather -> expert MLPs -> weighted scatter-add ----------------------
    valid = tok_for_slot >= 0
    xin = jnp.where(
        valid[:, None], jnp.take(xt, jnp.clip(tok_for_slot, 0), axis=0), 0
    ).reshape(E_local, C, d)

    up = jnp.einsum("ecd,edf->ecf", xin, p["w_up"].astype(dtype))
    if gated:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"].astype(dtype))) * up
    else:
        h = jax.nn.silu(up)
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dtype))
    y_buf = y_buf.reshape(E_local * C, d) * w_for_slot[:, None].astype(dtype)

    out = (
        jnp.zeros((T + 1, d), dtype)
        .at[jnp.where(valid, tok_for_slot, T)]
        .add(y_buf)[:-1]
    )
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)
    return out.reshape(B, S, d), aux
