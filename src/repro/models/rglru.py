"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block structure (the Griffin "recurrent block"):
    u    = x @ w_in            (width w)
    gate = gelu(x @ w_gate)
    u    = causal_conv(u)
    h_t  = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)       (RG-LRU)
    out  = (h * gate) @ w_out
with input gate i_t = sigmoid(Wx u_t), recurrence gate r_t = sigmoid(Wa u_t),
a_t = exp(-c * softplus(Lambda) * r_t), c = 8. Wa/Wx are block-diagonal
(``n_blocks`` blocks) as in Griffin.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.compat import shard_map

from repro.models.layers import Params, dense_init, subkey
from repro.models.ssm import _causal_conv

RG_C = 8.0
N_BLOCKS = 8


def init_rglru(key: jax.Array, d: int, width: int, conv: int) -> Params:
    nb = N_BLOCKS
    bs = width // nb
    # Lambda init so that a in [0.9, 0.999] at r=1 (Griffin appendix)
    lam = jax.random.uniform(subkey(key, "lam"), (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(lam) / RG_C))  # softplus^-1(-log(a)/c)
    blk = lambda tag: (bs**-0.5) * jax.random.normal(
        subkey(key, tag), (nb, bs, bs), jnp.float32
    )
    return {
        "w_in": dense_init(subkey(key, "in"), d, width),
        "w_gate": dense_init(subkey(key, "gate"), d, width),
        "conv_w": 0.1 * jax.random.normal(subkey(key, "cw"), (conv, width), jnp.float32),
        "conv_b": jnp.zeros((width,), jnp.float32),
        "gate_a": blk("ga"),
        "bias_a": jnp.zeros((width,), jnp.float32),
        "gate_x": blk("gx"),
        "bias_x": jnp.zeros((width,), jnp.float32),
        "lam": lam,
        "w_out": dense_init(subkey(key, "out"), width, d),
    }


def _block_diag(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """u: (..., width) x block-diagonal w: (nb, bs, bs) -> (..., width)."""
    nb, bs, _ = w.shape
    ub = u.reshape(u.shape[:-1] + (nb, bs))
    out = jnp.einsum("...nc,ncd->...nd", ub, w.astype(u.dtype))
    return out.reshape(u.shape) + b.astype(u.dtype)


def _gates(p: Params, u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(a_t, gated input) in f32. u: (..., w)."""
    r = jax.nn.sigmoid(_block_diag(u, p["gate_a"], p["bias_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(u, p["gate_x"], p["bias_x"]).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * u.astype(jnp.float32)


def rglru_apply(p: Params, x: jax.Array, *, collect_state: bool = False):
    """Full-sequence recurrent block. x: (B, S, d)."""
    dtype = x.dtype
    u = x @ p["w_in"].astype(dtype)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dtype))
    u = _causal_conv(u, p["conv_w"].astype(dtype), p["conv_b"])

    a, b = _gates(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(dtype) * gate) @ p["w_out"].astype(dtype)
    if collect_state:
        K = p["conv_w"].shape[0]
        S = x.shape[1]
        xi = x @ p["w_in"].astype(dtype)
        if S >= K - 1:
            conv_state = xi[:, S - (K - 1) :, :]
        else:
            conv_state = jnp.pad(xi, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"conv": conv_state, "h": h[:, -1]}
    return out


def rglru_apply_seqpar(
    p: Params,
    x: jax.Array,
    *,
    mesh,
    batch_axes,
    axis: str = "model",
):
    """Sequence-parallel RG-LRU: distribute the linear recurrence over
    ``axis`` (same chunk-summary construction as
    ``repro.models.ssm.mamba_apply_seqpar``; the RG-LRU recurrence is the
    same first-order affine scan with elementwise (B, width) state)."""
    import jax.sharding as jsh

    P = jsh.PartitionSpec
    bspec = tuple(batch_axes) if batch_axes else None
    xspec = P(bspec, axis, None)
    pspec = jax.tree.map(lambda _: P(), p)

    def inner(p_, x_):
        dtype = x_.dtype
        n = compat.axis_size(axis)
        idx = jax.lax.axis_index(axis)
        u = x_ @ p_["w_in"].astype(dtype)
        gate = jax.nn.gelu(x_ @ p_["w_gate"].astype(dtype))

        K = p_["conv_w"].shape[0]
        tail = u[:, -(K - 1) :, :]
        halo = jax.lax.ppermute(tail, axis, [(i, (i + 1) % n) for i in range(n)])
        halo = jnp.where(idx == 0, jnp.zeros_like(halo), halo)
        u_ext = jnp.concatenate([halo, u], axis=1)
        u = _causal_conv(u_ext, p_["conv_w"].astype(dtype), p_["conv_b"])[
            :, K - 1 :, :
        ]

        a, b = _gates(p_, u)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        A_cum, h_loc = jax.lax.associative_scan(combine, (a, b), axis=1)
        all_A = jax.lax.all_gather(A_cum[:, -1], axis)
        all_h = jax.lax.all_gather(h_loc[:, -1], axis)
        _, h_pref = jax.lax.associative_scan(combine, (all_A, all_h), axis=0)
        h0 = jnp.take(h_pref, jnp.maximum(idx - 1, 0), axis=0)
        h0 = jnp.where(idx == 0, jnp.zeros_like(h0), h0)
        h = h_loc + A_cum * h0[:, None]
        return (h.astype(dtype) * gate) @ p_["w_out"].astype(dtype)

    fn = shard_map(inner, mesh=mesh, in_specs=(pspec, xspec), out_specs=xspec)
    return fn(p, x)


def init_rglru_state(p: Params, B: int, dtype) -> Dict[str, jax.Array]:
    width = p["w_in"].shape[1]
    K = p["conv_w"].shape[0]
    return {
        "conv": jnp.zeros((B, K - 1, width), dtype),
        "h": jnp.zeros((B, width), jnp.float32),
    }


def rglru_decode(
    p: Params, x: jax.Array, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step. x: (B, 1, d)."""
    dtype = x.dtype
    u_new = x[:, 0] @ p["w_in"].astype(dtype)                 # (B, w)
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"].astype(dtype))

    w = p["conv_w"].astype(dtype)
    hist = jnp.concatenate([cache["conv"], u_new[:, None]], axis=1)
    u = jnp.einsum("bkd,kd->bd", hist, w) + p["conv_b"].astype(dtype)

    a, b = _gates(p, u)
    h = a * cache["h"] + b
    out = (h.astype(dtype) * gate) @ p["w_out"].astype(dtype)
    return out[:, None], {"conv": hist[:, 1:], "h": h}
