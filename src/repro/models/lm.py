"""Top-level model: embeddings -> (encoder) -> decoder stack -> logits.

Batch convention (all arrays optional except tokens):
  tokens          (B, S_text) int32         decoder input ids
  labels          (B, S_text) int32         next-token targets, -1 = masked
  frontend_embeds (B, P, d) compute-dtype   stub modality embeddings:
                                            * audio/enc-dec: encoder input
                                            * vlm: patch embeds prepended to text

The VLM forward concatenates [image_embeds; embed(tokens)] so the sequence
length seen by the stack is P + S_text; loss is only taken on text positions.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import stack as stack_mod
from repro.models.layers import (
    Params,
    embed_apply,
    init_embed,
    init_norm,
    logits_apply,
    norm_apply,
    dense_init,
    subkey,
)
from repro.models.runtime import Runtime
from repro.models.stack import LayerSpec, layer_specs


# ----------------------------------------------------------------------- init
def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    p: Params = {
        "embed": init_embed(subkey(key, "embed"), cfg.vocab_padded, cfg.d_model),
        "stack": stack_mod.init_stack(cfg, subkey(key, "stack"), cross=cfg.is_encdec),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = {
            "w": dense_init(subkey(key, "head"), cfg.d_model, cfg.vocab_padded)
        }
    if cfg.is_encdec:
        enc_cfg = _encoder_cfg(cfg)
        p["enc_stack"] = stack_mod.init_stack(enc_cfg, subkey(key, "enc"))
        p["enc_norm"] = init_norm(cfg.norm, cfg.d_model)
    return p


def _encoder_cfg(cfg: ArchConfig) -> ArchConfig:
    import dataclasses

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-enc",
        n_layers=cfg.enc_layers,
        enc_layers=0,
        pattern=("attn",),
        ffn_kind="dense",
        frontend=None,
    )


# ------------------------------------------------------------------- encoder
def encode(cfg: ArchConfig, params: Params, embeds: jax.Array, rt: Runtime) -> jax.Array:
    """Bidirectional encoder over precomputed frontend embeddings."""
    enc_cfg = _encoder_cfg(cfg)
    specs = layer_specs(enc_cfg, seq_len=embeds.shape[1])
    x, _, _ = stack_mod.stack_forward(
        enc_cfg, params["enc_stack"], embeds.astype(rt.dtype), rt, specs,
        causal=False,
    )
    return norm_apply(params["enc_norm"], x, cfg.norm, fused=rt.fused_backward)


# ------------------------------------------------------------------- forward
def _decoder_input(
    cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array], rt: Runtime
) -> Tuple[jax.Array, Optional[jax.Array], int]:
    """Returns (x (B,S,d), memory, n_prefix) — n_prefix = non-text positions."""
    tok = embed_apply(params["embed"], batch["tokens"], rt.dtype)
    memory = None
    n_prefix = 0
    if cfg.frontend == "vision":
        img = batch["frontend_embeds"].astype(rt.dtype)
        tok = jnp.concatenate([img, tok], axis=1)
        n_prefix = img.shape[1]
    elif cfg.is_encdec:
        memory = encode(cfg, params, batch["frontend_embeds"], rt)
    return tok, memory, n_prefix


def forward_hidden(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    rt: Runtime,
) -> Tuple[jax.Array, jax.Array]:
    """Training forward up to (but not including) the vocab projection.

    Returns (hidden (B, S, d) after the final norm, aux). Splitting here lets
    ``loss_fn`` route the head through the chunked cross-entropy path without
    ever materializing (B, S, V) logits.
    """
    x, memory, _ = _decoder_input(cfg, params, batch, rt)
    specs = layer_specs(cfg, seq_len=x.shape[1], long_variant=rt.long_variant)
    x, aux, _ = stack_mod.stack_forward(
        cfg, params["stack"], x, rt, specs, memory=memory
    )
    x = norm_apply(params["final_norm"], x, cfg.norm, fused=rt.fused_backward)
    return x, aux


def forward(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    rt: Runtime,
) -> Tuple[jax.Array, jax.Array]:
    """Training forward: logits over the full sequence. Returns (logits, aux)."""
    x, aux = forward_hidden(cfg, params, batch, rt)
    logits = logits_apply(params.get("head"), params["embed"], x, cfg.tie_embeddings)
    return logits, aux


Z_LOSS_DEFAULT = 1e-4


def masked_token_ce(
    ll: jax.Array, logz: jax.Array, labels: jax.Array,
    z_loss: float = Z_LOSS_DEFAULT,
) -> Tuple[jax.Array, jax.Array]:
    """(xent, z_loss) from per-token (label log-lik, logZ); labels -1 masked.

    The one definition of the token loss — shared by ``loss_fn`` (dense and
    chunked-CE heads) and the pipeline trainer's last stage, so the 2D and
    3D paths cannot drift apart.
    """
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    xent = -(ll * mask).sum() / denom
    zl = z_loss * ((logz**2) * mask).sum() / denom
    return xent, zl


def loss_fn(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    rt: Runtime,
    z_loss: float = Z_LOSS_DEFAULT,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ router aux + z-loss). labels -1 are masked.

    ``rt.fused_backward`` routes the head through the vocab-chunked CE op
    (repro.kernels.chunked_ce): same loss/grads as the dense path, but the
    (B, S, V) logits and their gradient are never materialized at once.
    """
    h, aux = forward_hidden(cfg, params, batch, rt)
    labels = batch["labels"]
    if cfg.frontend == "vision":  # image prefix positions carry no loss
        n_prefix = batch["frontend_embeds"].shape[1]
        h = h[:, n_prefix:]
    safe = jnp.maximum(labels, 0)
    if rt.fused_backward:
        from repro.kernels.chunked_ce import chunked_ce

        w = (
            params["embed"]["table"]
            if cfg.tie_embeddings
            else params["head"]["w"].T
        )
        lab, logz = chunked_ce(h, w, safe, rt.ce_chunk)
        ll = lab - logz
    else:
        logits = logits_apply(
            params.get("head"), params["embed"], h, cfg.tie_embeddings
        )
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0] - logz
    xent, zl = masked_token_ce(ll, logz, labels, z_loss)
    total = xent + zl + cfg.router_aux_coef * aux
    metrics = {"loss": total, "xent": xent, "aux": aux, "z_loss": zl}
    return total, metrics


# ------------------------------------------------------------- 3D training
def pipeline_fns(cfg: ArchConfig, rt: Runtime, tp: int = 1):
    """(first_fn, stage_fn, last_fn) for ``repro.core.pipeline.pipeline_grads``.

    Splits the training forward at the plan's stage boundaries: stage 0
    embeds (first_fn), every stage applies its layer slice via the manual-TP
    ``stack_stage_apply`` (stage_fn — returns the router-aux loss term so
    MoE aux gradients flow from every stage), and the last stage runs final
    norm + head + masked cross-entropy with z-loss (last_fn), numerically
    identical to ``loss_fn``'s dense path per microbatch. Shared params
    (embed / final_norm / head) are replicated over the pipe axis; tied
    embeddings get their two contributions summed by the runner's psum.

    Loss normalization caveat: the step loss is the uniform mean of
    per-(microbatch, data-shard) masked means. With -1-masked labels whose
    valid-token counts differ across microbatches this weights microbatches
    equally rather than tokens (the microbatched-training standard; a
    global token mean would need the total valid count before any backward
    seeds, i.e. a second pass). Identical across schedules either way — it
    only differs from the 2D single-mean trainer on unevenly-masked
    batches.
    """
    from repro.models.stack import (
        pipeline_incompatibility, stack_stage_apply, stage_layer_params,
    )

    why = pipeline_incompatibility(cfg, tp)
    if why is not None:
        raise ValueError(f"{cfg.name}: {why}")
    kind = cfg.pattern[0]
    window = cfg.sliding_window if kind == "local" else 0
    spec = LayerSpec(kind, window, 0)

    def first_fn(shared: Params, mb: Dict[str, jax.Array]) -> jax.Array:
        return embed_apply(shared["embed"], mb["tokens"], rt.dtype)

    def stage_fn(sp: Params, x: jax.Array):
        y, aux = stack_stage_apply(
            cfg, stage_layer_params(sp), x, rt, spec, tp=tp
        )
        return y, cfg.router_aux_coef * aux

    def last_fn(shared: Params, y: jax.Array, mb: Dict[str, jax.Array]):
        h = norm_apply(shared["final_norm"], y, cfg.norm)
        logits = logits_apply(
            shared.get("head"), shared["embed"], h, cfg.tie_embeddings
        )
        labels = mb["labels"]
        safe = jnp.maximum(labels, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0] - logz
        xent, zl = masked_token_ce(ll, logz, labels)
        return xent + zl, {"xent": xent, "z_loss": zl}

    return first_fn, stage_fn, last_fn


# ------------------------------------------------------------------- serving
def prefill(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    rt: Runtime,
    max_len: Optional[int] = None,
    gather_pos: Optional[jax.Array] = None,
    full_cache: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Forward over the prompt, returning last-position logits + decode cache.

    ``max_len`` sizes the kv caches for the decode horizon (default: prompt
    length — i.e. ring-buffer reuse from the first generated token).
    ``gather_pos`` (traced scalar) selects which position's logits to return
    instead of the last — the bucketed-prefill path pads prompts to a shape
    bucket on the right and gathers at the true final position.
    ``full_cache`` collects every position for sliding-window layers too
    (cache_len = horizon instead of the window) — required by the paged
    serve engine, whose pool keeps all positions: a window-sized ring would
    drop real in-window tokens whenever the prompt is right-padded past the
    window (bucketed prefill).
    """
    x, memory, _ = _decoder_input(cfg, params, batch, rt)
    S = x.shape[1]
    specs = layer_specs(cfg, seq_len=S, long_variant=rt.long_variant)
    cache_specs = layer_specs(
        cfg, seq_len=max_len or S, long_variant=rt.long_variant
    )
    if full_cache:
        cache_specs = tuple(
            s._replace(cache_len=max_len or S)
            if s.kind in ("attn", "local") else s
            for s in cache_specs
        )
    x, _, caches = stack_mod.stack_forward(
        cfg, params["stack"], x, rt, specs, memory=memory, collect_cache=True,
        cache_specs=cache_specs,
    )
    if gather_pos is None:
        x = x[:, -1:]
    else:
        x = jax.lax.dynamic_slice_in_dim(x, gather_pos, 1, axis=1)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = logits_apply(params.get("head"), params["embed"], x, cfg.tie_embeddings)
    state = {"caches": caches, "t": jnp.array(S, jnp.int32)}
    if memory is not None:
        state["memory"] = memory
    return logits[:, 0], state


def init_decode_state(
    cfg: ArchConfig, params: Params, B: int, seq_len: int, rt: Runtime
) -> Dict[str, Any]:
    """Zero cache sized for a ``seq_len`` context (dry-run / bench entry)."""
    specs = layer_specs(cfg, seq_len=seq_len, long_variant=rt.long_variant)
    enc_len = cfg.frontend_tokens if cfg.is_encdec else 0
    caches = stack_mod.init_stack_cache(cfg, params["stack"], B, rt, specs, enc_len)
    state: Dict[str, Any] = {"caches": caches, "t": jnp.array(seq_len - 1, jnp.int32)}
    if cfg.is_encdec:
        state["memory"] = jnp.zeros((B, enc_len, cfg.d_model), rt.dtype)
    return state


def init_paged_state(
    cfg: ArchConfig,
    B: int,
    rt: Runtime,
    *,
    num_pages: int,
    page_size: int,
    max_len: int,
) -> Dict[str, Any]:
    """Paged decode state: per-layer KV page pools shared by ``B`` slots.

    ``tables`` rows are all-zero (null page) until the serve engine admits a
    request into the slot; ``lengths`` count cached tokens per slot. With
    ``rt.mesh`` set the pools are laid out head-sharded over the ``model``
    axis from the start (``sharding.specs.paged_state_specs``) — each device
    holds ``Kv / tp`` heads of every page — and the slot-addressing arrays
    are committed replicated so host-side ``.at[].set`` updates stay on the
    mesh.
    """
    specs = layer_specs(cfg, seq_len=max_len, long_variant=rt.long_variant)
    table_width = -(-max_len // page_size)

    def build() -> Dict[str, Any]:
        return {
            "caches": stack_mod.init_stack_pool(
                cfg, rt, specs, num_pages, page_size
            ),
            "tables": jnp.zeros((B, table_width), jnp.int32),
            "lengths": jnp.zeros((B,), jnp.int32),
        }

    if rt.mesh is None:
        return build()
    from repro.sharding.specs import paged_state_specs, with_sharding

    shardings = with_sharding(
        rt.mesh, paged_state_specs(cfg, jax.eval_shape(build), rt.mesh)
    )
    # allocate sharded from the start: a pool sized for TP-sharded capacity
    # need never fit on one chip, so no single-device staging copy
    return jax.jit(build, out_shardings=shardings)()


def prefill_chunk_paged(
    cfg: ArchConfig,
    params: Params,
    caches: List[Any],
    table_row: jax.Array,
    tokens: jax.Array,
    start: jax.Array,
    q_len: jax.Array,
    rt: Runtime,
    max_len: int,
) -> Tuple[jax.Array, List[Any]]:
    """Prefill ONE chunk of one request's prompt into the paged pool.

    tokens: (T,) int32 chunk token ids (right-padded past ``q_len``);
    table_row: (P,) the request's block-table row; start: scalar absolute
    position of tokens[0]; q_len: scalar valid tokens in this chunk.
    Returns (logits (V,) at the chunk's last valid position, new caches).
    The logits only matter on the prompt's final chunk (first-token
    sampling); computing them every chunk keeps one compiled program.

    With a cached prefix adopted from the radix cache, the first chunk
    starts at ``start = cached_tokens`` — the shared prefix is never
    re-computed (zero prefill FLOPs for it), only attended through the
    block table.
    """
    specs = layer_specs(cfg, seq_len=max_len, long_variant=rt.long_variant)
    x = embed_apply(params["embed"], tokens[None], rt.dtype)      # (1, T, d)
    x, caches = stack_mod.stack_prefill_paged(
        cfg, params["stack"], x, caches, table_row[None],
        start[None], q_len[None], rt, specs,
    )
    x = jax.lax.dynamic_slice_in_dim(x, q_len - 1, 1, axis=1)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = logits_apply(params.get("head"), params["embed"], x, cfg.tie_embeddings)
    return logits[0, 0], caches


def decode_step_paged(
    cfg: ArchConfig,
    params: Params,
    state: Dict[str, Any],
    token: jax.Array,
    rt: Runtime,
    max_len: int,
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One paged decode step; each slot advances at its own position.

    token: (B,) int32. ``active`` masks slots (inactive slots neither write
    the pool nor advance ``lengths``; their logits are discarded by the
    caller). Returns (logits (B, V), new state).
    """
    specs = layer_specs(cfg, seq_len=max_len, long_variant=rt.long_variant)
    lengths = state["lengths"]
    if active is None:
        active = jnp.ones(lengths.shape, bool)
    x = embed_apply(params["embed"], token[:, None], rt.dtype)
    x, caches = stack_mod.stack_decode(
        cfg, params["stack"], x, state["caches"], lengths, rt, specs,
        tables=state["tables"], active=active,
    )
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = logits_apply(params.get("head"), params["embed"], x, cfg.tie_embeddings)
    new_state = dict(
        state, caches=caches, lengths=lengths + active.astype(jnp.int32)
    )
    return logits[:, 0], new_state


def verify_step_paged(
    cfg: ArchConfig,
    params: Params,
    state: Dict[str, Any],
    tokens: jax.Array,
    q_len: jax.Array,
    rt: Runtime,
    max_len: int,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Batched multi-token verify pass for speculative decoding.

    tokens: (B, T) int32 — per slot, the pending token followed by the k
    draft tokens (T = k + 1), sitting at absolute positions
    ``lengths[b] .. lengths[b] + T - 1``; q_len: (B,) valid rows per slot
    (0 disables a slot: its rows write the null page and its logits are
    zeros). Returns (logits (B, T, V), new state).

    This is ``attention_prefill_paged`` at T = k + 1 — the chunked-prefill
    write-then-attend path — so row t's KV is written before any row
    attends, and row t attends exactly positions ``kpos <= lengths + t``:
    the same band a sequential decode step at that position would see.
    Rows therefore reproduce the sequential greedy decode stream, and
    rejected rows need no device-side rollback: their KV sits past the
    committed length, is never attended there, and is overwritten before
    any future attend. ``lengths`` is NOT advanced — the caller commits
    the accepted run length (host-side truncation via ``PagePool.truncate``
    is the pool-accounting half of the rollback).
    """
    specs = layer_specs(cfg, seq_len=max_len, long_variant=rt.long_variant)
    x = embed_apply(params["embed"], tokens, rt.dtype)            # (B, T, d)
    x, caches = stack_mod.stack_prefill_paged(
        cfg, params["stack"], x, state["caches"], state["tables"],
        state["lengths"], q_len, rt, specs,
    )
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = logits_apply(params.get("head"), params["embed"], x, cfg.tie_embeddings)
    return logits, dict(state, caches=caches)


def decode_step(
    cfg: ArchConfig,
    params: Params,
    state: Dict[str, Any],
    token: jax.Array,
    rt: Runtime,
    seq_len: int,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. token: (B,) int32. Returns (logits (B, V), new state)."""
    specs = layer_specs(cfg, seq_len=seq_len, long_variant=rt.long_variant)
    x = embed_apply(params["embed"], token[:, None], rt.dtype)
    t = state["t"]
    x, caches = stack_mod.stack_decode(
        cfg, params["stack"], x, state["caches"], t, rt, specs
    )
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = logits_apply(params.get("head"), params["embed"], x, cfg.tie_embeddings)
    new_state = dict(state, caches=caches, t=t + 1)
    return logits[:, 0], new_state
