"""Runtime knobs threaded through model application (not part of ArchConfig).

ArchConfig is *what* the network is; Runtime is *how* to execute it on the
current step: compute dtype, attention chunking, kernel routing, MoE execution
mode, remat policy, and the mesh axes the batch is sharded over (needed by
shard_map-based sub-modules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Runtime:
    dtype: Any = jnp.bfloat16
    chunk_q: int = 512               # query-chunk for flash-structured attention
    use_flash_kernel: bool = False   # route attention through the Pallas kernel
    scan_mode: str = "assoc"         # mamba scan: assoc | chunked
    ssm_chunk: int = 256
    moe_mode: str = "auto"           # auto (pjit decides) | ep (shard_map expert-parallel)
    mesh: Optional[Any] = None       # jax Mesh, required for moe_mode="ep"
    batch_axes: Tuple[str, ...] = () # mesh axes the batch dim is sharded over
    remat: str = "none"              # none | full | dots | offload
    # Route backward passes through the fused Pallas/chunked paths:
    # flash-attention dq/dkv kernels (with use_flash_kernel), the fused
    # RMSNorm dx/dscale kernel, and the vocab-chunked cross-entropy head
    # that never materializes (B, S, V) logits (survey §2.2).
    fused_backward: bool = False
    ce_chunk: int = 2048             # vocab chunk for the fused CE head
    # checkpoint granularity: group this many scan units per checkpoint —
    # the executable form of the §2.1 periodic/binomial plans (a plan with
    # L/k checkpoints == remat="full" at remat_period=k); see
    # repro.core.remat.period_from_plan
    remat_period: int = 1
    long_variant: bool = False       # run the sliding-window long-context variant
    moe_aux: bool = True             # include router load-balance aux loss
    # Activation-sharding mode at layer boundaries (EXPERIMENTS.md §Perf):
    #   "seq"    — Megatron-SP analog: shard the SEQUENCE dim over 'model';
    #              stored activations shrink by the TP factor, XLA inserts
    #              AG before attention / RS after.
    #   "hidden" — shard the HIDDEN dim over 'model': same memory win, but
    #              keeps channel-sharded layers (Mamba d_inner) in one layout
    #              end-to-end (no per-layer S<->channel resharding).
    seq_shard: str = ""              # "" | "seq" | "hidden"
    # distributed selective scan: shard the SSM sequence over 'model' with
    # chunk-summary handoff (repro.models.ssm.mamba_apply_seqpar)
    ssm_seqpar: bool = False
    # Paged-KV decode (repro.serve engine): route the per-slot decode
    # attention through the Pallas paged kernel (block-table page gathers)
    # instead of the pure-jnp oracle. The oracle is the faster CPU path.
    use_paged_kernel: bool = False
    # Activation-stash codec routing (core.stash.QuantStash): route the
    # int8/fp8 slot quantize/dequantize through the fused Pallas kernels
    # where they compile (kernels.blockwise_quant.ops.fused_codec_backend;
    # the jnp path elsewhere — bitwise-identical either way)
    fused_stash: bool = False
    # Paged KV pool storage dtype: "" = native (pools stored at ``dtype``),
    # "int8" / "fp8" = quantized pages + per-(page-slot, head) f32 scales,
    # dequantized inside the paged kernels' page gather
    # (kernels.paged_attention.quant). Write paths quantize each token row
    # exactly once at write time, preserving batched==alone determinism at
    # a fixed kv_dtype.
    kv_dtype: str = ""

    def replace(self, **kw) -> "Runtime":
        return dataclasses.replace(self, **kw)
