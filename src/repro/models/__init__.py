from repro.models.lm import (  # noqa: F401
    decode_step,
    decode_step_paged,
    forward,
    forward_hidden,
    init_decode_state,
    init_paged_state,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.runtime import Runtime  # noqa: F401
