from repro.models.lm import (  # noqa: F401
    decode_step,
    decode_step_paged,
    forward,
    forward_hidden,
    init_decode_state,
    init_paged_state,
    init_params,
    loss_fn,
    prefill,
    prefill_chunk_paged,
    verify_step_paged,
)
from repro.models.runtime import Runtime  # noqa: F401
