"""Layer-stack engine: init + forward/prefill/decode over scanned units.

The stack is executed as a sequence of *segments*; each segment is a
``lax.scan`` over ``R`` repeats of a *unit* of layers (statically-known mixer
kinds and windows inside the unit). This keeps HLO size O(unit) for 88-layer
stacks while supporting patterned architectures:

  granite / phi3 / qwen3 / ...   unit = (attn,)           R = n_layers
  gemma3                         unit = 5x local + attn   R = 4  (+ tail 2)
  recurrentgemma                 unit = (rglru, rglru, local)  R = 8 (+ tail 2)
  sw-variant long-context        unit = 7x local + attn   R = n_layers/8

Parameters are stored grouped by the *param pattern* (mixer kinds modulo
attn==local, which share parameters); at apply time they are re-grouped to
the *runtime pattern* (which also fixes windows/cache sizes) by strided
slicing — a pure-layout transform.

For 3D pipelined training, ``stack_stage_apply`` applies one pipeline
stage's contiguous layer slice of a homogeneous stack (the canonical
stacked layout sharded over ``pipe`` IS the stage split) with manual
Megatron tensor parallelism (``tp_region_start/end``); see the
"pipeline stage apply" section below and repro.core.pipeline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import Params, init_mlp, init_norm, mlp_apply, norm_apply
from repro.models.runtime import Runtime


class LayerSpec(NamedTuple):
    kind: str       # attn | local | rglru | mamba
    window: int     # 0 = full attention
    cache_len: int  # kv cache entries (attention kinds only)


def param_kind(kind: str) -> str:
    return "attn" if kind == "local" else kind


# --------------------------------------------------------------------- specs
def layer_specs(
    cfg: ArchConfig, *, seq_len: int, long_variant: bool = False
) -> Tuple[LayerSpec, ...]:
    kinds = cfg.mixer_kinds()
    out: List[LayerSpec] = []
    if long_variant and cfg.long_context == "sw_variant":
        for i in range(cfg.n_layers):
            if (i + 1) % cfg.lc_global_every == 0:
                out.append(LayerSpec("attn", 0, seq_len))
            else:
                w = cfg.lc_window
                out.append(LayerSpec("local", w, min(w, seq_len)))
        return tuple(out)
    for k in kinds:
        if k == "attn":
            out.append(LayerSpec("attn", 0, seq_len))
        elif k == "local":
            w = cfg.sliding_window
            out.append(LayerSpec("local", w, min(w, seq_len)))
        else:
            out.append(LayerSpec(k, 0, 0))
    return tuple(out)


def runtime_period(cfg: ArchConfig, long_variant: bool) -> int:
    if long_variant and cfg.long_context == "sw_variant":
        return cfg.lc_global_every
    return len(cfg.pattern)


def param_groups(cfg: ArchConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """[(unit param-kind pattern, repeats)] — variant-independent storage."""
    kinds = tuple(param_kind(k) for k in cfg.pattern)
    if len(set(kinds)) == 1:
        return [((kinds[0],), cfg.n_layers)]
    u = len(kinds)
    n, rem = divmod(cfg.n_layers, u)
    groups = [(kinds, n)]
    if rem:
        groups.append((kinds[:rem], 1))
    return groups


# ---------------------------------------------------------------------- init
def _init_block(cfg: ArchConfig, key: jax.Array, kind: str, cross: bool) -> Params:
    p: Params = {"norm1": init_norm(cfg.norm, cfg.d_model)}
    if kind == "attn":
        p["mixer"] = attn_mod.init_attention(
            jax.random.fold_in(key, 1), cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.head_dim,
        )
    elif kind == "mamba":
        p["mixer"] = ssm_mod.init_mamba(
            jax.random.fold_in(key, 1), cfg.d_model, cfg.d_inner, cfg.ssm_state,
            cfg.ssm_conv,
        )
    elif kind == "rglru":
        p["mixer"] = rglru_mod.init_rglru(
            jax.random.fold_in(key, 1), cfg.d_model,
            cfg.rglru_width or cfg.d_model, cfg.ssm_conv,
        )
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = init_norm(cfg.norm, cfg.d_model)
        p["cross"] = attn_mod.init_attention(
            jax.random.fold_in(key, 2), cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.head_dim, cross=True,
        )
    if cfg.ffn_kind != "none":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
        if cfg.ffn_kind == "dense":
            p["ffn"] = init_mlp(
                jax.random.fold_in(key, 3), cfg.d_model, cfg.d_ff, cfg.mlp_gated
            )
        else:
            p["ffn"] = moe_mod.init_moe(
                jax.random.fold_in(key, 3), cfg.d_model, cfg.d_ff, cfg.n_experts,
                cfg.mlp_gated,
            )
            extra = cfg.n_shared_experts * cfg.d_ff + (
                cfg.residual_d_ff if cfg.dense_residual else 0
            )
            if extra:
                p["extra_mlp"] = init_mlp(
                    jax.random.fold_in(key, 4), cfg.d_model, extra, cfg.mlp_gated
                )
    return p


def init_stack(cfg: ArchConfig, key: jax.Array, cross: bool = False) -> Params:
    """Stacked (R, ...) params per param-group (see ``param_groups``)."""
    stack: Params = {}
    layer0 = 0
    for gi, (pattern, R) in enumerate(param_groups(cfg)):
        def init_unit(k: jax.Array) -> Params:
            return {
                f"p{j}": _init_block(cfg, jax.random.fold_in(k, j), kind, cross)
                for j, kind in enumerate(pattern)
            }

        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            layer0 + jnp.arange(R)
        )
        stack[f"g{gi}"] = jax.vmap(init_unit)(keys)
        layer0 += R * len(pattern)
    return stack


# ----------------------------------------------------------------- segments
@dataclasses.dataclass(frozen=True)
class Segment:
    unit_specs: Tuple[LayerSpec, ...]   # static per-position specs
    group_key: str                      # which param group this reads from
    take: Tuple[Tuple[int, int, int], ...]  # per-position (start, stop, step) on L axis
    repeats: int
    patterned: bool                     # param storage keyed by unit position?


def _spec_period(specs: Tuple[LayerSpec, ...]) -> int:
    """Smallest U with specs[i] == specs[i % U] for the non-tail prefix."""
    L = len(specs)
    for U in range(1, L + 1):
        if all(specs[i] == specs[i % U] for i in range(L - (L % U))):
            return U
    return L


def build_segments(
    cfg: ArchConfig, specs: Tuple[LayerSpec, ...]
) -> List[Segment]:
    groups = param_groups(cfg)
    if len(groups[0][0]) > 1:
        # patterned param storage (recurrentgemma): runtime unit == param unit
        segs = []
        off = 0
        for gi, (pattern, R) in enumerate(groups):
            u = len(pattern)
            segs.append(
                Segment(
                    unit_specs=specs[off : off + u],
                    group_key=f"g{gi}",
                    take=tuple((j, j + 1, 1) for j in range(u)),
                    repeats=R,
                    patterned=True,
                )
            )
            off += R * u
        return segs

    # homogeneous params: re-group to the runtime period by strided slices
    L = cfg.n_layers
    U = _spec_period(specs)
    n, rem = divmod(L, U)
    segs = [
        Segment(
            unit_specs=specs[:U],
            group_key="g0",
            take=tuple((j, n * U, U) for j in range(U)),
            repeats=n,
            patterned=False,
        )
    ]
    if rem:
        segs.append(
            Segment(
                unit_specs=specs[n * U :],
                group_key="g0",
                take=tuple((n * U + j, n * U + j + 1, 1) for j in range(rem)),
                repeats=1,
                patterned=False,
            )
        )
    return segs


def _widen_segment(seg: Segment, k: int) -> Segment:
    """Group k repeats into one scan unit (plan-based remat granularity).

    Only applies cleanly to homogeneous-storage segments whose repeat count
    divides by k; otherwise the segment is returned unchanged (the plan
    degrades gracefully on pattern tails)."""
    if seg.patterned or seg.repeats % k or seg.repeats < k:
        return seg
    U = len(seg.unit_specs)
    new_take = []
    for rep in range(k):
        for j, (start, stop, step) in enumerate(seg.take):
            # position (rep, j) reads layer (r*k + rep)*U + j = start + rep*U + r*(k*U)
            new_take.append((start + rep * step, stop, step * k))
    return Segment(
        unit_specs=seg.unit_specs * k,
        group_key=seg.group_key,
        take=tuple(new_take),
        repeats=seg.repeats // k,
        patterned=False,
    )


def segment_params(stack: Params, seg: Segment) -> Params:
    """Extract per-unit-position stacked params: {'p{j}': leaves (R, ...)}."""
    group = stack[seg.group_key]
    if seg.patterned:
        return {f"p{j}": group[f"p{j}"] for j in range(len(seg.take))}
    # homogeneous storage: group = {'p0': leaves (L, ...)}; strided re-group
    src = group["p0"]
    out: Params = {}
    for j, (start, stop, step) in enumerate(seg.take):
        out[f"p{j}"] = jax.tree.map(lambda p, s=start, e=stop, st=step: p[s:e:st], src)
    return out


# ------------------------------------------------------------------- apply
def _seq_shard_constraint(h: jax.Array, rt: Runtime) -> jax.Array:
    """Sequence/hidden-parallel residual stream (see Runtime.seq_shard)."""
    if not rt.seq_shard or rt.mesh is None or h.ndim != 3:
        return h
    dim = 1 if rt.seq_shard == "seq" else 2
    if h.shape[dim] % rt.mesh.shape["model"] != 0:
        return h
    P = jax.sharding.PartitionSpec
    b = tuple(rt.batch_axes) if rt.batch_axes else None
    spec = P(b, "model", None) if dim == 1 else P(b, None, "model")
    return jax.lax.with_sharding_constraint(
        h, jax.sharding.NamedSharding(rt.mesh, spec)
    )


def _ffn_apply(
    cfg: ArchConfig, p: Params, x: jax.Array, rt: Runtime
) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    if cfg.ffn_kind == "none":
        return x, aux
    h = norm_apply(p["norm2"], x, cfg.norm, fused=rt.fused_backward)
    if cfg.ffn_kind == "dense":
        out = mlp_apply(p["ffn"], h, cfg.mlp_gated)
    else:
        out, aux = _moe_dispatch(cfg, p["ffn"], h, rt)
        if "extra_mlp" in p:
            out = out + mlp_apply(p["extra_mlp"], h, cfg.mlp_gated)
    return x + out, aux


def _moe_dispatch(
    cfg: ArchConfig, p: Params, h: jax.Array, rt: Runtime
) -> Tuple[jax.Array, jax.Array]:
    kw = dict(
        top_k=cfg.experts_top_k,
        capacity_factor=cfg.capacity_factor,
        gated=cfg.mlp_gated,
    )
    if rt.moe_mode != "ep":
        return moe_mod.moe_apply(p, h, **kw)
    assert rt.mesh is not None, "moe_mode='ep' requires Runtime.mesh"
    P = jax.sharding.PartitionSpec
    bspec = P(rt.batch_axes if rt.batch_axes else None, None, None)
    pspec = {
        "router": P(None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if cfg.mlp_gated:
        pspec["w_gate"] = P("model", None, None)

    def inner(p_, h_):
        out, aux = moe_mod.moe_apply(p_, h_, axis_name="model", **kw)
        axes = tuple(rt.batch_axes) + ("model",)
        return out, jax.lax.pmean(aux, axes)

    fn = shard_map(
        inner,
        mesh=rt.mesh,
        in_specs=(pspec, bspec),
        out_specs=(bspec, P()),
        check_vma=False,
    )
    return fn(p, h)


def _mixer_apply(
    cfg: ArchConfig,
    p: Params,
    x: jax.Array,
    spec: LayerSpec,
    rt: Runtime,
    positions: Optional[jax.Array],
    collect: bool,
    causal: bool = True,
    cache_len: Optional[int] = None,
):
    h = norm_apply(p["norm1"], x, cfg.norm, fused=rt.fused_backward)
    if spec.kind in ("attn", "local"):
        out, kv = attn_mod.attention_apply(
            p["mixer"], h,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            theta=cfg.rope_theta, window=spec.window, causal=causal,
            positions=positions, chunk_q=rt.chunk_q, collect_kv=collect,
            use_kernel=rt.use_flash_kernel,
        )
        cache = None
        if collect:
            cache = attn_mod.fill_kv_cache(
                attn_mod.init_kv_cache(
                    x.shape[0], cache_len or spec.cache_len, cfg.n_kv_heads,
                    cfg.head_dim, rt.dtype,
                ),
                kv["k"], kv["v"], positions,
            )
        return x + out, cache
    if spec.kind == "mamba":
        if rt.ssm_seqpar and rt.mesh is not None and not collect:
            res = ssm_mod.mamba_apply_seqpar(
                p["mixer"], h, mesh=rt.mesh, batch_axes=rt.batch_axes,
            )
        else:
            res = ssm_mod.mamba_apply(
                p["mixer"], h, scan_mode=rt.scan_mode, chunk=rt.ssm_chunk,
                collect_state=collect,
            )
    else:
        if rt.ssm_seqpar and rt.mesh is not None and not collect:
            res = rglru_mod.rglru_apply_seqpar(
                p["mixer"], h, mesh=rt.mesh, batch_axes=rt.batch_axes,
            )
        else:
            res = rglru_mod.rglru_apply(p["mixer"], h, collect_state=collect)
    if collect:
        out, cache = res
        return x + out, cache
    return x + res, None


def _cross_apply(
    cfg: ArchConfig, p: Params, x: jax.Array, memory: jax.Array, rt: Runtime
) -> jax.Array:
    h = norm_apply(p["norm_x"], x, cfg.norm, fused=rt.fused_backward)
    out, _ = attn_mod.attention_apply(
        p["cross"], h,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        theta=cfg.rope_theta, window=0, causal=False, memory=memory,
        chunk_q=rt.chunk_q,
    )
    return x + out


def stack_forward(
    cfg: ArchConfig,
    stack: Params,
    x: jax.Array,
    rt: Runtime,
    specs: Tuple[LayerSpec, ...],
    *,
    positions: Optional[jax.Array] = None,
    memory: Optional[jax.Array] = None,
    collect_cache: bool = False,
    causal: bool = True,
    cache_specs: Optional[Tuple[LayerSpec, ...]] = None,
):
    """Full-sequence forward. Returns (x, aux_loss, caches | None).

    ``caches``: list aligned with segments; each entry is a pytree whose
    leaves are stacked (R, ...) per unit position — the decode cache layout.
    ``cache_specs`` (same period as ``specs``) sizes the collected caches for
    a longer decode horizon than the prefill length.
    """
    if positions is None:
        B, S = x.shape[0], x.shape[1]
        positions = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None, :], (B, 1))
    if cache_specs is None:
        cache_specs = specs
    segments = build_segments(cfg, specs)
    if rt.remat_period > 1:
        segments = [_widen_segment(s, rt.remat_period) for s in segments]
    aux_total = jnp.zeros((), jnp.float32)
    caches: List[Any] = []
    seg_off = 0

    for seg in segments:
        params_seg = segment_params(stack, seg)
        unit_cache_specs = tuple(
            cache_specs[(seg_off + j) % len(cache_specs)]
            for j in range(len(seg.unit_specs))
        )
        seg_off += seg.repeats * len(seg.unit_specs)

        def unit_body(carry, unit_p, _seg=seg, _cspecs=unit_cache_specs):
            h, aux = carry
            h = _seq_shard_constraint(h, rt)
            unit_caches = {}
            for j, spec in enumerate(_seg.unit_specs):
                bp = unit_p[f"p{j}"]
                h, cache = _mixer_apply(
                    cfg, bp, h, spec, rt, positions, collect_cache, causal,
                    cache_len=_cspecs[j].cache_len,
                )
                if memory is not None and "cross" in bp:
                    h = _cross_apply(cfg, bp, h, memory, rt)
                    if collect_cache:
                        dtype = rt.dtype
                        ck = (memory @ bp["cross"]["wk"].astype(dtype)).reshape(
                            memory.shape[0], memory.shape[1], cfg.n_kv_heads, cfg.head_dim
                        )
                        cv = (memory @ bp["cross"]["wv"].astype(dtype)).reshape(
                            memory.shape[0], memory.shape[1], cfg.n_kv_heads, cfg.head_dim
                        )
                        cache = {"self": cache, "ck": ck, "cv": cv}
                h, aux_l = _ffn_apply(cfg, bp, h, rt)
                aux = aux + aux_l
                if collect_cache:
                    unit_caches[f"p{j}"] = cache
            return (h, aux), (unit_caches if collect_cache else None)

        body = unit_body
        if rt.remat == "full":
            body = jax.checkpoint(unit_body, prevent_cse=False)
        elif rt.remat == "dots":
            body = jax.checkpoint(
                unit_body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        elif rt.remat == "offload":
            body = jax.checkpoint(
                unit_body, prevent_cse=False,
                policy=jax.checkpoint_policies.offload_dot_with_no_batch_dims(
                    "device", "pinned_host"
                ),
            )

        (x, aux_total), seg_cache = jax.lax.scan(
            body, (x, aux_total), params_seg
        )
        caches.append(seg_cache)

    return x, aux_total, (caches if collect_cache else None)


def init_stack_cache(
    cfg: ArchConfig,
    stack: Params,
    B: int,
    rt: Runtime,
    specs: Tuple[LayerSpec, ...],
    enc_len: int = 0,
) -> List[Any]:
    """Zero decode cache in the segment layout (used when skipping prefill)."""
    segments = build_segments(cfg, specs)
    caches = []
    for seg in segments:
        unit: Dict[str, Any] = {}
        for j, spec in enumerate(seg.unit_specs):
            if spec.kind in ("attn", "local"):
                c: Any = attn_mod.init_kv_cache(
                    B, spec.cache_len, cfg.n_kv_heads, cfg.head_dim, rt.dtype
                )
            elif spec.kind == "mamba":
                p0 = jax.tree.map(
                    lambda p: p[0], segment_params(stack, seg)[f"p{j}"]
                )
                c = ssm_mod.init_mamba_state(p0["mixer"], B, rt.dtype)
            else:
                p0 = jax.tree.map(
                    lambda p: p[0], segment_params(stack, seg)[f"p{j}"]
                )
                c = rglru_mod.init_rglru_state(p0["mixer"], B, rt.dtype)
            if cfg.is_encdec and enc_len:
                c = {
                    "self": c,
                    "ck": jnp.zeros((B, enc_len, cfg.n_kv_heads, cfg.head_dim), rt.dtype),
                    "cv": jnp.zeros((B, enc_len, cfg.n_kv_heads, cfg.head_dim), rt.dtype),
                }
            unit[f"p{j}"] = c
        caches.append(
            jax.tree.map(
                lambda l: jnp.broadcast_to(l, (seg.repeats,) + l.shape), unit
            )
        )
    return caches


def init_stack_pool(
    cfg: ArchConfig,
    rt: Runtime,
    specs: Tuple[LayerSpec, ...],
    num_pages: int,
    page_size: int,
) -> List[Any]:
    """Per-layer paged KV pools in the segment layout (attention kinds only).

    Every layer shares one block table per request (the vLLM convention), so
    all pools have identical page geometry; pools are slot-count independent
    — requests share the pool through their block tables.
    """
    segments = build_segments(cfg, specs)
    pools = []
    for seg in segments:
        unit: Dict[str, Any] = {}
        for j, spec in enumerate(seg.unit_specs):
            assert spec.kind in ("attn", "local"), (
                f"paged decode supports attention mixers only, got {spec.kind}"
            )
            unit[f"p{j}"] = attn_mod.init_paged_kv_cache(
                num_pages, page_size, cfg.n_kv_heads, cfg.head_dim, rt.dtype,
                kv_dtype=rt.kv_dtype,
            )
        pools.append(
            jax.tree.map(
                lambda l: jnp.broadcast_to(l, (seg.repeats,) + l.shape), unit
            )
        )
    return pools


def write_prefill_to_pool(
    pools: List[Any], caches: List[Any], table: jax.Array, page_size: int
) -> List[Any]:
    """Scatter one request's prefill KV into its block-table pages.

    ``caches`` is the segment-layout ring-cache pytree collected by
    ``stack_forward(collect_cache=True)`` for a batch of ONE request; each
    entry's explicit ``pos`` array drives placement (pool position = absolute
    token position), so ring-truncated local-layer caches land exactly on
    their surviving window band and invalid entries fall into null page 0.
    ``table``: (P,) int32 page ids for this request.

    Quantized pools (``ksc`` present): each cache row is quantized here,
    exactly once, before landing in its page — same codes + scales the
    chunked/decode write paths would have produced for the same values.
    """
    from repro.kernels.paged_attention import quant

    def scatter(pool, k, v, pos):
        # entries that are invalid OR beyond the table's coverage go to the
        # null page (a clip would clobber the last real page instead)
        valid = (pos >= 0) & (pos // page_size < table.shape[0])
        pid = jnp.where(
            valid,
            table[jnp.clip(pos // page_size, 0, table.shape[0] - 1)],
            0,
        )
        slot = jnp.where(valid, pos % page_size, 0)
        new = dict(pool)
        if "ksc" in pool:
            k_codes, k_sc = quant.kv_quantize(k[0], pool["kp"].dtype)
            v_codes, v_sc = quant.kv_quantize(v[0], pool["vp"].dtype)
            new["kp"] = pool["kp"].at[pid, slot].set(k_codes)
            new["vp"] = pool["vp"].at[pid, slot].set(v_codes)
            new["ksc"] = pool["ksc"].at[pid, slot].set(k_sc)
            new["vsc"] = pool["vsc"].at[pid, slot].set(v_sc)
        else:
            new["kp"] = pool["kp"].at[pid, slot].set(k[0])
            new["vp"] = pool["vp"].at[pid, slot].set(v[0])
        return new

    new_pools: List[Any] = []
    for seg_pool, seg_cache in zip(pools, caches):
        unit: Dict[str, Any] = {}
        for key, pool in seg_pool.items():
            c = seg_cache[key]
            unit[key] = jax.vmap(scatter)(pool, c["k"], c["v"], c["pos"])
        new_pools.append(unit)
    return new_pools


def stack_prefill_paged(
    cfg: ArchConfig,
    stack: Params,
    x: jax.Array,
    caches: List[Any],
    tables: jax.Array,
    start: jax.Array,
    q_len: jax.Array,
    rt: Runtime,
    specs: Tuple[LayerSpec, ...],
):
    """One prefill chunk through the stack, writing KV into pool pages.

    x: (B, T, d) embedded chunk; ``caches`` are page pools (see
    ``init_stack_pool``); ``tables``/``start``/``q_len`` as in
    ``attention_prefill_paged``. Returns (x, new_caches). The chunked-
    prefill sibling of ``stack_decode`` — attention-mixer families only
    (the same families the paged engine serves).
    """
    segments = build_segments(cfg, specs)
    new_caches: List[Any] = []

    for seg, seg_cache in zip(segments, caches):
        params_seg = segment_params(stack, seg)

        def unit_body(h, xs, _seg=seg):
            unit_p, unit_c = xs
            new_unit_c = {}
            for j, spec in enumerate(_seg.unit_specs):
                assert spec.kind in ("attn", "local"), spec.kind
                bp = unit_p[f"p{j}"]
                hn = norm_apply(bp["norm1"], h, cfg.norm)
                out, new_unit_c[f"p{j}"] = attn_mod.attention_prefill_paged(
                    bp["mixer"], hn, unit_c[f"p{j}"], tables, start, q_len,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=cfg.head_dim, theta=cfg.rope_theta,
                    window=spec.window, use_kernel=rt.use_paged_kernel,
                    mesh=rt.mesh,
                )
                h = h + out
                h, _ = _ffn_apply(cfg, bp, h, rt)
            return h, new_unit_c

        x, new_seg_cache = jax.lax.scan(unit_body, x, (params_seg, seg_cache))
        new_caches.append(new_seg_cache)

    return x, new_caches


def stack_decode(
    cfg: ArchConfig,
    stack: Params,
    x: jax.Array,
    caches: List[Any],
    t: jax.Array,
    rt: Runtime,
    specs: Tuple[LayerSpec, ...],
    *,
    tables: Optional[jax.Array] = None,
    active: Optional[jax.Array] = None,
):
    """One-token decode. x: (B, 1, d). Returns (x, new_caches).

    Dense mode (``tables is None``): ``t`` is the scalar position shared by
    the whole batch and ``caches`` are ring buffers / recurrent states.
    Paged mode: ``caches`` are page pools (see ``init_stack_pool``), ``t`` is
    the per-slot (B,) lengths vector, and ``tables``/``active`` address the
    pool — each slot decodes at its own depth (continuous batching).
    """
    segments = build_segments(cfg, specs)
    new_caches: List[Any] = []

    for seg, seg_cache in zip(segments, caches):
        params_seg = segment_params(stack, seg)

        def unit_body(h, xs, _seg=seg):
            unit_p, unit_c = xs
            new_unit_c = {}
            for j, spec in enumerate(_seg.unit_specs):
                bp = unit_p[f"p{j}"]
                c = unit_c[f"p{j}"]
                self_c = c["self"] if (cfg.is_encdec and isinstance(c, dict) and "self" in c) else c
                hn = norm_apply(bp["norm1"], h, cfg.norm)
                if spec.kind in ("attn", "local") and tables is not None:
                    out, self_c = attn_mod.attention_decode_paged(
                        bp["mixer"], hn, self_c, tables, t, active,
                        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                        head_dim=cfg.head_dim, theta=cfg.rope_theta,
                        window=spec.window, use_kernel=rt.use_paged_kernel,
                        mesh=rt.mesh,
                    )
                elif spec.kind in ("attn", "local"):
                    out, self_c = attn_mod.attention_decode(
                        bp["mixer"], hn, self_c, t,
                        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                        head_dim=cfg.head_dim, theta=cfg.rope_theta,
                        window=spec.window,
                    )
                elif spec.kind == "mamba":
                    out, self_c = ssm_mod.mamba_decode(bp["mixer"], hn, self_c)
                else:
                    out, self_c = rglru_mod.rglru_decode(bp["mixer"], hn, self_c)
                h = h + out
                if cfg.is_encdec and "cross" in bp:
                    hx = norm_apply(bp["norm_x"], h, cfg.norm)
                    out, _ = _cross_decode(cfg, bp["cross"], hx, c["ck"], c["cv"])
                    h = h + out
                    new_unit_c[f"p{j}"] = {"self": self_c, "ck": c["ck"], "cv": c["cv"]}
                else:
                    new_unit_c[f"p{j}"] = self_c
                h, _ = _ffn_apply(cfg, bp, h, rt)
            return h, new_unit_c

        x, new_seg_cache = jax.lax.scan(unit_body, x, (params_seg, seg_cache))
        new_caches.append(new_seg_cache)

    return x, new_caches


# ------------------------------------------------- pipeline stage apply
# Megatron's f/g operators as explicit custom-vjp pairs, for MANUAL tensor
# parallelism inside the fully-manual pipeline shard_map (where XLA's auto
# SPMD is unavailable). A TP region runs on per-device parameter shards
# between the two markers; activations outside the region are replicated
# over the model axis:
#
#   tp_region_start ("f"): identity forward, psum backward — the replicated
#       activation fans out to tp shard-local computations, so its cotangent
#       is the SUM of the per-shard partials.
#   tp_region_end ("g"): psum forward, identity backward — shard-local
#       partial outputs (row-parallel wo / w_down) combine to the replicated
#       value; the replicated cotangent passes through to every shard.
#
# Skip-connection paths never enter a region, so their cotangents are
# counted exactly once — the invariant that makes per-layer "psum at the
# end" schemes wrong and this pairing right.
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _tp_start(axis_name, x):
    return x


def _tp_start_fwd(axis_name, x):
    return x, None


def _tp_start_bwd(axis_name, _, ct):
    return (jax.lax.psum(ct, axis_name),)


_tp_start.defvjp(_tp_start_fwd, _tp_start_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _tp_end(axis_name, x):
    return jax.lax.psum(x, axis_name)


def _tp_end_fwd(axis_name, x):
    return jax.lax.psum(x, axis_name), None


def _tp_end_bwd(axis_name, _, ct):
    return (ct,)


_tp_end.defvjp(_tp_end_fwd, _tp_end_bwd)


def tp_region_start(x: jax.Array, axis_name: str = "model") -> jax.Array:
    return _tp_start(axis_name, x)


def tp_region_end(x: jax.Array, axis_name: str = "model") -> jax.Array:
    return _tp_end(axis_name, x)


def pipeline_incompatibility(cfg: ArchConfig, tp: int = 1) -> Optional[str]:
    """Why ``cfg`` cannot run the executable pipeline path (None = it can).

    The 1F1B/GPipe runner slices the layer stack at plan boundaries, which
    requires homogeneous param storage (one param group, single-kind
    pattern) and — for tp > 1 — Megatron-divisible attention/dense shapes
    (the manual-TP stage body computes on true shards; the auto-SPMD paths'
    silent replication fallback has no manual equivalent).
    """
    groups = param_groups(cfg)
    if len(groups) != 1 or len(groups[0][0]) != 1:
        return "patterned parameter storage (multi-kind layer unit)"
    if len(set(cfg.pattern)) != 1:
        return "mixed mixer kinds in the layer pattern"
    if cfg.is_encdec or cfg.frontend is not None:
        return "encoder-decoder / frontend architectures"
    if tp > 1:
        kind = param_kind(cfg.pattern[0])
        if kind != "attn":
            return f"tensor parallelism over {kind!r} mixers (attention only)"
        if cfg.n_heads % tp or cfg.n_kv_heads % tp:
            return (
                f"heads ({cfg.n_heads}/{cfg.n_kv_heads}) not divisible by tp={tp}"
            )
        if cfg.ffn_kind == "dense" and cfg.d_ff % tp:
            return f"d_ff={cfg.d_ff} not divisible by tp={tp}"
        if cfg.ffn_kind == "moe":
            return "MoE with tp > 1 (expert parallelism stays on the 2D path)"
    return None


def stage_layer_params(stack: Params) -> Params:
    """Per-layer param tree of a homogeneous stack ({'g0': {'p0': ...}})."""
    assert set(stack) == {"g0"} and set(stack["g0"]) == {"p0"}, (
        "pipeline stages require homogeneous param storage"
    )
    return stack["g0"]["p0"]


def stack_stage_apply(
    cfg: ArchConfig,
    layers: Params,
    x: jax.Array,
    rt: Runtime,
    spec: LayerSpec,
    *,
    tp: int = 1,
    tp_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """Apply one pipeline stage's contiguous layer slice. Returns (y, aux).

    Runs inside the fully-manual pipeline ``shard_map``: ``layers`` leaves
    are the LOCAL (layers_per_stage, tp-shard) slices of the canonical
    stacked params. Tensor parallelism is manual Megatron — shard-local
    attention heads / MLP columns bracketed by tp_region_start/end (see
    above); the residual stream stays replicated over ``tp_axis``. The
    stage's remat policy (``rt.remat``, from the ParallelPlan) wraps each
    layer; the pipeline runner additionally recomputes the whole stage
    forward from its stored input during backward, so a stage's live
    activations never outlast its tick.

    ``block`` below deliberately mirrors the manual-TP subset of
    ``_mixer_apply``/``_ffn_apply`` (attention + dense/MoE FFN, no caches,
    no fused-kernel routing, no shard_map-based EP — those assume auto-SPMD
    and cannot run in this manual context; make_pipeline_step rejects the
    corresponding TrainConfig flags loudly). A structural change to the
    canonical block must be mirrored here — tests/test_train_3d.py's
    losses-match-single-device check is the tripwire.
    """
    from repro.core.remat import policy_for

    B, S = x.shape[0], x.shape[1]
    positions = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None, :], (B, 1))

    def block(h, p):
        hn = norm_apply(p["norm1"], h, cfg.norm)
        if spec.kind in ("attn", "local"):
            if tp > 1:
                hn = tp_region_start(hn, tp_axis)
            out, _ = attn_mod.attention_apply(
                p["mixer"], hn,
                n_heads=cfg.n_heads // tp, n_kv=cfg.n_kv_heads // tp,
                head_dim=cfg.head_dim, theta=cfg.rope_theta,
                window=spec.window, positions=positions, chunk_q=rt.chunk_q,
            )
            if tp > 1:
                out = tp_region_end(out, tp_axis)
        elif spec.kind == "mamba":
            assert tp == 1, "mamba stages run at tp=1 (see pipeline_incompatibility)"
            out = ssm_mod.mamba_apply(
                p["mixer"], hn, scan_mode=rt.scan_mode, chunk=rt.ssm_chunk
            )
        else:
            assert tp == 1, "rglru stages run at tp=1"
            out = rglru_mod.rglru_apply(p["mixer"], hn)
        h = h + out
        aux = jnp.zeros((), jnp.float32)
        if cfg.ffn_kind != "none":
            h2 = norm_apply(p["norm2"], h, cfg.norm)
            if cfg.ffn_kind == "dense":
                if tp > 1:
                    h2 = tp_region_start(h2, tp_axis)
                o = mlp_apply(p["ffn"], h2, cfg.mlp_gated)
                if tp > 1:
                    o = tp_region_end(o, tp_axis)
            else:
                o, aux = moe_mod.moe_apply(
                    p["ffn"], h2, top_k=cfg.experts_top_k,
                    capacity_factor=cfg.capacity_factor, gated=cfg.mlp_gated,
                )
                if "extra_mlp" in p:
                    o = o + mlp_apply(p["extra_mlp"], h2, cfg.mlp_gated)
            h = h + o
        return h, aux

    pol = policy_for(rt.remat)
    body = block if pol is None else pol(block)
    y, auxs = jax.lax.scan(body, x, layers)
    return y, jnp.sum(auxs)


def _cross_decode(cfg: ArchConfig, p: Params, x: jax.Array, ck, cv):
    """Cross-attention for one decode token against cached encoder k/v."""
    B = x.shape[0]
    dtype = x.dtype
    G = cfg.n_heads // cfg.n_kv_heads
    q = (x @ p["wq"].astype(dtype)).reshape(B, cfg.n_kv_heads, G, cfg.head_dim)
    q = q * (cfg.head_dim ** -0.5)
    scores = jnp.einsum("bkgh,bskh->bkgs", q, ck, preferred_element_type=jnp.float32)
    w = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", w, cv).reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"].astype(dtype), None
