"""Deterministic synthetic LM data pipeline with background prefetch.

Generates structured synthetic token streams (not uniform noise — a learnable
mixture of Markov chains with per-example transition tables) so training
losses decrease measurably: the end-to-end examples use the loss curve as the
correctness signal. Frontend-equipped architectures (audio/vlm) get matching
stub embeddings derived deterministically from the same seed.

Host sharding: ``DataPipeline(..., shard=(i, n))`` yields the i-th of n
disjoint streams — the per-host pipeline of a multi-host deployment
(launch/train.py wires jax.process_index()/process_count()).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig


class MarkovLM:
    """Per-stream vocabulary-restricted Markov chain (order 1)."""

    def __init__(self, vocab: int, seed: int, n_states: int = 64):
        rng = np.random.RandomState(seed)
        self.n_states = n_states
        self.vocab = vocab
        # each state emits from a small token subset; transitions are sparse
        self.emit = rng.randint(0, vocab, size=(n_states, 8))
        self.trans = rng.randint(0, n_states, size=(n_states, 4))
        self._rng = rng

    def sample(self, length: int) -> np.ndarray:
        rng = self._rng
        out = np.empty(length, np.int32)
        s = rng.randint(self.n_states)
        for i in range(length):
            out[i] = self.emit[s, rng.randint(8)]
            s = self.trans[s, rng.randint(4)]
        return out


class DataPipeline:
    def __init__(
        self,
        cfg: ArchConfig,
        batch_size: int,
        seq_len: int,
        seed: int = 0,
        shard: Tuple[int, int] = (0, 1),
        prefetch: int = 2,
    ):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.shard = shard
        base_seed = seed * 1000 + shard[0]
        self._chains = [
            MarkovLM(cfg.vocab_size, base_seed * 97 + i) for i in range(batch_size)
        ]
        self._emb_rng = np.random.RandomState(base_seed + 7)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make_batch(self) -> Dict[str, np.ndarray]:
        toks = np.stack([c.sample(self.seq_len + 1) for c in self._chains])
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.frontend is not None:
            batch["frontend_embeds"] = self._emb_rng.randn(
                self.batch_size, self.cfg.frontend_tokens, self.cfg.d_model
            ).astype(np.float32)
        return batch

    def _producer(self):
        while not self._stop.is_set():
            batch = self._make_batch()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
