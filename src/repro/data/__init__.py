from repro.data.pipeline import DataPipeline, MarkovLM  # noqa: F401
