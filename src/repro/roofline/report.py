"""Markdown report generators for EXPERIMENTS.md §Dry-run / §Roofline.

    PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
prints the tables; the EXPERIMENTS.md author pastes/refreshes them.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.roofline.analysis import derive_terms

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    rows = [
        "| arch | shape | compiles | temp GiB/dev | args GiB/dev | "
        "wire GiB/step/dev | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(
        [r for r in recs if r["mesh"] == mesh and not r.get("tag")],
        key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])),
    ):
        m = r["memory_analysis"]
        note = " (SW-variant)" if r.get("sw_variant") else ""
        rows.append(
            f"| {r['arch']} | {r['shape']}{note} | yes | "
            f"{fmt_bytes(m.get('temp_size_in_bytes', 0))} | "
            f"{fmt_bytes(m.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(r['wire_bytes'])} | {r.get('compile_s', 0):.0f} |"
        )
    return "\n".join(rows)


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = [
        "| arch | shape | t_compute | t_mem [lb, ub] | t_coll | dominant | "
        "roofline frac | MODEL/HLO flops | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(
        [r for r in recs if r["mesh"] == mesh and not r.get("tag")],
        key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])),
    ):
        d = derive_terms(r)
        note = _note(r, d)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {d['t_compute']*1e3:.1f}ms | "
            f"[{d['t_memory_lb']*1e3:.1f}, {d['t_memory_ub']*1e3:.0f}]ms | "
            f"{d['t_collective']*1e3:.1f}ms | {d['dominant_lb']} | "
            f"{d['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} | {note} |"
        )
    return "\n".join(rows)


def _note(r: Dict, d: Dict) -> str:
    if d["dominant_lb"] == "memory":
        m = r["memory_analysis"]
        if m.get("temp_size_in_bytes", 0) > m.get("argument_size_in_bytes", 0):
            return "activations dominate: raise remat/seq-shard"
        return "weights/cache dominate: ZeRO-3 / cache layout"
    if d["dominant_lb"] == "collective":
        kinds = {k: v for k, v in r["collectives"].items() if v}
        top = max(kinds, key=kinds.get) if kinds else "?"
        return f"{top} dominates: reshard or overlap"
    return "compute-bound: good (raise MFU via kernels/fusion)"


def perf_compare(recs: List[Dict], arch: str, shape: str, mesh: str) -> str:
    """Baseline-vs-tagged comparison rows for §Perf."""
    subset = [
        r for r in recs if r["arch"] == arch and r["shape"] == shape
        and r["mesh"] == mesh
    ]
    rows = [
        "| variant | t_compute | t_mem_lb | t_coll | temp GiB | args GiB | wire GiB |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(subset, key=lambda r: r.get("tag") or ""):
        d = derive_terms(r)
        m = r["memory_analysis"]
        rows.append(
            f"| {r.get('tag') or 'baseline'} | {d['t_compute']*1e3:.1f}ms | "
            f"{d['t_memory_lb']*1e3:.1f}ms | {d['t_collective']*1e3:.1f}ms | "
            f"{fmt_bytes(m.get('temp_size_in_bytes', 0))} | "
            f"{fmt_bytes(m.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(r['wire_bytes'])} |"
        )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--perf", default="", help="arch:shape:mesh for §Perf rows")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.perf:
        arch, shape, mesh = args.perf.split(":")
        print(perf_compare(recs, arch, shape, mesh))
        return
    for mesh in ("single", "multi"):
        if any(r["mesh"] == mesh for r in recs):
            print(f"\n## Dry-run ({mesh})\n")
            print(dryrun_table(recs, mesh))
    if any(r["mesh"] == "single" for r in recs):
        print("\n## Roofline (single pod)\n")
        print(roofline_table(recs, "single"))


if __name__ == "__main__":
    main()
