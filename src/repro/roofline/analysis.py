"""Three-term roofline from a compiled dry-run artifact.

compute   = HLO_FLOPs / (chips * peak_FLOP/s)          [cost_analysis]
memory    = HLO_bytes / (chips * HBM_bw)               [cost_analysis]
collective= wire_bytes / (chips * n_links * link_bw)   [HLO text parse]

cost_analysis numbers from an SPMD-partitioned module are already
per-device, so the ``chips`` division is baked in — we report per-device
times directly.

Collective bytes are NOT in cost_analysis: we parse the partitioned HLO,
sum payloads of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, and multiply ops inside ``while`` bodies (lax.scan over
layers) by the loop trip count recovered from the loop-condition constant
(fallback: a caller-provided hint, usually the layer count).

Wire-byte model per op (per device): all-reduce 2x result bytes (ring),
all-gather result bytes x (g-1)/g, reduce-scatter operand bytes x (g-1)/g,
all-to-all operand bytes, collective-permute operand bytes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """bytes of 'f32[16,128]' or a tuple '(f32[2], u8[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # header: [ENTRY] %name (args...) -> type {    (args may nest parens)
        if stripped.endswith("{") and "->" in stripped and "(" in stripped:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _while_info(comps: Dict[str, List[str]]) -> List[Tuple[str, str, int]]:
    """[(parent_comp, body_comp, trip_count_guess)] for every while op."""
    out = []
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" not in ln:
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", ln)
            mc = re.search(r"condition=%?([\w\.\-]+)", ln)
            if not mb or not mc:
                continue
            trip = 0
            cond = comps.get(mc.group(1), [])
            for cl in cond:
                for c in re.findall(r"constant\((\d+)\)", cl):
                    trip = max(trip, int(c))
            out.append((cname, mb.group(1), trip))
    return out


def _group_size(line: str, n_devices: int) -> int:
    """participants per replica group (for (g-1)/g factors)."""
    m = re.search(r"replica_groups=\{([^}]*)\}", line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        if first:
            return max(2, len(first.split(",")))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [groups, group_size]
        return max(2, int(m.group(2)))
    return max(2, n_devices)


def _line_collective_bytes(
    ln: str, n_devices: int, symbols: Optional[Dict[str, List[int]]] = None
) -> Optional[Tuple[str, float]]:
    m = re.match(r"%?[\w\.\-]+\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)\(", ln)
    if not m:
        return None
    result_type, op = m.group(1), m.group(2)
    kind = None
    for c in COLLECTIVES:
        if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
            kind = c
            break
    if kind is None:
        return None
    result_b = _shape_bytes(result_type)
    # operand bytes: inline shapes if typed, else resolved via symbol table
    args = ln[ln.index("(", ln.index(op)) :].split("), ")[0]
    operand_b = _shape_bytes(args)
    if operand_b == 0 and symbols is not None:
        om = _OPERAND_RE.search(args)
        if om and om.group(1) in symbols:
            n = 1
            for d in symbols[om.group(1)]:
                n *= d
            operand_b = n * 4  # dtype unknown from name: assume f32
    if operand_b == 0:
        operand_b = result_b
    g = _group_size(ln, n_devices)
    frac = (g - 1) / g
    if kind == "all-reduce":
        wire = 2.0 * result_b * frac
    elif kind == "all-gather":
        wire = result_b * frac
    elif kind == "reduce-scatter":
        wire = operand_b * frac
    elif kind == "all-to-all":
        wire = operand_b * frac
    else:  # collective-permute
        wire = operand_b
    return kind, wire


def _call_edges(comps: Dict[str, List[str]]) -> List[Tuple[str, str]]:
    """(parent, callee) for fusion/call/cond references (multiplier x1)."""
    edges = []
    for cname, lines in comps.items():
        for ln in lines:
            for m in re.finditer(r"(?:calls|to_apply|branches)=\{?%?([\w\.\-]+)", ln):
                edges.append((cname, m.group(1)))
    return edges


def _multipliers(
    comps: Dict[str, List[str]], trip_hint: int
) -> Dict[str, int]:
    """Execution count per computation: while bodies x trip count, fusions
    and calls inherit their parent's count (fixpoint over the call graph)."""
    whiles = _while_info(comps)  # (parent, body, trip)
    calls = _call_edges(comps)
    multiplier: Dict[str, int] = {}
    for _ in range(len(whiles) + len(calls) + 2):
        changed = False
        for parent, body, trip in whiles:
            t = max(trip if trip > 0 else trip_hint, 1)
            new = multiplier.get(parent, 1) * t
            if multiplier.get(body) != new:
                multiplier[body] = new
                changed = True
        for parent, callee in calls:
            new = multiplier.get(parent, 1)
            if multiplier.get(callee, 1) < new:
                multiplier[callee] = new
                changed = True
        if not changed:
            break
    return multiplier


_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DEF_RE = re.compile(r"^%?([\w\.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x] if s else []


def _symbol_table(hlo: str) -> Dict[str, List[int]]:
    """instruction/param name -> dims (post-opt HLO prints operands by name
    only, so dot/collective operand shapes must be resolved through defs)."""
    table: Dict[str, List[int]] = {}
    for line in hlo.splitlines():
        s = line.strip()
        m = _DEF_RE.match(s)
        if m and m.group(2) in _DTYPE_BYTES:
            table[m.group(1)] = _dims(m.group(3))
        if s.endswith("{") and "(" in s:  # computation header: typed params
            for pm in _PARAM_RE.finditer(s):
                if pm.group(2) in _DTYPE_BYTES:
                    table[pm.group(1)] = _dims(pm.group(3))
    return table


def dot_flops(
    comps: Dict[str, List[str]],
    multiplier: Dict[str, int],
    symbols: Optional[Dict[str, List[int]]] = None,
) -> float:
    """Total matmul FLOPs = sum over dot ops of 2 * |result| * |contracted|,
    weighted by the computation's execution count. The lhs operand shape is
    taken inline if typed, else resolved via the symbol table."""
    symbols = symbols or {}
    total = 0.0
    for cname, lines in comps.items():
        mult = multiplier.get(cname, 1)
        for ln in lines:
            di = ln.find(" dot(")
            if di < 0 or "=" not in ln[:di]:
                continue
            res_m = _SHAPE_RE.search(ln)
            if not res_m:
                continue
            result = _dims(res_m.group(2))
            args = ln[di + 5 :]
            close = args.find(")")
            lhs_m = _SHAPE_RE.search(args[: close if close > 0 else len(args)])
            if lhs_m:
                lhs = _dims(lhs_m.group(2))
            else:
                op_m = _OPERAND_RE.search(args)
                lhs = symbols.get(op_m.group(1), []) if op_m else []
            mc = _LHS_C_RE.search(ln)
            contract = 1
            if mc and lhs:
                for d in _dims(mc.group(1)):
                    if d < len(lhs):
                        contract *= lhs[d]
            elif not lhs:
                continue  # unresolvable operand: skip (undercount, logged)
            n_out = 1
            for d in result:
                n_out *= d
            total += 2.0 * n_out * contract * mult
    return total


def loop_scaling_factor(hlo: str, trip_hint: int) -> float:
    """XLA cost_analysis counts while bodies ONCE; this factor corrects it.

    factor = dot-FLOPs with loop multipliers / dot-FLOPs counted once.
    Valid because scan bodies dominate both FLOPs and bytes and have a
    constant per-iteration op mix (homogeneous layer stacks). Applied to
    both the flops and bytes terms by :func:`analyze`.
    """
    comps = _split_computations(hlo)
    mult = _multipliers(comps, trip_hint)
    symbols = _symbol_table(hlo)
    once = dot_flops(comps, {}, symbols)
    many = dot_flops(comps, mult, symbols)
    if once <= 0:
        return 1.0
    return max(1.0, many / once)


def collective_bytes(
    hlo: str, n_devices: int, trip_hint: int = 1
) -> CollectiveStats:
    comps = _split_computations(hlo)
    multiplier = _multipliers(comps, trip_hint)

    symbols = _symbol_table(hlo)
    bytes_by_kind: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    count_by_kind: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    for name, lines in comps.items():
        mult = multiplier.get(name, 1)
        for ln in lines:
            got = _line_collective_bytes(ln, n_devices, symbols)
            if got is None:
                continue
            kind, wire = got
            bytes_by_kind[kind] += wire * mult
            count_by_kind[kind] += mult
    return CollectiveStats(bytes_by_kind, count_by_kind)


def decode_kv_read_bytes(
    n_kv: int, head_dim: int, n_layers: int, tokens: int,
    kv_dtype: str = "", native_itemsize: int = 2,
) -> int:
    """Bytes ONE decode step streams from the KV pool for one request at
    depth ``tokens`` — the dominant decode working set (weights amortize
    over the batch, KV does not). Dtype-aware: a quantized pool reads int8/
    fp8 codes plus the per-(slot, head) f32 scales instead of native-width
    K/V, which is where the paged int8 decode speedup comes from on a
    memory-bound roofline."""
    from repro.kernels.paged_attention.quant import kv_token_bytes

    return tokens * n_layers * kv_token_bytes(
        n_kv, head_dim, kv_dtype, native_itemsize
    )


def predicted_decode_kv_speedup(
    n_kv: int, head_dim: int, kv_dtype: str, native_itemsize: int = 2,
) -> float:
    """KV-read byte ratio native : ``kv_dtype`` — the decode speedup a
    perfectly memory-bound paged-attention roofline predicts (compute and
    non-KV bytes are batch-amortized; the bench reports predicted vs
    measured)."""
    return (
        decode_kv_read_bytes(n_kv, head_dim, 1, 1, "", native_itemsize)
        / decode_kv_read_bytes(n_kv, head_dim, 1, 1, kv_dtype, native_itemsize)
    )


def stash_bytes_per_slot(
    n_elems: int, stash: str = "raw", native_itemsize: int = 2,
    block: int = 256,
) -> int:
    """Exact bytes one pipeline activation slot occupies under a stash
    backend (core.stash). ``raw``/``host`` store the native dtype (host's
    device *window* is raw-width; the accounting caller multiplies by the
    window, not the slot count). ``int8``/``fp8`` store 1-byte codes
    zero-padded to the block multiple plus one f32 scale per block — the
    same arithmetic core.stash.QuantStash.slot_bytes performs on a real
    leaf struct, kept here in closed form for planning."""
    from repro.core.stash import normalize_stash

    s = normalize_stash(stash)
    if s in ("raw", "host"):
        return n_elems * native_itemsize
    padded = (n_elems + block - 1) // block * block
    return padded + (padded // block) * 4   # SCALE_BYTES


def predicted_stash_capacity_factor(
    n_elems: int, stash: str, native_itemsize: int = 2, block: int = 256,
) -> float:
    """Per-slot byte ratio raw : ``stash`` — how many stashed microbatch
    activations fit where one raw one did (>= 1.8x for fp8/int8 vs bf16 at
    block 256: 2 / (1 + 4/block))."""
    return (
        stash_bytes_per_slot(n_elems, "raw", native_itemsize, block)
        / stash_bytes_per_slot(n_elems, stash, native_itemsize, block)
    )


def predicted_pipeline_stash_bytes(
    n_elems: int, n_act_slots: int, n_cot_slots: int, stash: str,
    native_itemsize: int = 2, block: int = 256, host_window: int = 2,
    cot_stash: str = "raw",
) -> int:
    """Predicted device-resident pipeline-state bytes per device: activation
    slots at stash width plus cotangent slots at ``cot_stash`` width (native
    by default — cotangents are consumed the tick after they arrive, so the
    runner only compresses them when asked via ``QuantStash(cotangents=
    True)``). ``host`` keeps only ``window`` activation slots on device."""
    from repro.core.stash import normalize_stash

    s = normalize_stash(stash)
    act_slots = min(host_window, n_act_slots) if s == "host" else n_act_slots
    act = act_slots * stash_bytes_per_slot(n_elems, s, native_itemsize, block)
    cot = n_cot_slots * stash_bytes_per_slot(
        n_elems, cot_stash, native_itemsize, block
    )
    return act + cot


def predicted_stash_host_bytes(
    n_elems: int, n_act_slots: int, stash: str, native_itemsize: int = 2,
    block: int = 256, host_window: int = 2,
) -> int:
    """Host-RAM high water the stash backend needs: every activation slot
    beyond the device window lands on host at native width for ``host``
    (in-flight async evictions count — they are host-destined); zero for
    the device-resident backends."""
    from repro.core.stash import normalize_stash

    if normalize_stash(stash) != "host":
        return 0
    spill = max(0, n_act_slots - host_window)
    return spill * stash_bytes_per_slot(n_elems, "raw", native_itemsize, block)


def predicted_stage_transient_bytes(
    n_elems: int, layers_per_stage: int, remat: str = "none",
    native_itemsize: int = 2,
) -> int:
    """Within-stage backward transient per device: the runner recomputes a
    stage's forward from its stored input, so AD must hold one inter-layer
    activation per layer of the stage — unless per-stage remat (``"full"``)
    collapses that to a single layer's worth. This is the term the
    remat-vs-compression trade prices against slot bytes: compressing
    slots shrinks ``n_act_slots`` terms, remat shrinks this one."""
    live_layers = 1 if remat == "full" else layers_per_stage
    return live_layers * n_elems * native_itemsize


def derive_terms(rec: Dict) -> Dict[str, float]:
    """Report-side roofline terms from a dry-run JSON record.

    The compute and collective terms come straight from the record. For the
    memory term two estimates are derived:

      t_memory_ub — cost_analysis "bytes accessed" x loop factor: counts every
                    operand of every op (UNFUSED — a loose upper bound; the
                    XLA:CPU cost model does not model TPU fusion).
      t_memory_lb — (arguments + outputs + 2 x temp) / HBM_BW: every live
                    buffer crosses HBM at least once each way — a hard lower
                    bound that fusion cannot beat.

    Dominance is judged with the LB (the defensible claim); both are
    reported. See EXPERIMENTS.md §Roofline for the discussion.
    """
    from repro.launch.mesh import HBM_BW as _HBM

    mem = rec.get("memory_analysis", {})
    lb_bytes = (
        mem.get("argument_size_in_bytes", 0.0)
        + mem.get("output_size_in_bytes", 0.0)
        + 2.0 * mem.get("temp_size_in_bytes", 0.0)
    )
    t_lb = lb_bytes / _HBM
    t_c, t_x = rec["t_compute"], rec["t_collective"]
    dom = max(
        (("compute", t_c), ("memory", t_lb), ("collective", t_x)),
        key=lambda kv: kv[1],
    )[0]
    total = max(t_c, t_lb, t_x)
    return {
        "t_compute": t_c,
        "t_memory_lb": t_lb,
        "t_memory_ub": rec["t_memory"],
        "t_collective": t_x,
        "dominant_lb": dom,
        "bound_step_time": total,
        "roofline_fraction": t_c / total if total > 0 else 0.0,
    }


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    wire_bytes: float            # per-device collective bytes
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float           # 6*N*D (analytic, global)
    useful_ratio: float          # model_flops / (global HLO flops)
    collectives: Dict[str, float]
    memory_analysis: Dict[str, float]
    loop_factor: float = 1.0     # while-body trip-count correction applied

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    cost: Dict[str, float],
    hlo: str,
    trip_hint: int,
    model_flops: float,
    memory_analysis: Optional[Dict[str, float]] = None,
    n_links: int = 4,
) -> Roofline:
    factor = loop_scaling_factor(hlo, trip_hint)
    flops = float(cost.get("flops", 0.0)) * factor
    hbm = float(cost.get("bytes accessed", 0.0)) * factor
    stats = collective_bytes(hlo, n_devices, trip_hint)
    t_c = flops / PEAK_FLOPS_BF16
    t_m = hbm / HBM_BW
    t_x = stats.total_bytes / (n_links * ICI_BW)
    dom = max(
        (("compute", t_c), ("memory", t_m), ("collective", t_x)),
        key=lambda kv: kv[1],
    )[0]
    useful = model_flops / max(flops * n_devices, 1.0)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops=flops, hbm_bytes=hbm, wire_bytes=stats.total_bytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dom,
        model_flops=model_flops, useful_ratio=useful,
        collectives=stats.bytes_by_kind,
        memory_analysis=memory_analysis or {},
        loop_factor=factor,
    )
