"""Sharded npz checkpoints: save/restore arbitrary pytrees.

Layout: <dir>/step_<N>/shard_<i>.npz + manifest.json. Leaves are addressed
by flattened key paths; each host saves the leaves it owns (single-host here,
but the manifest format carries the shard split so a multi-host restore maps
cleanly). Partial restore (``restore(..., subset=prefix)``) supports
fine-tuning flows that load model params but fresh optimizer state.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(directory: str, step: int, tree: Any, shard_index: int = 0) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(d, f"shard_{shard_index}.npz")
    np.savez(path, **flat)
    manifest = {
        "step": step,
        "n_leaves": len(flat),
        "keys": sorted(flat.keys()),
        "shards": [f"shard_{shard_index}.npz"],
    }
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return d


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for name in os.listdir(directory)
        if (m := re.match(r"step_(\d+)$", name))
    ]
    return max(steps) if steps else None


def _load_step(directory: str, step: Optional[int]) -> Dict[str, np.ndarray]:
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints under {directory}"
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data: Dict[str, np.ndarray] = {}
    for shard in manifest["shards"]:
        with np.load(os.path.join(d, shard)) as z:
            for k in z.files:
                data[k] = z[k]
    return data


def restore(
    directory: str,
    template: Any,
    step: Optional[int] = None,
    subset: str = "",
) -> Any:
    """Restore into the structure of ``template`` (shape/dtype checked).

    ``subset``: only leaves whose key starts with this prefix are loaded;
    others keep the template value (partial restore).
    """
    data = _load_step(directory, step)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out: List[Any] = []
    for path, leaf in leaves:
        key = "/".join(_path_str(p) for p in path)
        if key.startswith(subset) and key in data:
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_resharded(
    directory: str,
    target: Any,
    step: Optional[int] = None,
) -> Any:
    """Restore a checkpoint into a DIFFERENT (dp, tp, pp) layout.

    ``target`` is a pytree of ``jax.ShapeDtypeStruct`` with shardings
    attached — e.g. the state struct returned by ``launch.train.build_train``
    or ``build_train_pipeline`` for the new mesh/plan. Checkpoints store
    full (host-gathered) arrays keyed by tree path and the state tree is
    layout-invariant across plans (same pytree, different PartitionSpecs),
    so reshard-on-load is: load every leaf, ``device_put`` straight to the
    target sharding. Every target leaf must exist in the checkpoint —
    unlike ``restore`` there is no template value to silently keep.
    """
    data = _load_step(directory, step)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
    out: List[Any] = []
    for path, leaf in leaves:
        key = "/".join(_path_str(p) for p in path)
        assert key in data, f"checkpoint is missing {key!r}"
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            # device_put straight from host numpy: each device receives only
            # its shard — never stage the full array on one device (a ZeRO-3
            # / 3D leaf need not fit there)
            val = jax.device_put(np.asarray(arr, dtype=leaf.dtype), sharding)
        else:
            val = jax.numpy.asarray(arr, dtype=leaf.dtype)
        out.append(val)
    return jax.tree_util.tree_unflatten(treedef, out)
