from repro.checkpoint import ckpt  # noqa: F401
from repro.checkpoint.ckpt import (  # noqa: F401
    latest_step,
    restore,
    restore_resharded,
    save,
)
