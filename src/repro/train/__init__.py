from repro.train.loop import (  # noqa: F401
    TrainConfig,
    finish_step,
    fit,
    make_state,
    make_train_step,
)
from repro.train.serve import generate, sample_token  # noqa: F401
