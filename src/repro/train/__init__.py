from repro.train.loop import TrainConfig, fit, make_state, make_train_step  # noqa: F401
from repro.train.serve import generate, sample_token  # noqa: F401
