"""Training loop: composes model, precision, remat, compression, optimizer.

``make_train_step`` builds a jitted step for three execution modes:

* ``single``        — one device (smoke tests / examples).
* ``dp_compressed`` — shard_map over a ``data`` mesh axis with replicated
                      params and compressed gradient sync (survey §4.3's
                      data-parallel setting; see DESIGN.md §4).
* distributed pjit (TP x DP x ZeRO) lives in ``repro.launch.train`` — it
  needs mesh/sharding context this module stays free of.

The loop itself (``fit``) is mode-agnostic: it pulls batches, calls the
step, handles checkpoints and logging.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.core import compression as comp_mod
from repro.core.precision import (
    PrecisionPolicy,
    init_scale_state,
    scale_loss,
    unscale_and_check,
)
from repro.models import Runtime, init_params, loss_fn
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    lr: Any = 3e-4
    grad_clip: float = 1.0
    precision: str = "f32"            # f32 | bf16 | fp16
    remat: str = "none"               # none | full | dots | offload
    remat_period: int = 1             # checkpoint every k-th scan unit (§2.1 plans)
    fused_backward: bool = False      # fused Pallas backwards + chunked-CE head
    compression: Any = None           # repro.core.compression method or None
    zero_stage: int = 0               # used by the distributed trainer
    moe_mode: str = "auto"            # auto (pjit) | ep (shard_map expert-parallel)
    seq_shard: str = ""               # activation sharding: "" | "seq" | "hidden"
    scan_mode: str = "assoc"          # mamba scan: assoc | chunked
    ssm_seqpar: bool = False          # distributed selective scan over 'model'
    # 3D pipeline training (repro.launch.train.build_train_pipeline):
    # pipe > 1 runs the executable 1F1B/GPipe schedule over a `pipe` mesh
    # axis, streaming `microbatches` per step (the degrees become a
    # core.partitioner.ParallelPlan).
    pipe: int = 1                     # pipeline stages (pp degree)
    microbatches: int = 1             # microbatches per step (pipeline mode)
    schedule: str = "1f1b"            # executable schedule: 1f1b | gpipe
    stash: str = "raw"                # activation-slot storage: raw|int8|fp8|host
    fused_stash: bool = False         # stash codec via the fused Pallas kernels
    stash_cot: bool = False           # quantize cotangent slots too (int8/fp8)
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0


def make_state(
    cfg: ArchConfig, opt: Optimizer, tc: TrainConfig, seed: int = 0
) -> Dict[str, Any]:
    params = init_params(cfg, jax.random.PRNGKey(seed))
    policy = getattr(PrecisionPolicy, tc.precision)()
    return {
        "params": params,
        "opt": opt.init(params),
        "scale": init_scale_state(policy),
        "comp": comp_mod.init_state(tc.compression, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _runtime(cfg: ArchConfig, tc: TrainConfig) -> Runtime:
    policy = getattr(PrecisionPolicy, tc.precision)()
    return Runtime(dtype=policy.compute_dtype, remat=tc.remat,
                   remat_period=tc.remat_period,
                   fused_backward=tc.fused_backward,
                   use_flash_kernel=tc.fused_backward,
                   fused_stash=tc.fused_stash)


def finish_step(
    state: Dict[str, Any],
    grads: Any,
    metrics: Dict[str, jax.Array],
    tc: TrainConfig,
    policy,
    opt: Optimizer,
    axis_name: Optional[str] = None,
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Shared train-step tail: unscale/check grads, sync (pmean or
    compressed), clip, optimizer update with the non-finite guard, rebuild
    state, finalize metrics. Used by ``core_step`` here and by the 3D
    pipeline step (repro.launch.train), whose grads arrive pre-reduced."""
    grads, scale_state, finite = unscale_and_check(grads, state["scale"], policy)

    if axis_name is not None and tc.compression is None:
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)
        comp_state = state["comp"]
        wire = jnp.asarray(comp_mod.wire_bytes_dense(grads), jnp.float32)
    elif tc.compression is not None:
        grads, comp_state, wire = comp_mod.sync(
            tc.compression, grads, state["comp"], axis_name
        )
    else:
        comp_state = state["comp"]
        wire = jnp.zeros((), jnp.float32)

    if tc.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    else:
        gnorm = jnp.zeros((), jnp.float32)

    updates, opt_state = opt.update(grads, state["opt"], state["params"])
    # skip the update on non-finite grads (fp16 loss-scaling path)
    new_params = apply_updates(state["params"], updates)
    new_params = jax.tree.map(
        lambda n, o: jnp.where(finite, n, o), new_params, state["params"]
    )
    opt_state = jax.tree.map(
        lambda n, o: jnp.where(finite, n, o) if n.shape == o.shape else n,
        opt_state, state["opt"],
    )
    new_state = {
        "params": new_params,
        "opt": opt_state,
        "scale": scale_state,
        "comp": comp_state,
        "step": state["step"] + 1,
    }
    metrics = dict(metrics, grad_norm=gnorm, wire_bytes=wire,
                   loss_scale=scale_state["scale"])
    if axis_name is not None:
        metrics = {k: jax.lax.pmean(v, axis_name) for k, v in metrics.items()}
    return new_state, metrics


def make_train_step(
    cfg: ArchConfig,
    opt: Optimizer,
    tc: TrainConfig,
    mode: str = "single",
    mesh=None,
    data_axis: str = "data",
    rt: Optional[Runtime] = None,
) -> Callable:
    policy = getattr(PrecisionPolicy, tc.precision)()
    rt = rt if rt is not None else _runtime(cfg, tc)

    def core_step(state, batch, axis_name=None):
        def scaled_loss(p):
            loss, metrics = loss_fn(cfg, p, batch, rt)
            return scale_loss(loss, state["scale"]), metrics

        (loss_s, metrics), grads = jax.value_and_grad(scaled_loss, has_aux=True)(
            state["params"]
        )
        return finish_step(state, grads, metrics, tc, policy, opt, axis_name)

    if mode == "single":
        return jax.jit(lambda state, batch: core_step(state, batch, None))

    if mode == "core":
        # unjitted step on GLOBAL arrays — the distributed trainer jits it
        # with explicit in/out shardings (pjit handles the data-parallel mean
        # through the global-batch loss; no axis_name needed)
        return lambda state, batch: core_step(state, batch, None)

    if mode == "dp_compressed":
        assert mesh is not None
        from jax.sharding import PartitionSpec as P

        def wrapped(state, batch):
            def inner(state, batch):
                return core_step(state, batch, data_axis)

            bspec = jax.tree.map(lambda _: P(data_axis), batch)
            sspec = jax.tree.map(lambda _: P(), state)
            fn = shard_map(
                inner, mesh=mesh,
                in_specs=(sspec, bspec),
                out_specs=(sspec, jax.tree.map(lambda _: P(), _metric_struct())),
                check_vma=False,
            )
            return fn(state, batch)

        return jax.jit(wrapped)

    raise ValueError(mode)


def _metric_struct():
    z = jnp.zeros(())
    return {
        "loss": z, "xent": z, "aux": z, "z_loss": z,
        "grad_norm": z, "wire_bytes": z, "loss_scale": z,
    }


def fit(
    cfg: ArchConfig,
    tc: TrainConfig,
    data: Iterable[Dict[str, Any]],
    steps: int,
    opt: Optimizer,
    state: Optional[Dict[str, Any]] = None,
    step_fn: Optional[Callable] = None,
    log: Callable[[str], None] = print,
) -> Tuple[Dict[str, Any], list]:
    if state is None:
        state = make_state(cfg, opt, tc)
    if step_fn is None:
        step_fn = make_train_step(cfg, opt, tc)
    history = []
    it = iter(data)
    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % tc.log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i + 1, **m})
            log(
                f"step {i+1:5d} loss={m['loss']:.4f} xent={m['xent']:.4f} "
                f"gnorm={m['grad_norm']:.2f} ({(time.time()-t0)/(i+1):.2f}s/it)"
            )
        if tc.ckpt_dir and tc.ckpt_every and (i + 1) % tc.ckpt_every == 0:
            from repro.checkpoint import ckpt

            ckpt.save(tc.ckpt_dir, i + 1, state)
    return state, history
