"""Batched serving: prefill + autoregressive decode with sampling.

``generate`` drives the KV-cache decode path for any architecture family
(attention ring buffers, SSM/RG-LRU recurrent states, enc-dec cross caches).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import Runtime, decode_step, prefill
from repro.models.layers import Params


def sample_token(
    logits: jax.Array, key: jax.Array, temperature: float = 0.0, vocab: int = 0
) -> jax.Array:
    """logits: (B, Vp). temperature 0 = greedy. Padding ids masked out."""
    if vocab:
        mask = jnp.arange(logits.shape[-1]) < vocab
        logits = jnp.where(mask[None, :], logits, -1e30)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


def generate(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    rt: Runtime,
    max_new_tokens: int,
    temperature: float = 0.0,
    seed: int = 0,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Returns (tokens (B, max_new_tokens), final decode state)."""
    prompt_len = batch["tokens"].shape[1]
    total = prompt_len + max_new_tokens
    if cfg.frontend == "vision":
        total += cfg.frontend_tokens

    logits, state = jax.jit(
        lambda p, b: prefill(cfg, p, b, rt, max_len=total)
    )(params, batch)

    step = jax.jit(
        lambda p, s, t: decode_step(cfg, p, s, t, rt, seq_len=total)
    )
    key = jax.random.PRNGKey(seed)
    tok = sample_token(logits, key, temperature, cfg.vocab_size)
    out = [tok]
    for i in range(max_new_tokens - 1):
        key = jax.random.fold_in(key, i)
        logits, state = step(params, state, tok)
        tok = sample_token(logits, key, temperature, cfg.vocab_size)
        out.append(tok)
    return jnp.stack(out, axis=1), state
