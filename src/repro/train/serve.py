"""Batched serving API: prefill + autoregressive decode with sampling.

Thin public wrapper over the serving subsystem (``repro.serve``):

* dense path (default) — cached compiled prefill + one jitted ``lax.scan``
  decode loop per (cfg, rt, shapes, horizon) key (``repro.serve.dense``);
  works for every architecture family (ring-buffer attention, SSM/RG-LRU
  recurrences, enc-dec cross caches).
* ``paged=True`` — routes through the continuous-batching engine and its
  paged KV-cache pool (``repro.serve.engine``); supported for KV-cache
  attention families (``repro.serve.paged_supported``).

``sample_token`` lives in ``repro.serve.sampling`` and is re-exported here
for backwards compatibility.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import Runtime
from repro.models.layers import Params
from repro.serve.sampling import sample_token  # noqa: F401  (re-export)


def generate(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    rt: Runtime,
    max_new_tokens: int,
    temperature: float = 0.0,
    seed: int = 0,
    paged: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Returns (tokens (B, max_new_tokens), final decode state).

    With ``paged=True`` the batch is served by the continuous-batching
    engine (one request per batch row) and the second element is the
    engine's stats dict instead of a dense decode state. Greedy outputs are
    identical across both paths; temperature>0 streams differ (the engine
    samples with per-request keys so outputs are batch-composition
    independent — the dense path's shared key is not).
    """
    if paged:
        from repro.serve import EngineConfig, ServeEngine

        B, S = batch["tokens"].shape
        prompt_total = S + (
            cfg.frontend_tokens if cfg.frontend == "vision" else 0
        )
        eng = ServeEngine(
            cfg, params, rt,
            EngineConfig.capacity(
                prompt_total, max_new_tokens, slots=B,
            ).engine(temperature=temperature, seed=seed),
        )
        fe = batch.get("frontend_embeds")
        rids = [
            eng.submit(
                jnp.asarray(batch["tokens"][b]),
                max_new_tokens,
                frontend_embeds=None if fe is None else fe[b],
            )
            for b in range(B)
        ]
        out = eng.run()
        tokens = jnp.stack([jnp.asarray(out[r]) for r in rids])
        return tokens, eng.stats

    from repro.serve.dense import generate_dense

    tokens, state, _ = generate_dense(
        cfg, params, batch, rt, max_new_tokens,
        temperature=temperature, seed=seed,
    )
    return tokens, state
