"""jax version-compatibility shims.

``shard_map`` was promoted from ``jax.experimental.shard_map`` to the
top-level namespace (and its ``check_rep`` flag renamed ``check_vma``) in
newer jax releases, and ``jax.lax.axis_size`` only exists on the new side;
the containers this repo runs on may carry either. Route every use through
here.
"""
from __future__ import annotations

import jax


def axis_size(axis_name):
    """Size of a bound mesh axis (inside shard_map/pmap)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)  # jax <= 0.4.x

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )

else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
        kw = {} if check_vma is None else {"check_rep": check_vma}
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
