"""Serving launcher: batched generation driver on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 32

With ``--reduced`` (the CPU-container mode) a smoke-size variant of the
architecture family is instantiated and driven through the real prefill +
decode path. Without it, the full config is built (requires a TPU fleet;
params are initialized sharded via the dry-run shardings).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_config, get_reduced
from repro.models import Runtime, init_params
from repro.train import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=ASSIGNED)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    rt = Runtime(dtype=jnp.float32 if args.reduced else jnp.bfloat16, chunk_q=32)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    rng = np.random.RandomState(args.seed)
    batch = {
        "tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32,
        )
    }
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    t0 = time.perf_counter()
    tokens, _ = generate(
        cfg, params, batch, rt, max_new_tokens=args.new_tokens,
        temperature=args.temperature, seed=args.seed,
    )
    dt = time.perf_counter() - t0
    print(f"{cfg.name} [{cfg.family}]: {tokens.size} tokens in {dt:.1f}s")
    for b in range(min(2, args.batch)):
        print(f"  seq[{b}]: {tokens[b].tolist()}")


if __name__ == "__main__":
    main()
