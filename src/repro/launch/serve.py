"""Serving launcher: continuous-batching engine / batched generation driver.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 32

    # continuous batching over the paged KV pool (variable-length requests):
    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --paged \
        --requests 8 --slots 4 --page-size 16

    # radix prefix cache + chunked prefill (shared system prompt workload):
    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --paged \
        --requests 8 --prefix-cache --prefill-chunk 8 --shared-prefix 24

    # sharded serving: 2 data replicas x TP=2 over 4 (forced-host) devices
    PYTHONPATH=src python -m repro.launch.serve --arch moonshot-v1-16b-a3b \
        --paged --mesh 2x2 --requests 8

    # SLO-grade trace replay through the async front-end (p50/p99 + goodput):
    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --paged \
        --trace poisson --arrival-rate 0.8 --qos mixed --max-queue 4

With ``--reduced`` (the CPU-container mode) a smoke-size variant of the
architecture family is instantiated and driven through the real prefill +
decode path. Without it, the full config is built (requires a TPU fleet;
params are initialized sharded via the dry-run shardings).

``--paged`` routes through ``repro.serve.ServeEngine``: requests with
varying prompt lengths are admitted into fixed decode slots against the
paged KV-cache pool; unsupported families (SSM / enc-dec) fall back to the
dense path automatically.

``--prefix-cache`` turns on the radix-tree KV prefix cache (retired prompts'
pages stay pooled; token-exact shared prefixes are adopted with zero prefill
FLOPs) and ``--prefill-chunk N`` interleaves N-token prefill chunks with the
decode batch (one jitted step runs both). ``--shared-prefix K`` prepends a
common K-token system prompt to every generated request so the hit rate is
demonstrable; engine prefix stats (hit rate, cached-token fraction, mean
TTFT) print at exit.

``--mesh DxM`` serves over a ``(data, model)`` mesh: the KV pool and params
shard over the ``model`` axis (Megatron head split; KV bytes per device
shrink by M) and the ``data`` axis runs D least-loaded-routed engine
replicas. On CPU the device count is forced via
``--xla_force_host_platform_device_count`` unless ``--no-force-devices``
(set it for real TPU fleets, where the devices already exist).
"""
from __future__ import annotations

import argparse
import os
import time


def _replay_cli(args, cfg, eng) -> None:
    """--trace mode: replay a timed arrival stream through the async
    front-end and print the latency distribution + tick-exact goodput."""
    import asyncio

    import numpy as np

    from repro.serve import bursty_trace, goodput, poisson_trace, replay_trace

    rng = np.random.RandomState(args.seed)
    kw = dict(
        vocab=cfg.vocab_size,
        prompt_range=(max(args.prompt_len // 2, 1), args.prompt_len),
        new_range=(max(args.new_tokens // 2, 1), args.new_tokens),
        qos_batch_frac={"interactive": 0.0, "batch": 1.0, "mixed": 0.25}[
            args.qos
        ],
        shared_prefix=(
            rng.randint(0, cfg.vocab_size, (args.shared_prefix,)).astype(
                np.int32
            )
            if args.shared_prefix else None
        ),
        shared_frac=0.5 if args.shared_prefix else 0.0,
    )
    if args.trace == "poisson":
        trace = poisson_trace(
            rng, args.requests, rate=args.arrival_rate, **kw
        )
    else:
        gap = max(int(round(4 / args.arrival_rate)), 1)
        trace = bursty_trace(rng, args.requests, burst=4, gap=gap, **kw)

    records, fe = asyncio.run(replay_trace(eng, trace))
    ttfts = [r["ttft_s"] for r in records if r["ttft_s"] is not None]
    tpots = [r["tpot_s"] for r in records if r["tpot_s"] is not None]
    met, total = goodput(records, args.slo_ticks)
    s = eng.stats
    print(
        f"{cfg.name} [{cfg.family}] trace={args.trace} "
        f"rate={args.arrival_rate}/tick qos={args.qos}: "
        f"{total} requests over {fe.ticks} ticks, "
        f"{s['tokens_per_s']:.1f} tok/s"
    )
    if ttfts:
        print(
            f"  ttft_ms p50={np.percentile(ttfts, 50) * 1e3:.1f} "
            f"p99={np.percentile(ttfts, 99) * 1e3:.1f}; "
            f"tpot_ms p50={np.percentile(tpots, 50) * 1e3:.2f} "
            f"p99={np.percentile(tpots, 99) * 1e3:.2f}"
            if tpots else
            f"  ttft_ms p50={np.percentile(ttfts, 50) * 1e3:.1f} "
            f"p99={np.percentile(ttfts, 99) * 1e3:.1f}"
        )
    completed = sum(1 for r in records if r["status"] == "complete")
    deferred = sum(r["deferred_ticks"] for r in records)
    print(
        f"  goodput={met}/{total} (first token within {args.slo_ticks} "
        f"ticks of arrival); completed={completed}; "
        f"preemptions={s.get('evictions', 0)}; deferred_ticks={deferred}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="continuous batching over the paged KV pool")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of variable-length requests (--paged)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kernel", action="store_true",
                    help="route decode through the Pallas paged kernel")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=("bf16", "int8", "fp8"),
                    help="KV pool storage dtype: quantized pages are "
                         "dequantized inside the paged kernels (~2x "
                         "resident requests per device at int8)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree KV prefix reuse across requests")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="interleave N-token prefill chunks with decode "
                         "(0 = whole-prompt prefill at admission)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common K-token system prompt to every "
                         "request (makes --prefix-cache hits observable)")
    ap.add_argument("--trace", default="", choices=("", "poisson", "bursty"),
                    help="replay a timed arrival trace through the async "
                         "front-end instead of one submit-all drain "
                         "(requires --paged); prints p50/p99 TTFT/TPOT "
                         "and tick-exact SLO goodput")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="mean arrivals per engine tick for --trace "
                         "(poisson: exponential gaps; bursty: bursts of 4 "
                         "spaced to the same mean rate)")
    ap.add_argument("--qos", default="mixed",
                    choices=("interactive", "batch", "mixed"),
                    help="QoS population for --trace: all-interactive, "
                         "all-batch, or a 25%% batch mix")
    ap.add_argument("--slo-ticks", type=int, default=10,
                    help="goodput SLO for --trace: first token within this "
                         "many ticks of arrival")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="per-QoS-tier admission queue cap (0 = unbounded); "
                         "overflow raises QueueFull / defers trace arrivals")
    ap.add_argument("--spec", type=int, default=0, metavar="K",
                    help="speculative decoding: draft K tokens per tick and "
                         "commit the verified run (greedy only — forces "
                         "temperature 0; requires --paged)")
    ap.add_argument("--drafter", default="ngram", choices=("ngram", "model"),
                    help="--spec drafter: model-free prompt lookup, or a "
                         "paired reduced same-family model "
                         "(spec.paired_drafter_cfg)")
    ap.add_argument("--mesh", default="",
                    help="DxM (data replicas x model shards), e.g. 2x2")
    ap.add_argument("--no-force-devices", dest="force_devices",
                    action="store_false", default=True,
                    help="don't force host platform device count for --mesh")
    args = ap.parse_args()

    data_par = model_par = 1
    if args.mesh:
        if not args.paged:
            ap.error("--mesh requires --paged (only the continuous-batching "
                     "engine serves sharded; the dense driver is unsharded)")
        data_par, model_par = (int(x) for x in args.mesh.lower().split("x"))
        if args.force_devices:
            # must land before jax initializes its backend (first device use)
            flags = os.environ.get("XLA_FLAGS", "")
            if "--xla_force_host_platform_device_count" not in flags:
                os.environ["XLA_FLAGS"] = (
                    flags
                    + f" --xla_force_host_platform_device_count="
                    f"{data_par * model_par}"
                ).strip()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import ASSIGNED, get_config, get_reduced
    from repro.launch.mesh import make_serve_mesh
    from repro.models import Runtime, init_params
    from repro.serve import (
        EngineConfig,
        ReplicatedServeEngine,
        ServeEngine,
        paged_supported,
    )
    from repro.train import generate

    assert args.arch in ASSIGNED, f"--arch must be one of {ASSIGNED}"
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_serve_mesh(data_par, model_par) if args.mesh else None
    rt = Runtime(dtype=jnp.float32 if args.reduced else jnp.bfloat16, chunk_q=32)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.RandomState(args.seed)

    if args.paged:
        paged = paged_supported(cfg)
        if not paged:
            print(f"{cfg.name}: family {cfg.family!r} -> dense fallback")
        if args.spec and not paged:
            ap.error(f"--spec needs a paged-capable family, not {args.arch}")
        max_prompt = args.prompt_len + args.shared_prefix
        temperature = 0.0 if args.spec else args.temperature
        ecfg = EngineConfig.capacity(
            max_prompt + cfg.frontend_tokens, args.new_tokens,
            slots=args.slots, page_size=args.page_size, headroom=2.0,
            kv_dtype=args.kv_dtype,
        ).engine(
            temperature=temperature, seed=args.seed,
            use_kernel=args.kernel,
            prefill_bucket=args.page_size,  # random lengths: bound compiles
            prefix_cache=args.prefix_cache,
            prefill_chunk=args.prefill_chunk,
            max_queue=args.max_queue,
            spec_tokens=args.spec, spec_drafter=args.drafter,
        )
        draft_params = draft_cfg = None
        if args.spec and args.drafter == "model":
            from repro.serve import paired_drafter_cfg

            draft_cfg = paired_drafter_cfg(cfg)
            draft_params = init_params(
                draft_cfg, jax.random.PRNGKey(args.seed + 1)
            )
        if mesh is not None:
            eng = ReplicatedServeEngine(
                cfg, params, rt, ecfg, mesh=mesh, paged=paged,
                draft_params=draft_params, draft_cfg=draft_cfg,
            )
        else:
            eng = ServeEngine(
                cfg, params, rt, ecfg, paged=paged,
                draft_params=draft_params, draft_cfg=draft_cfg,
            )
        if args.trace:
            # the dense fallback works too: _step_dense is one tick
            _replay_cli(args, cfg, eng)
            return
        sys_prompt = rng.randint(
            0, cfg.vocab_size, (args.shared_prefix,)
        ).astype(np.int32)
        rids = []
        for _ in range(args.requests):
            plen = rng.randint(max(args.prompt_len // 2, 1), args.prompt_len + 1)
            tokens = np.concatenate([
                sys_prompt,
                rng.randint(0, cfg.vocab_size, (plen,)).astype(np.int32),
            ])
            fe = (
                rng.randn(cfg.frontend_tokens, cfg.d_model).astype(np.float32)
                if cfg.frontend is not None else None
            )
            rids.append(eng.submit(tokens, args.new_tokens, frontend_embeds=fe))
        out = eng.run()
        s = eng.stats
        # per-run mean (submit -> first token); stats["ttft_s"] accumulates
        # per-rid entries across runs on a reused engine
        ttft = s["run_mean_ttft_s"]
        print(
            f"{cfg.name} [{cfg.family}] paged={paged}"
            + (f" mesh={data_par}x{model_par}" if mesh is not None else "")
            + f": {sum(len(v) for v in out.values())} tokens, "
            f"{s['tokens_per_s']:.1f} tok/s, mean TTFT {ttft * 1e3:.0f}ms, "
            f"evictions={s.get('evictions', 0)}"
        )
        from repro.kernels.paged_attention.quant import kv_token_bytes

        kv_maps = (
            [e.stats.get("kv_bytes", {}) for e in eng.engines]
            if mesh is not None else [s.get("kv_bytes", {})]
        )
        per_req = [b for m in kv_maps for b in m.values()]
        cap_factor = (
            kv_token_bytes(cfg.n_kv_heads, cfg.head_dim, "bf16")
            / kv_token_bytes(cfg.n_kv_heads, cfg.head_dim, args.kv_dtype)
        )
        print(
            f"  kv-pool: dtype={args.kv_dtype}, "
            f"bytes/request={np.mean(per_req):.0f} (mean over {len(per_req)}), "
            f"capacity_factor_vs_bf16={cap_factor:.2f}x"
        )
        if args.spec:
            print(
                f"  spec: k={args.spec} drafter={args.drafter} "
                f"accept_rate={s.get('spec_accept_rate', 0.0):.2f} "
                f"accepted_per_verify="
                f"{s.get('spec_accepted_per_verify', 1.0):.2f}"
            )
        if args.prefix_cache and "prefix_lookups" in s:
            hit_rate = s["prefix_hits"] / max(s["prefix_lookups"], 1)
            cached_frac = (
                s["prefix_cached_tokens"] / max(s.get("prompt_tokens", 1), 1)
            )
            print(
                f"  prefix-cache: hit_rate={hit_rate:.2f} "
                f"({s['prefix_hits']}/{s['prefix_lookups']}), "
                f"cached_token_fraction={cached_frac:.2f}, "
                f"prefill_chunks={s.get('prefill_chunks', 0)}, "
                f"mean_ttft_ms={ttft * 1e3:.1f}"
            )
        if mesh is not None:
            print(
                f"  replicas={s.get('replica_requests')} "
                f"kv_pool_bytes_per_device={s.get('kv_pool_bytes_per_device')}"
            )
        for rid in rids[:2]:
            print(f"  req[{rid}]: {out[rid][:12].tolist()}...")
        return

    batch = {
        "tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32,
        )
    }
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    t0 = time.perf_counter()
    tokens, _ = generate(
        cfg, params, batch, rt, max_new_tokens=args.new_tokens,
        temperature=args.temperature, seed=args.seed,
    )
    dt = time.perf_counter() - t0
    print(f"{cfg.name} [{cfg.family}]: {tokens.size} tokens in {dt:.1f}s")
    for b in range(min(2, args.batch)):
        print(f"  seq[{b}]: {tokens[b].tolist()}")


if __name__ == "__main__":
    main()
