import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST precede any other import (jax locks the device
count on first init): this process sees 512 placeholder CPU devices so the
production meshes (16x16 single-pod / 2x16x16 two-pod) can be built.

Per combination this driver:
  1. builds the jitted step via repro.launch.train (train / prefill /
     serve_step per the shape's kind),
  2. .lower()s it with sharded ShapeDtypeStructs (no allocation),
  3. .compile()s — SPMD partitioning must succeed (sharding bugs die here),
  4. records memory_analysis() (proves the per-device footprint),
     cost_analysis() (FLOPs/bytes for the roofline), and the parsed
     collective bytes into experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --all --mesh multi      # 512-chip 2-pod pass
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.train import build_decode, build_prefill, build_train
from repro.roofline.analysis import analyze
from repro.train import TrainConfig

OUT_DIR = "experiments/dryrun"


_KEEP = ("while(", " dot(", "all-reduce", "all-gather", "reduce-scatter",
         "all-to-all", "collective-permute", "constant(", "fusion(", "calls=",
         "to_apply=", "condition=")


def _filter_hlo(hlo: str) -> str:
    """Keep only the lines the roofline parser reads (headers, closers,
    whiles, dots, collectives, constants, call edges) — ~100x smaller."""
    out = []
    for line in hlo.splitlines():
        s = line.strip()
        if (
            s == "}"
            or (s.endswith("{") and "->" in s)
            or any(k in s for k in _KEEP)
        ):
            out.append(s)
    return "\n".join(out)


def should_skip(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.long_context == "sw_variant":
        # runs via the sliding-window variant — never skipped, but flagged
        return None
    return None


def run_one(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    tc: Optional[TrainConfig] = None,
    tag: str = "",
    out_dir: str = OUT_DIR,
    moe_mode: str = "auto",
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    t0 = time.time()

    base_tc = tc or TrainConfig(precision="bf16", remat="full", zero_stage=1)
    if moe_mode != "auto":
        import dataclasses

        base_tc = dataclasses.replace(base_tc, moe_mode=moe_mode)

    zero3_w = base_tc.zero_stage >= 3  # serve paths: 2D-shard the weights
    if shape.kind == "train":
        jitted, (s_struct, b_struct) = build_train(arch, mesh, base_tc, shape)
        args = (s_struct, b_struct)
    elif shape.kind == "prefill":
        jitted, (p_struct, b_struct) = build_prefill(
            arch, mesh, shape, base_tc, zero3_params=zero3_w
        )
        args = (p_struct, b_struct)
    else:
        jitted, (p_struct, c_struct, t_struct) = build_decode(
            arch, mesh, shape, base_tc, zero3_params=zero3_w
        )
        args = (p_struct, c_struct, t_struct)

    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {
        k: float(getattr(mem, k, 0) or 0)
        for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()

    trip_hint = cfg.n_layers
    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = mult * n_active * n_tokens

    roof = analyze(
        arch=arch, shape=shape_name, mesh_name=mesh_kind, n_devices=n_dev,
        cost={k: cost.get(k, 0.0) for k in ("flops", "bytes accessed")},
        hlo=hlo, trip_hint=trip_hint, model_flops=model_flops,
        memory_analysis=mem_d,
    )
    rec = roof.as_dict()
    rec.update(
        n_devices=n_dev, kind=shape.kind,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        sw_variant=(shape_name == "long_500k" and cfg.long_context == "sw_variant"),
        zero_stage=base_tc.zero_stage, remat=base_tc.remat, tag=tag,
        seq_shard=base_tc.seq_shard, moe_mode=base_tc.moe_mode,
        scan_mode=base_tc.scan_mode, hlo_bytes_text=len(hlo),
    )
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    stem = f"{arch}__{shape_name}__{mesh_kind}{suffix}"
    path = os.path.join(out_dir, f"{stem}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    # filtered HLO digest: re-analyzable without recompiling (see roofline/)
    import gzip

    with gzip.open(os.path.join(out_dir, f"{stem}.hlo.gz"), "wt") as f:
        f.write(_filter_hlo(hlo))
    print(
        f"OK  {arch:22s} {shape_name:12s} {mesh_kind:6s} "
        f"temp={mem_d['temp_size_in_bytes']/2**30:7.2f}GiB "
        f"args={mem_d['argument_size_in_bytes']/2**30:7.2f}GiB "
        f"t_c={rec['t_compute']*1e3:8.2f}ms t_m={rec['t_memory']*1e3:8.2f}ms "
        f"t_x={rec['t_collective']*1e3:8.2f}ms dom={rec['dominant']:10s} "
        f"(compile {t_compile:.0f}s)"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--zero", type=int, default=1)
    ap.add_argument("--moe-mode", default="auto", choices=["auto", "ep"])
    ap.add_argument("--seq-shard", nargs="?", const="seq", default="",
                    choices=["", "seq", "hidden"])
    ap.add_argument("--scan-mode", default="assoc", choices=["assoc", "chunked"])
    ap.add_argument("--ssm-seqpar", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    tc = TrainConfig(precision="bf16", remat=args.remat, zero_stage=args.zero,
                     seq_shard=args.seq_shard, scan_mode=args.scan_mode,
                     ssm_seqpar=args.ssm_seqpar)
    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        try:
            run_one(arch, shape, args.mesh, tc=tc, tag=args.tag,
                    out_dir=args.out_dir, moe_mode=args.moe_mode)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape, repr(e)))
            print(f"FAIL {arch} {shape}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} combination(s) failed: {failures}")


if __name__ == "__main__":
    main()
