"""Production meshes (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — jax locks the device count on first backend initialization, and the
dry-run must set XLA_FLAGS before that happens.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip constants (used by repro.roofline)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for subprocess tests (device count forced by XLA_FLAGS)."""
    return jax.make_mesh((data, model), ("data", "model"))
