"""Production meshes (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — jax locks the device count on first backend initialization, and the
dry-run must set XLA_FLAGS before that happens.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip constants (used by repro.roofline)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for subprocess tests (device count forced by XLA_FLAGS)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_train_mesh(data: int = 1, model: int = 1, pipe: int = 1):
    """3D training mesh (data, model, pipe) for the executable pipeline.

    ``pipe`` spans the 1F1B/GPipe stages (slowest-varying so stage
    neighbours sit on contiguous device spans), ``model`` the Megatron TP
    shards within each stage, ``data`` the ZeRO/data-parallel replicas.
    With pipe=1 this degenerates to the classic (data, model) layout plus a
    size-1 axis, so one code path serves 1D/2D/3D runs.
    """
    n = len(jax.devices())
    if data * model * pipe > n:
        raise ValueError(
            f"mesh {data}x{model}x{pipe} needs {data * model * pipe} "
            f"devices, have {n}"
        )
    return jax.make_mesh((data, model, pipe), ("data", "model", "pipe"))


def make_serve_mesh(data: int = 1, model: int = 1):
    """Serving mesh: `model` shards one engine (TP), `data` counts replicas.

    Requires data*model visible devices (force with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU).
    """
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"mesh {data}x{model} needs {data * model} devices, have {n}"
        )
    return jax.make_mesh((data, model), ("data", "model"))


def replica_submeshes(mesh):
    """Split a ``(data, model)`` mesh into per-replica model-only meshes.

    Each data slice becomes an independent serving replica holding a full
    (TP-sharded) parameter copy; a mesh without a ``data`` axis is one
    replica.
    """
    from jax.sharding import Mesh

    names = tuple(mesh.axis_names)
    if "data" not in names or mesh.shape["data"] == 1:
        keep = [a for a in names if a != "data"] or list(names)
        devs = mesh.devices.reshape(
            tuple(mesh.shape[a] for a in keep)
        )
        return [Mesh(devs, tuple(keep))]
    d_axis = names.index("data")
    devs = mesh.devices
    out = []
    for i in range(mesh.shape["data"]):
        sl = [slice(None)] * devs.ndim
        sl[d_axis] = i
        keep = tuple(a for a in names if a != "data")
        out.append(Mesh(devs[tuple(sl)], keep))
    return out
