"""Distributed trainer/server builder: pjit + TP specs + ZeRO overlays.

``build_train`` / ``build_prefill`` / ``build_decode`` return
(step_fn_jitted, input ShapeDtypeStructs with shardings attached) — used by
the multi-pod dry-run (lower+compile only) and by the real trainer entry
point (``main``) on whatever devices exist. ``build_train_pipeline`` is the
3D sibling: it executes a ``core.partitioner.ParallelPlan`` — an
(data, model, pipe) mesh whose pipe axis streams the executable 1F1B/GPipe
schedule (repro.core.pipeline tick tables) while TP follows the same
Megatron specs sliced per stage and ZeRO overlays shard optimizer state
over ``data`` within each stage.

Sharding recipe (DESIGN.md §4):
  batch        over ("pod", "data")        [whichever axes divide it]
  params       TP over "model" (sharding/specs.py) + ZeRO-3 adds "data";
               stacked layer params add "pipe" on the layer axis (3D mesh)
  grads        ZeRO-2+ adds "data"
  opt state    ZeRO-1+ adds "data"
  kv caches    kv-heads over "model", else sequence-parallel over "model"
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, ShapeSpec, get_config, get_shape
from repro.core import zero as zero_mod
from repro.models import Runtime, decode_step, init_decode_state, prefill
from repro.models.runtime import Runtime as RuntimeT
from repro.optim import get as get_opt
from repro.sharding import specs as S
from repro.train import TrainConfig, make_state, make_train_step


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _struct_with(shardings, structs):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        structs, shardings,
    )


def make_runtime(cfg: ArchConfig, mesh, shape: ShapeSpec, tc: TrainConfig) -> Runtime:
    from repro.core.precision import PrecisionPolicy

    policy = getattr(PrecisionPolicy, tc.precision)()
    return Runtime(
        dtype=policy.compute_dtype,
        remat=tc.remat,
        moe_mode=tc.moe_mode,
        mesh=mesh,
        batch_axes=S.batch_axes(mesh, shape.global_batch),
        long_variant=(shape.name == "long_500k"),
        seq_shard=tc.seq_shard,
        scan_mode=tc.scan_mode,
        ssm_seqpar=tc.ssm_seqpar,
        remat_period=tc.remat_period,
        fused_backward=tc.fused_backward,
        use_flash_kernel=tc.fused_backward,
    )


def _batch_struct(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    B, L = shape.global_batch, shape.seq_len
    text_len = L - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    out = {
        "tokens": jax.ShapeDtypeStruct((B, text_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, text_len), jnp.int32),
    }
    if cfg.frontend is not None:
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return out


def state_specs(cfg: ArchConfig, state_struct: Any, mesh, zero_stage: int) -> Any:
    """Sharding specs for the full train state (params/opt/scale/comp/step)."""
    pspecs = S.param_specs(cfg, state_struct["params"], mesh)
    p_shapes = state_struct["params"]
    p_over, g_over, o_over = zero_mod.overlay(zero_stage, pspecs, p_shapes, mesh)

    def opt_specs(opt_struct):
        # m/v/mu mirror the params tree; scalars replicate
        out = {}
        for k, v in opt_struct.items():
            if k in ("m", "v", "mu"):
                out[k] = o_over
            elif k == "slots":
                out[k] = jax.tree.map(lambda _: P(), v)
            else:
                out[k] = jax.tree.map(lambda _: P(), v) if isinstance(v, dict) else P()
        return out

    return {
        "params": p_over,
        "opt": opt_specs(state_struct["opt"]),
        "scale": jax.tree.map(lambda _: P(), state_struct["scale"]),
        "comp": jax.tree.map(lambda _: P(), state_struct["comp"]),
        "step": P(),
    }


METRIC_SPECS = {
    "loss": P(), "xent": P(), "aux": P(), "z_loss": P(),
    "grad_norm": P(), "wire_bytes": P(), "loss_scale": P(),
}


def build_train(
    arch: str, mesh, tc: Optional[TrainConfig] = None,
    shape: Optional[ShapeSpec] = None,
) -> Tuple[Callable, Tuple[Any, Any]]:
    """Returns (jitted step, (state_struct, batch_struct)) for train_4k-style
    shapes. Structs carry shardings — pass them to .lower() for the dry-run
    or build real arrays with those shardings for execution."""
    cfg = get_config(arch)
    tc = tc or TrainConfig(precision="bf16", remat="full")
    shape = shape or get_shape("train_4k")
    opt = get_opt(tc.optimizer, tc.lr)
    rt = make_runtime(cfg, mesh, shape, tc)

    state_struct = jax.eval_shape(lambda: make_state(cfg, opt, tc))
    batch_struct = _batch_struct(cfg, shape)

    sspecs = state_specs(cfg, state_struct, mesh, tc.zero_stage)
    bspecs = S.batch_specs(batch_struct, mesh, shape.global_batch)

    s_shard, b_shard = _ns(mesh, sspecs), _ns(mesh, bspecs)
    step = make_train_step(cfg, opt, tc, mode="core", rt=rt)
    jitted = jax.jit(
        step,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, _ns(mesh, METRIC_SPECS)),
        donate_argnums=(0,),
    )
    return jitted, (
        _struct_with(s_shard, state_struct),
        _struct_with(b_shard, batch_struct),
    )


def make_pipeline_step(cfg: ArchConfig, mesh, plan, tc: TrainConfig, opt):
    """Unjitted (state, batch) -> (state, metrics) executing ``plan``.

    The gradient computation runs through the manual-backward pipeline
    runner (repro.core.pipeline.pipeline_grads): the batch splits into
    ``plan.microbatches`` microbatches, the stacked layer params are already
    pipe-sharded by ``sharding.specs.param_specs`` (stage slices land on
    their devices with no relayout), shared params ride in replicated, and
    the returned grads re-enter the standard step tail
    (``train.loop.finish_step``: unscale, clip, ZeRO-sharded optimizer
    update). State layout is IDENTICAL to the 2D trainer's — only shardings
    differ — which is what makes checkpoint reshard-on-load trivial
    (checkpoint.ckpt.restore_resharded).
    """
    from repro.core.pipeline import tick_table
    from repro.core.precision import PrecisionPolicy
    from repro.core.stash import get_backend
    from repro.models.lm import pipeline_fns
    from repro.train.loop import finish_step

    plan.validate(cfg)
    stash_backend = get_backend(
        plan.stash, fused=tc.fused_stash,
        cotangents=plan.stash_cot or tc.stash_cot,
    )
    if not stash_backend.scan_capable:
        raise ValueError(
            f"stash={plan.stash!r} is host-driven; use "
            "build_train_pipeline_host (single-device eager runner)"
        )
    if tc.compression is not None:
        raise ValueError("pipeline mode composes with ZeRO, not compressed DP")
    if tc.fused_backward:
        raise ValueError(
            "pipeline mode does not route the fused Pallas backward / "
            "chunked-CE head (the runner owns the backward); drop "
            "fused_backward"
        )
    policy = getattr(PrecisionPolicy, tc.precision)()
    rt = RuntimeT(dtype=policy.compute_dtype, remat=plan.remat,
                  fused_stash=tc.fused_stash)
    table = tick_table(plan.schedule, plan.pp, plan.microbatches)
    first_fn, stage_fn, last_fn = pipeline_fns(cfg, rt, plan.tp)
    M = plan.microbatches
    dp_full = mesh.shape["data"]

    from repro.core.pipeline import pipeline_grads

    def step(state, batch):
        params = state["params"]
        stack = params["stack"]
        shared = {k: v for k, v in params.items() if k != "stack"}
        B, seq = batch["tokens"].shape
        assert B % M == 0, f"batch {B} not divisible into {M} microbatches"
        mbs = jax.tree.map(
            lambda a: a.reshape((M, B // M) + a.shape[1:]), batch
        )
        mb_specs = S.microbatch_specs(mbs, mesh, B // M)
        ba = S.batch_axes(mesh, B // M)
        b_local = (B // M) // S._size(mesh, ba)
        x_struct = jax.ShapeDtypeStruct((b_local, seq, cfg.d_model), rt.dtype)
        metrics_struct = {
            "xent": jax.ShapeDtypeStruct((), jnp.float32),
            "z_loss": jax.ShapeDtypeStruct((), jnp.float32),
        }
        stage_specs = S.param_specs(cfg, params, mesh)["stack"]
        norm = M * dp_full
        seed = state["scale"]["scale"] / norm
        loss_sum, msum, stack_g, shared_g = pipeline_grads(
            first_fn, stage_fn, last_fn, stack, shared, mbs,
            mesh=mesh, table=table, x_struct=x_struct,
            metrics_struct=metrics_struct, stage_specs=stage_specs,
            mb_specs=mb_specs, seed=seed, data_axis="data",
            stash=stash_backend,
        )
        grads = dict(shared_g, stack=stack_g)
        loss = loss_sum / norm
        xent = msum["xent"] / norm
        zl = msum["z_loss"] / norm
        aux = (
            (loss - xent - zl) / cfg.router_aux_coef
            if cfg.router_aux_coef else jnp.zeros((), jnp.float32)
        )
        metrics = {"loss": loss, "xent": xent, "z_loss": zl, "aux": aux}
        return finish_step(state, grads, metrics, tc, policy, opt)

    return step


def build_train_pipeline(
    arch: str, mesh, plan, tc: Optional[TrainConfig] = None,
    shape: Optional[ShapeSpec] = None,
) -> Tuple[Callable, Tuple[Any, Any]]:
    """3D pipelined twin of ``build_train``: same state/batch structs and
    sharding plumbing, step from ``make_pipeline_step``. ``mesh`` must carry
    (data, model, pipe) axes matching ``plan``'s degrees."""
    cfg = get_config(arch)
    tc = tc or TrainConfig(precision="bf16")
    shape = shape or get_shape("train_4k")
    for ax, deg in (("data", plan.dp), ("model", plan.tp), ("pipe", plan.pp)):
        if mesh.shape.get(ax) != deg:
            raise ValueError(f"mesh {dict(mesh.shape)} != plan {plan.describe()}")
    opt = get_opt(tc.optimizer, tc.lr)

    state_struct = jax.eval_shape(lambda: make_state(cfg, opt, tc))
    batch_struct = _batch_struct(cfg, shape)

    sspecs = state_specs(cfg, state_struct, mesh, tc.zero_stage)
    bspecs = S.batch_specs(batch_struct, mesh, shape.global_batch)
    s_shard, b_shard = _ns(mesh, sspecs), _ns(mesh, bspecs)

    step = make_pipeline_step(cfg, mesh, plan, tc, opt)
    jitted = jax.jit(
        step,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, _ns(mesh, METRIC_SPECS)),
        donate_argnums=(0,),
    )
    return jitted, (
        _struct_with(s_shard, state_struct),
        _struct_with(b_shard, batch_struct),
    )


def build_train_pipeline_host(
    arch: str, plan, tc: Optional[TrainConfig] = None,
    shape: Optional[ShapeSpec] = None, host_window: int = 2,
    lookahead: int = 2,
) -> Tuple[Callable, Tuple[Any, Any], Any]:
    """Host-driven twin of ``build_train_pipeline`` for ``stash='host'``:
    the per-tick runner (core.pipeline.pipeline_grads_host) on ONE device
    (dp = tp = 1), with the HostStash evicting activation slots to host RAM
    between a microbatch's forward and backward. ``lookahead`` ticks of the
    table's B-entries are prefetched ahead of use so host->device loads
    overlap compute (0 = eager baseline; results are bitwise-equal either
    way). Returns (unjitted step, (state_struct, batch_struct),
    stash_backend) — the backend handle exposes ``stats()`` (overlap /
    stall counters) for exit reporting."""
    from repro.core.pipeline import pipeline_grads_host, tick_table
    from repro.core.precision import PrecisionPolicy
    from repro.core.stash import get_backend
    from repro.models.lm import pipeline_fns
    from repro.train.loop import finish_step

    cfg = get_config(arch)
    tc = tc or TrainConfig(precision="bf16")
    shape = shape or get_shape("train_4k")
    plan.validate(cfg)   # host stash requires dp == tp == 1
    opt = get_opt(tc.optimizer, tc.lr)
    policy = getattr(PrecisionPolicy, tc.precision)()
    rt = RuntimeT(dtype=policy.compute_dtype, remat=plan.remat)
    table = tick_table(plan.schedule, plan.pp, plan.microbatches)
    first_fn, stage_fn, last_fn = pipeline_fns(cfg, rt, 1)
    M = plan.microbatches
    backend = get_backend(plan.stash, host_window=host_window)

    def step(state, batch):
        params = state["params"]
        stack = params["stack"]
        shared = {k: v for k, v in params.items() if k != "stack"}
        B, seq = batch["tokens"].shape
        assert B % M == 0, f"batch {B} not divisible into {M} microbatches"
        mbs = jax.tree.map(
            lambda a: a.reshape((M, B // M) + a.shape[1:]), batch
        )
        x_struct = jax.ShapeDtypeStruct((B // M, seq, cfg.d_model), rt.dtype)
        metrics_struct = {
            "xent": jax.ShapeDtypeStruct((), jnp.float32),
            "z_loss": jax.ShapeDtypeStruct((), jnp.float32),
        }
        norm = M
        seed = state["scale"]["scale"] / norm
        loss_sum, msum, stack_g, shared_g = pipeline_grads_host(
            first_fn, stage_fn, last_fn, stack, shared, mbs,
            table=table, x_struct=x_struct,
            metrics_struct=metrics_struct, seed=seed, stash=backend,
            lookahead=lookahead,
        )
        grads = dict(shared_g, stack=stack_g)
        loss = loss_sum / norm
        xent = msum["xent"] / norm
        zl = msum["z_loss"] / norm
        aux = (
            (loss - xent - zl) / cfg.router_aux_coef
            if cfg.router_aux_coef else jnp.zeros((), jnp.float32)
        )
        metrics = {"loss": loss, "xent": xent, "z_loss": zl, "aux": aux}
        return finish_step(state, grads, metrics, tc, policy, opt)

    state_struct = jax.eval_shape(lambda: make_state(cfg, opt, tc))
    batch_struct = _batch_struct(cfg, shape)
    return step, (state_struct, batch_struct), backend


def _params_struct_and_shard(cfg: ArchConfig, mesh, zero3: bool = False):
    from repro.models import init_params

    p_struct = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = S.param_specs(cfg, p_struct, mesh)
    if zero3:
        pspecs, _, _ = zero_mod.overlay(3, pspecs, p_struct, mesh)
    return p_struct, _ns(mesh, pspecs)


def build_prefill(
    arch: str, mesh, shape: Optional[ShapeSpec] = None,
    tc: Optional[TrainConfig] = None, zero3_params: bool = False,
) -> Tuple[Callable, Tuple[Any, Any]]:
    cfg = get_config(arch)
    tc = tc or TrainConfig(precision="bf16", remat="none")
    shape = shape or get_shape("prefill_32k")
    rt = make_runtime(cfg, mesh, shape, tc)

    p_struct, p_shard = _params_struct_and_shard(cfg, mesh, zero3_params)
    batch_struct = _batch_struct(cfg, shape)
    batch_struct.pop("labels")
    b_shard = _ns(mesh, S.batch_specs(batch_struct, mesh, shape.global_batch))

    def fn(params, batch):
        logits, state = prefill(cfg, params, batch, rt)
        return logits

    jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
    return jitted, (
        _struct_with(p_shard, p_struct),
        _struct_with(b_shard, batch_struct),
    )


def main() -> None:
    """Real trainer entry point on whatever devices exist.

        PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
            --reduced --steps 20 --batch 16 --seq 128 --zero 2

    2D (default): pjit step via build_train on a (data x model) mesh.
    3D pipelined: ``--pipe P --microbatches M --schedule {gpipe,1f1b}``
    executes the plan through build_train_pipeline on a
    (data, model, pipe) mesh; ``--plan auto`` instead runs
    ``core.partitioner.dp_pp_search`` over the real device count (at the
    given ``--tp``) and executes the winning (dp, pp) split. Multi-host
    wiring: set jax.distributed + per-host DataPipeline shard (repro.data).
    ``--reduced`` instantiates the smoke-size family variant so the driver
    runs on CPU containers.
    """
    import argparse

    import numpy as np

    from repro.configs import ASSIGNED, get_reduced
    import repro.configs.registry as registry
    from repro.core.partitioner import ParallelPlan, auto_plan
    from repro.data import DataPipeline
    from repro.launch.mesh import make_train_mesh
    from repro.optim import get as get_opt
    from repro.train import make_state

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ASSIGNED)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--zero", type=int, default=1)
    ap.add_argument("--precision", default="f32")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--fused-backward", action="store_true",
                    help="fused Pallas backwards + chunked-CE head")
    ap.add_argument("--tp", type=int, default=0,
                    help="model-axis size (0 = auto: largest of 4/2/1 that "
                         "divides the devices — and, in pipeline mode, that "
                         "the arch supports under manual TP)")
    ap.add_argument("--pipe", type=int, default=1,
                    help="pipeline stages; > 1 selects the 3D trainer")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="microbatches per step (default 2*pipe)")
    ap.add_argument("--schedule", default="1f1b", choices=("1f1b", "gpipe"))
    ap.add_argument("--plan", default="", choices=("", "auto"),
                    help="'auto': dp_pp_search picks (dp, pp) for the "
                         "device count")
    ap.add_argument("--stash", default="raw",
                    choices=("raw", "int8", "fp8", "host"),
                    help="pipeline activation-slot storage (core.stash): "
                         "int8/fp8 compress slots in-scan, host evicts "
                         "them to host RAM (single-device eager runner)")
    ap.add_argument("--act-budget-mb", type=float, default=0.0,
                    help="per-device activation-state budget in MiB; with "
                         "--plan auto the search walks the (stash, remat) "
                         "ladder: raw -> fp8 slot+cotangent compression, "
                         "then per-stage full remat")
    ap.add_argument("--fused-stash", action="store_true",
                    help="route the int8/fp8 stash codec through the fused "
                         "Pallas kernels where they compile (bitwise-"
                         "identical to the jnp path)")
    ap.add_argument("--stash-cot", action="store_true",
                    help="store pipeline cotangent slots through the stash "
                         "codec too (int8/fp8 only)")
    ap.add_argument("--stash-lookahead", type=int, default=2,
                    help="host-runner prefetch window in ticks (0 = eager; "
                         "stash=host only)")
    args = ap.parse_args()

    n = len(jax.devices())
    cfg = get_reduced(args.arch) if args.reduced else None
    assert cfg is not None, "--full training requires a TPU fleet"
    registry.ARCHITECTURES[cfg.name] = cfg
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    def tp_auto(budget: int) -> int:
        """Largest of 4/2/1 that divides the budget AND that the arch can
        actually run under manual pipeline TP (head divisibility etc.)."""
        from repro.models.stack import pipeline_incompatibility

        for cand in (4, 2, 1):
            if budget % cand == 0 and pipeline_incompatibility(cfg, cand) is None:
                return cand
        return 1

    from repro.core.precision import PrecisionPolicy

    itemsize = jnp.dtype(
        getattr(PrecisionPolicy, args.precision)().compute_dtype
    ).itemsize
    act_budget = int(args.act_budget_mb * 2**20) or None

    plan = None
    if args.stash == "host" and args.pipe <= 1 and args.plan != "auto":
        raise SystemExit("--stash host needs the pipeline trainer (--pipe P)")
    if args.plan == "auto":
        if args.pipe > 1:
            raise SystemExit(
                "--plan auto searches (dp, pp) itself; drop --pipe (or set "
                "--pipe without --plan to fix the degrees by hand)"
            )
        tp = args.tp or tp_auto(n)
        # microbatch count is a free knob: if the requested (or default)
        # count leaves no feasible (dp, pp) under the batch cap
        # dp <= batch/M — or a plan the batch can't divide into — halve it
        mb = args.microbatches or 8
        while plan is None:
            try:
                plan = auto_plan(
                    cfg, n, microbatches=mb, tp=tp,
                    schedule=args.schedule, remat=args.remat,
                    max_dp=max(args.batch // mb, 1),
                    stash=args.stash, act_budget=act_budget,
                    global_batch=args.batch, seq_len=args.seq,
                    itemsize=itemsize,
                )
            except AssertionError:
                plan = None
            except ValueError:
                if act_budget is None:   # budget misses retry at smaller M
                    raise
                plan = None
            if plan is not None and args.batch % (mb * plan.dp):
                plan = None
            if plan is None:
                if mb == 1:
                    raise SystemExit(
                        f"no feasible plan for {n} devices at batch "
                        f"{args.batch} (try a larger --batch)"
                    )
                mb //= 2
    elif args.pipe > 1:
        host = args.stash == "host"
        tp = 1 if host else (args.tp or tp_auto(n // args.pipe))
        if not host and n % (tp * args.pipe):
            raise SystemExit(
                f"{n} devices don't factor into tp={tp} x pipe={args.pipe}"
            )
        plan = ParallelPlan(
            dp=1 if host else n // (tp * args.pipe), tp=tp, pp=args.pipe,
            microbatches=args.microbatches or 2 * args.pipe,
            schedule=args.schedule, remat=args.remat, stash=args.stash,
            stash_cot=args.stash_cot,
        ).validate(cfg, global_batch=args.batch, seq_len=args.seq,
                   act_budget=act_budget, itemsize=itemsize)

    tc = TrainConfig(precision=args.precision,
                     remat=plan.remat if plan else args.remat,
                     zero_stage=args.zero,
                     fused_backward=args.fused_backward,
                     pipe=plan.pp if plan else 1,
                     schedule=args.schedule,
                     microbatches=plan.microbatches if plan else 1,
                     stash=plan.stash if plan else "raw",
                     fused_stash=args.fused_stash,
                     stash_cot=plan.stash_cot if plan else False)

    stash_backend = None
    if plan is not None:
        if args.batch % (plan.microbatches * plan.dp):
            raise SystemExit(
                f"--batch {args.batch} must divide into "
                f"microbatches*dp = {plan.microbatches}x{plan.dp}"
            )
        if plan.stash == "host":
            print(f"devices={n} host-driven runner (1 device) "
                  f"plan: {plan.describe()}")
            jitted, (s_struct, b_struct), stash_backend = (
                build_train_pipeline_host(
                    cfg.name, plan, tc, shape,
                    lookahead=args.stash_lookahead,
                )
            )
        else:
            mesh = make_train_mesh(plan.dp, plan.tp, plan.pp)
            print(f"devices={n} mesh=({plan.dp} data x {plan.tp} model x "
                  f"{plan.pp} pipe) plan: {plan.describe()}")
            jitted, (s_struct, b_struct) = build_train_pipeline(
                cfg.name, mesh, plan, tc, shape
            )
    else:
        model_ax = args.tp or 1
        if not args.tp:
            for cand in (4, 2, 1):
                if n % cand == 0 and cand <= n:
                    model_ax = cand
                    break
        mesh = jax.make_mesh((n // model_ax, model_ax), ("data", "model"))
        print(f"devices={n} mesh=({n//model_ax} data x {model_ax} model)")
        jitted, (s_struct, b_struct) = build_train(cfg.name, mesh, tc, shape)

    state = make_state(cfg, get_opt(tc.optimizer, tc.lr), tc)
    state = jax.tree.map(
        lambda x, st: jax.device_put(x, st.sharding), state, s_struct
    )
    data = DataPipeline(cfg, args.batch, args.seq, seed=0)
    try:
        import time

        t0 = time.time()
        for i in range(args.steps):
            raw = next(data)
            batch = jax.tree.map(
                lambda v, st: jax.device_put(jnp.asarray(v), st.sharding),
                dict(raw), b_struct,
            )
            state, metrics = jitted(state, batch)
            if (i + 1) % 5 == 0 or i == 0:
                print(f"step {i+1:4d} loss={float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/it)")
    finally:
        data.close()
    if plan is not None:
        rep = plan.stash_report(
            cfg, global_batch=args.batch, seq_len=args.seq, itemsize=itemsize
        )
        print(f"stash={rep['backend']} bytes/slot={rep['bytes_per_slot']} "
              f"(raw {rep['raw_bytes_per_slot']}) "
              f"act high-water={rep['n_act_slots']} slots "
              f"device bytes={rep['device_bytes']} "
              f"host bytes={rep['host_bytes']} "
              f"transient bytes={rep['transient_bytes']} (remat={rep['remat']}) "
              f"capacity={rep['capacity_factor']:.2f}x raw")
        if stash_backend is not None:
            stats = stash_backend.stats()
            host_hits = max(stats.get("host_hits", 0), 1)
            print(f"host stash stats: {stats}")
            print(f"host overlap: stall fraction="
                  f"{stats.get('stalled_gets', 0) / host_hits:.2f} "
                  f"prefetch hit rate="
                  f"{stats.get('prefetch_hits', 0) / host_hits:.2f} "
                  f"(of {stats.get('host_hits', 0)} off-window gets)")
    print("train main OK")


def build_decode(
    arch: str, mesh, shape: Optional[ShapeSpec] = None,
    tc: Optional[TrainConfig] = None, zero3_params: bool = False,
) -> Tuple[Callable, Tuple[Any, Any, Any]]:
    """serve_step: ONE new token against a seq_len-deep cache."""
    cfg = get_config(arch)
    tc = tc or TrainConfig(precision="bf16", remat="none")
    shape = shape or get_shape("decode_32k")
    rt = make_runtime(cfg, mesh, shape, tc)
    B = shape.global_batch

    p_struct, p_shard = _params_struct_and_shard(cfg, mesh, zero3_params)
    cache_struct = jax.eval_shape(
        lambda p: init_decode_state(cfg, p, B, shape.seq_len, rt), p_struct
    )
    c_shard = _ns(
        mesh, S.cache_specs(cfg, cache_struct, mesh, shape.global_batch)
    )
    tok_struct = jax.ShapeDtypeStruct((B,), jnp.int32)
    ba = S.batch_axes(mesh, B)
    t_shard = NamedSharding(mesh, P(tuple(ba) if ba else None))

    def fn(params, state, token):
        logits, new_state = decode_step(cfg, params, state, token, rt, shape.seq_len)
        return logits, new_state

    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, c_shard, t_shard),
        donate_argnums=(1,),
    )
    return jitted, (
        _struct_with(p_shard, p_struct),
        _struct_with(c_shard, cache_struct),
        jax.ShapeDtypeStruct(tok_struct.shape, tok_struct.dtype, sharding=t_shard),
    )
if __name__ == "__main__":
    main()
