"""Pallas TPU flash attention (fwd), GQA + causal + sliding window.

TPU-native tiling (canonical 3D sequential grid, as in the upstream pallas
TPU flash kernel): grid = (batch*heads, n_q_blocks, n_k_blocks) with the
innermost k-block axis executed sequentially per core, carrying the online-
softmax state (row max m, row sum l, accumulator acc) in VMEM scratch.

  q tile  (block_q, hd)  VMEM      k/v tiles (block_k, hd)  VMEM
  scores = q @ k^T on the MXU in f32; masking via explicit mask multiply
  (never exp(-inf + inf) NaNs on fully-masked tiles — sliding windows make
  those reachable).

GQA: the grid's head axis enumerates query heads; the k/v index_map divides
by the group size so each kv head's tiles are shared by its G query heads.

Sequence lengths need not be block multiples: inputs are zero-padded up to
the tile grid and real extents are masked via the static ``q_len``/``kv_len``
kernel parameters.

Backward: fully kernel-fused (``kernel_bwd.py``) — the forward additionally
emits the per-row logsumexp ``lse = m + log(l)`` so the backward can
recompute tile probabilities ``p = exp(s - lse)`` on the MXU from saved
stats instead of replaying the softmax reduction. ``repro.kernels
.flash_attention.ops`` wires both directions into one ``custom_vjp``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def pick_blocks(S: int, Sk: int, block_q: int, block_k: int) -> Tuple[int, int]:
    """Clamp block sizes to the (8-aligned) padded sequence extents."""
    return min(block_q, _round_up(S, 8)), min(block_k, _round_up(Sk, 8))


def pad_seq(x: jax.Array, block: int) -> jax.Array:
    """Zero-pad the sequence axis (axis 1) of (BH, S, hd) to a block multiple."""
    pad = _round_up(x.shape[1], block) - x.shape[1]
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


def make_mask(
    qpos: jax.Array, kpos: jax.Array, *, causal: bool, window: int, kv_len: int
) -> jax.Array:
    """Shared validity mask: kv padding + causal + sliding window."""
    mask = kpos < kv_len
    if causal:
        mask = jnp.logical_and(mask, qpos >= kpos)
    if window > 0:
        mask = jnp.logical_and(mask, qpos - kpos < window)
    return mask


def _fa_fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
    *, causal: bool, window: int, block_q: int, block_k: int, n_k: int,
    kv_len: int,
):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (bq, hd)
    k = k_ref[0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (bq, bk)

    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = make_mask(qpos, kpos, causal=causal, window=window, kv_len=kv_len)

    scores = jnp.where(mask, scores, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
    # explicit mask multiply: exp() of fully-masked tiles contributes 0
    p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_new = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(denom)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "group"),
)
def flash_attention_fwd_flat(
    q: jax.Array,   # (BH, S, hd) query heads, pre-scaled
    k: jax.Array,   # (BKv, Sk, hd)
    v: jax.Array,
    *,
    group: int,     # BH // BKv
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret=None,
) -> Tuple[jax.Array, jax.Array]:
    """Forward with saved stats. Returns (o (BH, S, hd), lse (BH, S) f32)."""
    interpret = resolve_interpret(interpret)
    BH, S, hd = q.shape
    Sk = k.shape[1]
    block_q, block_k = pick_blocks(S, Sk, block_q, block_k)
    q = pad_seq(q, block_q)
    k = pad_seq(k, block_k)
    v = pad_seq(v, block_k)
    Sp, Skp = q.shape[1], k.shape[1]
    n_q, n_k = Sp // block_q, Skp // block_k

    kernel = functools.partial(
        _fa_fwd_kernel, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k, kv_len=Sk,
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, Sp), jnp.float32),
        ],
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o[:, :S], lse[:, :S]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "group"),
)
def flash_attention_flat(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    group: int,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret=None,
) -> jax.Array:
    """Output-only forward (compat wrapper over ``flash_attention_fwd_flat``)."""
    o, _ = flash_attention_fwd_flat(
        q, k, v, group=group, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return o


def _vmem(shape: Tuple[int, ...], dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
