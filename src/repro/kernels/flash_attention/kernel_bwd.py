"""Pallas TPU flash attention backward: two-pass dq / dk+dv from saved stats.

Standard scheme (FlashAttention §3.2, adapted to the TPU sequential grid):

  pass 0 (preprocess)  delta_i = sum_h dO_ih * O_ih            (BH, S)
  pass 1 (dq)          grid (BH, n_q, n_k), k sequential:
                         p  = exp(q k^T - lse)   (recomputed on the MXU)
                         ds = p * (dO v^T - delta)
                         dq += ds @ k            (VMEM accumulator)
  pass 2 (dk/dv)       grid (BKv, n_k, G * n_q), inner axis sequential over
                       (query head in group, q block):
                         dv += p^T @ dO
                         dk += ds^T @ q

The dk/dv grid walks kv heads, so the GQA group accumulation (G query heads
sharing one kv head) happens in the VMEM scratch accumulator — kv grads are
written once per k block, never materialized per query head.

Scores are recomputed from q/k and the saved forward stats ``lse = m +
log(l)``; nothing quadratic in sequence length is ever read from or written
to HBM. Masking (causal / sliding window / kv padding) matches the forward:
probabilities use an explicit mask-where so fully-masked rows (reachable via
sliding windows and block padding) contribute exact zeros, never NaNs.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention.kernel import (
    _vmem,
    make_mask,
    pad_seq,
    pick_blocks,
)
from repro.kernels.runtime import resolve_interpret


def _fa_bwd_delta_kernel(o_ref, do_ref, delta_ref):
    o = o_ref[0].astype(jnp.float32)              # (bq, hd)
    do = do_ref[0].astype(jnp.float32)
    delta_ref[0] = jnp.sum(o * do, axis=-1)


def _fa_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_scr,
    *, causal: bool, window: int, block_q: int, block_k: int, n_k: int,
    kv_len: int,
):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)              # (bq, hd)
    k = k_ref[0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)            # (bq, hd)
    lse = lse_ref[0]                              # (bq,)
    delta = delta_ref[0]                          # (bq,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (bq, bk)
    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = make_mask(qpos, kpos, causal=causal, window=window, kv_len=kv_len)

    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (bq, bk)
    ds = p * (dp - delta[:, None])
    dq_scr[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(j == n_k - 1)
    def _finish():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(
    q_ref, do_ref, lse_ref, delta_ref, k_ref, v_ref, dk_ref, dv_ref,
    dk_scr, dv_scr,
    *, causal: bool, window: int, block_q: int, block_k: int, n_q: int,
    n_inner: int, kv_len: int,
):
    jk = pl.program_id(1)
    t = pl.program_id(2)              # enumerates (group member g, q block qi)
    qi = t % n_q

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0].astype(jnp.float32)              # (bq, hd)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]                              # (bq,)
    delta = delta_ref[0]
    k = k_ref[0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (bq, bk)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = make_mask(qpos, kpos, causal=causal, window=window, kv_len=kv_len)

    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    # dv += p^T dO
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta[:, None])
    # dk += ds^T q
    dk_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(t == n_inner - 1)
    def _finish():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "group"),
)
def flash_attention_bwd_flat(
    q: jax.Array,    # (BH, S, hd) pre-scaled, as in the forward
    k: jax.Array,    # (BKv, Sk, hd)
    v: jax.Array,
    o: jax.Array,    # (BH, S, hd) forward output
    lse: jax.Array,  # (BH, S) f32 forward stats
    do: jax.Array,   # (BH, S, hd) upstream cotangent
    *,
    group: int,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (dq (BH, S, hd), dk (BKv, Sk, hd), dv (BKv, Sk, hd))."""
    interpret = resolve_interpret(interpret)
    BH, S, hd = q.shape
    BKv, Sk = k.shape[0], k.shape[1]
    assert BH == BKv * group, (BH, BKv, group)
    block_q, block_k = pick_blocks(S, Sk, block_q, block_k)

    q = pad_seq(q, block_q)
    o = pad_seq(o, block_q)
    do = pad_seq(do, block_q)
    lse_p = jnp.pad(lse, ((0, 0), (0, q.shape[1] - S)))
    k = pad_seq(k, block_k)
    v = pad_seq(v, block_k)
    Sp, Skp = q.shape[1], k.shape[1]
    n_q, n_k = Sp // block_q, Skp // block_k

    # pass 0: per-row delta = sum(dO * O)
    delta = pl.pallas_call(
        _fa_bwd_delta_kernel,
        grid=(BH, n_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, block_q, hd), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda h, i: (h, i)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp), jnp.float32),
        interpret=interpret,
    )(o, do)

    # pass 1: dq over the forward's (BH, n_q, n_k) grid
    dq = pl.pallas_call(
        functools.partial(
            _fa_bwd_dq_kernel, causal=causal, window=window,
            block_q=block_q, block_k=block_k, n_k=n_k, kv_len=Sk,
        ),
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, block_q), lambda h, i, j: (h, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
        scratch_shapes=[_vmem((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse_p, delta)

    # pass 2: dk/dv per kv head; the inner axis walks the G query heads of
    # the group times the q blocks, accumulating into one VMEM tile
    n_inner = group * n_q

    def _qh(h, t, g=group, nq=n_q):
        return h * g + t // nq

    dk, dv = pl.pallas_call(
        functools.partial(
            _fa_bwd_dkv_kernel, causal=causal, window=window,
            block_q=block_q, block_k=block_k, n_q=n_q, n_inner=n_inner,
            kv_len=Sk,
        ),
        grid=(BKv, n_k, n_inner),
        in_specs=[
            pl.BlockSpec(
                (1, block_q, hd),
                lambda h, jk, t, nq=n_q: (_qh(h, t), t % nq, 0),
            ),
            pl.BlockSpec(
                (1, block_q, hd),
                lambda h, jk, t, nq=n_q: (_qh(h, t), t % nq, 0),
            ),
            pl.BlockSpec(
                (1, block_q), lambda h, jk, t, nq=n_q: (_qh(h, t), t % nq)
            ),
            pl.BlockSpec(
                (1, block_q), lambda h, jk, t, nq=n_q: (_qh(h, t), t % nq)
            ),
            pl.BlockSpec((1, block_k, hd), lambda h, jk, t: (h, jk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, jk, t: (h, jk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, hd), lambda h, jk, t: (h, jk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, jk, t: (h, jk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BKv, Skp, hd), k.dtype),
            jax.ShapeDtypeStruct((BKv, Skp, hd), v.dtype),
        ],
        scratch_shapes=[
            _vmem((block_k, hd), jnp.float32),
            _vmem((block_k, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, do, lse_p, delta, k, v)

    return dq[:, :S], dk[:, :Sk], dv[:, :Sk]
