"""Pure-jnp oracle for the flash-attention kernel.

q: (B, S, Kv, G, hd) pre-scaled by hd^-0.5 (matches repro.models.attention)
k/v: (B, S, Kv, hd). Supports causal masking and sliding windows.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, S, Kv, G, hd = q.shape
    scores = jnp.einsum(
        "bskgh,btkh->bkgst", q, k, preferred_element_type=jnp.float32
    )
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((S, k.shape[1]), bool)
    if causal:
        mask = qpos >= kpos
    if window > 0:
        mask = mask & (qpos - kpos < window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out
