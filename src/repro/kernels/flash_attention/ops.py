"""Public flash-attention op in the model's (B, S, Kv, G, hd) layout.

Forward runs the Pallas kernel; backward (custom_vjp) recomputes with the
pure-JAX reference — flash memory profile, oracle-exact gradients.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_flat
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, S, Kv, G, hd) pre-scaled; k/v: (B, Sk, Kv, hd) -> (B, S, Kv, G, hd)."""
    B, S, Kv, G, hd = q.shape
    Sk = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * Kv * G, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, Sk, hd)
    of = flash_attention_flat(
        qf, kf, vf, group=G, causal=causal, window=window, interpret=interpret
    )
    return of.reshape(B, Kv, G, S, hd).transpose(0, 3, 1, 2, 4)


def _fwd(q, k, v, causal, window, interpret):
    return flash_attention(q, k, v, causal, window, interpret), (q, k, v)


def _bwd(causal, window, interpret, res, dout):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(
            q_, k_, v_, causal=causal, window=window
        ),
        q, k, v,
    )
    return vjp(dout)


flash_attention.defvjp(_fwd, _bwd)
