"""Public flash-attention op in the model's (B, S, Kv, G, hd) layout.

Kernel-fused in both directions: the forward Pallas kernel saves per-row
softmax stats (``lse``) alongside the output; the backward (custom_vjp) runs
the two-pass Pallas dq / dk+dv kernels (``kernel_bwd``) which recompute tile
scores from the saved stats — flash memory profile without replaying the
pure-JAX reference. The reference (``ref.py``) remains the correctness
oracle for both directions.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd_flat
from repro.kernels.flash_attention.kernel_bwd import flash_attention_bwd_flat


def _flatten_q(q: jax.Array) -> jax.Array:
    B, S, Kv, G, hd = q.shape
    return q.transpose(0, 2, 3, 1, 4).reshape(B * Kv * G, S, hd)


def _unflatten_q(qf: jax.Array, B: int, Kv: int, G: int) -> jax.Array:
    BH, S, hd = qf.shape
    return qf.reshape(B, Kv, G, S, hd).transpose(0, 3, 1, 2, 4)


def _flatten_kv(k: jax.Array) -> jax.Array:
    B, Sk, Kv, hd = k.shape
    return k.transpose(0, 2, 1, 3).reshape(B * Kv, Sk, hd)


def _unflatten_kv(kf: jax.Array, B: int, Kv: int) -> jax.Array:
    BKv, Sk, hd = kf.shape
    return kf.reshape(B, Kv, Sk, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    interpret=None,
) -> jax.Array:
    """q: (B, S, Kv, G, hd) pre-scaled; k/v: (B, Sk, Kv, hd) -> (B, S, Kv, G, hd)."""
    out, _ = _fwd(q, k, v, causal, window, interpret)
    return out


def _fwd(q, k, v, causal, window, interpret):
    B, S, Kv, G, hd = q.shape
    qf = _flatten_q(q)
    kf = _flatten_kv(k)
    vf = _flatten_kv(v)
    of, lse = flash_attention_fwd_flat(
        qf, kf, vf, group=G, causal=causal, window=window, interpret=interpret
    )
    return _unflatten_q(of, B, Kv, G), (qf, kf, vf, of, lse)


def _bwd(causal, window, interpret, res, dout):
    qf, kf, vf, of, lse = res
    B, S, Kv, G, hd = dout.shape
    dof = _flatten_q(dout)
    dqf, dkf, dvf = flash_attention_bwd_flat(
        qf, kf, vf, of, lse, dof,
        group=G, causal=causal, window=window, interpret=interpret,
    )
    return (
        _unflatten_q(dqf, B, Kv, G),
        _unflatten_kv(dkf, B, Kv),
        _unflatten_kv(dvf, B, Kv),
    )


flash_attention.defvjp(_fwd, _bwd)
