"""Fused RMSNorm op: Pallas forward, oracle-recompute backward."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    return rmsnorm_pallas(x, scale, eps=eps)


def _fwd(x, scale, eps):
    return rmsnorm(x, scale, eps), (x, scale)


def _bwd(eps, res, dout):
    x, scale = res
    _, vjp = jax.vjp(lambda x_, s_: rmsnorm_ref(x_, s_, eps), x, scale)
    return vjp(dout)


rmsnorm.defvjp(_fwd, _bwd)
