"""Fused RMSNorm op: Pallas forward AND fused dx/dscale Pallas backward."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_bwd_pallas, rmsnorm_pallas


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    return rmsnorm_pallas(x, scale, eps=eps)


def _fwd(x, scale, eps):
    return rmsnorm(x, scale, eps), (x, scale)


def _bwd(eps, res, dout):
    x, scale = res
    dx, dscale = rmsnorm_bwd_pallas(x, scale, dout, eps=eps)
    return dx, dscale.astype(scale.dtype)


rmsnorm.defvjp(_fwd, _bwd)
