"""Pallas TPU fused RMSNorm.

One pass per row tile: mean-of-squares reduce, rsqrt, scale — fused so the
row is read from HBM once (XLA emits separate reduce + multiply kernels when
the norm is unfused at the boundary of a remat block). Rows tile over the
grid; the feature dim stays whole in VMEM (d_model <= 8192 -> <= 32 KiB f32
per row, well inside VMEM at TILE_ROWS=256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (rows, d)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    o_ref[...] = (x * inv * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_pallas(
    x: jax.Array, scale: jax.Array, eps: float = 1e-6, interpret: bool = True
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = x.size // d
    xr = x.reshape(rows, d)
    tile = min(TILE_ROWS, rows)
    # pad rows to a tile multiple
    pad = (-rows) % tile
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((rows + pad), d), x.dtype),
        interpret=interpret,
    )(xr, scale[None, :])
    return out[:rows].reshape(orig_shape)
