"""Pallas TPU fused RMSNorm, forward and backward.

Forward — one pass per row tile: mean-of-squares reduce, rsqrt, scale —
fused so the row is read from HBM once (XLA emits separate reduce + multiply
kernels when the norm is unfused at the boundary of a remat block). Rows tile
over the grid; the feature dim stays whole in VMEM (d_model <= 8192 ->
<= 32 KiB f32 per row, well inside VMEM at TILE_ROWS=256).

Backward — fused dx/dscale in the same row tiling. With xhat = x * inv and
gs = g * scale:

  dx     = inv * (gs - xhat * mean(gs * xhat))     per row
  dscale = sum_rows g * xhat                       cross-row reduce

The dscale reduce accumulates into a single (1, d) output block revisited by
every sequential grid step (init at step 0), so x and g are read from HBM
once for BOTH cotangents — the unfused backward reads x twice (once per
cotangent) and re-derives inv both times.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

TILE_ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (rows, d)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    o_ref[...] = (x * inv * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rmsnorm_bwd_kernel(x_ref, s_ref, g_ref, dx_ref, ds_ref, *, eps: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        ds_ref[...] = jnp.zeros_like(ds_ref)

    x = x_ref[...].astype(jnp.float32)                 # (rows, d)
    g = g_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)                 # (1, d)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    xhat = x * inv
    gs = g * s
    rowmean = jnp.mean(gs * xhat, axis=-1, keepdims=True)
    dx_ref[...] = (inv * (gs - xhat * rowmean)).astype(dx_ref.dtype)
    ds_ref[...] += jnp.sum(g * xhat, axis=0, keepdims=True)


def _tile(rows: int) -> Tuple[int, int]:
    tile = min(TILE_ROWS, rows)
    pad = (-rows) % tile
    return tile, pad


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_pallas(
    x: jax.Array, scale: jax.Array, eps: float = 1e-6, interpret=None
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = x.size // d
    xr = x.reshape(rows, d)
    tile, pad = _tile(rows)
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((rows + pad), d), x.dtype),
        interpret=interpret,
    )(xr, scale[None, :])
    return out[:rows].reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_bwd_pallas(
    x: jax.Array, scale: jax.Array, g: jax.Array, eps: float = 1e-6,
    interpret=None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused backward. Returns (dx with x's shape/dtype, dscale (d,) f32)."""
    interpret = resolve_interpret(interpret)
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = x.size // d
    xr = x.reshape(rows, d)
    gr = g.reshape(rows, d)
    tile, pad = _tile(rows)
    if pad:
        # zero rows contribute exact zeros to both dx (sliced off) and dscale
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        gr = jnp.pad(gr, ((0, pad), (0, 0)))
    dx, dscale = pl.pallas_call(
        functools.partial(_rmsnorm_bwd_kernel, eps=eps),
        grid=((rows + pad) // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(((rows + pad), d), x.dtype),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(xr, scale[None, :], gr)
    return dx[:rows].reshape(orig_shape), dscale[0]
