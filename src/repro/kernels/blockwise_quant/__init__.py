from repro.kernels.blockwise_quant.ops import (  # noqa: F401
    BLOCK,
    dequantize,
    quantize,
)
