"""Pure-jnp oracle for blockwise dynamic quantization (Dettmers et al. 2021).

The dynamic 8-bit data type: 1 sign bit, a variable-length exponent prefix
(leading zero bits), and the rest linear mantissa — giving high relative
precision for small magnitudes and coverage up to 1.0. We reproduce the
bitsandbytes construction: for each number of exponent bits e in [0, 6],
fractions with (7 - e) mantissa bits scaled by 10^-e ... implemented below in
its standard "create_dynamic_map" form.

Quantization is blockwise: per block of ``block`` values, scale = absmax,
then nearest code in the map. State = (codes uint8, scales f32).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


@functools.lru_cache(maxsize=None)
def dynamic_map(signed: bool = True, total_bits: int = 8) -> np.ndarray:
    """The 2^total_bits sorted code values in [-1, 1].

    Dynamic-exponent construction (Dettmers'21): 7 exponent levels, level i
    holding 2^i linear fractions of the decade 10^(i-6) — dense relative
    precision near zero, coverage to 1.0. Exactly 127 positive codes
    (+ mirrored negatives + {0, 1}) = 256.
    """
    assert signed and total_bits == 8, "only the signed 8-bit map is used"
    pos = []
    for i in range(7):
        boundaries = np.linspace(0.1, 1.0, 2**i + 1)
        means = (boundaries[:-1] + boundaries[1:]) / 2.0
        pos += (10.0 ** (i - 6) * means).tolist()
    assert len(pos) == 127
    data = pos + [-v for v in pos] + [0.0, 1.0]
    data.sort()
    out = np.asarray(data, dtype=np.float32)
    assert out.shape == (256,), out.shape
    return out


def _codes() -> jnp.ndarray:
    return jnp.asarray(dynamic_map())


def quantize_ref(x: jax.Array, block: int = BLOCK) -> Tuple[jax.Array, jax.Array]:
    """x: flat f32 (n,), n % block == 0 -> (codes uint8 (n,), scales f32 (n/block,))."""
    codes = _codes()
    xb = x.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    safe = jnp.where(scale > 0, scale, 1.0)
    normed = xb / safe
    mid = (codes[1:] + codes[:-1]) / 2.0
    idx = jnp.searchsorted(mid, normed, side="right").astype(jnp.uint8)
    return idx.reshape(-1), scale[:, 0]


def dequantize_ref(
    idx: jax.Array, scale: jax.Array, block: int = BLOCK
) -> jax.Array:
    codes = _codes()
    vals = jnp.take(codes, idx.astype(jnp.int32)).reshape(-1, block)
    return (vals * scale[:, None]).reshape(-1)
