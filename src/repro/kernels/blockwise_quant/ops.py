"""Public blockwise-quant ops: pad-to-block, kernel/ref routing.

Interpret-vs-compiled execution is resolved centrally by
``repro.kernels.runtime`` (interpret off TPU, ``REPRO_PALLAS_INTERPRET``
override); pass ``interpret`` explicitly only to force a mode.
``backend="ref"`` uses the pure-jnp oracle (fastest under jit on CPU — the
interpret-mode kernel is for validation, not speed).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.blockwise_quant import ref as _ref
from repro.kernels.blockwise_quant.kernel import (
    TILE_ROWS,
    dequantize_pallas,
    quantize_pallas,
)

BLOCK = _ref.BLOCK


def _pad(n: int, block: int) -> int:
    unit = block * TILE_ROWS
    return (n + unit - 1) // unit * unit


def quantize(
    x: jax.Array, block: int = BLOCK, backend: str = "ref", interpret=None
) -> Tuple[jax.Array, jax.Array, int]:
    """Flattens, zero-pads to a tile multiple, quantizes.

    Returns (codes uint8, scales f32, original_size).
    """
    n = x.size
    flat = x.reshape(-1).astype(jnp.float32)
    padded = _pad(n, block)
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    if backend == "pallas":
        codes, scales = quantize_pallas(flat, block=block, interpret=interpret)
    else:
        codes, scales = _ref.quantize_ref(flat, block=block)
    return codes, scales, n


def dequantize(
    codes: jax.Array,
    scales: jax.Array,
    n: int,
    shape,
    block: int = BLOCK,
    backend: str = "ref",
    interpret=None,
) -> jax.Array:
    if backend == "pallas":
        flat = dequantize_pallas(codes, scales, block=block, interpret=interpret)
    else:
        flat = _ref.dequantize_ref(codes, scales, block=block)
    return flat[:n].reshape(shape)
