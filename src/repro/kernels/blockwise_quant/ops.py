"""Public blockwise-quant ops: pad-to-block, kernel/ref routing.

Interpret-vs-compiled execution is resolved centrally by
``repro.kernels.runtime`` (interpret off TPU, ``REPRO_PALLAS_INTERPRET``
override); pass ``interpret`` explicitly only to force a mode.
``backend="ref"`` uses the pure-jnp oracle (fastest under jit on CPU — the
interpret-mode kernel is for validation, not speed).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.blockwise_quant import ref as _ref
from repro.kernels.blockwise_quant.kernel import (
    TILE_ROWS,
    dequantize_pallas,
    quantize_pallas,
    stash_dequantize_pallas,
    stash_quantize_pallas,
)

BLOCK = _ref.BLOCK


def _pad(n: int, block: int) -> int:
    unit = block * TILE_ROWS
    return (n + unit - 1) // unit * unit


def quantize(
    x: jax.Array, block: int = BLOCK, backend: str = "ref", interpret=None
) -> Tuple[jax.Array, jax.Array, int]:
    """Flattens, zero-pads to a tile multiple, quantizes.

    Returns (codes uint8, scales f32, original_size).
    """
    n = x.size
    flat = x.reshape(-1).astype(jnp.float32)
    padded = _pad(n, block)
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    if backend == "pallas":
        codes, scales = quantize_pallas(flat, block=block, interpret=interpret)
    else:
        codes, scales = _ref.quantize_ref(flat, block=block)
    return codes, scales, n


def dequantize(
    codes: jax.Array,
    scales: jax.Array,
    n: int,
    shape,
    block: int = BLOCK,
    backend: str = "ref",
    interpret=None,
) -> jax.Array:
    if backend == "pallas":
        flat = dequantize_pallas(codes, scales, block=block, interpret=interpret)
    else:
        flat = _ref.dequantize_ref(codes, scales, block=block)
    return flat[:n].reshape(shape)


# ------------------------------------------------------ activation stash
# Blockwise SYMMETRIC-LINEAR quantization for pipeline activation stashes
# (core.stash.QuantStash). Deliberately NOT the Dettmers dynamic-map codec
# above: the stash needs the per-block |err| <= scale/2 bound of the paged
# KV pool (int8, scale = absmax/127) so the grad-accuracy argument carries
# over — so it reuses kernels.paged_attention.quant row quantization with
# the "row" axis reinterpreted as a flat block of ``block`` elements.
STASH_BLOCK = BLOCK


def stash_padded_size(n: int, block: int = STASH_BLOCK) -> int:
    """Flat element count after zero-padding to a block multiple."""
    return (n + block - 1) // block * block


def _stash_storage_dtype(storage: str):
    from repro.kernels.paged_attention.quant import _QUANT

    if storage not in _QUANT:
        raise ValueError(f"stash storage {storage!r} not in {tuple(_QUANT)}")
    return _QUANT[storage][0]


def fused_codec_backend() -> str:
    """Codec backend the ``fused_stash`` knob resolves to: the Pallas
    kernels where they run compiled (TPU), the jnp path where they would
    only interpret (the CPU containers) — interpret-mode Pallas is a
    validation tool, not an execution path, and XLA already fuses the jnp
    codec into the slot update on CPU (same convention as
    ``Runtime.use_paged_kernel``). Codes/scales are bitwise identical
    either way, so the choice never changes training numerics."""
    from repro.kernels.runtime import default_interpret

    return "ref" if default_interpret() else "pallas"


def stash_quantize(
    x: jax.Array,
    storage: str = "int8",
    block: int = STASH_BLOCK,
    backend: str = "ref",
    interpret=None,
) -> Tuple[jax.Array, jax.Array]:
    """One stash leaf -> (codes (nblocks, block) int8/fp8, scales (nblocks,) f32).

    Flattens, zero-pads to a block multiple (pad blocks quantize to exact
    zeros — absmax 0 gives scale 0), and applies the paged-KV symmetric
    row quantizer per block: int8 scale = absmax/127 (|err| <= scale/2),
    fp8-e4m3 scale = absmax/448. ``backend="pallas"`` runs the fused
    kernel (bitwise-identical codes/scales to the jnp path, asserted in
    tests/test_kernels_quant.py).
    """
    from repro.kernels.paged_attention.quant import kv_quantize

    n = x.size
    flat = x.reshape(-1)
    padded = stash_padded_size(n, block)
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    xb = flat.reshape(-1, block)
    if backend == "pallas":
        return stash_quantize_pallas(
            xb, storage=storage, block=block, interpret=interpret
        )
    return kv_quantize(xb, _stash_storage_dtype(storage))


def stash_dequantize(
    codes: jax.Array,
    scales: jax.Array,
    shape,
    dtype,
    block: int = STASH_BLOCK,
    backend: str = "ref",
    interpret=None,
) -> jax.Array:
    """Inverse of :func:`stash_quantize`: (nblocks, block) codes + per-block
    scales -> the original ``shape``/``dtype`` leaf (pad tail dropped)."""
    from repro.kernels.paged_attention.quant import kv_dequantize

    n = 1
    for d in shape:
        n *= int(d)
    if backend == "pallas":
        flat = stash_dequantize_pallas(
            codes, scales, dtype=jnp.dtype(dtype), block=block,
            interpret=interpret,
        ).reshape(-1)
    else:
        flat = kv_dequantize(codes, scales, dtype).reshape(-1)
    return flat[:n].reshape(shape)
