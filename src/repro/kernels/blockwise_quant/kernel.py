"""Pallas TPU kernel: blockwise dynamic quantization / dequantization.

TPU adaptation of the CUDA kernel in bitsandbytes (Dettmers'21): the GPU
version binary-searches the code map per thread; on TPU we keep the whole
256-entry map resident in VMEM and use fully vectorized VPU compares:

  quantize tile:  absmax-reduce over the quant block axis, normalize, then
                  idx = #(midpoints <= value) via a (tile, 255) broadcast
                  compare-sum (no divergent control flow).
  dequantize:     code lookup as a (tile, 256) one-hot select-sum.

Tiling: values are reshaped (n_blocks, block); the grid walks TILE_ROWS
quant-blocks per program; block = 256 keeps the lane dimension MXU/VPU
aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.blockwise_quant.ref import BLOCK, dynamic_map
from repro.kernels.runtime import resolve_interpret

TILE_ROWS = 64


def _quant_kernel(x_ref, codes_ref, out_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                  # (TILE_ROWS, BLOCK)
    codes = codes_ref[...]                              # (1, 256)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # (TILE_ROWS, 1)
    safe = jnp.where(scale > 0, scale, 1.0)
    normed = x / safe
    mid = (codes[0, 1:] + codes[0, :-1]) * 0.5          # (255,)
    # idx = number of midpoints strictly below the value (searchsorted right)
    idx = jnp.sum(
        (normed[:, :, None] >= mid[None, None, :]).astype(jnp.int32), axis=-1
    )
    out_ref[...] = idx.astype(jnp.uint8)
    scale_ref[...] = scale


def _dequant_kernel(idx_ref, scale_ref, codes_ref, out_ref):
    idx = idx_ref[...].astype(jnp.int32)                # (TILE_ROWS, BLOCK)
    codes = codes_ref[...]                              # (1, 256)
    onehot = (idx[:, :, None] == jnp.arange(256)[None, None, :]).astype(
        jnp.float32
    )
    vals = jnp.sum(onehot * codes[0][None, None, :], axis=-1)
    out_ref[...] = vals * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_pallas(x: jax.Array, block: int = BLOCK, interpret=None):
    interpret = resolve_interpret(interpret)
    n = x.size
    assert n % block == 0, (n, block)
    rows = n // block
    assert rows % TILE_ROWS == 0, (rows, TILE_ROWS)
    xb = x.reshape(rows, block)
    codes = jnp.asarray(dynamic_map())[None, :]

    grid = (rows // TILE_ROWS,)
    out, scale = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 256), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, block), jnp.uint8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xb, codes)
    return out.reshape(-1), scale[:, 0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize_pallas(
    idx: jax.Array, scale: jax.Array, block: int = BLOCK, interpret=None
):
    interpret = resolve_interpret(interpret)
    rows = idx.size // block
    assert rows % TILE_ROWS == 0, (rows, TILE_ROWS)
    codes = jnp.asarray(dynamic_map())[None, :]
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rows // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 256), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), jnp.float32),
        interpret=interpret,
    )(idx.reshape(rows, block), scale[:, None], codes)
    return out.reshape(-1)
