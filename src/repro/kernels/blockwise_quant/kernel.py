"""Pallas TPU kernel: blockwise dynamic quantization / dequantization.

TPU adaptation of the CUDA kernel in bitsandbytes (Dettmers'21): the GPU
version binary-searches the code map per thread; on TPU we keep the whole
256-entry map resident in VMEM and use fully vectorized VPU compares:

  quantize tile:  absmax-reduce over the quant block axis, normalize, then
                  idx = #(midpoints <= value) via a (tile, 255) broadcast
                  compare-sum (no divergent control flow).
  dequantize:     code lookup as a (tile, 256) one-hot select-sum.

Tiling: values are reshaped (n_blocks, block); the grid walks TILE_ROWS
quant-blocks per program; block = 256 keeps the lane dimension MXU/VPU
aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.blockwise_quant.ref import BLOCK, dynamic_map
from repro.kernels.runtime import resolve_interpret

TILE_ROWS = 64


def _quant_kernel(x_ref, codes_ref, out_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                  # (TILE_ROWS, BLOCK)
    codes = codes_ref[...]                              # (1, 256)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # (TILE_ROWS, 1)
    safe = jnp.where(scale > 0, scale, 1.0)
    normed = x / safe
    mid = (codes[0, 1:] + codes[0, :-1]) * 0.5          # (255,)
    # idx = number of midpoints strictly below the value (searchsorted right)
    idx = jnp.sum(
        (normed[:, :, None] >= mid[None, None, :]).astype(jnp.int32), axis=-1
    )
    out_ref[...] = idx.astype(jnp.uint8)
    scale_ref[...] = scale


def _dequant_kernel(idx_ref, scale_ref, codes_ref, out_ref):
    idx = idx_ref[...].astype(jnp.int32)                # (TILE_ROWS, BLOCK)
    codes = codes_ref[...]                              # (1, 256)
    onehot = (idx[:, :, None] == jnp.arange(256)[None, None, :]).astype(
        jnp.float32
    )
    vals = jnp.sum(onehot * codes[0][None, None, :], axis=-1)
    out_ref[...] = vals * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_pallas(x: jax.Array, block: int = BLOCK, interpret=None):
    interpret = resolve_interpret(interpret)
    n = x.size
    assert n % block == 0, (n, block)
    rows = n // block
    assert rows % TILE_ROWS == 0, (rows, TILE_ROWS)
    xb = x.reshape(rows, block)
    codes = jnp.asarray(dynamic_map())[None, :]

    grid = (rows // TILE_ROWS,)
    out, scale = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 256), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, block), jnp.uint8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xb, codes)
    return out.reshape(-1), scale[:, 0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize_pallas(
    idx: jax.Array, scale: jax.Array, block: int = BLOCK, interpret=None
):
    interpret = resolve_interpret(interpret)
    rows = idx.size // block
    assert rows % TILE_ROWS == 0, (rows, TILE_ROWS)
    codes = jnp.asarray(dynamic_map())[None, :]
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rows // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 256), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), jnp.float32),
        interpret=interpret,
    )(idx.reshape(rows, block), scale[:, None], codes)
    return out.reshape(-1)


# ------------------------------------------------- symmetric stash codec
# Fused kernels for the activation-stash codec (ops.stash_quantize /
# stash_dequantize): per 256-elem block, scale = absmax / code_max, codes
# round-to-int8 or cast-to-fp8-e4m3. Arithmetic order matches
# kernels.paged_attention.quant.kv_quantize op-for-op in f32, so codes and
# scales are BITWISE identical to the jnp reference — PR 9's grad-accuracy
# suite transfers unchanged to the fused path. Rows (= flat blocks) are
# padded to the tile multiple inside the wrapper; pad rows quantize to
# scale-0 / code-0 and are sliced off.
STASH_TILE_ROWS = 32   # int8/fp8 min sublane tile on TPU


def _stash_quant_kernel(x_ref, codes_ref, scale_ref, *, cmax, int_codes):
    xf = x_ref[...].astype(jnp.float32)                 # (TILE, block)
    absmax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    scale = absmax / cmax
    safe = jnp.where(scale > 0, scale, 1.0)
    scaled = jnp.clip(xf / safe, -cmax, cmax)
    if int_codes:
        codes_ref[...] = jnp.round(scaled).astype(jnp.int8)
    else:
        codes_ref[...] = scaled.astype(codes_ref.dtype)
    scale_ref[...] = scale


def _stash_dequant_kernel(codes_ref, scale_ref, out_ref):
    x = codes_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    out_ref[...] = x.astype(out_ref.dtype)


def _pad_rows(a: jax.Array, rows: int) -> jax.Array:
    pad = (-rows) % STASH_TILE_ROWS
    if pad:
        a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    return a


@functools.partial(jax.jit, static_argnames=("storage", "block", "interpret"))
def stash_quantize_pallas(
    xb: jax.Array, storage: str = "int8", block: int = BLOCK, interpret=None
):
    """(rows, block) flat blocks -> (codes (rows, block) int8/fp8,
    scales (rows,) f32), bitwise-equal to kv_quantize on the same blocks."""
    from repro.kernels.paged_attention.quant import _QUANT

    interpret = resolve_interpret(interpret)
    sdt, cmax = _QUANT[storage]
    rows, b = xb.shape
    assert b == block, (xb.shape, block)
    xp = _pad_rows(xb, rows)
    prows = xp.shape[0]
    codes, scale = pl.pallas_call(
        functools.partial(
            _stash_quant_kernel, cmax=cmax,
            int_codes=jnp.dtype(sdt) == jnp.dtype(jnp.int8),
        ),
        grid=(prows // STASH_TILE_ROWS,),
        in_specs=[pl.BlockSpec((STASH_TILE_ROWS, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((STASH_TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((STASH_TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((prows, block), sdt),
            jax.ShapeDtypeStruct((prows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return codes[:rows], scale[:rows, 0]


@functools.partial(jax.jit, static_argnames=("dtype", "block", "interpret"))
def stash_dequantize_pallas(
    codes: jax.Array,
    scales: jax.Array,
    dtype=jnp.float32,
    block: int = BLOCK,
    interpret=None,
):
    """(rows, block) codes + (rows,) scales -> (rows, block) ``dtype``,
    bitwise-equal to kv_dequantize (f32 multiply, then one cast)."""
    interpret = resolve_interpret(interpret)
    rows, b = codes.shape
    assert b == block, (codes.shape, block)
    cp = _pad_rows(codes, rows)
    sp = _pad_rows(scales[:, None], rows)
    prows = cp.shape[0]
    out = pl.pallas_call(
        _stash_dequant_kernel,
        grid=(prows // STASH_TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((STASH_TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((STASH_TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((STASH_TILE_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((prows, block), jnp.dtype(dtype)),
        interpret=interpret,
    )(cp, sp)
    return out[:rows]
