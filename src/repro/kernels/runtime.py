"""Central Pallas execution-mode policy for every kernel in this package.

All kernel entry points take ``interpret=None`` and resolve it here instead of
hard-coding per-call-site literals: on a TPU backend the kernels run compiled,
anywhere else (the CPU containers this repo's tests run on) they run in
interpret mode. ``REPRO_PALLAS_INTERPRET=0|1`` overrides the platform detect —
useful for forcing interpret-mode validation on TPU or asserting that compiled
lowering is exercised in CI.

Note: kernel wrappers are jitted with ``interpret`` as a static argument, so
the environment variable is read at trace time; changing it mid-process only
affects call signatures not yet traced.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

ENV_VAR = "REPRO_PALLAS_INTERPRET"

_FALSY = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """True when Pallas kernels should run in interpret mode."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        return env.strip().lower() not in _FALSY
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a per-call ``interpret`` argument (None -> platform policy)."""
    return default_interpret() if interpret is None else bool(interpret)
