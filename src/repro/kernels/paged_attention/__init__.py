from repro.kernels.paged_attention import quant  # noqa: F401
from repro.kernels.paged_attention.ops import (  # noqa: F401
    paged_attention,
    paged_prefill_attention,
)
from repro.kernels.paged_attention.ref import (  # noqa: F401
    paged_attention_ref,
    paged_prefill_attention_ref,
)
