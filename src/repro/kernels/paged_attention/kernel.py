"""Pallas TPU paged decode attention: block-table-gathered K/V pages.

Grid = (B, Kv, n_pages): one program row per (request slot, kv head), the
innermost page axis executed sequentially per core carrying the online-
softmax state (m, l, acc) in VMEM scratch — the decode-shaped sibling of the
flash forward kernel (``kernels/flash_attention/kernel.py``).

The page gather is the point of the kernel: ``tables`` (B, P) rides in as a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``), so the k/v
BlockSpec index_maps can address pool page ``tables[b, j]`` directly — each
(page, hd) tile is DMA'd straight out of the global pool in HBM without ever
materializing a gathered (B, S, hd) key band.

GQA: the G query heads sharing a kv head sit in one (G, hd) q tile, so group
accumulation is a single (G, page) score tile on the MXU. Per-request
``lengths`` mask the tail page (non-page-multiple lengths) and — combined
with ``window`` — the sliding-window band, via explicit mask multiplies
(fully-masked pages contribute exact zeros, never NaNs).

``paged_prefill_attention_kernel`` is the chunked-prefill generalization:
T-row query chunks (flattened with the GQA groups into one (T*G, page)
score tile) attend to the same block-table pages with per-row causal
masking by absolute position — decode is its T=1 special case. The chunk's
own KV is written to the pool before the kernel runs, so in-chunk causality
needs no separate path.

Quantized pools (``kv_dtype`` int8/fp8): per-(page-slot, kv-head) f32
scales (N, page, Kv) enter as two extra gathered operands whose BlockSpec
index_map is the same ``tables[b, j]`` page select, so each (page,) scale
tile is DMA'd alongside its page and the dequant multiply happens on the
f32 tile right before the score matmul — no dequantized pool is ever
materialized in HBM (kernels/paged_attention/quant.py has the write side).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _pa_kernel(
    tables_ref,   # scalar prefetch (B, P) int32
    lengths_ref,  # scalar prefetch (B,) int32
    q_ref,        # (1, 1, G, hd)
    k_ref,        # (1, page, 1, hd) — pool page selected by index_map
    v_ref,
    *rest,        # [ks_ref, vs_ref (1, page, 1) f32,] o_ref, m/l/acc scratch
    page: int, n_pages: int, window: int, quantized: bool,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    L = lengths_ref[b]                                   # valid tokens (>= 1)

    # Pages at or beyond the request's extent contribute nothing; skip them.
    @pl.when(j * page < L)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            # fused in-gather dequant: the per-(slot, head) scale tile rides
            # the same block-table index_map as its page, so score tiles
            # compute in f32 with no materialized dequantized pool
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]

        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                # (G, page)

        G = scores.shape[0]
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (G, page), 1)
        t = L - 1                                        # query position
        mask = kpos <= t
        if window > 0:
            mask = jnp.logical_and(mask, kpos > t - window)

        scores = jnp.where(mask, scores, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
        p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _pp_kernel(
    tables_ref,   # scalar prefetch (B, P) int32
    starts_ref,   # scalar prefetch (B,) int32 — chunk's first absolute pos
    qlens_ref,    # scalar prefetch (B,) int32 — valid rows in the chunk
    q_ref,        # (1, T, 1, G, hd)
    k_ref,        # (1, page, 1, hd) — pool page selected by index_map
    v_ref,
    *rest,        # [ks_ref, vs_ref (1, page, 1) f32,] o_ref, m/l/acc scratch
    page: int, n_pages: int, window: int, T: int, quantized: bool,
):
    """Chunked-prefill sibling of ``_pa_kernel``: T query rows per request
    instead of one. The T*G (row, group) pairs are flattened into a single
    score tile per page — one (T*G, page) MXU matmul — and the causal /
    sliding-window masks become per-row absolute-position comparisons
    (row t sits at ``start + t``). Decode is the T=1 special case."""
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    start = starts_ref[b]
    qlen = qlens_ref[b]                       # valid rows (>= 1)

    # Pages entirely beyond the last VALID row's position contribute
    # nothing to any row the caller keeps; skip them. (Padding rows t >=
    # qlen may see fewer pages than their kpos<=qpos mask admits — their
    # output is garbage by contract.)
    @pl.when(j * page < start + qlen)
    def _accumulate():
        G = q_ref.shape[3]
        hd = q_ref.shape[4]
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(T * G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]

        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                # (T*G, page)

        kpos = j * page + jax.lax.broadcasted_iota(
            jnp.int32, (T * G, page), 1
        )
        row_t = jax.lax.broadcasted_iota(jnp.int32, (T * G, page), 0) // G
        qpos = start + row_t
        mask = kpos <= qpos
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)

        scores = jnp.where(mask, scores, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
        p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finish():
        G = o_ref.shape[3]
        hd = o_ref.shape[4]
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0] = (
            (acc_scr[...] / denom[:, None]).reshape(T, G, hd)
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_prefill_attention_kernel(
    q: jax.Array,        # (B, T, Kv, G, hd) pre-scaled, roped at start + t
    k_pages: jax.Array,  # (N, page, Kv, hd)
    v_pages: jax.Array,
    tables: jax.Array,   # (B, P) int32, padding entries 0 (null page)
    start: jax.Array,    # (B,) int32 absolute position of row 0
    q_len: jax.Array,    # (B,) int32 valid rows per request (1..T)
    *,
    window: int = 0,
    interpret=None,
    k_scale=None,        # (N, page, Kv) f32 when the pool is quantized
    v_scale=None,
) -> jax.Array:
    """Returns (B, T, Kv, G, hd); see ``_pp_kernel`` for the tiling."""
    interpret = resolve_interpret(interpret)
    B, T, Kv, G, hd = q.shape
    page = k_pages.shape[1]
    P = tables.shape[1]
    quantized = k_scale is not None

    kernel = functools.partial(
        _pp_kernel, page=page, n_pages=P, window=window, T=T,
        quantized=quantized,
    )
    pool_spec = pl.BlockSpec(
        (1, page, 1, hd), lambda b, k, j, tbl, st, ln: (tbl[b, j], 0, k, 0)
    )
    in_specs = [
        pl.BlockSpec(
            (1, T, 1, G, hd), lambda b, k, j, tbl, st, ln: (b, 0, k, 0, 0)
        ),
        pool_spec,
        pool_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        # scale tiles ride the same block-table index_map as their pages
        scale_spec = pl.BlockSpec(
            (1, page, 1), lambda b, k, j, tbl, st, ln: (tbl[b, j], 0, k)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Kv, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, T, 1, G, hd), lambda b, k, j, tbl, st, ln: (b, 0, k, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((T * G,), jnp.float32),
            pltpu.VMEM((T * G,), jnp.float32),
            pltpu.VMEM((T * G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, Kv, G, hd), q.dtype),
        interpret=interpret,
    )(
        tables.astype(jnp.int32), start.astype(jnp.int32),
        q_len.astype(jnp.int32), *operands,
    )


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_kernel(
    q: jax.Array,        # (B, Kv, G, hd) pre-scaled
    k_pages: jax.Array,  # (N, page, Kv, hd)
    v_pages: jax.Array,
    tables: jax.Array,   # (B, P) int32, padding entries 0 (null page)
    lengths: jax.Array,  # (B,) int32
    *,
    window: int = 0,
    interpret=None,
    k_scale=None,        # (N, page, Kv) f32 when the pool is quantized
    v_scale=None,
) -> jax.Array:
    """Returns (B, Kv, G, hd); see module docstring for the tiling."""
    interpret = resolve_interpret(interpret)
    B, Kv, G, hd = q.shape
    page = k_pages.shape[1]
    P = tables.shape[1]
    quantized = k_scale is not None

    kernel = functools.partial(
        _pa_kernel, page=page, n_pages=P, window=window, quantized=quantized
    )
    pool_spec = pl.BlockSpec(
        (1, page, 1, hd), lambda b, k, j, tbl, ln: (tbl[b, j], 0, k, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda b, k, j, tbl, ln: (b, k, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec(
            (1, page, 1), lambda b, k, j, tbl, ln: (tbl[b, j], 0, k)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Kv, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, k, j, tbl, ln: (b, k, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
        interpret=interpret,
    )(
        tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands
    )
