"""Pure-jnp oracle for paged decode attention.

One decode token per request slot attends over that request's KV history,
which lives scattered across a global page pool and is addressed through a
per-request block table — the inference-side analogue of the survey's
virtualized tensor memory (vDNN-style paging).

Layouts (match ``repro.models.attention`` conventions):
  q        (B, Kv, G, hd)   pre-scaled by hd^-0.5, roped at position L-1
  k_pages  (N, page, Kv, hd) global pool; page 0 is the reserved null page
  v_pages  (N, page, Kv, hd)
  tables   (B, P) int32      page ids per request (padding entries -> 0)
  lengths  (B,) int32        valid tokens per request (incl. current token)

The oracle gathers the full (B, P*page) key band and masks by absolute
position, so it is exact for non-page-multiple lengths and sliding windows.

Quantized pools (``kv_dtype`` int8/fp8): ``k_scale``/``v_scale``
(N, page, Kv) f32 ride along and are gathered through the same block
table, dequantizing the band in f32 right at the gather — the oracle
counterpart of the kernels' fused in-gather dequant (no dequantized pool
is materialized beyond the gathered band this oracle builds anyway).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gather_band(pages, tables, S, scale):
    """(N, page, Kv, hd) pool -> (B, S, Kv, hd) f32 band via the block
    table, dequantized when ``scale`` (N, page, Kv) is present."""
    B = tables.shape[0]
    Kv, hd = pages.shape[2], pages.shape[3]
    band = pages[tables].reshape(B, S, Kv, hd).astype(jnp.float32)
    if scale is not None:
        band = band * scale[tables].reshape(B, S, Kv, 1).astype(jnp.float32)
    return band


def paged_attention_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    tables: jax.Array,
    lengths: jax.Array,
    *,
    window: int = 0,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Returns (B, Kv, G, hd). Query position is ``lengths - 1`` per slot."""
    B, Kv, G, hd = q.shape
    page = k_pages.shape[1]
    P = tables.shape[1]

    k = _gather_band(k_pages, tables, P * page, k_scale)
    v = _gather_band(v_pages, tables, P * page, v_scale)

    scores = jnp.einsum(
        "bkgh,bskh->bkgs", q.astype(jnp.float32), k,
        preferred_element_type=jnp.float32,
    )
    kpos = jnp.arange(P * page, dtype=jnp.int32)[None, :]          # (1, S)
    t = (lengths - 1)[:, None]                                     # query pos
    valid = kpos <= t
    if window > 0:
        valid = valid & (kpos > t - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(v.dtype), v)
    return out.astype(q.dtype)


def paged_prefill_attention_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    tables: jax.Array,
    start: jax.Array,
    q_len: jax.Array,
    *,
    window: int = 0,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Chunked-prefill sibling of :func:`paged_attention_ref`.

    A *chunk* of queries per request attends over that request's pool pages
    — which, by the engine's write-then-attend contract, already hold the
    chunk's own KV — so in-chunk causality and attention to the cached
    prefix are the same absolute-position mask ``kpos <= qpos``.

      q      (B, T, Kv, G, hd)  pre-scaled, roped at start + t
      start  (B,) int32         absolute position of the chunk's first row
      q_len  (B,) int32         valid rows (1..T); rows >= q_len are padding
                                (their output is garbage by contract — the
                                caller masks it; see attention_prefill_paged)

    Exactness notes: only keys at ``kpos <= qpos`` are read, and every such
    position was written (cached prefix or earlier-in-chunk), so stale data
    in allocated-but-unwritten pages is never attended by a valid row.
    """
    B, T, Kv, G, hd = q.shape
    page = k_pages.shape[1]
    P = tables.shape[1]

    k = _gather_band(k_pages, tables, P * page, k_scale)
    v = _gather_band(v_pages, tables, P * page, v_scale)
    scores = jnp.einsum(
        "btkgh,bskh->bkgts", q.astype(jnp.float32), k,
        preferred_element_type=jnp.float32,
    )                                                     # (B, Kv, G, T, S)
    kpos = jnp.arange(P * page, dtype=jnp.int32)[None, None, :]    # (1,1,S)
    qpos = (start[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :])[
        :, :, None
    ]                                                              # (B,T,1)
    valid = kpos <= qpos
    if window > 0:
        valid = valid & (kpos > qpos - window)
    scores = jnp.where(valid[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w.astype(v.dtype), v)
    return out.astype(q.dtype)
