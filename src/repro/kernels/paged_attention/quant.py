"""Pool-dtype quantization for the paged KV cache.

The serving pool stores K/V pages in a reduced ``kv_dtype`` — int8
(symmetric linear) or fp8 (e4m3, scaled) — with one f32 scale per
(page, slot, kv-head), i.e. per written token-row per head: the absmax of
that row's ``head_dim`` block. This is the only granularity compatible
with the engine's write-once invariant: a page fills one token at a time
(decode appends) or one chunk at a time (chunked prefill), and a token's
stored bytes must never depend on what was written later or on batch
composition — so each row is quantized from its own values exactly once,
at write time. COW page copies and prefix-cache adoption move the codes
and scales together, byte-identical (zero re-quantization FLOPs).

Dequantization is fused into the consumers' page gather: the Pallas paged
decode/prefill kernels read the (page,) scale tile selected by the same
block-table index_map as the page itself, and the jnp oracles gather
scales through ``tables`` alongside the pools — no dequantized pool is
ever materialized.

Scale layout: pools (N, page, Kv, hd) carry scales (N, page, Kv) f32.
Per-token bytes go from ``2 * Kv * hd * itemsize(native)`` to
``2 * Kv * (hd + 4)`` — ~0.53x at bf16/hd=64, i.e. ~1.9x resident
requests per device at an equal pool-byte budget.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# storage dtype and max representable code magnitude per pool dtype
_QUANT = {
    "int8": (jnp.int8, 127.0),
    "fp8": (jnp.float8_e4m3fn, 448.0),
}
KV_DTYPES = ("bf16", "int8", "fp8")
SCALE_BYTES = 4  # one f32 scale per (page-slot, kv-head)


def normalize_kv_dtype(kv_dtype: str) -> str:
    """Canonical pool-dtype name: '' means native (pool stored at the
    runtime compute dtype — the unquantized baseline; 'bf16' is its CLI
    spelling)."""
    if kv_dtype in ("", "native", "bf16"):
        return ""
    if kv_dtype not in _QUANT:
        raise ValueError(
            f"kv_dtype {kv_dtype!r} not in {('bf16',) + tuple(_QUANT)}"
        )
    return kv_dtype


def is_quantized(kv_dtype: str) -> bool:
    return normalize_kv_dtype(kv_dtype) != ""


def kv_storage_dtype(kv_dtype: str, native) -> jnp.dtype:
    kv_dtype = normalize_kv_dtype(kv_dtype)
    return jnp.dtype(_QUANT[kv_dtype][0]) if kv_dtype else jnp.dtype(native)


def _code_max(storage_dtype) -> float:
    for dt, cmax in _QUANT.values():
        if jnp.dtype(dt) == jnp.dtype(storage_dtype):
            return cmax
    raise ValueError(f"not a quantized pool dtype: {storage_dtype}")


def kv_quantize(x: jax.Array, storage_dtype) -> Tuple[jax.Array, jax.Array]:
    """Quantize K/V rows to the pool dtype.

    x: (..., hd) native-dtype rows -> (codes (..., hd) storage_dtype,
    scales (...,) f32) with ``scale = absmax / code_max`` per row, so
    dequantization is ``codes * scale``. All-zero rows get scale 0 and
    codes 0 (dequantizes to exact zeros — null-page semantics preserved).
    """
    cmax = _code_max(storage_dtype)
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1)
    scale = absmax / cmax
    safe = jnp.where(scale > 0, scale, 1.0)[..., None]
    scaled = jnp.clip(xf / safe, -cmax, cmax)
    if jnp.dtype(storage_dtype) == jnp.dtype(jnp.int8):
        codes = jnp.round(scaled).astype(jnp.int8)
    else:
        codes = scaled.astype(storage_dtype)
    return codes, scale


def kv_dequantize(codes: jax.Array, scales: jax.Array, dtype=jnp.float32):
    """codes (..., hd) pool dtype, scales (...,) f32 -> (..., hd) ``dtype``."""
    return (codes.astype(jnp.float32) * scales[..., None].astype(jnp.float32)
            ).astype(dtype)


def kv_token_bytes(n_kv: int, head_dim: int, kv_dtype: str,
                   native_itemsize: int = 2) -> int:
    """Pool bytes per cached token (K + V + scales) at ``kv_dtype``;
    ``native_itemsize`` prices the unquantized baseline (2 = bf16)."""
    if is_quantized(kv_dtype):
        itemsize = kv_storage_dtype(kv_dtype, None).itemsize
        return 2 * n_kv * (head_dim * itemsize + SCALE_BYTES)
    return 2 * n_kv * head_dim * native_itemsize
