"""Public paged decode-attention op + page-layout helpers.

``paged_attention`` routes between the Pallas kernel (``kernel.py``) and the
pure-jnp oracle (``ref.py``); the kernel is the TPU path, the oracle doubles
as the fast CPU path (interpret-mode Pallas inside a decode scan is far
slower than one gather + einsum). Both share the exact layout contract
documented in ``ref.py``.
"""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    tables: jax.Array,
    lengths: jax.Array,
    *,
    window: int = 0,
    use_kernel: bool = True,
    interpret=None,
) -> jax.Array:
    """q: (B, Kv, G, hd) pre-scaled; pools (N, page, Kv, hd) -> (B, Kv, G, hd)."""
    if use_kernel:
        return paged_attention_kernel(
            q, k_pages, v_pages, tables, lengths,
            window=window, interpret=interpret,
        )
    return paged_attention_ref(
        q, k_pages, v_pages, tables, lengths, window=window
    )
