"""Public paged decode-attention op + page-layout helpers.

``paged_attention`` routes between the Pallas kernel (``kernel.py``) and the
pure-jnp oracle (``ref.py``); the kernel is the TPU path, the oracle doubles
as the fast CPU path (interpret-mode Pallas inside a decode scan is far
slower than one gather + einsum). Both share the exact layout contract
documented in ``ref.py``.

Quantized pools: pass ``k_scale``/``v_scale`` (N, page, Kv) f32 and both
backends dequantize inside the page gather (see ``quant.py``); the scale
operands shard over the same ``model`` kv-head axis as their pools.

Tensor parallelism: with ``mesh`` set and a divisible KV-head count, the op
runs inside ``shard_map`` over the ``model`` axis — each shard holds
``Kv / tp`` heads of the page pools (``sharding.specs.pool_kv_spec``) and
runs the kernel on its local head slice; the block table and lengths are
replicated, so page ids address the same (head-sliced) pages everywhere.
No collective is needed here: per-kv-head outputs are independent, and the
row-sharded ``wo`` matmul downstream carries the reduce.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.kernels.paged_attention.kernel import (
    paged_attention_kernel,
    paged_prefill_attention_kernel,
)
from repro.kernels.paged_attention.ref import (
    paged_attention_ref,
    paged_prefill_attention_ref,
)


def tp_size(mesh) -> int:
    return mesh.shape["model"] if mesh is not None and "model" in mesh.shape else 1


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    tables: jax.Array,
    lengths: jax.Array,
    *,
    window: int = 0,
    use_kernel: bool = True,
    interpret=None,
    mesh=None,
    k_scale=None,
    v_scale=None,
) -> jax.Array:
    """q: (B, Kv, G, hd) pre-scaled; pools (N, page, Kv, hd) -> (B, Kv, G, hd)."""
    quantized = k_scale is not None

    def attend(q_, kp_, vp_, tbl_, ln_, *sc_):
        ks_, vs_ = sc_ if quantized else (None, None)
        if use_kernel:
            return paged_attention_kernel(
                q_, kp_, vp_, tbl_, ln_, window=window, interpret=interpret,
                k_scale=ks_, v_scale=vs_,
            )
        return paged_attention_ref(
            q_, kp_, vp_, tbl_, ln_, window=window, k_scale=ks_, v_scale=vs_
        )

    args = (q, k_pages, v_pages, tables, lengths)
    if quantized:
        args = args + (k_scale, v_scale)
    tp = tp_size(mesh)
    if tp > 1 and q.shape[1] % tp == 0:
        # per-shard head slices: the kernel grid sees Kv/tp program rows,
        # gathering from a pool that only stores those heads' pages
        head = P(None, "model", None, None)
        pool = P(None, None, "model", None)
        in_specs = (head, pool, pool, P(None, None), P(None))
        if quantized:
            scale = P(None, None, "model")
            in_specs = in_specs + (scale, scale)
        fn = shard_map(
            attend,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=head,
            check_vma=False,
        )
        return fn(*args)
    return attend(*args)


def paged_prefill_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    tables: jax.Array,
    start: jax.Array,
    q_len: jax.Array,
    *,
    window: int = 0,
    use_kernel: bool = True,
    interpret=None,
    mesh=None,
    k_scale=None,
    v_scale=None,
) -> jax.Array:
    """Chunked-prefill attention over pool pages.

    q: (B, T, Kv, G, hd) pre-scaled, roped at ``start + t``; pools
    (N, page, Kv, hd); per-request ``start`` (absolute position of row 0)
    and ``q_len`` (valid rows). Returns (B, T, Kv, G, hd).

    Same tensor-parallel contract as :func:`paged_attention`: the kv-head
    axis shards over ``model`` (q axis 2 here), tables / positions stay
    replicated, and no collective runs inside attention.

    This op is also the speculative-decoding verify path
    (``models.lm.verify_step_paged``): drafts are written to the pool
    then attended as a T=k+1 "prefill" whose row ``t`` sees
    ``kpos <= start + t`` — so verify correctness is exactly chunked-
    prefill correctness, no separate masking code path.
    """
    quantized = k_scale is not None

    def attend(q_, kp_, vp_, tbl_, st_, ln_, *sc_):
        ks_, vs_ = sc_ if quantized else (None, None)
        if use_kernel:
            return paged_prefill_attention_kernel(
                q_, kp_, vp_, tbl_, st_, ln_, window=window,
                interpret=interpret, k_scale=ks_, v_scale=vs_,
            )
        return paged_prefill_attention_ref(
            q_, kp_, vp_, tbl_, st_, ln_, window=window,
            k_scale=ks_, v_scale=vs_,
        )

    args = (q, k_pages, v_pages, tables, start, q_len)
    if quantized:
        args = args + (k_scale, v_scale)
    tp = tp_size(mesh)
    if tp > 1 and q.shape[2] % tp == 0:
        head = P(None, None, "model", None, None)
        pool = P(None, None, "model", None)
        in_specs = (head, pool, pool, P(None, None), P(None), P(None))
        if quantized:
            scale = P(None, None, "model")
            in_specs = in_specs + (scale, scale)
        fn = shard_map(
            attend,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=head,
            check_vma=False,
        )
        return fn(*args)
    return attend(*args)
