"""Vocab-chunked cross-entropy head with a custom VJP.

For small models with large vocabularies the dominant activation term of the
training step is the (B, S, V) logits tensor plus its equally-sized gradient
(survey §2.2). This op computes the two per-position statistics the loss
needs — the label logit and the partition logsumexp — by scanning the head
matmul over vocab chunks, so only one (B, S, chunk) tile is ever live:

  forward   online logsumexp over chunks (running max / sum-exp carry) and
            a compare-gather of the label logit; saves only x, w and logz.
  backward  re-scans the chunks: d logits_c = dlogz * softmax_c, folded into
            dx and dw immediately; the label one-hot terms are a gather
            (dll * w[labels] into dx) and a scatter-add (dll * x into dw).

Neither direction materializes (B, S, V); the (V, d) weight gradient is the
only vocab-sized array, and that is parameter-shaped, not activation-shaped.
The dense oracle lives in ``ref.py``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _chunk_weights(w: jax.Array, chunk: int):
    """Pad (V, d) to a chunk multiple; returns ((n, C, d) f32, (n, C) ids)."""
    V, d = w.shape
    C = min(chunk, V)
    Vp = (V + C - 1) // C * C
    wf = w.astype(jnp.float32)
    if Vp != V:
        wf = jnp.pad(wf, ((0, Vp - V), (0, 0)))
    ids = jnp.arange(Vp, dtype=jnp.int32).reshape(Vp // C, C)
    return wf.reshape(Vp // C, C, d), ids, V


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def chunked_ce(
    x: jax.Array, w: jax.Array, labels: jax.Array, chunk: int = 2048
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d); w: (V, d) vocab-major; labels: (B, S) int in [0, V).

    Returns (label_logit (B, S), logz (B, S)), both f32. Matches
    ``ref.chunked_ce_ref`` without ever materializing (B, S, V) logits.
    """
    (ll, logz), _ = _fwd(x, w, labels, chunk)
    return ll, logz


def _fwd(x, w, labels, chunk):
    xf = x.astype(jnp.float32)
    wc, ids, V = _chunk_weights(w, chunk)
    B, S = labels.shape

    def body(carry, sl):
        m, l, ll = carry
        w_c, id_c = sl
        logits = jnp.einsum("bsd,cd->bsc", xf, w_c)          # (B, S, C)
        valid = (id_c < V)[None, None, :]
        logits = jnp.where(valid, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.where(valid, jnp.exp(logits - m_new[..., None]), 0.0), axis=-1
        )
        ll = ll + jnp.sum(
            jnp.where(labels[..., None] == id_c[None, None, :], logits, 0.0),
            axis=-1,
        )
        return (m_new, l, ll), None

    init = (
        jnp.full((B, S), NEG_INF, jnp.float32),
        jnp.zeros((B, S), jnp.float32),
        jnp.zeros((B, S), jnp.float32),
    )
    (m, l, ll), _ = jax.lax.scan(body, init, (wc, ids))
    logz = m + jnp.log(jnp.maximum(l, 1e-30))
    return (ll, logz), (x, w, labels, logz)


def _bwd(chunk, res, cts):
    x, w, labels, logz = res
    dll, dlogz = cts
    xf = x.astype(jnp.float32)
    wc, ids, V = _chunk_weights(w, chunk)

    def body(dx, sl):
        w_c, id_c = sl
        logits = jnp.einsum("bsd,cd->bsc", xf, w_c)
        valid = (id_c < V)[None, None, :]
        p = jnp.where(valid, jnp.exp(logits - logz[..., None]), 0.0)
        dlog = dlogz[..., None] * p                          # (B, S, C)
        dx = dx + jnp.einsum("bsc,cd->bsd", dlog, w_c)
        dw_c = jnp.einsum("bsc,bsd->cd", dlog, xf)
        return dx, dw_c

    dx, dw_chunks = jax.lax.scan(body, jnp.zeros_like(xf), (wc, ids))
    d = w.shape[1]
    dw = dw_chunks.reshape(-1, d)[:V]
    # label one-hot terms: gather into dx, scatter-add into dw
    dx = dx + dll[..., None] * jnp.take(w.astype(jnp.float32), labels, axis=0)
    dw = dw.at[labels.reshape(-1)].add(
        (dll[..., None] * xf).reshape(-1, d)
    )
    return (
        dx.astype(x.dtype),
        dw.astype(w.dtype),
        np.zeros(labels.shape, jax.dtypes.float0),
    )


chunked_ce.defvjp(_fwd, _bwd)
