from repro.kernels.chunked_ce.ops import chunked_ce  # noqa: F401
