"""Dense oracle for the chunked cross-entropy head.

Materializes the full (B, S, V) logits — this is exactly the activation the
chunked op exists to avoid; it is the correctness reference only.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def chunked_ce_ref(
    x: jax.Array, w: jax.Array, labels: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d); w: (V, d) vocab-major; labels: (B, S) int in [0, V).

    Returns (label_logit (B, S), logz (B, S)), both f32.
    """
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), w.astype(jnp.float32)
    )
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return ll, logz
