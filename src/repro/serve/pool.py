"""Host-side KV page-pool allocator for the paged serve engine.

The device side is a set of per-layer ``(num_pages, page_size, Kv, hd)``
pools plus per-request block tables (``repro.models.stack.init_stack_pool``);
this module owns the metadata: which pages belong to which sequence, page
refcounts for prefix sharing, and the free list. It is the inference-side
analogue of vDNN-style memory virtualization — KV tensors are addressed
through a translation table instead of living at a dense (B, S) extent.

Semantics
---------
* Page 0 is reserved as the null page (block-table padding and inactive-slot
  writes land there); the usable budget is ``num_pages - 1``.
* ``alloc``/``append`` reserve *capacity* in tokens; ``append`` grows a
  sequence page-by-page and raises :class:`PoolExhausted` (never
  overcommits) when the budget is gone.
* ``fork`` shares all of a sequence's pages (refcount++) — the shared-prompt
  -prefix path. A forked sequence that appends into a shared, partially
  filled tail page triggers copy-on-write: a fresh page is allocated and a
  (src, dst) device copy is queued (``drain_copies``). Full shared pages are
  immutable (appends never rewrite positions below the sequence length), so
  they stay shared for free.
* The radix prefix cache (``serve.prefix``) holds pages *outside* any
  sequence via ``retain``/``release`` (tracked separately so ``check`` can
  still prove every refcount), and turns a matched page run back into a
  request-owned sequence with ``adopt`` — fork generalized to an arbitrary
  page list.
* Quantized pools (``EngineConfig.kv_dtype`` int8/fp8) change nothing here:
  scale buffers are extra leaves of the same device pool tree, indexed by
  the same page ids, so freeing a page frees its scales, COW copies move
  codes + scales together, and adoption stays zero-FLOP (the bytes were
  quantized once at write time). ``kv_page_bytes`` is the one byte-pricing
  rule for both layouts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


class PoolExhausted(RuntimeError):
    """Raised when an alloc/append cannot be served from the free list."""


def kv_page_bytes(
    page_size: int, n_kv: int, head_dim: int, n_layers: int,
    kv_dtype: str = "", native_itemsize: int = 2,
) -> int:
    """Device bytes one pool page costs across all layers (K + V codes, plus
    the per-(slot, head) f32 scale buffers under a quantized ``kv_dtype``).
    The single byte-accounting rule shared by the engine's per-request
    stats, ``EngineConfig.capacity``, the serve CLI, and the
    quantized-pool bench — page metadata here is host-side and free."""
    from repro.kernels.paged_attention.quant import kv_token_bytes

    return page_size * n_layers * kv_token_bytes(
        n_kv, head_dim, kv_dtype, native_itemsize
    )


@dataclasses.dataclass
class _Seq:
    pages: List[int]
    tokens: int          # reserved capacity in tokens


class PagePool:
    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        # pop() hands out ascending page ids; page 0 reserved (null page)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._ref: Dict[int, int] = {}
        self._seqs: Dict[int, _Seq] = {}
        self._cache_refs: Dict[int, int] = {}   # prefix-cache retains
        self._next_id = 0
        self.high_water = 0
        self._pending_copies: List[Tuple[int, int]] = []

    # ------------------------------------------------------------- inspect
    @property
    def budget(self) -> int:
        return self.num_pages - 1

    @property
    def pages_in_use(self) -> int:
        return self.budget - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def tokens_in_use(self) -> int:
        """Total reserved token capacity across live sequences (the load
        measure the replica router balances on)."""
        return sum(s.tokens for s in self._seqs.values())

    def seq_pages(self, sid: int) -> List[int]:
        return list(self._seqs[sid].pages)

    def seq_tokens(self, sid: int) -> int:
        return self._seqs[sid].tokens

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def refcount(self, page: int) -> int:
        """Live references (sequence memberships + cache retains) on a page."""
        return self._ref.get(page, 0)

    # -------------------------------------------------------------- verbs
    def _take(self) -> int:
        if not self._free:
            raise PoolExhausted(
                f"pool exhausted: {self.pages_in_use}/{self.budget} pages in use"
            )
        page = self._free.pop()
        self._ref[page] = 1
        self.high_water = max(self.high_water, self.pages_in_use)
        return page

    def _release(self, page: int) -> None:
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            self._free.append(page)
            # a pending COW copy into a now-dead page has no beneficiary;
            # drop it so a future owner of the page cannot be clobbered.
            # (Copies FROM a released page stay: the device data is intact
            # until the page is reallocated AND rewritten, and the engine
            # drains copies at every allocation point before any write.)
            self._pending_copies = [
                c for c in self._pending_copies if c[1] != page
            ]

    def alloc(self, n_tokens: int) -> int:
        """Reserve capacity for ``n_tokens`` in a fresh sequence; returns its
        id. All-or-nothing: on exhaustion nothing is leaked."""
        n_pages = self.pages_for(n_tokens)
        if n_pages > len(self._free):
            raise PoolExhausted(
                f"need {n_pages} pages, {len(self._free)} free"
            )
        sid = self._next_id
        self._next_id += 1
        self._seqs[sid] = _Seq([self._take() for _ in range(n_pages)],
                               max(1, n_tokens))
        return sid

    def append(self, sid: int, n_tokens: int = 1) -> None:
        """Grow a sequence's reserved capacity by ``n_tokens``, allocating
        pages on boundary crossings (copy-on-write first if the tail page is
        shared and partially filled)."""
        seq = self._seqs[sid]
        if n_tokens <= 0:
            return
        new_tokens = seq.tokens + n_tokens
        tail = seq.pages[-1]
        if self._ref[tail] > 1 and seq.tokens % self.page_size != 0:
            fresh = self._take()          # copy-on-write of the shared tail
            self._pending_copies.append((tail, fresh))
            self._release(tail)
            seq.pages[-1] = fresh
        need = self.pages_for(new_tokens) - len(seq.pages)
        if need > len(self._free):
            raise PoolExhausted(
                f"need {need} pages, {len(self._free)} free"
            )
        seq.pages.extend(self._take() for _ in range(need))
        seq.tokens = new_tokens

    def ensure(self, sid: int, n_tokens: int) -> None:
        """Grow reserved capacity to at least ``n_tokens`` (idempotent)."""
        self.append(sid, n_tokens - self._seqs[sid].tokens)

    def truncate(self, sid: int, n_tokens: int) -> None:
        """Shrink a sequence's reserved capacity to ``n_tokens`` (floor 1,
        matching ``alloc``), releasing tail pages past
        ``pages_for(n_tokens)`` — the rejection-rollback verb of speculative
        decoding. Refcount/COW-safe by construction: a released tail page
        only drops one reference (a fork or prefix-cache retain keeps the
        device bytes alive for its other owners), ``_release`` already drops
        pending COW copies whose destination page dies with the truncation,
        and no data moves — positions below ``n_tokens`` are untouched,
        while stale tokens above are unreachable under the engine's
        write-then-attend contract (never attended past the committed
        length, overwritten before any future attend). Growing is not this
        verb's job: ``n_tokens >= tokens`` is a no-op."""
        seq = self._seqs[sid]
        n_tokens = max(1, n_tokens)
        if n_tokens >= seq.tokens:
            return
        keep = self.pages_for(n_tokens)
        while len(seq.pages) > keep:
            self._release(seq.pages.pop())
        seq.tokens = n_tokens

    def retain(self, pages: List[int]) -> None:
        """Cache-side reference on already-live pages (no sequence). The
        prefix cache retains a retiring request's prompt pages so they
        survive ``free``; ``release`` is the eviction-side inverse."""
        for p in pages:
            assert p in self._ref, f"retain of dead page {p}"
            self._ref[p] += 1
            self._cache_refs[p] = self._cache_refs.get(p, 0) + 1

    def release(self, pages: List[int]) -> None:
        """Drop cache-side references (pages return to the free list at 0)."""
        for p in pages:
            assert self._cache_refs.get(p, 0) > 0, f"release of unretained {p}"
            self._cache_refs[p] -= 1
            if self._cache_refs[p] == 0:
                del self._cache_refs[p]
            self._release(p)

    def adopt(self, pages: List[int], n_tokens: int) -> int:
        """New sequence referencing an existing page run (refcount++ each) —
        ``fork`` generalized to an arbitrary page list. The prefix-cache
        adoption path: a request's matched prefix pages become the head of
        its own sequence, then ``append``/``ensure`` grow the tail. The
        caller guarantees ``pages`` covers ``n_tokens`` (page-aligned match,
        so the shared tail page is always full and appends never COW it)."""
        assert pages, "adopt of empty page run (use alloc)"
        assert len(pages) == self.pages_for(n_tokens), (pages, n_tokens)
        for p in pages:
            assert p in self._ref, f"adopt of dead page {p}"
            self._ref[p] += 1
        sid = self._next_id
        self._next_id += 1
        self._seqs[sid] = _Seq(list(pages), max(1, n_tokens))
        return sid

    def fork(self, sid: int) -> int:
        """New sequence sharing every page of ``sid`` (prompt-prefix reuse)."""
        src = self._seqs[sid]
        for p in src.pages:
            self._ref[p] += 1
        new_sid = self._next_id
        self._next_id += 1
        self._seqs[new_sid] = _Seq(list(src.pages), src.tokens)
        return new_sid

    def free(self, sid: int) -> None:
        seq = self._seqs.pop(sid)
        for p in seq.pages:
            self._release(p)

    def drain_copies(self) -> List[Tuple[int, int]]:
        """Pending (src, dst) device page copies queued by copy-on-write."""
        out, self._pending_copies = self._pending_copies, []
        return out

    def table(self, sid: int, width: int) -> List[int]:
        """Block-table row, padded with 0 (the null page)."""
        pages = self._seqs[sid].pages
        assert len(pages) <= width, (len(pages), width)
        return pages + [0] * (width - len(pages))

    # ---------------------------------------------------------- invariants
    def check(self) -> None:
        """Internal consistency (exercised by the property tests)."""
        held: Dict[int, int] = {}
        for seq in self._seqs.values():
            assert len(seq.pages) == len(set(seq.pages)), "dup page in seq"
            for p in seq.pages:
                held[p] = held.get(p, 0) + 1
        for p, n in self._cache_refs.items():
            assert n > 0, (p, n)
            held[p] = held.get(p, 0) + n
        assert held == self._ref, (held, self._ref)
        assert not (set(held) & set(self._free)), "page both held and free"
        assert 0 not in held, "null page handed out"
        assert len(held) + len(self._free) == self.budget, "page leaked"
        assert self.high_water <= self.budget
