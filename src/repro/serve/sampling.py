"""Token sampling shared by the dense path and the paged serve engine.

``sample_token`` keeps the historical ``repro.train.serve`` contract (one key
for the whole batch); ``sample_slots`` is the continuous-batching variant —
every decode slot carries its own key and per-request step counter, so a
request's sample stream is identical whether it runs alone or packed into a
busy batch (admission order cannot perturb outputs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mask_padded_logits(logits: jax.Array, vocab: int) -> jax.Array:
    """Mask vocab-padding ids with the dtype's finfo min (not a hard-coded
    -1e30, which overflows to -inf in fp16 and is above bf16's range)."""
    if not vocab or vocab >= logits.shape[-1]:
        return logits
    neg = jnp.finfo(logits.dtype).min
    mask = jnp.arange(logits.shape[-1]) < vocab
    return jnp.where(mask[None, :], logits, neg)


def sample_token(
    logits: jax.Array, key: jax.Array, temperature: float = 0.0, vocab: int = 0
) -> jax.Array:
    """logits: (B, Vp). temperature 0 = greedy. Padding ids masked out."""
    logits = mask_padded_logits(logits, vocab)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


def sample_slots(
    logits: jax.Array,
    keys: jax.Array,
    steps: jax.Array,
    temperature: float,
    vocab: int,
) -> jax.Array:
    """Per-slot sampling. logits: (B, Vp); keys: (B, 2) PRNG keys; steps:
    (B,) int32 per-request sample counters (folded into the slot key so the
    stream depends only on the request, not on global engine time)."""
    logits = mask_padded_logits(logits, vocab)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(logit, key, step):
        k = jax.random.fold_in(key, step)
        return jax.random.categorical(k, logit / temperature)

    return jax.vmap(one)(logits, keys, steps).astype(jnp.int32)
