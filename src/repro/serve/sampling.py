"""Token sampling shared by the dense path and the paged serve engine.

:class:`SamplingPolicy` is the one policy object every token-producing path
goes through — dense generation, the engine's prefill first-token and decode
scan, and speculative decoding's verify/acceptance rule — so greedy vs
temperature behavior and per-slot key derivation are defined in exactly one
place (and spec-sampling acceptance has one seam to land in later).

The module-level primitives remain: ``sample_token`` keeps the historical
``repro.train.serve`` contract (one key for the whole batch);
``sample_slots`` is the continuous-batching variant — every decode slot
carries its own key and per-request step counter, so a request's sample
stream is identical whether it runs alone or packed into a busy batch
(admission order cannot perturb outputs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def mask_padded_logits(logits: jax.Array, vocab: int) -> jax.Array:
    """Mask vocab-padding ids with the dtype's finfo min (not a hard-coded
    -1e30, which overflows to -inf in fp16 and is above bf16's range)."""
    if not vocab or vocab >= logits.shape[-1]:
        return logits
    neg = jnp.finfo(logits.dtype).min
    mask = jnp.arange(logits.shape[-1]) < vocab
    return jnp.where(mask[None, :], logits, neg)


def sample_token(
    logits: jax.Array, key: jax.Array, temperature: float = 0.0, vocab: int = 0
) -> jax.Array:
    """logits: (B, Vp). temperature 0 = greedy. Padding ids masked out."""
    logits = mask_padded_logits(logits, vocab)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


def sample_slots(
    logits: jax.Array,
    keys: jax.Array,
    steps: jax.Array,
    temperature: float,
    vocab: int,
) -> jax.Array:
    """Per-slot sampling. logits: (B, Vp); keys: (B, 2) PRNG keys; steps:
    (B,) int32 per-request sample counters (folded into the slot key so the
    stream depends only on the request, not on global engine time)."""
    logits = mask_padded_logits(logits, vocab)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(logit, key, step):
        k = jax.random.fold_in(key, step)
        return jax.random.categorical(k, logit / temperature)

    return jax.vmap(one)(logits, keys, steps).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class SamplingPolicy:
    """Greedy/temperature sampling plus per-request key derivation, as one
    value. Hashable and usable as part of a jit cache key, so jitted step
    functions can close over a policy without retracing per request.

      temperature  0 = greedy (argmax); >0 = categorical at that temperature
      vocab        true vocab size; padding ids above it are masked out
      seed         engine seed; per-request streams are fold_in(seed, rid)
    """

    temperature: float = 0.0
    vocab: int = 0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def request_key(self, rid: int) -> jax.Array:
        """Root PRNG key of request ``rid``'s sample stream (depends only on
        (seed, rid) — never on engine time or co-resident requests)."""
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), rid)

    def sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        """One-key-per-batch sampling (dense path / prefill first token).
        logits: (B, Vp)."""
        return sample_token(logits, key, self.temperature, self.vocab)

    def first_token(self, logits: jax.Array, rid: int) -> jax.Array:
        """Step 0 of request ``rid``'s stream — the prefill-produced token."""
        key = jax.random.fold_in(self.request_key(rid), 0)
        return sample_token(logits, key, self.temperature, self.vocab)

    def sample_slots(
        self, logits: jax.Array, keys: jax.Array, steps: jax.Array
    ) -> jax.Array:
        """Per-slot sampling inside the decode scan. logits: (B, Vp); keys:
        (B, 2) per-slot request keys; steps: (B,) per-request counters."""
        return sample_slots(logits, keys, steps, self.temperature, self.vocab)

    def greedy_tokens(self, logits: jax.Array) -> jax.Array:
        """argmax over vocab-masked logits at any leading shape — the
        speculative-decoding verify/acceptance rule. Deliberately ignores
        ``temperature``: greedy acceptance is what makes accepted tokens
        token-identical to the target's own greedy stream."""
        flat = mask_padded_logits(
            logits.reshape(-1, logits.shape[-1]), self.vocab
        )
        toks = jnp.argmax(flat, axis=-1).astype(jnp.int32)
        return toks.reshape(logits.shape[:-1])
