"""Speculative-decoding drafters for the paged serve engine.

Two drafter kinds feed ``ServeEngine``'s batched verify pass
(``models.lm.verify_step_paged`` — the paged-prefill write-then-attend path
at T = k + 1):

* ``"ngram"`` (default): model-free prompt-lookup drafting. The k proposed
  tokens are the continuation that followed the most recent earlier
  occurrence of the context's final n-gram (longest n first). No second
  model, no extra device state, works for every family and engine mode —
  and it shines exactly where greedy decode is most wasteful: repetitive
  continuations (cycles, boilerplate, copied spans).
* ``"model"``: a paired small config of the SAME family from the config
  registry (:func:`paired_drafter_cfg`), decoded greedily k steps per tick.
  The drafter shares the target's block tables and page geometry — its own
  (smaller) per-layer pools are indexed by the SAME page ids — so the host
  pool accounting is done once, for both models.

Correctness never depends on draft quality: the engine's greedy acceptance
rule only commits a draft token when it EQUALS the target's own argmax at
that position, so a bad draft (or the zero-padding behind a short n-gram
proposal) costs speed, never tokens, and the committed stream is the target
model's own greedy stream.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig, reduced

DRAFTER_KINDS = ("ngram", "model")


def ngram_draft(ctx: np.ndarray, k: int, max_n: int = 3) -> np.ndarray:
    """Prompt-lookup proposal: up to ``k`` tokens continuing ``ctx``.

    Scans for the most recent earlier occurrence of the context's final
    n-gram, longest ``n`` first (``n = max_n .. 1``), and proposes the
    tokens that followed it. A match within ``k`` tokens of the end means
    the continuation runs off the context — and also that the tail is
    (locally) periodic with the match distance as its period, so the
    proposal is extended by cycling that tail window instead of being
    truncated. Blind truncation would cap every accepted run on a
    periodic stream at under one period — exactly the streams prompt
    lookup is best at. Returns an empty array when the context never
    repeats — the engine then runs a draft-free verify (T = 1), which is
    exactly one ordinary decode step, so the no-match tick is never
    slower than non-speculative decode by more than the acceptance
    bookkeeping.

    Pure host-side and deterministic in ``ctx`` alone, so the batched ==
    alone guarantee is untouched by drafting.
    """
    ctx = np.asarray(ctx, np.int32).reshape(-1)
    L = len(ctx)
    if k <= 0 or L < 2:
        return np.zeros(0, np.int32)
    for n in range(min(max_n, L - 1), 0, -1):
        pat = ctx[L - n:]
        # candidate starts (most recent first), strictly before the final
        # occurrence so there is always at least one continuation token
        starts = np.flatnonzero(ctx[: L - n] == pat[0])
        for s in starts[::-1]:
            if n == 1 or np.array_equal(ctx[s : s + n], pat):
                cont = ctx[s + n :]
                if len(cont) < k:      # periodic tail: cycle it out to k
                    cont = np.tile(cont, -(-k // len(cont)))
                return cont[:k].astype(np.int32)
    return np.zeros(0, np.int32)


def paired_drafter_cfg(target: ArchConfig, **over) -> ArchConfig:
    """The registry pairing rule: a drafter config of the SAME family as
    ``target``, built by ``configs.base.reduced`` shrunk to a single layer —
    but with the target's own vocabulary kept, because draft tokens must BE
    target tokens (acceptance compares token ids). The mixer pattern, GQA
    ratio, and head layout survive ``reduced``, so the drafter is paged-
    capable whenever the target is and shares the engine's page geometry.
    """
    upd = dict(
        name=target.name + "-draft",
        n_layers=1,
        vocab_size=target.vocab_size,
        frontend_tokens=target.frontend_tokens,
    )
    upd.update(over)
    return reduced(target, **upd)
