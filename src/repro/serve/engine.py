"""Continuous-batching serve engine over the paged KV-cache pool.

Execution model
---------------
A fixed number of decode *slots* (the jitted batch dimension) is fed from a
FIFO scheduler. Each admitted request is prefilled alone (B=1, cached
compiled prefill), its KV scattered into pool pages through its block table,
and its first token sampled (that wall time is the request's TTFT). Decode
then runs in jitted ``lax.scan`` chunks of ``inner_steps`` single-token
steps over ALL slots at once — every slot at its own depth, masked by a
per-slot ``remaining`` counter — with the host only intervening between
chunks to retire finished requests (freeing their pages) and admit new ones
into the vacated slots. Per-slot sample keys + step counters make each
request's token stream independent of what else shares the batch, so engine
output is identical to running the request alone (the dense path can only
promise that for greedy decoding).

Tick API + async intake
-----------------------
The drain loop is reentrant: one ``step()`` is a complete engine tick
(admit -> top-up -> one jitted chunk -> collect -> retire) that an external
driver — ``serve.frontend.AsyncFrontend`` — can call between its own
events. ``run()`` is now just ``run_begin(); while busy: step();
run_finalize()``. Requests carry a QoS tier (interactive beats batch at
admission, strictly and deterministically — see ``serve.scheduler``) and an
optional per-token callback ``on_token(rid, tokens, done)`` invoked as
tokens are collected from each chunk; delivered-token counts survive
preemption, so a preempted-and-recomputed request never re-delivers tokens
it already streamed (decode is deterministic, the regenerated prefix is
identical). ``cancel(rid)`` removes a queued request or stops an in-flight
one mid-decode, freeing its pool pages immediately; it is safe to call
from inside an ``on_token`` callback (early stop). Latency accounting:
``stats["ttft_s"]`` is measured from ``submit()`` wall time on every path
(queue wait included; preempt-then-readmit spans the original submit),
while ``stats["prefill_s"]`` keeps the prefill compute time separately.

Families whose decode state is not a KV cache (SSM / RG-LRU recurrences,
enc-dec cross caches) fall back to the dense path (``paged=False``), grouped
into equal-prompt-length batches.

Speculative decoding
--------------------
With ``EngineConfig.spec_tokens = k`` a decode tick commits a VARIABLE-
length token run per slot instead of exactly one token: a drafter proposes
k tokens per slot (model-free prompt-lookup by default, or a paired small
same-family model — ``serve.spec``), one batched (k+1)-row pass through the
paged PREFILL path verifies them (``models.lm.verify_step_paged``), and the
longest draft prefix matching the target's own argmax chain commits
together with the verify pass's bonus token — 1..k+1 tokens per slot per
tick. Greedy acceptance (``temperature`` must be 0) makes every committed
token the target's own argmax, so spec-on output is token-identical to
spec-off (and to running alone); draft quality only moves throughput.
Rejected rows roll back for free on device (their KV sits past the
committed length, masked and overwritten) and via ``PagePool.truncate``
host-side for the pool reservation under the optimistic policy. The
multi-token commit rides the existing emits contract: ``on_token``
streaming, TPOT/goodput accounting, cancel/preempt bookkeeping all see the
same per-slot token runs they would under one-token ticks.

Prefix cache + chunked prefill
------------------------------
With ``EngineConfig.prefix_cache`` a radix tree (``serve.prefix``) keeps
retired prompts' KV pages alive: a new request adopts the longest token-
exact cached prefix (refcount++ on the shared pages — zero prefill FLOPs
for the shared part) and only its uncached remainder is computed. With
``prefill_chunk`` the remainder is split into fixed-size chunks that run
*inside* the decode step: one jitted program executes a prefill chunk for
the admitting request AND ``inner_steps`` decode steps for every active
slot, so long prompts no longer stall in-flight decodes (continuous
batching stays continuous). Both features keep the batched == alone
guarantee: the paged-prefill path produces bit-identical logits to the
dense prefill (asserted in tests), and the per-slot sample streams are
untouched. Requests with a modality prefix (vision) fall back to the
legacy whole-prompt prefill — the radix key is token IDs and cannot see
image content.

Sharded serving
---------------
With ``Runtime.mesh`` set, one engine spans the mesh's ``model`` axis:
params are laid out by the Megatron rules in ``sharding.specs.param_specs``,
the KV page pools shard their kv-head axis (``paged_state_specs``) so KV
bytes per device shrink by the TP factor, and the paged-attention op runs
inside shard_map on per-shard head slices — only the final (vocab-sharded)
logits are gathered for sampling. Block tables, lengths, and every other
slot-addressing array stay replicated, so the host-side scheduler is
topology-blind. ``ReplicatedServeEngine`` adds the ``data`` axis: one engine
per data slice, with requests routed to the least-loaded replica
(``scheduler.ReplicaRouter``).
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import (
    Runtime,
    decode_step_paged,
    init_paged_state,
    prefill_chunk_paged,
)
from repro.models.layers import Params
from repro.models.lm import verify_step_paged
from repro.models.stack import write_prefill_to_pool
from repro.serve import dense as dense_mod
from repro.serve import spec as spec_mod
from repro.serve.pool import PagePool, PoolExhausted
from repro.serve.prefix import PrefixCache
from repro.serve.sampling import SamplingPolicy
from repro.serve.scheduler import Request, Scheduler


def paged_supported(cfg: ArchConfig) -> bool:
    """Paged decode needs every mixer to be a KV-cache attention kind and no
    cross-attention cache (enc-dec)."""
    return (
        not cfg.is_encdec
        and cfg.n_heads > 0
        and all(k in ("attn", "local") for k in cfg.pattern)
    )


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4        # decode batch width (jit-static)
    page_size: int = 16       # tokens per KV page (= kernel block size)
    num_pages: int = 129      # pool size incl. reserved null page 0
    max_len: int = 256        # per-request horizon; block-table width
    inner_steps: int = 8      # decode steps per jitted scan chunk
    temperature: float = 0.0
    seed: int = 0
    use_kernel: bool = False  # Pallas paged kernel vs jnp oracle gather
    policy: str = "reserve"   # admission policy (see serve.scheduler)
    # Pad prompts up to a multiple of this bucket before prefill, so distinct
    # prompt lengths share max_len/bucket compiled programs instead of one
    # XLA compile each (0 = exact shapes). Exactness: padded positions are
    # causally invisible, the engine prefills with full (un-windowed) caches
    # so no real token is ring-evicted by the padding, and padded KV is
    # either null-paged or overwritten before it can be attended — outputs
    # are unchanged for every attention head layout (MHA, GQA, and MQA
    # alike: the causal mask is head-agnostic; asserted across layouts in
    # tests/test_serve_engine.py). The one exception is MoE routing, which
    # sees pad tokens in its capacity count and can perturb token dropping
    # vs an exact-shape run — the engine warns on that combination (same
    # caveat applies to chunked prefill, whose chunk grid changes the
    # token population each router call sees).
    prefill_bucket: int = 0
    # Radix-tree KV prefix reuse: retired prompts' pages stay cached and
    # new requests adopt their longest token-exact cached prefix (COW/fork
    # machinery of the pool; LRU eviction under pressure).
    prefix_cache: bool = False
    # Split uncached prompt remainders into chunks of this many tokens,
    # each executed INSIDE a decode step (one jitted program = 1 prefill
    # chunk + inner_steps decode steps over all slots), bounding the decode
    # stall a long prompt can cause. 0 with prefix_cache on still routes
    # through the chunked path using page_size-ish chunks (see
    # ``chunk_tokens``); 0 with prefix_cache off = legacy whole-prompt
    # prefill at admission.
    prefill_chunk: int = 0
    # KV pool storage dtype: "bf16" (native — pages stored at the runtime
    # compute dtype), "int8", or "fp8". Quantized pools store ~0.5x the
    # bytes per token (codes + per-(slot, head) f32 scales), dequantized
    # inside the paged kernels' page gather; each token row is quantized
    # exactly once at write time, so batched==alone determinism holds at
    # any fixed kv_dtype (see kernels.paged_attention.quant).
    kv_dtype: str = "bf16"
    # Admission backpressure: bound on EACH QoS tier's wait queue (0 =
    # unbounded). submit() raises scheduler.QueueFull at the bound; the
    # async front-end turns that into an awaitable retry.
    max_queue: int = 0
    # Speculative decoding: draft spec_tokens tokens per decode tick and
    # verify them in ONE batched (k+1)-row pass through the paged-prefill
    # write-then-attend path; a prefix of matching drafts plus the verify
    # pass's own next token commit together (1..k+1 tokens per slot per
    # tick). Greedy acceptance only (temperature must be 0): every
    # committed token equals the target model's own argmax, so the stream
    # is the target's greedy stream and batched==alone survives. 0 = off.
    spec_tokens: int = 0
    # Drafter kind: "ngram" (model-free prompt lookup, default) or "model"
    # (a paired small same-family config; pass draft_params to ServeEngine).
    spec_drafter: str = "ngram"
    # Longest n-gram the prompt-lookup drafter matches on.
    spec_ngram: int = 3

    @property
    def chunk_tokens(self) -> int:
        """Effective prefill-chunk width for the paged-prefill path (one
        compiled chunk shape: ragged tails are right-padded to this)."""
        return self.prefill_chunk or self.prefill_bucket or self.page_size

    @classmethod
    def capacity(
        cls,
        max_prompt_total: int,
        max_new: int,
        *,
        slots: Optional[int] = None,
        pool_bytes: Optional[int] = None,
        cfg=None,
        page_size: int = 16,
        headroom: float = 1.0,
        kv_dtype: str = "bf16",
        native_itemsize: int = 2,
    ) -> "Capacity":
        """THE capacity arithmetic, in one direction-agnostic call.

        Give ``slots`` to size a pool for that many worst-case requests
        (prompt incl. any frontend prefix + ``max_new``; the reservation
        policy needs ``horizon - 1`` tokens per request), or ``pool_bytes``
        to size the SLOT count to an HBM budget at ``kv_dtype`` page
        pricing (``pool.kv_page_bytes``, incl. scale buffers — the
        resident-request capacity quantized pools multiply). Exactly one of
        the two. ``headroom`` > 1 over-provisions pages for queue churn.
        Byte pricing needs the model ``cfg`` (required with ``pool_bytes``;
        optional with ``slots``, where the byte fields report 0 without
        it). Returns a :class:`Capacity`; call ``.engine(**kw)`` on it for
        the ``EngineConfig``."""
        if (slots is None) == (pool_bytes is None):
            raise ValueError("pass exactly one of slots= / pool_bytes=")
        if pool_bytes is not None and cfg is None:
            raise ValueError("pool_bytes sizing needs cfg= for byte pricing")
        horizon = max_prompt_total + max_new
        max_len = -(-horizon // page_size) * page_size
        pages_per_request = max_len // page_size
        page_bytes = 0
        if cfg is not None:
            from repro.serve.pool import kv_page_bytes

            page_bytes = kv_page_bytes(
                page_size, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers,
                kv_dtype, native_itemsize,
            )
        if slots is not None:
            num_pages = 1 + math.ceil(slots * pages_per_request * headroom)
        else:
            # The pool allocates 1 + slots * per_slot pages and the reserved
            # null page costs page_bytes like any other, so it is charged
            # against the budget too — otherwise the pool overspends
            # pool_bytes by up to one page. (The max(1, .) floor still
            # returns a working 1-slot config for budgets too small to
            # honor; callers sizing to a real HBM budget pass enough.)
            budget_pages = pool_bytes // page_bytes - 1    # null page charged
            per_slot = math.ceil(pages_per_request * headroom)
            slots = max(1, int(budget_pages) // per_slot)
            num_pages = 1 + slots * per_slot
        return Capacity(
            slots=slots, page_size=page_size, max_len=max_len,
            pages_per_request=pages_per_request, num_pages=num_pages,
            bytes_per_token=(page_bytes // page_size if page_bytes else 0),
            page_bytes=page_bytes, pool_bytes=num_pages * page_bytes,
            kv_dtype=kv_dtype,
        )


@dataclasses.dataclass(frozen=True)
class Capacity:
    """Named result of :meth:`EngineConfig.capacity` — the worst-case-request
    -> pages -> pool arithmetic as one inspectable value instead of fields
    scattered across an ``EngineConfig``."""

    slots: int                # concurrent worst-case requests
    page_size: int
    max_len: int              # per-request horizon, page-aligned
    pages_per_request: int    # pages one worst-case request spans (no headroom)
    num_pages: int            # pool size INCLUDING the reserved null page 0
    bytes_per_token: int      # KV bytes/token across layers (0 without cfg)
    page_bytes: int           # bytes_per_token * page_size (0 without cfg)
    pool_bytes: int           # num_pages * page_bytes (null page included)
    kv_dtype: str

    def engine(self, **kw) -> EngineConfig:
        """The ``EngineConfig`` realizing this capacity plan; ``kw`` passes
        every non-capacity field through (inner_steps, policy, ...)."""
        kw.setdefault("kv_dtype", self.kv_dtype)
        return EngineConfig(
            max_slots=self.slots, page_size=self.page_size,
            num_pages=self.num_pages, max_len=self.max_len, **kw,
        )


@dataclasses.dataclass
class _Slot:
    rid: int
    sid: int                  # pool sequence id
    req: Request
    order: int                # admission order (eviction picks the youngest)
    phase: str = "decode"     # "prefill" while chunks of the prompt remain
    pf_next: int = 0          # next uncomputed prompt position (chunked path)
    t_admit: float = 0.0      # admission wall time (TTFT under chunking)


# Module-wide compile caches: fresh ServeEngine instances with an identical
# (cfg, rt, engine-config) key reuse the jitted chunk fn instead of
# retracing (same policy as repro.serve.dense's prefill/loop cache). The
# page pools are donated in both fns — per-chunk/per-admission updates land
# in place instead of double-buffering the whole KV pool (the donation is a
# no-op on CPU backends, which jax reports with a one-time warning).
_CHUNK_CACHE: Dict[Any, Any] = {}
_SCATTER = jax.jit(
    write_prefill_to_pool, static_argnames=("page_size",), donate_argnums=(0,)
)
_COPY_PAGES = jax.jit(
    lambda caches, src, dst: jax.tree.map(
        lambda leaf: leaf.at[:, dst].set(leaf[:, src]), caches
    ),
    donate_argnums=(0,),
)


def dense_kv_bytes(cfg: ArchConfig, rt: Runtime, total: int) -> int:
    """Dense per-request cache footprint for a ``total``-token horizon: each
    layer holds its full ``cache_len`` extent regardless of request length
    (window-truncated local layers, rough recurrent-state share)."""
    from repro.models.stack import layer_specs

    itemsize = jnp.dtype(rt.dtype).itemsize
    specs = layer_specs(cfg, seq_len=total, long_variant=rt.long_variant)
    tokens = sum(s.cache_len for s in specs if s.kind in ("attn", "local"))
    per_token = cfg.n_kv_heads * cfg.head_dim * 2 * itemsize
    rec = sum(
        1 for s in specs if s.kind not in ("attn", "local")
    ) * cfg.d_model * 4 * itemsize
    return tokens * per_token + rec


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Params,
        rt: Optional[Runtime] = None,
        engine: EngineConfig = EngineConfig(),
        paged: Optional[bool] = None,
        draft_params: Optional[Params] = None,
        draft_cfg: Optional[ArchConfig] = None,
    ):
        from repro.kernels.paged_attention import quant

        self.cfg = cfg
        self.params = params
        self.ecfg = engine
        rt = rt if rt is not None else Runtime()
        self.rt = rt.replace(
            use_paged_kernel=engine.use_kernel or rt.use_paged_kernel,
            kv_dtype=quant.normalize_kv_dtype(engine.kv_dtype or rt.kv_dtype),
        )
        if paged is None:
            paged = paged_supported(cfg)
        if paged and not paged_supported(cfg):
            raise ValueError(
                f"{cfg.name}: family {cfg.family!r} has non-KV decode state; "
                "use paged=False (dense fallback)"
            )
        self.paged = paged
        self._policy = SamplingPolicy(
            temperature=engine.temperature, vocab=cfg.vocab_size,
            seed=engine.seed,
        )
        if engine.spec_tokens:
            if not paged:
                raise ValueError(
                    "speculative decoding needs the paged engine (the "
                    "verify pass is the paged-prefill path); spec_tokens=0 "
                    "for dense-fallback families"
                )
            if engine.temperature != 0.0:
                raise ValueError(
                    "speculative decoding is greedy-acceptance only: "
                    "spec_tokens>0 requires temperature=0.0"
                )
            if engine.spec_drafter not in spec_mod.DRAFTER_KINDS:
                raise ValueError(
                    f"spec_drafter={engine.spec_drafter!r} not in "
                    f"{spec_mod.DRAFTER_KINDS}"
                )
            if engine.spec_drafter == "model":
                if engine.prefix_cache or engine.prefill_chunk:
                    raise ValueError(
                        "spec_drafter='model' needs the legacy whole-prompt "
                        "admission prefill (the drafter's KV is built "
                        "there); prefix_cache/prefill_chunk admit without "
                        "recompute, leaving the drafter blind — use the "
                        "ngram drafter with those modes"
                    )
                if cfg.frontend is not None:
                    raise ValueError(
                        "spec_drafter='model': modality-prefix embeddings "
                        "are sized for the target d_model and cannot feed "
                        "the reduced drafter — use the ngram drafter"
                    )
                if draft_params is None:
                    raise ValueError(
                        "spec_drafter='model' needs draft_params (init the "
                        "paired config from spec.paired_drafter_cfg(cfg))"
                    )
        if self.rt.mesh is not None and params is not None:
            # Megatron layout over the mesh's `model` axis; leaves whose
            # dims don't divide stay replicated (specs.py guards), so any
            # reduced config lowers on any mesh.
            from repro.sharding.specs import param_specs, with_sharding

            shardings = with_sharding(
                self.rt.mesh,
                param_specs(cfg, jax.eval_shape(lambda: params), self.rt.mesh),
            )
            self.params = jax.tree.map(jax.device_put, params, shardings)
        self.pool = PagePool(engine.num_pages, engine.page_size)
        self.scheduler = Scheduler(
            policy=engine.policy, max_queue=engine.max_queue
        )
        self._next_rid = 0
        self._admit_count = 0
        self._slots: List[Optional[_Slot]] = [None] * engine.max_slots
        self._outputs: Dict[int, List[int]] = {}
        self._callbacks: Dict[int, Any] = {}   # rid -> on_token(rid, toks, done)
        self._emitted: Dict[int, int] = {}     # tokens DELIVERED per rid
        self._completed_run: set = set()
        self._run_t0: Optional[float] = None   # open measurement window
        self.stats: Dict[str, Any] = {
            "ttft_s": {}, "prefill_s": {}, "kv_bytes": {},
        }
        if self.paged:
            self._dev = init_paged_state(
                cfg, engine.max_slots, self.rt,
                num_pages=engine.num_pages, page_size=engine.page_size,
                max_len=engine.max_len,
            )
            B = engine.max_slots
            extras = dict(
                remaining=jnp.zeros((B,), jnp.int32),
                tok=jnp.zeros((B,), jnp.int32),
                keys=jnp.stack([jax.random.PRNGKey(0)] * B),
                steps=jnp.zeros((B,), jnp.int32),
            )
            if self.rt.mesh is not None:
                # commit replicated so host-side .at[].set updates stay on
                # the mesh's device set (mixing with sharded pool args in
                # one jit otherwise errors with incompatible devices)
                from jax.sharding import NamedSharding, PartitionSpec

                extras = {
                    k: jax.device_put(
                        v,
                        NamedSharding(
                            self.rt.mesh, PartitionSpec(*([None] * v.ndim))
                        ),
                    )
                    for k, v in extras.items()
                }
            self._dev.update(extras)
            self.stats["kv_pool_bytes_per_device"] = self.kv_pool_bytes_per_device()
            # key only on what the trace depends on (seed/policy are
            # host-side; self.rt already folds in use_kernel)
            ckey = (
                cfg, self.rt, engine.max_slots, engine.page_size,
                engine.num_pages, engine.max_len, engine.inner_steps,
                engine.temperature, engine.spec_tokens,
            )  # seed/policy/prefill_bucket are host-side only
            if ckey not in _CHUNK_CACHE:
                _CHUNK_CACHE[ckey] = self._build_chunk_fn()
            self._chunk_fn = _CHUNK_CACHE[ckey]
            self._scatter_fn = _SCATTER
            if engine.prefix_cache or engine.prefill_chunk:
                # one fused fn handles any chunk width (jit specializes on
                # the p_tokens shape; the engine only ever passes one)
                fkey = ckey + ("fused",)
                if fkey not in _CHUNK_CACHE:
                    _CHUNK_CACHE[fkey] = (
                        self._build_fused_fn(), self._build_prefill_fn()
                    )
                self._fused_fn, self._prefill_fn = _CHUNK_CACHE[fkey]
            if engine.spec_tokens:
                vkey = ckey + ("verify",)
                if vkey not in _CHUNK_CACHE:
                    _CHUNK_CACHE[vkey] = self._build_verify_fn()
                self._verify_fn = _CHUNK_CACHE[vkey]
                if engine.spec_drafter == "model":
                    # drafter caches share the TARGET's block tables and
                    # page geometry (same page ids index its own smaller
                    # per-layer pools), so pool accounting is done once;
                    # only the caches leafset and the host-side lengths /
                    # catch-up trackers are drafter-private. The drafter
                    # never shards: it is reduced() — tiny — and its pools
                    # must not entangle the mesh donation of the target's.
                    self._draft_cfg = (
                        draft_cfg if draft_cfg is not None
                        else spec_mod.paired_drafter_cfg(cfg)
                    )
                    self._draft_params = draft_params
                    self._draft_rt = self.rt.replace(mesh=None)
                    self._draft_dev = {
                        "caches": init_paged_state(
                            self._draft_cfg, B, self._draft_rt,
                            num_pages=engine.num_pages,
                            page_size=engine.page_size,
                            max_len=engine.max_len,
                        )["caches"]
                    }
                    self._draft_len = np.zeros(B, np.int64)
                    self._spec_catchup = np.full(B, -1, np.int64)
                    dkey = ckey + ("draft", self._draft_cfg)
                    if dkey not in _CHUNK_CACHE:
                        _CHUNK_CACHE[dkey] = self._build_draft_fn()
                    self._draft_fn = _CHUNK_CACHE[dkey]
        self.prefix: Optional[PrefixCache] = (
            PrefixCache(self.pool)
            if self.paged and engine.prefix_cache else None
        )
        if (
            self.paged and cfg.ffn_kind == "moe"
            and (engine.prefill_bucket or engine.prefix_cache
                 or engine.prefill_chunk)
        ):
            warnings.warn(
                f"{cfg.name}: MoE routing counts pad/chunk tokens in its "
                "expert capacity, so bucketed or chunked prefill is not "
                "guaranteed token-exact vs an exact-shape run (attention "
                "itself is exact for MHA/GQA/MQA; see EngineConfig). "
                "Identical engine configs remain deterministic.",
                stacklevel=2,
            )

    # ------------------------------------------------------------- public
    def submit(
        self,
        tokens: np.ndarray,
        max_new: int,
        frontend_embeds: Optional[np.ndarray] = None,
        qos: str = "interactive",
        on_token=None,
    ) -> int:
        """Enqueue a request; returns its rid. ``qos`` picks the admission
        tier (``interactive`` | ``batch``); ``on_token(rid, tokens, done)``
        is called with each newly delivered token batch (``done`` is None
        while streaming, then ``"complete"`` / ``"cancelled"`` exactly
        once). Raises before ANY engine state changes — capacity rejects
        (ValueError) and backpressure (scheduler.QueueFull) leave the rid
        counter, queues, and callbacks untouched, which is what makes
        replica routing transactional one level up."""
        assert max_new >= 1
        req = Request(
            rid=self._next_rid,
            tokens=np.asarray(tokens, np.int32).reshape(-1),
            max_new=int(max_new),
            frontend_embeds=frontend_embeds,
            qos=qos,
            t_submit=time.perf_counter(),
        )
        if self.paged:
            total = self._prompt_total(req) + req.max_new - 1
            if total > self.ecfg.max_len:
                raise ValueError(
                    f"request needs {total} tokens > max_len={self.ecfg.max_len}"
                )
            if self.pool.pages_for(total) > self.pool.budget:
                raise ValueError(
                    f"request needs {self.pool.pages_for(total)} pages "
                    f"> pool budget {self.pool.budget}"
                )
        self.scheduler.add(req)            # may raise QueueFull
        self._next_rid += 1
        if on_token is not None:
            self._callbacks[req.rid] = on_token
        return req.rid

    @property
    def busy(self) -> bool:
        """Work pending: queued requests or seated decode slots."""
        if self.paged:
            return bool(
                len(self.scheduler)
                or any(s is not None for s in self._slots)
            )
        return bool(len(self.scheduler))

    def run_begin(self) -> None:
        """Open a measurement window: per-run counters snapshot here so a
        second submit()/run() cycle on the same engine reports its own
        throughput/latency, not a mix with the previous run's."""
        self._completed_run = set()
        self._run_t0 = time.perf_counter()
        self._run_admit0 = self._admit_count
        self._run_evict0 = self.stats.get("evictions", 0)
        self._run_discard0 = self.stats.get("discarded_tokens", 0)
        self._run_decode_tokens = 0
        self._run_spec0 = tuple(
            self.stats.get(key, 0) for key in (
                "spec_verify_calls", "spec_drafted_tokens",
                "spec_accepted_tokens",
            )
        )

    def step(self) -> Dict[str, Any]:
        """ONE engine tick: admit -> top-up -> one jitted chunk -> collect
        -> retire. Reentrant and externally drivable (the async front-end
        calls this between its own events); an idle engine returns
        ``busy=False`` without touching the device. Opens a measurement
        window implicitly if none is open."""
        if not self.busy:
            return {"busy": False, "finished": [], "decoded": 0}
        if self._run_t0 is None:
            self.run_begin()
        if not self.paged:
            return self._step_dense()
        self._admit_free_slots()
        self._topup_or_evict()
        emits, remaining = self._device_step()
        self._run_decode_tokens += self._collect(emits)
        finished = self._retire(remaining)
        return {
            "busy": True,
            "finished": finished,
            "decoded": int((emits >= 0).sum()),
        }

    def run_finalize(self) -> Dict[int, np.ndarray]:
        """Close the measurement window: compute per-run throughput/latency
        stats and return {rid: generated tokens} for the requests completed
        SINCE run_begin(). No-op ({}) when no window is open."""
        if self._run_t0 is None:
            return {}
        wall = time.perf_counter() - self._run_t0
        # throughput counts DELIVERED tokens; work thrown away by
        # preemption is reported separately, not inflated into tokens/s
        discarded = (
            self.stats.get("discarded_tokens", 0) - self._run_discard0
        )
        n_prefill = (self._admit_count - self._run_admit0) - (
            self.stats.get("evictions", 0) - self._run_evict0
        )
        decode_tokens = self._run_decode_tokens
        self.stats["decode_tokens"] = decode_tokens - discarded
        self.stats["wall_s"] = wall
        self.stats["tokens_per_s"] = (
            decode_tokens - discarded + n_prefill
        ) / max(wall, 1e-9)
        self.stats["pool_high_water_pages"] = self.pool.high_water
        if self.ecfg.spec_tokens:
            # run-window acceptance stats: rate = accepted drafts over
            # drafted; accepted-per-verify adds the bonus token (mean
            # committed run length per verify call, 1..k+1)
            v0, d0, a0 = self._run_spec0
            verifies = self.stats.get("spec_verify_calls", 0) - v0
            drafted = self.stats.get("spec_drafted_tokens", 0) - d0
            acc = self.stats.get("spec_accepted_tokens", 0) - a0
            self.stats["spec_accept_rate"] = acc / max(drafted, 1)
            self.stats["spec_accepted_per_verify"] = (
                (acc + verifies) / max(verifies, 1)
            )
        if self.prefix is not None:
            self.stats.update(self.prefix.stats())
        run_rids = sorted(self._completed_run)
        # per-run latency aggregates: benches must read these (or index
        # ttft_s by this run's rids) — never average the accumulated
        # per-rid dict across runs
        ttfts = [
            self.stats["ttft_s"][r] for r in run_rids
            if r in self.stats["ttft_s"]
        ]
        self.stats["run_completed"] = len(run_rids)
        self.stats["run_mean_ttft_s"] = (
            float(np.mean(ttfts)) if ttfts else 0.0
        )
        self._run_t0 = None
        return {
            rid: np.asarray(self._outputs[rid], np.int32)
            for rid in run_rids
        }

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue; returns {rid: generated tokens (max_new,)} for
        the requests completed by THIS call (the engine is reusable —
        submit more and run again; ``self.stats`` throughput fields are
        likewise per-run, while the per-rid dicts accumulate).
        """
        self.run_begin()
        while self.busy:
            self.step()
        return self.run_finalize()

    def cancel(self, rid: int) -> bool:
        """Cancel a request: remove it from the wait queue, or stop it
        mid-flight and free its pool pages immediately. Already-delivered
        tokens stand; the request's callback (if any) gets a final
        ``done="cancelled"`` event. Returns False for unknown or already-
        finished rids. Safe to call from inside an ``on_token`` callback
        (early stop) — the current tick's collect/retire skip the vacated
        slot. Dense fallback: only still-queued requests can be cancelled
        (a launched dense batch is one compiled call)."""
        req = self.scheduler.cancel(rid)
        if req is not None:
            self.stats["cancelled"] = self.stats.get("cancelled", 0) + 1
            self._deliver_done(rid, "cancelled")
            return True
        if not self.paged:
            return False
        for slot_id, slot in enumerate(self._slots):
            if slot is None or slot.rid != rid:
                continue
            if (
                self.prefix is not None and self._use_chunked(slot.req)
                and slot.phase == "decode"
            ):
                # the full prompt was computed — its pages are as cacheable
                # as a retired request's (generated-token pages stay out)
                n_full = slot.req.prompt_len // self.ecfg.page_size
                self.prefix.insert(
                    slot.req.tokens,
                    self.pool.seq_pages(slot.sid)[:n_full],
                )
            self.pool.free(slot.sid)
            d = self._dev
            d["tables"] = d["tables"].at[slot_id].set(0)
            d["lengths"] = d["lengths"].at[slot_id].set(0)
            d["remaining"] = d["remaining"].at[slot_id].set(0)
            self._slots[slot_id] = None
            self.stats["cancelled"] = self.stats.get("cancelled", 0) + 1
            self._deliver_done(rid, "cancelled")
            return True
        return False

    # -------------------------------------------------- token delivery
    def _deliver(self, rid: int) -> None:
        """Push not-yet-delivered output tokens to the request's callback.
        ``_emitted`` tracks the delivered count independently of
        ``_outputs`` (which eviction clears), so a preempted request whose
        deterministic recompute regenerates the same prefix never
        re-delivers tokens the consumer already saw."""
        toks = self._outputs.get(rid)
        if toks is None:
            return
        sent = self._emitted.get(rid, 0)
        if len(toks) <= sent:
            return
        fresh = toks[sent:]
        self._emitted[rid] = len(toks)
        cb = self._callbacks.get(rid)
        if cb is not None:
            cb(rid, list(fresh), None)

    def _deliver_done(self, rid: int, reason: str) -> None:
        self._deliver(rid)
        cb = self._callbacks.pop(rid, None)
        self._emitted.pop(rid, None)
        if cb is not None:
            cb(rid, [], reason)

    # ----------------------------------------------------------- internals
    def _prompt_total(self, req: Request) -> int:
        extra = (
            self.cfg.frontend_tokens if self.cfg.frontend == "vision" else 0
        )
        return req.prompt_len + extra

    def _kv_bytes_per_page(self) -> int:
        from repro.serve.pool import kv_page_bytes

        return kv_page_bytes(
            self.ecfg.page_size, self.cfg.n_kv_heads, self.cfg.head_dim,
            self.cfg.n_layers, self.rt.kv_dtype,
            jnp.dtype(self.rt.dtype).itemsize,
        )

    def kv_pool_bytes_per_device(self) -> int:
        """Bytes of KV pool resident on ONE device — the capacity bound the
        tensor-parallel sharding relaxes. Computed from the actual shard
        shapes, so it reflects replication fallbacks exactly."""
        if not self.paged:
            return 0
        total = 0
        for leaf in jax.tree.leaves(self._dev["caches"]):
            shape = (
                leaf.sharding.shard_shape(leaf.shape)
                if hasattr(leaf, "sharding") else leaf.shape
            )
            total += int(np.prod(shape)) * leaf.dtype.itemsize
        return total

    @property
    def outstanding_tokens(self) -> int:
        """Token-weighted load: queued work plus pool-resident sequences."""
        queued = self.scheduler.queued_tokens(self._prompt_total)
        return queued + self.pool.tokens_in_use

    def _decode_scan_fn(self):
        """Traceable body shared by the decode-only and fused chunk fns."""
        cfg, rt, ecfg = self.cfg, self.rt, self.ecfg
        policy = self._policy

        def chunk(params, caches, tables, lengths, remaining, tok, keys, steps):
            state0 = {"caches": caches, "tables": tables, "lengths": lengths}

            def step(carry, _):
                state, rem, tok, steps = carry
                active = rem > 0
                logits, state = decode_step_paged(
                    cfg, params, state, tok, rt, max_len=ecfg.max_len,
                    active=active,
                )
                nxt = policy.sample_slots(logits, keys, steps)
                emit = jnp.where(active, nxt, -1)
                tok = jnp.where(active, nxt, tok)
                act = active.astype(jnp.int32)
                return (state, rem - act, tok, steps + act), emit

            (state, remaining, tok, steps), emits = jax.lax.scan(
                step, (state0, remaining, tok, steps), None,
                length=ecfg.inner_steps,
            )
            return (
                state["caches"], state["lengths"], remaining, tok, steps, emits
            )

        return chunk

    def _build_chunk_fn(self):
        # caches update in place
        return jax.jit(self._decode_scan_fn(), donate_argnums=(1,))

    def _build_fused_fn(self):
        """One jitted program = one prefill chunk for the admitting request
        + ``inner_steps`` decode steps for every active slot (the prefilling
        slot sits inactive in the decode scan: remaining == 0). Disjoint
        page sets keep the two halves independent — the chunk writes only
        its own sequence's pages, decode slots read only theirs, and shared
        (adopted) prefix pages are read-only for both."""
        cfg, rt, ecfg = self.cfg, self.rt, self.ecfg
        decode_scan = self._decode_scan_fn()

        def fused(
            params, caches, tables, lengths, remaining, tok, keys, steps,
            p_tokens, p_slot, p_start, p_len,
        ):
            row = jax.lax.dynamic_index_in_dim(
                tables, p_slot, 0, keepdims=False
            )
            pf_logits, caches = prefill_chunk_paged(
                cfg, params, caches, row, p_tokens, p_start, p_len, rt,
                ecfg.max_len,
            )
            caches, lengths, remaining, tok, steps, emits = decode_scan(
                params, caches, tables, lengths, remaining, tok, keys, steps
            )
            return caches, lengths, remaining, tok, steps, emits, pf_logits

        return jax.jit(fused, donate_argnums=(1,))

    def _build_prefill_fn(self):
        """Prefill-chunk-only step, taken when NO slot is decode-active (an
        idle engine admitting a request should not pay the decode scan —
        this is what makes warm-cache TTFT a real reduction rather than a
        decode-tax trade)."""
        cfg, rt, ecfg = self.cfg, self.rt, self.ecfg

        def pf_only(params, caches, tables, p_tokens, p_slot, p_start, p_len):
            row = jax.lax.dynamic_index_in_dim(
                tables, p_slot, 0, keepdims=False
            )
            pf_logits, caches = prefill_chunk_paged(
                cfg, params, caches, row, p_tokens, p_start, p_len, rt,
                ecfg.max_len,
            )
            return caches, pf_logits

        return jax.jit(pf_only, donate_argnums=(1,))

    def _build_verify_fn(self):
        """Batched (k+1)-row verify + greedy acceptance, one jitted program.

        ``tokens`` (B, k+1) carries each slot's pending token + its k drafts
        at positions ``lengths .. lengths + k``; ``q_len`` 0 disables a
        slot. Returns (caches, g (B, k+1), a (B,)): ``g`` is the target's
        own argmax of every verify row — row j's argmax is what a
        sequential greedy decode would emit AFTER token j of the run — and
        ``a`` is the count of leading drafts that equal that argmax chain
        (``d_j == g_{j-1}``), i.e. the accepted prefix. Committing
        ``c = a + 1`` tokens ``g_0 .. g_{c-1}`` is therefore exactly the
        target's greedy stream regardless of draft quality (a junk or
        zero-padded draft is accepted only when it IS the argmax)."""
        cfg, rt, ecfg = self.cfg, self.rt, self.ecfg
        policy = self._policy

        def verify(params, caches, tables, lengths, tokens, q_len):
            state = {"caches": caches, "tables": tables, "lengths": lengths}
            logits, state = verify_step_paged(
                cfg, params, state, tokens, q_len, rt, ecfg.max_len
            )
            g = policy.greedy_tokens(logits)                   # (B, k+1)
            ok = (tokens[:, 1:] == g[:, :-1]).astype(jnp.int32)
            a = jnp.cumprod(ok, axis=1).sum(axis=1)            # (B,)
            return state["caches"], g, a

        return jax.jit(verify, donate_argnums=(1,))

    def _build_draft_fn(self):
        """Greedy k-step decode scan of the paired drafter model.

        The drafter trails the target by at most one token (full-accept
        catch-up), so each tick force-feeds 1–2 known tokens — ``forced``
        (B, 2) = [catch-up-or-pending, pending-or-junk], ``n_forced`` in
        {1, 2} — then free-runs on its own argmax emits. Per slot the scan
        takes ``(n_forced - 1) + k`` active steps (masked per-step), so the
        k proposals for slot b are ``emits[n_forced_b - 1 : n_forced_b - 1
        + k, b]``. ``d_len`` is the drafter's own cached length (host-
        tracked); tables are the TARGET's block tables — same page ids,
        drafter-private pools."""
        dcfg, rt, ecfg = self._draft_cfg, self._draft_rt, self.ecfg
        k = ecfg.spec_tokens
        policy = SamplingPolicy(temperature=0.0, vocab=dcfg.vocab_size)

        def draft(params, caches, tables, d_len, forced, n_forced, active):
            state0 = {"caches": caches, "tables": tables, "lengths": d_len}
            n_steps = (n_forced - 1) + k

            def step(carry, i):
                state, tok = carry
                inp = jnp.where(
                    i == 0, forced[:, 0],
                    jnp.where(i < n_forced, forced[:, 1], tok),
                )
                act = active & (i < n_steps)
                logits, state = decode_step_paged(
                    dcfg, params, state, inp, rt, max_len=ecfg.max_len,
                    active=act,
                )
                emit = policy.greedy_tokens(logits)
                return (state, emit), emit

            (state, _), emits = jax.lax.scan(
                step, (state0, forced[:, 0]), jnp.arange(k + 1)
            )
            return state["caches"], emits                      # (k+1, B)

        return jax.jit(draft, donate_argnums=(1,))

    @property
    def _lookahead(self) -> int:
        """Tokens one tick may write per slot: ``inner_steps`` for the
        decode scan, ``spec_tokens + 1`` verify rows for a speculative
        tick (writes land at ``lengths .. lengths + k`` even when fewer
        commit)."""
        ecfg = self.ecfg
        if ecfg.spec_tokens:
            return max(ecfg.inner_steps, ecfg.spec_tokens + 1)
        return ecfg.inner_steps

    def _admission_headroom(self) -> int:
        """Extra free pages required beyond a newcomer's reservation under
        the optimistic policy: one chunk's worth of page-boundary crossings
        for every request that would then be running. Without this, a
        preempted request re-admits into a pool that cannot sustain the next
        chunk and is immediately evicted again (prefill thrash)."""
        if self.ecfg.policy != "optimistic":
            return 0
        n_active = sum(1 for s in self._slots if s is not None)
        if n_active == 0:
            return 0
        per_slot = self._lookahead // self.ecfg.page_size + 1
        return (n_active + 1) * per_slot

    def _use_chunked(self, req: Request) -> bool:
        """Paged-prefill (prefix-adopting, chunk-interleaved) admission path.
        Modality-prefix requests keep the legacy whole-prompt prefill: the
        radix key is token IDs and cannot see image content, and the chunk
        embedder has no frontend concat."""
        return (
            self.paged
            and bool(self.ecfg.prefix_cache or self.ecfg.prefill_chunk)
            and self.cfg.frontend is None
        )

    def _admit_free_slots(self) -> None:
        for slot_id, slot in enumerate(self._slots):
            if slot is not None:
                continue
            req = self.scheduler.peek()
            if req is None:
                break
            cached, sid = 0, None
            if self.prefix is not None and self._use_chunked(req):
                cached, pages = self.prefix.match(
                    req.tokens, max_tokens=req.prompt_len - 1
                )
                if cached:
                    # adopt FIRST: the refcount pins the matched pages so
                    # the pre-eviction below can never free them
                    sid = self.pool.adopt(pages, cached)
            headroom = self._admission_headroom()
            cached_pages = cached // self.ecfg.page_size
            if self.prefix is not None:
                reserve = self.scheduler.reserve_tokens(
                    req, self._prompt_total(req)
                )
                shortfall = (
                    self.pool.pages_for(reserve) - cached_pages + headroom
                    - self.pool.free_pages
                )
                if shortfall > 0:
                    self.prefix.evict_until(shortfall)
            popped = self.scheduler.pop_admissible(
                self.pool, self._prompt_total, headroom_pages=headroom,
                cached_pages_of=(
                    (lambda r: cached_pages) if sid is not None else None
                ),
            )
            if popped is None:
                if sid is not None:
                    self.pool.free(sid)
                break
            assert popped is req
            self._admit(slot_id, popped, cached=cached, sid=sid)
        if not any(self._slots) and len(self.scheduler):
            raise RuntimeError(
                "deadlock: empty engine cannot admit the head request "
                "(pool too small for it — submit() should have rejected it)"
            )

    def _admit(
        self, slot_id: int, req: Request, cached: int = 0,
        sid: Optional[int] = None,
    ) -> None:
        ecfg, cfg = self.ecfg, self.cfg
        prompt_total = self._prompt_total(req)
        reserve = self.scheduler.reserve_tokens(req, prompt_total)
        self.stats["prompt_tokens"] = (
            self.stats.get("prompt_tokens", 0) + prompt_total
        )
        if self._use_chunked(req):
            if self.prefix is not None:
                self.prefix.note_lookup(cached)   # once per admission
            if sid is None:
                sid = self.pool.alloc(reserve)
            else:
                self.pool.ensure(sid, reserve)   # adopted prefix + fresh tail
            table_row = jnp.asarray(
                self.pool.table(sid, self._dev["tables"].shape[1]), jnp.int32
            )
            self._apply_copies()
            d = self._dev
            d["tables"] = d["tables"].at[slot_id].set(table_row)
            d["lengths"] = d["lengths"].at[slot_id].set(cached)
            d["remaining"] = d["remaining"].at[slot_id].set(0)  # not decoding yet
            self._slots[slot_id] = _Slot(
                req.rid, sid, req, self._admit_count, phase="prefill",
                pf_next=cached, t_admit=time.perf_counter(),
            )
            self._admit_count += 1
            return
        assert sid is None and cached == 0
        sid = self.pool.alloc(reserve)
        t0 = time.perf_counter()
        tokens = req.tokens
        bucket = ecfg.prefill_bucket
        if bucket:
            pad = -len(tokens) % bucket
            tokens = np.pad(tokens, (0, pad))
        batch = {"tokens": jnp.asarray(tokens[None])}
        if req.frontend_embeds is not None:
            batch["frontend_embeds"] = jnp.asarray(req.frontend_embeds[None])
        batch = dense_mod.place_batch(batch, self.rt)
        prefill_fn = dense_mod.compiled_prefill(
            cfg, self.rt, dense_mod.batch_shape_key(batch),
            prompt_total + (len(tokens) - req.prompt_len),
            dynamic_gather=bool(bucket), full_cache=True,
        )
        if bucket:
            logits, pstate = prefill_fn(
                self.params, batch, jnp.int32(prompt_total - 1)
            )
        else:
            logits, pstate = prefill_fn(self.params, batch)
        rkey = self._policy.request_key(req.rid)
        tok0 = self._policy.sample(logits, jax.random.fold_in(rkey, 0))
        tok0.block_until_ready()
        now = time.perf_counter()
        # TTFT from SUBMIT time — queue wait included — on every path; a
        # readmitted-after-preemption request whose first token was already
        # delivered keeps its original (honest) TTFT, see _evict
        self.stats["ttft_s"].setdefault(req.rid, now - req.t_submit)
        self.stats["prefill_s"][req.rid] = now - t0

        table_row = jnp.asarray(
            self.pool.table(sid, self._dev["tables"].shape[1]), jnp.int32
        )
        self._apply_copies()
        self._dev["caches"] = self._scatter_fn(
            self._dev["caches"], pstate["caches"], table_row,
            page_size=ecfg.page_size,
        )
        if ecfg.spec_tokens and ecfg.spec_drafter == "model":
            # bring the drafter level with the target: prefill the same
            # (padded) prompt through the paired config and scatter its KV
            # through the SAME table row into the drafter's pools; from
            # here on the drafter advances inside the spec tick's scan
            d_prefill = dense_mod.compiled_prefill(
                self._draft_cfg, self._draft_rt,
                dense_mod.batch_shape_key(batch),
                prompt_total + (len(tokens) - req.prompt_len),
                dynamic_gather=bool(bucket), full_cache=True,
            )
            if bucket:
                _, dstate = d_prefill(
                    self._draft_params, batch, jnp.int32(prompt_total - 1)
                )
            else:
                _, dstate = d_prefill(self._draft_params, batch)
            self._draft_dev["caches"] = self._scatter_fn(
                self._draft_dev["caches"], dstate["caches"], table_row,
                page_size=ecfg.page_size,
            )
            self._draft_len[slot_id] = prompt_total
            self._spec_catchup[slot_id] = -1
        d = self._dev
        d["tables"] = d["tables"].at[slot_id].set(table_row)
        d["lengths"] = d["lengths"].at[slot_id].set(prompt_total)
        d["remaining"] = d["remaining"].at[slot_id].set(req.max_new - 1)
        d["tok"] = d["tok"].at[slot_id].set(tok0[0])
        d["keys"] = d["keys"].at[slot_id].set(rkey)
        d["steps"] = d["steps"].at[slot_id].set(1)  # fold 0 used at prefill
        self._slots[slot_id] = _Slot(req.rid, sid, req, self._admit_count)
        self._admit_count += 1
        self._outputs[req.rid] = [int(tok0[0])]
        self._deliver(req.rid)   # last: a callback may cancel() this slot

    def _topup_or_evict(self) -> None:
        """Ensure every active slot's pages cover this chunk's writes;
        evict the youngest on exhaustion. Under the reserve policy the whole
        horizon was reserved at admission, so skip the per-chunk host sync
        and table rewrites entirely."""
        if self.ecfg.policy == "reserve":
            return
        lengths = np.asarray(self._dev["lengths"])
        remaining = np.asarray(self._dev["remaining"])
        for slot_id, slot in enumerate(self._slots):
            if slot is None:
                continue
            need = int(lengths[slot_id]) + min(
                int(remaining[slot_id]), self._lookahead
            )
            while self._slots[slot_id] is not None:
                try:
                    self.pool.ensure(slot.sid, need)
                    break
                except PoolExhausted:
                    # idle prefix-cache pages go first: evicting cached-but-
                    # unused KV is free, preempting a request discards work
                    if self.prefix is not None:
                        short = (
                            self.pool.pages_for(need)
                            - len(self.pool.seq_pages(slot.sid))
                            - self.pool.free_pages
                        )
                        if self.prefix.evict_until(max(short, 1)) > 0:
                            continue
                    # preempt the youngest active request — possibly the
                    # very slot that needs pages (FIFO fairness: the oldest
                    # admissions keep their pages and finish first)
                    actives = [
                        (s_id, s) for s_id, s in enumerate(self._slots)
                        if s is not None
                    ]
                    if len(actives) == 1:
                        raise   # a lone request frees nothing by preemption
                    self._evict(*max(actives, key=lambda kv: kv[1].order))
            if self._slots[slot_id] is None:
                continue                       # this slot was the victim
            self._apply_copies()
            row = jnp.asarray(
                self.pool.table(slot.sid, self._dev["tables"].shape[1]),
                jnp.int32,
            )
            self._dev["tables"] = self._dev["tables"].at[slot_id].set(row)

    def _evict(self, slot_id: int, slot: _Slot) -> None:
        """Recompute-style preemption: free pages, requeue from scratch."""
        self.pool.free(slot.sid)
        # all but the prefill-sampled token were counted as decode output
        # (a slot still mid-prefill has no output entry yet)
        if slot.rid in self._outputs:
            self.stats["discarded_tokens"] = (
                self.stats.get("discarded_tokens", 0)
                + len(self._outputs[slot.rid]) - 1
            )
            del self._outputs[slot.rid]
        # If the first token was never DELIVERED (no callback consumed it),
        # the recompute after readmission is what the user waits for: drop
        # the stale TTFT so it is re-measured — still from req.t_submit, so
        # preempt-then-readmit TTFT spans the original submit. If it WAS
        # delivered, the consumer already saw it at the recorded time and
        # that TTFT stays (the recompute is invisible to them:
        # _emitted suppresses re-delivery of the regenerated prefix).
        if self._emitted.get(slot.rid, 0) == 0:
            self.stats["ttft_s"].pop(slot.rid, None)
            self.stats["prefill_s"].pop(slot.rid, None)
        self.scheduler.requeue_front(slot.req)
        d = self._dev
        d["tables"] = d["tables"].at[slot_id].set(0)
        d["lengths"] = d["lengths"].at[slot_id].set(0)
        d["remaining"] = d["remaining"].at[slot_id].set(0)
        self._slots[slot_id] = None
        self.stats["evictions"] = self.stats.get("evictions", 0) + 1

    def _apply_copies(self) -> None:
        copies = self.pool.drain_copies()
        if not copies:
            return
        src = jnp.asarray([c[0] for c in copies], jnp.int32)
        dst = jnp.asarray([c[1] for c in copies], jnp.int32)
        self._dev["caches"] = _COPY_PAGES(self._dev["caches"], src, dst)

    def _run_chunk(self):
        d = self._dev
        caches, lengths, remaining, tok, steps, emits = self._chunk_fn(
            self.params, d["caches"], d["tables"], d["lengths"],
            d["remaining"], d["tok"], d["keys"], d["steps"],
        )
        d.update(
            caches=caches, lengths=lengths, remaining=remaining, tok=tok,
            steps=steps,
        )
        return np.asarray(emits), np.asarray(remaining)

    def _spec_step(self):
        """One speculative tick over all decode slots: draft k tokens per
        slot (host-side ngram lookup, or the paired drafter model's scan),
        verify every slot's [pending, drafts] run in ONE batched (k+1)-row
        pass through the paged-prefill write-then-attend path, and commit
        the accepted prefix plus the verify pass's own bonus token —
        1..k+1 tokens per slot per tick, never fewer than an ordinary
        decode step's 1 (row 0 alone IS that decode step). Every committed
        token is the target's own argmax, so the stream is token-identical
        to non-speculative greedy decode (and to running alone). Rejected
        rows need no device rollback (see ``models.lm.verify_step_paged``);
        under the optimistic policy the pool reservation is rewound
        host-side via ``PagePool.truncate``."""
        ecfg = self.ecfg
        k = ecfg.spec_tokens
        B = ecfg.max_slots
        d = self._dev
        lengths = np.array(d["lengths"])
        remaining = np.array(d["remaining"])
        tok = np.array(d["tok"])
        steps = np.array(d["steps"])
        active = np.array(
            [s is not None for s in self._slots]
        ) & (remaining > 0)
        n_act = int(active.sum())
        if n_act == 0:
            return np.full((0, B), -1, np.int32), remaining
        drafts = np.zeros((B, k), np.int32)
        if ecfg.spec_drafter == "model":
            drafts = self._run_draft(active, tok)
        else:
            for slot_id, slot in enumerate(self._slots):
                if not active[slot_id]:
                    continue
                ctx = np.concatenate([
                    slot.req.tokens,
                    np.asarray(self._outputs[slot.rid], np.int32),
                ])
                prop = spec_mod.ngram_draft(ctx, k, ecfg.spec_ngram)
                drafts[slot_id, : len(prop)] = prop
        # row 0 = the pending token (sampled last tick, not yet cached);
        # rows 1..k = drafts. Zero-padded/junk drafts are harmless: they
        # commit only if they equal the argmax — the correct token anyway.
        tokens = np.concatenate([tok[:, None], drafts], axis=1)
        q_len = np.where(active, k + 1, 0).astype(np.int32)
        caches, g, a = self._verify_fn(
            self.params, d["caches"], d["tables"], d["lengths"],
            self._place(jnp.asarray(tokens, jnp.int32)),
            self._place(jnp.asarray(q_len)),
        )
        d["caches"] = caches
        g, a = np.asarray(g), np.asarray(a)
        stats = self.stats
        stats["spec_verify_calls"] = stats.get("spec_verify_calls", 0) + n_act
        stats["spec_drafted_tokens"] = (
            stats.get("spec_drafted_tokens", 0) + n_act * k
        )
        emits = np.full((k + 1, B), -1, np.int32)
        accepted = 0
        for slot_id, slot in enumerate(self._slots):
            if not active[slot_id]:
                continue
            c = int(min(a[slot_id] + 1, remaining[slot_id]))
            emits[:c, slot_id] = g[slot_id, :c]
            lengths[slot_id] += c
            remaining[slot_id] -= c
            tok[slot_id] = g[slot_id, c - 1]   # new pending token
            steps[slot_id] += c
            accepted += c - 1
            if ecfg.policy == "optimistic":
                # pool-accounting half of rejection rollback: hand back
                # reservation the rejected tail no longer needs (refcount/
                # COW-safe inside the pool; table rows are rewritten from
                # the pool every tick under this policy)
                self.pool.truncate(slot.sid, int(lengths[slot_id]))
            if ecfg.spec_drafter == "model":
                if c == k + 1:
                    # full accept: the drafter never cached g_{k-1} (it
                    # only consumed through its own (k-1)th emit) — force-
                    # feed it next tick, then the new pending token
                    self._spec_catchup[slot_id] = int(g[slot_id, k - 1])
                    self._draft_len[slot_id] = int(lengths[slot_id]) - 1
                else:
                    # partial accept: the drafter's accepted prefix is
                    # already cached correctly; rewind its length past the
                    # rejected tail (stale KV beyond it is masked by
                    # length and overwritten by the next scan)
                    self._spec_catchup[slot_id] = -1
                    self._draft_len[slot_id] = int(lengths[slot_id])
        stats["spec_accepted_tokens"] = (
            stats.get("spec_accepted_tokens", 0) + accepted
        )
        d["lengths"] = self._place(jnp.asarray(lengths))
        d["remaining"] = self._place(jnp.asarray(remaining))
        d["tok"] = self._place(jnp.asarray(tok))
        d["steps"] = self._place(jnp.asarray(steps))
        return emits, remaining

    def _run_draft(self, active: np.ndarray, tok: np.ndarray) -> np.ndarray:
        """Advance the paired drafter model k greedy steps per active slot
        and return its proposals (B, k). The drafter trails the target by
        at most one cached token, so 1–2 known tokens are force-fed first
        (see ``_build_draft_fn``); its block tables ARE the target's."""
        ecfg = self.ecfg
        k = ecfg.spec_tokens
        B = ecfg.max_slots
        catch = self._spec_catchup
        n_forced = np.where(active & (catch >= 0), 2, 1).astype(np.int32)
        forced = np.zeros((B, 2), np.int32)
        forced[:, 0] = np.where(catch >= 0, catch, tok)
        forced[:, 1] = tok
        caches, emits = self._draft_fn(
            self._draft_params, self._draft_dev["caches"],
            self._dev["tables"], jnp.asarray(self._draft_len, jnp.int32),
            jnp.asarray(forced), jnp.asarray(n_forced), jnp.asarray(active),
        )
        self._draft_dev["caches"] = caches
        emits = np.asarray(emits)               # (k+1, B)
        drafts = np.zeros((B, k), np.int32)
        for b in range(B):
            if active[b]:
                o = int(n_forced[b]) - 1
                drafts[b] = emits[o : o + k, b]
        return drafts

    def _place(self, arr: jax.Array) -> jax.Array:
        """Commit a fresh host array replicated onto the mesh (the fused fn
        mixes it with sharded pools; see ``dense.place_batch``)."""
        if self.rt.mesh is None:
            return arr
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.device_put(
            arr,
            NamedSharding(self.rt.mesh, PartitionSpec(*([None] * arr.ndim))),
        )

    def _device_step(self):
        """One device dispatch: a decode-only chunk, or — when a slot is mid-
        prefill — the fused program (its next prompt chunk + the same decode
        chunk). Oldest-admitted prefilling slot goes first (FIFO fairness:
        one chunk per step keeps decode stalls bounded by one chunk)."""
        pf = [
            (i, s) for i, s in enumerate(self._slots)
            if s is not None and s.phase == "prefill"
        ]
        if not pf:
            # speculative ticks need every seated slot in the decode phase
            # (the verify batch spans all slots); while any prompt is still
            # chunking, the ordinary fused tick below keeps decode moving —
            # both paths emit the same greedy stream, so mixing them tick
            # by tick never changes tokens
            if self.ecfg.spec_tokens:
                return self._spec_step()
            return self._run_chunk()
        slot_id, slot = min(pf, key=lambda kv: kv[1].order)
        req = slot.req
        T = self.ecfg.chunk_tokens
        start = slot.pf_next
        n = min(T, req.prompt_len - start)
        chunk = np.zeros(T, np.int32)
        chunk[:n] = req.tokens[start : start + n]
        d = self._dev
        operands = (
            self._place(jnp.asarray(chunk)), self._place(jnp.int32(slot_id)),
            self._place(jnp.int32(start)), self._place(jnp.int32(n)),
        )
        # decode-phase slots still seated always have remaining > 0 (retire
        # runs right after every step), so phase alone decides
        decoding = any(
            s is not None and s.phase == "decode" for s in self._slots
        )
        if decoding:
            (
                caches, lengths, remaining, tok, steps, emits, pf_logits
            ) = self._fused_fn(
                self.params, d["caches"], d["tables"], d["lengths"],
                d["remaining"], d["tok"], d["keys"], d["steps"], *operands,
            )
            d.update(
                caches=caches, lengths=lengths, remaining=remaining, tok=tok,
                steps=steps,
            )
        else:
            caches, pf_logits = self._prefill_fn(
                self.params, d["caches"], d["tables"], *operands
            )
            d["caches"] = caches
            emits = np.full(
                (0, self.ecfg.max_slots), -1, np.int32
            )                                     # nothing decoded this step
        self.stats["prefill_chunks"] = (
            self.stats.get("prefill_chunks", 0) + 1
        )
        slot.pf_next = start + n
        if slot.pf_next >= req.prompt_len:
            self._finish_prefill(slot_id, slot, pf_logits)
        else:
            d["lengths"] = d["lengths"].at[slot_id].set(slot.pf_next)
        return np.asarray(emits), np.asarray(d["remaining"])

    def _finish_prefill(self, slot_id: int, slot: _Slot, pf_logits) -> None:
        """Last chunk done: sample the first token (the request's TTFT) and
        flip the slot into the decode phase — same key/step discipline as
        the legacy at-admission prefill, so the sample stream (and with it
        the batched == alone guarantee) is untouched."""
        ecfg, cfg = self.ecfg, self.cfg
        req = slot.req
        rkey = self._policy.request_key(req.rid)
        tok0 = self._policy.sample(
            pf_logits[None], jax.random.fold_in(rkey, 0)
        )
        tok0.block_until_ready()
        now = time.perf_counter()
        # TTFT from SUBMIT (queue wait + admission + every chunk), matching
        # the legacy path's origin; prefill compute time kept separately
        self.stats["ttft_s"].setdefault(req.rid, now - req.t_submit)
        self.stats["prefill_s"][req.rid] = now - slot.t_admit
        d = self._dev
        d["lengths"] = d["lengths"].at[slot_id].set(req.prompt_len)
        d["remaining"] = d["remaining"].at[slot_id].set(req.max_new - 1)
        d["tok"] = d["tok"].at[slot_id].set(tok0[0])
        d["keys"] = d["keys"].at[slot_id].set(rkey)
        d["steps"] = d["steps"].at[slot_id].set(1)  # fold 0 used just above
        slot.phase = "decode"
        self._outputs[req.rid] = [int(tok0[0])]
        self._deliver(req.rid)   # last: a callback may cancel() this slot

    def _collect(self, emits: np.ndarray) -> int:
        n = 0
        for slot_id, slot in enumerate(self._slots):
            if slot is None or slot.rid not in self._outputs:
                continue        # mid-prefill: no first token sampled yet
            toks = emits[:, slot_id]
            toks = toks[toks >= 0]
            self._outputs[slot.rid].extend(int(t) for t in toks)
            n += len(toks)
            self._deliver(slot.rid)  # may cancel() this slot (early stop)
        return n

    def _retire(self, remaining: np.ndarray) -> List[int]:
        finished: List[int] = []
        for slot_id, slot in enumerate(self._slots):
            if slot is None or slot.phase == "prefill" or remaining[slot_id] > 0:
                continue
            self.stats["kv_bytes"][slot.rid] = (
                len(self.pool.seq_pages(slot.sid)) * self._kv_bytes_per_page()
            )
            self._completed_run.add(slot.rid)
            if self.prefix is not None and self._use_chunked(slot.req):
                # full prompt pages go back into the radix tree (pages
                # holding generated tokens are not keyed by the prompt and
                # stay out); freeing the sequence below leaves only the
                # cache's retains on them
                n_full = slot.req.prompt_len // self.ecfg.page_size
                self.prefix.insert(
                    slot.req.tokens,
                    self.pool.seq_pages(slot.sid)[:n_full],
                )
            self.pool.free(slot.sid)
            d = self._dev
            d["tables"] = d["tables"].at[slot_id].set(0)
            d["lengths"] = d["lengths"].at[slot_id].set(0)
            self._slots[slot_id] = None
            finished.append(slot.rid)
            self._deliver_done(slot.rid, "complete")
        return finished

    # ------------------------------------------------------ dense fallback
    def _step_dense(self) -> Dict[str, Any]:
        """One dense-fallback tick: pop the head request plus every queued
        request sharing its (prompt_len, max_new) shape — they run as one
        cached compiled generate (contiguous (B, total) caches) — then
        deliver whole outputs. Matching requests beyond ``max_slots`` wait
        for the next tick."""
        cfg, ecfg = self.cfg, self.ecfg
        part = self.scheduler.pop_batch(ecfg.max_slots)
        plen, max_new = part[0].prompt_len, part[0].max_new
        batch = {
            "tokens": jnp.asarray(
                np.stack([r.tokens for r in part]), jnp.int32
            )
        }
        if part[0].frontend_embeds is not None:
            batch["frontend_embeds"] = jnp.asarray(
                np.stack([r.frontend_embeds for r in part])
            )
        t_call = time.perf_counter()
        tokens, _, pf_s = dense_mod.generate_dense(
            cfg, self.params, batch, self.rt, max_new,
            temperature=ecfg.temperature, seed=ecfg.seed,
        )
        tokens.block_until_ready()
        total = plen + max_new + (
            cfg.frontend_tokens if cfg.frontend == "vision" else 0
        )
        kv = self._dense_kv_bytes(total)
        finished: List[int] = []
        for b, r in enumerate(part):
            self._outputs[r.rid] = [int(t) for t in np.asarray(tokens[b])]
            # generate_dense's returned latency is its prefill(+first
            # sample) wall time from call start; TTFT spans from submit,
            # so queue wait before this tick is included
            self.stats["ttft_s"][r.rid] = t_call + pf_s - r.t_submit
            self.stats["prefill_s"][r.rid] = pf_s
            self.stats["kv_bytes"][r.rid] = kv
            self._run_decode_tokens += max_new - 1
            self._admit_count += 1
            self._completed_run.add(r.rid)
            finished.append(r.rid)
            self._deliver(r.rid)
            self._deliver_done(r.rid, "complete")
        return {
            "busy": True, "finished": finished,
            "decoded": len(part) * max_new,
        }

    def _dense_kv_bytes(self, total: int) -> int:
        return dense_kv_bytes(self.cfg, self.rt, total)


class ReplicatedServeEngine:
    """Data-parallel serving over a ``(data, model)`` mesh.

    The mesh factorizes into ``data`` replicas of a model-only submesh
    (``launch.mesh.replica_submeshes``); each replica carries a full
    (TP-sharded) parameter copy and its own KV pool + scheduler, and
    ``ReplicaRouter`` assigns every request to the least-loaded replica.
    Because an engine's per-request output is identical to running the
    request alone, routing can never change tokens — only latency — so the
    replicated engine inherits the batched==alone determinism guarantee.

    ``step()`` ticks every replica round-robin from this host (``run()``
    just loops it); on real hardware each replica's chunk executes on its
    own device slice, so a multi-controller launcher can drive them
    concurrently without any change to the engines themselves. Routing is
    transactional: if the chosen engine's ``submit`` raises (capacity
    reject, QueueFull backpressure), the routing decision is rolled back
    and no global rid is consumed.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params: Params,
        rt: Optional[Runtime] = None,
        engine: EngineConfig = EngineConfig(),
        mesh=None,
        paged: Optional[bool] = None,
        draft_params: Optional[Params] = None,
        draft_cfg: Optional[ArchConfig] = None,
    ):
        from repro.launch.mesh import replica_submeshes
        from repro.serve.scheduler import ReplicaRouter

        rt = rt if rt is not None else Runtime()
        meshes = replica_submeshes(mesh) if mesh is not None else [rt.mesh]
        self.engines = [
            ServeEngine(
                cfg, params, rt.replace(mesh=m), engine, paged=paged,
                draft_params=draft_params, draft_cfg=draft_cfg,
            )
            for m in meshes
        ]
        self.router = ReplicaRouter(len(self.engines))
        self._where: Dict[int, Tuple[int, int]] = {}  # rid -> (replica, local)
        self._next_rid = 0
        self.stats: Dict[str, Any] = {}

    def submit(
        self,
        tokens: np.ndarray,
        max_new: int,
        frontend_embeds: Optional[np.ndarray] = None,
        qos: str = "interactive",
        on_token=None,
    ) -> int:
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        idx = self.router.route(
            [e.outstanding_tokens for e in self.engines]
        )
        rid = self._next_rid
        cb = None
        if on_token is not None:
            # translate the replica-local rid back to the global one
            def cb(_local, toks, done, _g=rid, _f=on_token):
                _f(_g, toks, done)
        try:
            local = self.engines[idx].submit(
                tokens, max_new, frontend_embeds=frontend_embeds,
                qos=qos, on_token=cb,
            )
        except Exception:
            # transactional routing: a rejected request (capacity ValueError,
            # QueueFull backpressure) must not inflate the chosen replica's
            # routed count or consume a global rid
            self.router.unroute(idx)
            raise
        self._next_rid += 1
        self._where[rid] = (idx, local)
        return rid

    def cancel(self, rid: int) -> bool:
        if rid not in self._where:
            return False
        idx, local = self._where[rid]
        return self.engines[idx].cancel(local)

    @property
    def busy(self) -> bool:
        return any(e.busy for e in self.engines)

    def run_begin(self) -> None:
        # open windows on every replica, queued or not: an empty window
        # still resets that engine's per-run stats, so the aggregates in
        # run_finalize never mix a previous run's numbers into this one
        for e in self.engines:
            e.run_begin()

    def step(self) -> Dict[str, Any]:
        """One tick across all replicas (round-robin from this host; on
        real hardware each replica's chunk runs on its own device slice)."""
        if self.busy and any(e._run_t0 is None for e in self.engines):
            self.run_begin()
        finished: List[int] = []
        busy = False
        l2g = {
            (idx, local): rid
            for rid, (idx, local) in self._where.items()
        }
        for idx, e in enumerate(self.engines):
            rep = e.step()
            busy = busy or rep["busy"]
            finished.extend(
                l2g[(idx, lr)] for lr in rep["finished"]
                if (idx, lr) in l2g
            )
        return {"busy": busy, "finished": finished}

    def run_finalize(self) -> Dict[int, np.ndarray]:
        outs: List[Dict[int, np.ndarray]] = [
            eng.run_finalize() for eng in self.engines
        ]
        merged = {
            rid: outs[idx][local]
            for rid, (idx, local) in self._where.items()
            if local in outs[idx]
        }
        # replicas are stepped round-robin from this host, so their
        # measurement windows overlap: the elapsed window is the max
        # per-replica wall, and aggregate throughput is total delivered
        # work over it (a concurrent multi-controller drive would approach
        # the per-replica sum)
        wall = max(
            [e.stats.get("wall_s", 0.0) for e in self.engines] + [0.0]
        )
        delivered = sum(
            e.stats.get("decode_tokens", 0) for e in self.engines
        )
        completed = sum(
            e.stats.get("run_completed", 0) for e in self.engines
        )
        self.stats = {
            "replica_requests": list(self.router.routed),
            "tokens_per_s": delivered / max(wall, 1e-9),
            "wall_s": wall,
            "decode_tokens": delivered,
            "run_completed": completed,
            "run_mean_ttft_s": (
                sum(
                    e.stats.get("run_mean_ttft_s", 0.0)
                    * e.stats.get("run_completed", 0)
                    for e in self.engines
                ) / max(completed, 1)
            ),
            "evictions": sum(
                e.stats.get("evictions", 0) for e in self.engines
            ),
            "cancelled": sum(
                e.stats.get("cancelled", 0) for e in self.engines
            ),
            "ttft_s": {
                rid: self.engines[idx].stats["ttft_s"][local]
                for rid, (idx, local) in self._where.items()
                if local in self.engines[idx].stats["ttft_s"]
            },
            "prefill_s": {
                rid: self.engines[idx].stats["prefill_s"][local]
                for rid, (idx, local) in self._where.items()
                if local in self.engines[idx].stats["prefill_s"]
            },
            "kv_pool_bytes_per_device": max(
                e.stats.get("kv_pool_bytes_per_device", 0)
                for e in self.engines
            ),
        }
        # prefix-cache counters sum across replicas (each replica keys its
        # own radix tree over its own pool — a cross-replica hit requires
        # the router to have sent the matching request to the same replica)
        for key in (
            "prompt_tokens", "prefix_lookups", "prefix_hits",
            "prefix_cached_tokens", "prefill_chunks",
            "spec_verify_calls", "spec_drafted_tokens",
            "spec_accepted_tokens",
        ):
            vals = [e.stats[key] for e in self.engines if key in e.stats]
            if vals:
                self.stats[key] = sum(vals)
        if "spec_verify_calls" in self.stats:
            # fleet-level acceptance from the summed run-window counters
            # (each engine's own rates cover only its replica)
            acc = self.stats.get("spec_accepted_tokens", 0)
            self.stats["spec_accept_rate"] = acc / max(
                self.stats.get("spec_drafted_tokens", 0), 1
            )
            self.stats["spec_accepted_per_verify"] = (
                acc + self.stats["spec_verify_calls"]
            ) / max(self.stats["spec_verify_calls"], 1)
        return merged

    def run(self) -> Dict[int, np.ndarray]:
        self.run_begin()
        while self.busy:
            self.step()
        return self.run_finalize()
