"""Dense (contiguous-cache) serving path with cached compiled functions.

This is the ``paged=False`` fallback kept for architecture families the
paged engine cannot serve (recurrent SSM/RG-LRU states, enc-dec cross
caches) and for equal-length batch generation. Two fixes over the historical
``train/serve.py`` loop live here:

* prefill / decode are compiled ONCE per (cfg, rt, shapes, horizon) key and
  cached module-wide — the old code rebuilt and re-``jit``-ed its lambdas on
  every ``generate`` call, retracing every time (``CACHE_BUILDS`` is exposed
  so tests can assert a second same-shape call doesn't rebuild, alongside
  ``jax.jit``'s own ``_cache_size`` miss counters);
* the per-token Python decode loop is a single jitted ``lax.scan``, so a
  whole generation is one device program instead of ``max_new`` dispatches.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import Runtime, decode_step, prefill
from repro.models.layers import Params
from repro.serve.sampling import SamplingPolicy

# (cfg, rt, batch_key, total, max_new, temperature) -> (prefill_fn, loop_fn)
_CACHE: Dict[Any, Any] = {}
CACHE_BUILDS = 0  # incremented on every fresh compile-cache entry (tests)


def batch_shape_key(batch: Dict[str, jax.Array]) -> Tuple:
    return tuple(
        (k, tuple(v.shape), str(v.dtype)) for k, v in sorted(batch.items())
    )


def _dense_rt(rt: Runtime) -> Runtime:
    """Dense caches are contiguous native-dtype rings — the pool dtype never
    enters the trace — so strip ``kv_dtype`` before keying/tracing: engines
    that differ only in pool dtype share one compiled prefill/loop."""
    return rt.replace(kv_dtype="") if rt.kv_dtype else rt


def place_batch(batch: Dict[str, jax.Array], rt: Runtime) -> Dict[str, jax.Array]:
    """Commit batch arrays replicated onto ``rt.mesh`` (no-op without one).

    The sharded serving path mixes mesh-committed params/pools with host-
    built prompt arrays in one jit call; committing the batch replicated
    makes that mix explicit instead of relying on uncommitted-input
    auto-placement, and keeps the compiled signature stable across calls.
    """
    if rt.mesh is None:
        return batch
    from jax.sharding import NamedSharding, PartitionSpec

    return {
        k: jax.device_put(
            jnp.asarray(v),
            NamedSharding(rt.mesh, PartitionSpec(*([None] * jnp.ndim(v)))),
        )
        for k, v in batch.items()
    }


def compiled_prefill(
    cfg: ArchConfig, rt: Runtime, batch_key: Tuple, total: int,
    dynamic_gather: bool = False, full_cache: bool = False,
):
    """Cached jitted prefill sized for a ``total``-token decode horizon.

    With ``dynamic_gather`` the returned fn takes an extra traced position
    ``(params, batch, gather_pos)`` — the engine's bucketed-prefill path pads
    prompts up to a shape bucket (bounding distinct compiles) and gathers
    the first-token logits at the true prompt end. ``full_cache`` collects
    un-windowed caches (see ``repro.models.lm.prefill``) for the page pool.
    """
    rt = _dense_rt(rt)
    key = ("prefill", cfg, rt, batch_key, total, dynamic_gather, full_cache)
    if key not in _CACHE:
        global CACHE_BUILDS
        CACHE_BUILDS += 1
        if dynamic_gather:
            fn = jax.jit(
                lambda p, b, pos: prefill(
                    cfg, p, b, rt, max_len=total, gather_pos=pos,
                    full_cache=full_cache,
                )
            )
        else:
            fn = jax.jit(
                lambda p, b: prefill(
                    cfg, p, b, rt, max_len=total, full_cache=full_cache
                )
            )
        _CACHE[key] = fn
    return _CACHE[key]


def compiled_decode_loop(
    cfg: ArchConfig, rt: Runtime, batch_key: Tuple, total: int,
    max_new: int, temperature: float,
):
    """Cached jitted scan over ``max_new - 1`` decode steps.

    Returns ``loop(params, state, tok0, key) -> (tokens (B, max_new), state)``
    where ``tok0`` is the prefill-sampled first token and step ``i`` samples
    with ``fold_in(key, i)``.
    """
    rt = _dense_rt(rt)
    key = ("loop", cfg, rt, batch_key, total, max_new, temperature)
    if key not in _CACHE:
        global CACHE_BUILDS
        CACHE_BUILDS += 1

        policy = SamplingPolicy(temperature=temperature, vocab=cfg.vocab_size)

        def loop(params, state, tok0, rng):
            def step(carry, i):
                st, tok = carry
                logits, st = decode_step(cfg, params, st, tok, rt, seq_len=total)
                tok = policy.sample(logits, jax.random.fold_in(rng, i))
                return (st, tok), tok

            (state_f, _), toks = jax.lax.scan(
                step, (state, tok0), jnp.arange(max_new - 1)
            )
            tokens = jnp.concatenate([tok0[:, None], toks.T], axis=1)
            return tokens, state_f

        _CACHE[key] = jax.jit(loop)
    return _CACHE[key]


def generate_dense(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jax.Array],
    rt: Runtime,
    max_new_tokens: int,
    temperature: float = 0.0,
    seed: int = 0,
) -> Tuple[jax.Array, Dict[str, Any], float]:
    """Batched dense generation. Returns (tokens (B, max_new), state, ttft_s).

    ``ttft_s`` is wall time to the first sampled token (prefill + sample;
    includes compile on a cold cache — callers wanting steady-state numbers
    should warm the cache first).
    """
    import time

    assert max_new_tokens >= 1
    prompt_len = batch["tokens"].shape[1]
    total = prompt_len + max_new_tokens
    if cfg.frontend == "vision":
        total += cfg.frontend_tokens

    bkey = batch_shape_key(batch)
    batch = place_batch(batch, rt)
    prefill_fn = compiled_prefill(cfg, rt, bkey, total)
    loop_fn = compiled_decode_loop(
        cfg, rt, bkey, total, max_new_tokens, temperature
    )

    policy = SamplingPolicy(temperature=temperature, vocab=cfg.vocab_size)
    rng = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    logits, state = prefill_fn(params, batch)
    tok0 = policy.sample(logits, rng)
    tok0.block_until_ready()
    ttft = time.perf_counter() - t0

    tokens, state = loop_fn(params, state, tok0, rng)
    return tokens, state, ttft
