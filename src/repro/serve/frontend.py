"""Async serving front-end over the engine's reentrant tick loop.

The engine stays synchronous — one host thread, one jitted chunk at a time.
What this layer adds is *intake*: an asyncio driver that calls
``engine.step()`` and yields to the event loop between ticks, so client
coroutines submit, stream, and cancel between (never during) device chunks.
Per-token engine callbacks push into per-request ``asyncio.Queue``s, giving
each client an async iterator over its own token stream; admission
backpressure (``scheduler.QueueFull``) becomes an awaitable retry inside
``submit()``.

Because the engine's per-request token stream is independent of batch
composition (batched == alone), ANY interleaving of submissions with ticks
yields identical per-request outputs — the async layer can only change
latency, never tokens. The scheduling/accounting side is made reproducible
separately: :func:`replay_trace` keys a traffic trace's arrivals (and
cancels) to engine *ticks* — virtual time — so admission order, preemption
and cancel counts, and SLO goodput (first token within ``slo_ticks`` of
arrival) are machine-independent exact quantities, while wall-clock
TTFT/TPOT are measured per request for the timed percentile rows. That
split is what lets ``benchmarks/serve_trace_bench.py`` gate goodput/cancel
rows EXACTLY in CI and latency rows within tolerance.

TTFT here (and in ``engine.stats["ttft_s"]``) is submit -> first token,
queue wait included; prefill compute time is the separate ``prefill_s``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Any, AsyncIterator, Dict, List, Optional

import numpy as np

from repro.serve.scheduler import QueueFull

__all__ = [
    "AsyncFrontend", "StreamHandle", "TraceRequest",
    "poisson_trace", "bursty_trace", "replay_trace", "goodput",
]


@dataclasses.dataclass
class StreamHandle:
    """One submitted request as seen by a client coroutine."""
    rid: int
    qos: str
    max_new: int
    t_submit: float                       # wall clock at engine accept
    submit_tick: int                      # front-end tick at engine accept
    queue: "asyncio.Queue" = dataclasses.field(
        default_factory=asyncio.Queue
    )
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: Optional[str] = None            # None | "complete" | "cancelled"
    cancel_after: int = 0                 # early-stop after N tokens (0=off)
    arrive_tick: int = 0                  # trace arrival (virtual time)
    first_tick: Optional[int] = None
    done_tick: Optional[int] = None
    t_first: Optional[float] = None
    t_last: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        return (
            None if self.t_first is None else self.t_first - self.t_submit
        )

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean time per output token AFTER the first (decode cadence)."""
        if self.t_first is None or len(self.tokens) < 2:
            return None
        return (self.t_last - self.t_first) / (len(self.tokens) - 1)

    def record(self, deferred_ticks: int = 0) -> Dict[str, Any]:
        return {
            "rid": self.rid,
            "qos": self.qos,
            "status": self.done or "open",
            "n_tokens": len(self.tokens),
            "max_new": self.max_new,
            "arrive_tick": self.arrive_tick,
            "submit_tick": self.submit_tick,
            "first_tick": self.first_tick,
            "done_tick": self.done_tick,
            "deferred_ticks": deferred_ticks,
            "ttft_s": self.ttft_s,
            "tpot_s": self.tpot_s,
            "tokens": np.asarray(self.tokens, np.int32),
        }


class AsyncFrontend:
    """asyncio submission/streaming layer for ``ServeEngine`` /
    ``ReplicatedServeEngine``.

    Used either with a background drive task (``async with AsyncFrontend
    (engine) as fe: ...`` — ticks run whenever the engine has work, client
    coroutines interleave between them) or externally paced (construct
    without entering, call :meth:`tick` yourself — what
    :func:`replay_trace` does to keep virtual time deterministic).
    """

    def __init__(self, engine):
        self.engine = engine
        self.ticks = 0                     # virtual time: one per step()
        self.handles: Dict[int, StreamHandle] = {}
        self._space = asyncio.Event()      # set after each tick (backpressure)
        self._wake = asyncio.Event()       # set on submit (idle drive wakes)
        self._task: Optional[asyncio.Task] = None
        self._closing = False

    # ------------------------------------------------------------ sync core
    def try_submit(
        self,
        tokens,
        max_new: int,
        *,
        qos: str = "interactive",
        frontend_embeds=None,
        cancel_after: int = 0,
    ) -> Optional[StreamHandle]:
        """Non-blocking submit: a StreamHandle, or None under backpressure
        (the tier queue is at ``EngineConfig.max_queue``)."""
        handle = StreamHandle(
            rid=-1, qos=qos, max_new=int(max_new), t_submit=0.0,
            submit_tick=self.ticks, cancel_after=cancel_after,
        )

        def on_token(rid, toks, done):
            self._on_event(handle, toks, done)

        try:
            rid = self.engine.submit(
                tokens, max_new, frontend_embeds=frontend_embeds,
                qos=qos, on_token=on_token,
            )
        except QueueFull:
            return None
        handle.rid = rid
        handle.t_submit = time.perf_counter()
        self.handles[rid] = handle
        self._wake.set()
        return handle

    def _on_event(self, handle: StreamHandle, toks, done) -> None:
        now = time.perf_counter()
        if toks:
            if handle.t_first is None:
                handle.t_first = now
                handle.first_tick = self.ticks
            handle.t_last = now
            handle.tokens.extend(int(t) for t in toks)
            handle.queue.put_nowait(("tokens", list(toks)))
            if (
                handle.cancel_after
                and handle.done is None
                and len(handle.tokens) >= handle.cancel_after
            ):
                # early stop from inside the token callback: the engine
                # frees the slot's pages now and emits done="cancelled"
                self.engine.cancel(handle.rid)
                return
        if done is not None:
            handle.done = done
            handle.done_tick = self.ticks
            handle.t_done = now
            handle.queue.put_nowait(("done", done))

    def tick(self) -> Dict[str, Any]:
        """One engine tick; wakes any submitter awaiting backpressure.
        Ticks an idle engine too — virtual time advances while waiting for
        trace arrivals."""
        report = self.engine.step()
        self.ticks += 1
        self._space.set()
        return report

    def cancel(self, handle: StreamHandle) -> bool:
        return self.engine.cancel(handle.rid)

    # ----------------------------------------------------------- async API
    async def submit(
        self,
        tokens,
        max_new: int,
        *,
        qos: str = "interactive",
        frontend_embeds=None,
        cancel_after: int = 0,
    ) -> StreamHandle:
        """Submit, awaiting under admission backpressure until the tier
        queue has room (one retry per tick)."""
        while True:
            h = self.try_submit(
                tokens, max_new, qos=qos, frontend_embeds=frontend_embeds,
                cancel_after=cancel_after,
            )
            if h is not None:
                return h
            self._space.clear()
            await self._space.wait()

    async def stream(self, handle: StreamHandle) -> AsyncIterator[int]:
        """Async iterator over the request's tokens; ends when the request
        completes or is cancelled (already-delivered tokens stand)."""
        while True:
            kind, payload = await handle.queue.get()
            if kind == "tokens":
                for t in payload:
                    yield t
            else:
                return

    async def result(self, handle: StreamHandle) -> np.ndarray:
        """Drain the stream; the full generated sequence."""
        async for _ in self.stream(handle):
            pass
        return np.asarray(handle.tokens, np.int32)

    async def _drive(self) -> None:
        while not self._closing:
            if self.engine.busy:
                self.tick()
                # yield so client coroutines run between device chunks
                await asyncio.sleep(0)
            else:
                # drained: close the engine's measurement window (stats
                # land), then sleep until the next submit
                self.engine.run_finalize()
                self._wake.clear()
                await self._wake.wait()

    async def __aenter__(self) -> "AsyncFrontend":
        self._task = asyncio.create_task(self._drive())
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self) -> None:
        self._closing = True
        self._wake.set()
        if self._task is not None:
            try:
                await self._task
            finally:
                self._task = None


# --------------------------------------------------------------- traces
@dataclasses.dataclass
class TraceRequest:
    """One arrival in a traffic trace. Times are engine TICKS (virtual),
    which is what makes a replay's scheduling deterministic."""
    arrive_tick: int
    tokens: np.ndarray
    max_new: int
    qos: str = "interactive"
    cancel_after: int = 0        # client cancels after N streamed tokens


def _gen_common(
    rng: np.random.RandomState,
    n: int,
    arrive_ticks: List[int],
    *,
    vocab: int,
    prompt_range=(4, 24),
    new_range=(4, 12),
    qos_batch_frac: float = 0.0,
    shared_prefix: Optional[np.ndarray] = None,
    shared_frac: float = 0.0,
    cancel_frac: float = 0.0,
    cancel_after: int = 3,
) -> List[TraceRequest]:
    out: List[TraceRequest] = []
    for i in range(n):
        plen = int(rng.randint(prompt_range[0], prompt_range[1] + 1))
        toks = rng.randint(0, vocab, (plen,)).astype(np.int32)
        if shared_prefix is not None and rng.rand() < shared_frac:
            toks = np.concatenate(
                [np.asarray(shared_prefix, np.int32), toks]
            )
        out.append(TraceRequest(
            arrive_tick=arrive_ticks[i],
            tokens=toks,
            max_new=int(rng.randint(new_range[0], new_range[1] + 1)),
            qos="batch" if rng.rand() < qos_batch_frac else "interactive",
            cancel_after=(
                cancel_after if rng.rand() < cancel_frac else 0
            ),
        ))
    return out


def poisson_trace(
    rng: np.random.RandomState, n: int, *, rate: float, vocab: int, **kw
) -> List[TraceRequest]:
    """Poisson arrivals: exponential inter-arrival gaps with mean
    ``1/rate`` ticks, plus mixed prompt/output lengths and optional
    shared-prefix / QoS / cancel populations (see ``_gen_common``)."""
    t = 0.0
    ticks = []
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        ticks.append(int(t))
    return _gen_common(rng, n, ticks, vocab=vocab, **kw)


def bursty_trace(
    rng: np.random.RandomState, n: int, *, burst: int, gap: int,
    vocab: int, **kw,
) -> List[TraceRequest]:
    """Bursty arrivals: ``burst`` simultaneous requests every ``gap``
    ticks — the queue-depth / backpressure stressor."""
    ticks = [(i // burst) * gap for i in range(n)]
    return _gen_common(rng, n, ticks, vocab=vocab, **kw)


async def replay_trace(engine, trace: List[TraceRequest]):
    """Replay a trace against an engine in virtual (tick) time.

    Drives ticks itself (no background task) so the interleaving of
    arrivals, admissions, cancels, and chunks is a pure function of the
    trace — an idle engine's ticks still advance virtual time toward the
    next arrival, and an arrival hitting backpressure retries each tick
    (in arrival order) until admitted, with its deferral counted.

    Returns ``(records, frontend)`` where records[i] is
    ``trace[i]``'s :meth:`StreamHandle.record` (tick-exact fields for
    accounting, wall-clock ttft/tpot for timed rows).
    """
    fe = AsyncFrontend(engine)
    order = sorted(range(len(trace)), key=lambda i: (trace[i].arrive_tick, i))
    pending = list(order)
    handles: Dict[int, StreamHandle] = {}
    deferred: Dict[int, int] = {}
    while pending or engine.busy:
        while pending and trace[pending[0]].arrive_tick <= fe.ticks:
            i = pending[0]
            tr = trace[i]
            h = fe.try_submit(
                tr.tokens, tr.max_new, qos=tr.qos,
                cancel_after=tr.cancel_after,
            )
            if h is None:
                # backpressure: this arrival (and, FIFO, everything behind
                # it) waits a tick and retries
                deferred[i] = deferred.get(i, 0) + 1
                break
            h.arrive_tick = tr.arrive_tick
            handles[i] = h
            pending.pop(0)
        fe.tick()
        await asyncio.sleep(0)
    engine.run_finalize()
    records = [
        handles[i].record(deferred.get(i, 0)) for i in range(len(trace))
    ]
    return records, fe


def goodput(records: List[Dict[str, Any]], slo_ticks: int):
    """(met, total): requests that COMPLETED and got their first token
    within ``slo_ticks`` of trace arrival. Tick-based on both ends, so the
    count is machine-independent (an exact CI row, unlike wall-clock
    percentiles)."""
    met = sum(
        1 for r in records
        if r["status"] == "complete"
        and r["first_tick"] is not None
        and r["first_tick"] - r["arrive_tick"] <= slo_ticks
    )
    return met, len(records)
