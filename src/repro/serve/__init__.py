from repro.serve.engine import (  # noqa: F401
    EngineConfig,
    ReplicatedServeEngine,
    ServeEngine,
    paged_supported,
)
from repro.serve.pool import PagePool, PoolExhausted  # noqa: F401
from repro.serve.prefix import PrefixCache  # noqa: F401
from repro.serve.sampling import sample_slots, sample_token  # noqa: F401
from repro.serve.scheduler import ReplicaRouter, Request, Scheduler  # noqa: F401
