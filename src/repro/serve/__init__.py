from repro.serve.engine import (  # noqa: F401
    Capacity,
    EngineConfig,
    ReplicatedServeEngine,
    ServeEngine,
    paged_supported,
)
from repro.serve.frontend import (  # noqa: F401
    AsyncFrontend,
    StreamHandle,
    TraceRequest,
    bursty_trace,
    goodput,
    poisson_trace,
    replay_trace,
)
from repro.serve.pool import PagePool, PoolExhausted  # noqa: F401
from repro.serve.prefix import PrefixCache  # noqa: F401
from repro.serve.sampling import (  # noqa: F401
    SamplingPolicy,
    sample_slots,
    sample_token,
)
from repro.serve.spec import (  # noqa: F401
    ngram_draft,
    paired_drafter_cfg,
)
from repro.serve.scheduler import (  # noqa: F401
    QueueFull,
    ReplicaRouter,
    Request,
    Scheduler,
)
