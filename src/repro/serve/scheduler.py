"""Request admission/eviction policy for the continuous-batching engine.

FIFO with page-budget gating: the head request is admitted into a free
decode slot only when the pool can cover its reservation —

* ``reserve`` (default): the whole horizon (prompt + max_new - 1 tokens) is
  reserved at admission, so decode-time appends can never fail; admission
  throughput trades against pool utilization.
* ``optimistic``: only the prompt is reserved; the engine tops up pages
  chunk-by-chunk and, on exhaustion, preempts the youngest running request
  (pages freed, request requeued at the front — recompute-style preemption,
  the scheduling analogue of discard-and-rematerialize).

``ReplicaRouter`` is the layer above: data-parallel serving runs one engine
per ``data``-axis slice, and the router assigns each incoming request to the
replica with the least outstanding work (token-weighted, ties to the lowest
index so routing is deterministic).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.serve.pool import PagePool

POLICIES = ("reserve", "optimistic")


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                     # (S,) int32 prompt ids
    max_new: int
    frontend_embeds: Optional[np.ndarray] = None  # (P, d) modality prefix

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


class Scheduler:
    def __init__(self, policy: str = "reserve"):
        assert policy in POLICIES, policy
        self.policy = policy
        self._queue: Deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._queue)

    def add(self, req: Request) -> None:
        self._queue.append(req)

    def pop(self) -> Request:
        """Unconditional FIFO pop (dense fallback — no page gating)."""
        return self._queue.popleft()

    def peek(self) -> Optional[Request]:
        """Head request without popping (prefix-cache pre-eviction looks at
        the head's match before deciding how many cache pages to free)."""
        return self._queue[0] if self._queue else None

    def requeue_front(self, req: Request) -> None:
        """Preempted request goes back to the head (it was admitted first)."""
        self._queue.appendleft(req)

    def queued_tokens(self, prompt_total_of) -> int:
        """Token-weighted size of the wait queue (replica load accounting)."""
        return sum(prompt_total_of(r) + r.max_new for r in self._queue)

    def reserve_tokens(self, req: Request, prompt_total: int) -> int:
        """Tokens to reserve at admission. The final sampled token is never
        written back (nothing consumes it), hence ``max_new - 1``."""
        if self.policy == "reserve":
            return prompt_total + max(0, req.max_new - 1)
        return prompt_total

    def pop_admissible(
        self,
        pool: PagePool,
        prompt_total_of,
        headroom_pages: int = 0,
        cached_pages_of=None,
    ) -> Optional[Request]:
        """Head request if its reservation (+ the engine's chunk headroom,
        see ``ServeEngine._admission_headroom``) fits the pool's free pages.
        ``cached_pages_of`` discounts pages the request will adopt from the
        prefix cache instead of allocating (shared pages are already live).

        Strict FIFO: no head-of-line bypass, so admission order (and with it
        per-request output, under per-slot sample streams) is deterministic.
        """
        if not self._queue:
            return None
        req = self._queue[0]
        need = pool.pages_for(self.reserve_tokens(req, prompt_total_of(req)))
        if cached_pages_of is not None:
            need -= cached_pages_of(req)
        if need + headroom_pages > pool.free_pages:
            return None
        return self._queue.popleft()


class ReplicaRouter:
    """Least-loaded request routing across data-parallel engine replicas.

    The caller passes each replica's CURRENT load (token-weighted
    outstanding work — queued requests plus pool-resident sequences, see
    ``ServeEngine.outstanding_tokens``), so routing reflects what actually
    occupies KV pools and decode slots rather than a shadow counter that
    can drift from it. Ties go to the lowest index — deterministic.
    """

    def __init__(self, n_replicas: int):
        assert n_replicas >= 1
        self.routed: List[int] = [0] * n_replicas  # requests per replica

    def route(self, loads: List[int]) -> int:
        assert len(loads) == len(self.routed)
        idx = min(range(len(loads)), key=lambda i: (loads[i], i))
        self.routed[idx] += 1
        return idx
