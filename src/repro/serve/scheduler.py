"""Request admission/eviction policy for the continuous-batching engine.

Two QoS tiers with strict priority, FIFO within a tier, page-budget gating:
the head request is admitted into a free decode slot only when the pool can
cover its reservation —

* ``reserve`` (default): the whole horizon (prompt + max_new - 1 tokens) is
  reserved at admission, so decode-time appends can never fail; admission
  throughput trades against pool utilization.
* ``optimistic``: only the prompt is reserved; the engine tops up pages
  chunk-by-chunk and, on exhaustion, preempts the youngest running request
  (pages freed, request requeued at the front — recompute-style preemption,
  the scheduling analogue of discard-and-rematerialize).

QoS: every request carries a tier (``interactive`` or ``batch``). The
``interactive`` queue is always consulted first — a queued batch request is
admitted only when no interactive request is waiting. There is no
head-of-line bypass in either tier and no bypass *across* tiers (an
inadmissible interactive head blocks batch admission rather than letting
batch work claim the pages it is waiting for), so admission order is a
deterministic function of the submission sequence — which is what lets a
traced run reproduce per-request token streams exactly.

Backpressure: ``max_queue`` bounds each tier's wait queue; ``add`` raises
:class:`QueueFull` instead of growing past it. The async front-end
(``serve.frontend``) turns that exception into an awaitable retry, which is
how overload propagates to submitters instead of ballooning queue memory.

``ReplicaRouter`` is the layer above: data-parallel serving runs one engine
per ``data``-axis slice, and the router assigns each incoming request to the
replica with the least outstanding work (token-weighted, ties to the lowest
index so routing is deterministic). ``unroute`` rolls a routing decision
back when the chosen engine's ``submit`` raises — routing is transactional,
so a rejected request never inflates a replica's request count.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serve.pool import PagePool

POLICIES = ("reserve", "optimistic")
QOS_TIERS = ("interactive", "batch")


class QueueFull(RuntimeError):
    """Admission backpressure: the request's QoS tier queue is at its bound."""


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                     # (S,) int32 prompt ids
    max_new: int
    frontend_embeds: Optional[np.ndarray] = None  # (P, d) modality prefix
    qos: str = "interactive"               # QoS tier (see QOS_TIERS)
    # Wall clock at submit() — the one TTFT origin for every serving path
    # (queued wait, prefill, and preempt-then-readmit recompute all count).
    t_submit: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


class Scheduler:
    def __init__(self, policy: str = "reserve", max_queue: int = 0):
        assert policy in POLICIES, policy
        self.policy = policy
        self.max_queue = max_queue          # per-tier bound; 0 = unbounded
        self._queues: Dict[str, Deque[Request]] = {
            tier: deque() for tier in QOS_TIERS
        }

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def add(self, req: Request) -> None:
        assert req.qos in QOS_TIERS, req.qos
        q = self._queues[req.qos]
        if self.max_queue and len(q) >= self.max_queue:
            raise QueueFull(
                f"{req.qos} queue at max_queue={self.max_queue}; "
                f"retry after the engine drains"
            )
        q.append(req)

    def pop(self) -> Request:
        """Unconditional priority-FIFO pop (dense fallback — no page gating)."""
        for tier in QOS_TIERS:
            if self._queues[tier]:
                return self._queues[tier].popleft()
        raise IndexError("pop from empty scheduler")

    def peek(self) -> Optional[Request]:
        """The request ``pop_admissible`` would consider next: interactive
        head if any, else batch head (prefix-cache pre-eviction looks at the
        head's match before deciding how many cache pages to free)."""
        for tier in QOS_TIERS:
            if self._queues[tier]:
                return self._queues[tier][0]
        return None

    def requeue_front(self, req: Request) -> None:
        """Preempted request goes back to the head of ITS tier (it was
        admitted first within that tier; backpressure bounds don't apply —
        the request already held a queue slot once)."""
        self._queues[req.qos].appendleft(req)

    def cancel(self, rid: int) -> Optional[Request]:
        """Remove a still-queued request; returns it, or None if not queued."""
        for q in self._queues.values():
            for r in q:
                if r.rid == rid:
                    q.remove(r)
                    return r
        return None

    def queued_tokens(self, prompt_total_of) -> int:
        """Token-weighted size of the wait queues (replica load accounting)."""
        return sum(
            prompt_total_of(r) + r.max_new
            for q in self._queues.values() for r in q
        )

    def reserve_tokens(self, req: Request, prompt_total: int) -> int:
        """Tokens to reserve at admission. The final sampled token is never
        written back (nothing consumes it), hence ``max_new - 1``."""
        if self.policy == "reserve":
            return prompt_total + max(0, req.max_new - 1)
        return prompt_total

    def pop_admissible(
        self,
        pool: PagePool,
        prompt_total_of,
        headroom_pages: int = 0,
        cached_pages_of=None,
    ) -> Optional[Request]:
        """Head request (interactive tier first) if its reservation (+ the
        engine's chunk headroom, see ``ServeEngine._admission_headroom``)
        fits the pool's free pages. ``cached_pages_of`` discounts pages the
        request will adopt from the prefix cache instead of allocating
        (shared pages are already live).

        Strict priority + strict FIFO: no bypass within or across tiers, so
        admission order (and with it per-request output, under per-slot
        sample streams) is deterministic.
        """
        req = self.peek()
        if req is None:
            return None
        need = pool.pages_for(self.reserve_tokens(req, prompt_total_of(req)))
        if cached_pages_of is not None:
            need -= cached_pages_of(req)
        if need + headroom_pages > pool.free_pages:
            return None
        popped = self._queues[req.qos].popleft()
        assert popped is req
        return popped

    def pop_batch(self, max_n: int) -> List[Request]:
        """Dense-fallback grouping: the head request plus up to ``max_n - 1``
        queued requests sharing its (prompt_len, max_new) shape (they run as
        one compiled batch). Relative order of the remaining queue entries
        is preserved."""
        head = self.pop()
        part = [head]
        key = (head.prompt_len, head.max_new)
        for tier in QOS_TIERS:
            q = self._queues[tier]
            taken = []
            for r in q:
                if len(part) >= max_n:
                    break
                if (r.prompt_len, r.max_new) == key:
                    part.append(r)
                    taken.append(r)
            for r in taken:
                q.remove(r)
        return part


class ReplicaRouter:
    """Least-loaded request routing across data-parallel engine replicas.

    The caller passes each replica's CURRENT load (token-weighted
    outstanding work — queued requests plus pool-resident sequences, see
    ``ServeEngine.outstanding_tokens``), so routing reflects what actually
    occupies KV pools and decode slots rather than a shadow counter that
    can drift from it. Ties go to the lowest index — deterministic.
    """

    def __init__(self, n_replicas: int):
        assert n_replicas >= 1
        self.routed: List[int] = [0] * n_replicas  # requests per replica

    def route(self, loads: List[int]) -> int:
        assert len(loads) == len(self.routed)
        idx = min(range(len(loads)), key=lambda i: (loads[i], i))
        self.routed[idx] += 1
        return idx

    def unroute(self, idx: int) -> None:
        """Roll back a ``route`` whose downstream submit raised — the
        transactional half of replica routing (a rejected request must not
        count against the replica it never reached)."""
        assert self.routed[idx] > 0, idx
        self.routed[idx] -= 1
