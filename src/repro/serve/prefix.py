"""Radix-tree prefix cache: cross-request KV reuse over the page pool.

The survey's theme — share intermediate-tensor memory instead of recomputing
it — applied to serving: thousands of requests repeat the same system prompt
and few-shot prefix, so their prompt KV is the same tensor. This module
keeps retired prompts' KV pages alive in a radix tree keyed on token IDs;
a new request walks the tree, adopts the longest cached prefix as the head
of its own pool sequence (zero prefill FLOPs for the shared part — the
pages are the literal device pages an earlier request wrote), and inserts
its own prompt pages back into the tree when it completes.

Invariants
----------
* **Page-aligned edges.** Every node's token segment is a whole number of
  pages; matching walks page-by-page, so a partially matched edge still
  yields its matched pages and siblings always differ within their first
  page (child keys = the first page's token tuple are unique).
* **Nodes own only their segment's pages**, referenced via
  ``PagePool.retain`` (one cache ref per page; ``PagePool.check`` proves
  the arithmetic). The pages covering a node's *positions 0..start-1* are
  owned by its ancestors, so eviction must be leaf-first: a node is
  evictable only when its whole subtree is idle (every page refcount == 1,
  i.e. cache-only — no live request sequence and no descendant is pinned).
* **Adoption never COWs.** Matches are truncated to a page multiple (and to
  ``prompt_len - 1`` by the engine, so at least one token remains to
  produce first-token logits), so an adopted sequence's shared tail page is
  always full and ``PagePool.append`` allocates fresh pages instead of
  copy-on-writing shared ones.
* **LRU eviction.** ``evict_until`` frees least-recently-used idle leaves
  (cascading upward as parents become leaves) back to the pool; an adopted
  page has refcount >= 2 and can never be evicted out from under a running
  request.

Correctness of reuse: KV at position p is a pure function of tokens[0..p]
(causal attention, absolute rope positions) and the parameters, so a
token-exact prefix match means the cached pages hold bit-identical KV to
what prefill would recompute.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serve.pool import PagePool


@dataclasses.dataclass
class _Node:
    tokens: Tuple[int, ...]            # edge segment (len % page_size == 0)
    pages: List[int]                   # this segment's pages only
    parent: Optional["_Node"]
    children: Dict[Tuple[int, ...], "_Node"] = dataclasses.field(
        default_factory=dict
    )
    last_used: int = 0


class PrefixCache:
    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_size = pool.page_size
        self._root = _Node((), [], None)
        self._clock = 0
        self.lookups = 0
        self.hits = 0
        self.cached_tokens = 0      # tokens served from cache across lookups
        self.inserted_tokens = 0
        self.evicted_pages = 0

    # ------------------------------------------------------------- helpers
    def _key(self, tokens: Tuple[int, ...]) -> Tuple[int, ...]:
        return tokens[: self.page_size]

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_used = self._clock

    def _edge_match_pages(self, node: _Node, tokens, off: int) -> int:
        """Whole pages of ``node.tokens`` matching ``tokens[off:]``."""
        ps = self.page_size
        m = 0
        while (m + 1) * ps <= len(node.tokens):
            seg = node.tokens[m * ps : (m + 1) * ps]
            if tuple(tokens[off + m * ps : off + (m + 1) * ps]) != seg:
                break
            m += 1
        return m

    # -------------------------------------------------------------- verbs
    def match(self, tokens, max_tokens: Optional[int] = None) -> Tuple[int, List[int]]:
        """Longest page-aligned cached prefix of ``tokens[:max_tokens]``.

        Returns ``(n_tokens, pages)`` — the caller adopts ``pages`` via
        ``PagePool.adopt``. The engine passes ``max_tokens=prompt_len - 1``
        so at least one prompt token is always left to prefill (the request
        needs last-position logits to sample its first token). Touches
        every node on the path (LRU) but does NOT count stats — the engine
        may re-match a blocked head request on every step, so
        lookups/hits/cached_tokens are counted once per ADMISSION via
        ``note_lookup`` (inflating them here would corrupt the hit-rate
        and FLOPs-saved accounting).
        """
        tokens = [int(t) for t in tokens]
        if max_tokens is not None:
            tokens = tokens[:max_tokens]
        ps = self.page_size
        node, off, pages = self._root, 0, []
        while len(tokens) - off >= ps:
            child = node.children.get(tuple(tokens[off : off + ps]))
            if child is None:
                break
            m = self._edge_match_pages(child, tokens, off)
            pages.extend(child.pages[:m])
            off += m * ps
            self._touch(child)
            if m < len(child.pages):
                break                       # partial edge: cannot descend
            node = child
        return off, pages

    def note_lookup(self, cached_tokens: int) -> None:
        """Record one admission-time lookup outcome (see ``match``)."""
        self.lookups += 1
        if cached_tokens:
            self.hits += 1
            self.cached_tokens += cached_tokens

    def insert(self, tokens, pages: List[int]) -> int:
        """Cache a retired prompt's full pages (``pages[i]`` holds positions
        ``[i*page_size, (i+1)*page_size)`` of ``tokens``). Only whole pages
        are cacheable; the trailing partial page is ignored. New nodes
        retain their pages; segments already present keep the existing
        nodes' pages (same tokens => bit-identical KV). Returns the number
        of newly cached pages."""
        tokens = [int(t) for t in tokens]
        ps = self.page_size
        n_full = min(len(tokens) // ps, len(pages))
        node, off = self._root, 0
        while off < n_full * ps:
            key = tuple(tokens[off : off + ps])
            child = node.children.get(key)
            if child is None:
                seg = tuple(tokens[off : n_full * ps])
                new_pages = list(pages[off // ps : n_full])
                self.pool.retain(new_pages)
                fresh = _Node(seg, new_pages, node)
                node.children[key] = fresh
                self._touch(fresh)
                self.inserted_tokens += len(seg)
                return len(new_pages)
            m = self._edge_match_pages(child, tokens, off)
            avail = (n_full * ps - off) // ps
            m = min(m, avail)
            if m < len(child.pages):
                if m == avail:
                    # our prompt ends inside (or exactly at a page boundary
                    # of) this edge — fully covered, nothing new to cache
                    self._touch(child)
                    return 0
                # diverges mid-edge: split the child at the match point so
                # the shared pages get their own node
                self._split(node, child, m)
                child = node.children[key]
            off += m * ps
            self._touch(child)
            node = child
        return 0

    def _split(self, parent: _Node, child: _Node, m: int) -> None:
        """Split ``child`` after its first ``m`` pages (0 < m < len)."""
        ps = self.page_size
        assert 0 < m < len(child.pages)
        top = _Node(
            child.tokens[: m * ps], child.pages[:m], parent,
            last_used=child.last_used,
        )
        child.tokens = child.tokens[m * ps :]
        child.pages = child.pages[m:]
        child.parent = top
        top.children[self._key(child.tokens)] = child
        parent.children[self._key(top.tokens)] = top

    # ----------------------------------------------------------- eviction
    def _idle(self, node: _Node) -> bool:
        """No live sequence references any page of this subtree."""
        return all(self.pool.refcount(p) == 1 for p in node.pages) and all(
            self._idle(c) for c in node.children.values()
        )

    def evictable_pages(self) -> int:
        """Pages ``evict_until`` could return to the pool right now: every
        node whose whole subtree is idle frees by leaf-first cascade. A
        busy node's idle descendants still count (their own pages free);
        the busy node itself and its ancestors do not."""
        total = 0
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if self._idle(n):
                total += len(n.pages) + sum(
                    len(d.pages) for d in self._descendants(n)
                )
            else:
                stack.extend(n.children.values())
        return total

    def _descendants(self, node: _Node):
        out = []
        stack = list(node.children.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    def _evictable_leaves(self) -> List[_Node]:
        leaves = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif all(self.pool.refcount(p) == 1 for p in n.pages):
                leaves.append(n)
        return leaves

    def evict_until(self, n_pages: int) -> int:
        """Free at least ``n_pages`` pages (LRU idle leaves first, cascading
        into parents as they become childless). Returns pages freed — may
        be less than asked when everything left is pinned by live
        sequences. One tree scan seeds the victim heap; cascades are local
        (evicting a leaf can only newly expose its own parent), so the cost
        is O(tree + victims log victims), not a rescan per victim."""
        import heapq

        heap = [
            (n.last_used, id(n), n) for n in self._evictable_leaves()
        ]
        heapq.heapify(heap)
        freed = 0
        while freed < n_pages and heap:
            _, _, node = heapq.heappop(heap)
            parent = node.parent
            freed += self._evict(node)
            if (
                parent is not self._root
                and not parent.children
                and all(self.pool.refcount(p) == 1 for p in parent.pages)
            ):
                heapq.heappush(heap, (parent.last_used, id(parent), parent))
        return freed

    def _evict(self, node: _Node) -> int:
        assert not node.children
        self.pool.release(node.pages)
        n = len(node.pages)
        self.evicted_pages += n
        parent = node.parent
        parent.children.pop(self._key(node.tokens))
        return n

    def clear(self) -> int:
        """Evict everything evictable (pinned nodes stay). Returns pages
        freed."""
        return self.evict_until(self.pages_cached())

    # ------------------------------------------------------------ inspect
    def pages_cached(self) -> int:
        return sum(len(n.pages) for n in self._descendants(self._root))

    def tokens_cached(self) -> int:
        return self.pages_cached() * self.page_size

    def stats(self) -> Dict[str, float]:
        return {
            "prefix_lookups": self.lookups,
            "prefix_hits": self.hits,
            "prefix_cached_tokens": self.cached_tokens,
            "prefix_pages_cached": self.pages_cached(),
            "prefix_evicted_pages": self.evicted_pages,
        }

    def check(self) -> None:
        """Structural invariants (exercised by the property tests)."""
        seen: set = set()
        stack = [(self._root, True)]
        while stack:
            node, is_root = stack.pop()
            if not is_root:
                assert node.tokens and len(node.tokens) % self.page_size == 0
                assert len(node.pages) * self.page_size == len(node.tokens)
                for p in node.pages:
                    assert p not in seen, f"page {p} in two nodes"
                    seen.add(p)
                    assert self.pool.refcount(p) >= 1
            for key, child in node.children.items():
                assert key == self._key(child.tokens)
                assert child.parent is node
                stack.append((child, False))
