"""recurrentgemma-2b — Google RecurrentGemma 2B (Griffin). [arXiv:2402.19427]

Hybrid: repeating unit of (RG-LRU, RG-LRU, local-attention) — 1 attention per
2 recurrent blocks. 26 layers, d_model=2560, 10 heads MQA head_dim=256,
gated-GeLU d_ff=7680 (geglu treated as gated MLP), rglru width 2560, local
window 2048, vocab 256000.

RG-LRU state is O(width) and local attention is windowed -> long_500k native.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_gated=True,
    norm="rmsnorm",
    pattern=("rglru", "rglru", "local"),
    sliding_window=2048,
    ffn_kind="dense",
    rglru_width=2560,
    ssm_conv=4,
    long_context="native",
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
)
