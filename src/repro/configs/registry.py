"""Registry of assigned architectures (+ the survey's own demo config)."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchConfig, INPUT_SHAPES, ShapeSpec, reduced
from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM
from repro.configs.gemma3_1b import CONFIG as GEMMA3_1B
from repro.configs.granite_8b import CONFIG as GRANITE_8B
from repro.configs.falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B
from repro.configs.phi3_vision_4_2b import CONFIG as PHI3_VISION_4_2B
from repro.configs.qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE_30B_A3B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.moonshot_v1_16b_a3b import CONFIG as MOONSHOT_V1_16B_A3B
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B

# The survey has no model of its own; this is the framework's default demo
# config (a ~100M llama-style LM used by examples/ and the trainer default).
SURVEY_DEMO = ArchConfig(
    name="survey-demo-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    mlp_gated=True,
    norm="rmsnorm",
    pattern=("attn",),
    ffn_kind="dense",
    source="survey demo model (this repo)",
)

ARCHITECTURES: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        GRANITE_34B,
        SEAMLESS_M4T_MEDIUM,
        GEMMA3_1B,
        GRANITE_8B,
        FALCON_MAMBA_7B,
        PHI3_VISION_4_2B,
        QWEN3_MOE_30B_A3B,
        RECURRENTGEMMA_2B,
        MOONSHOT_V1_16B_A3B,
        ARCTIC_480B,
        SURVEY_DEMO,
    ]
}

ASSIGNED: List[str] = [
    "granite-34b",
    "seamless-m4t-medium",
    "gemma3-1b",
    "granite-8b",
    "falcon-mamba-7b",
    "phi-3-vision-4.2b",
    "qwen3-moe-30b-a3b",
    "recurrentgemma-2b",
    "moonshot-v1-16b-a3b",
    "arctic-480b",
]


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}"
        ) from None


def get_shape(name: str) -> ShapeSpec:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; available: {sorted(INPUT_SHAPES)}"
        ) from None


def get_reduced(name: str, **over) -> ArchConfig:
    return reduced(get_config(name), **over)
