"""phi-3-vision-4.2b — Microsoft Phi-3-vision-128k. [hf:microsoft/Phi-3-vision-128k-instruct]

VLM: phi3-mini dense decoder backbone (32L, d=3072, MHA 32 heads, SwiGLU
d_ff=8192, vocab 32064 padded to 32128) consuming CLIP-ViT patch embeddings.

The vision tower (CLIP ViT-L/14 + HD transform + projector) is the allowed
STUB: ``input_specs`` supplies precomputed, projected patch embeddings of
shape (batch, patches, d_model) which the model interleaves ahead of the text
tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    mlp_gated=True,
    norm="rmsnorm",
    pattern=("attn",),
    ffn_kind="dense",
    frontend="vision",
    frontend_tokens=576,
    long_context="sw_variant",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
