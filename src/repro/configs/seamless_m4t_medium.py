"""seamless-m4t-medium — Meta SeamlessM4T medium text backbone. [arXiv:2308.11596]

Encoder-decoder transformer (12 enc + 12 dec layers per the M4T-medium text
enc/dec depth; the assignment's "12L" is read as the per-stack depth — noted
in DESIGN.md). MHA (kv=16 == heads), plain MLP with d_ff=4096, LayerNorm,
256206-entry NLLB vocab (padded to 256256 for mesh divisibility).

The audio frontend (mel filterbank + conformer feature extractor) is the
allowed STUB: ``input_specs`` supplies precomputed frame embeddings of shape
(batch, frames, d_model) consumed directly by the encoder.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    mlp_gated=False,
    norm="layernorm",
    pattern=("attn",),
    ffn_kind="dense",
    frontend="audio",
    frontend_tokens=1024,
    long_context="sw_variant",
    source="arXiv:2308.11596 (SeamlessM4T)",
)
