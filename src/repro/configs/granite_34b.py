"""granite-34b — IBM Granite 34B Code. [arXiv:2405.04324]

GPT-BigCode-style dense decoder: MQA (kv=1), plain (non-gated) MLP — the
non-gated MLP is what makes 88 x (attn + 2*d*d_ff) + embeddings land at ~34B
with d_ff = 4*d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_gated=False,
    norm="layernorm",
    pattern=("attn",),
    ffn_kind="dense",
    long_context="sw_variant",
    source="arXiv:2405.04324 (Granite Code Models)",
)
