"""granite-8b — IBM Granite 8B Code. [arXiv:2405.04324]

Llama-arch dense decoder with GQA (32 q heads / 8 kv heads), SwiGLU MLP.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    mlp_gated=True,
    norm="rmsnorm",
    pattern=("attn",),
    ffn_kind="dense",
    long_context="sw_variant",
    source="arXiv:2405.04324 (Granite Code Models)",
)
