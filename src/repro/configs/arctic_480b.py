"""arctic-480b — Snowflake Arctic base. [hf:Snowflake/snowflake-arctic-base]

Dense-MoE hybrid: 35 layers, 128-expert top-2 router (per-expert SwiGLU hidden
4864) in PARALLEL with a dense residual SwiGLU MLP on every layer (Arctic's
"dense + MoE hybrid" design). GQA 56q/8kv head_dim=128, d_model=7168,
vocab 32000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    mlp_gated=True,
    norm="rmsnorm",
    pattern=("attn",),
    ffn_kind="moe",
    n_experts=128,
    experts_top_k=2,
    dense_residual=True,
    residual_d_ff=4864,
    long_context="sw_variant",
    source="hf:Snowflake/snowflake-arctic-base",
)
