"""gemma3-1b — Google Gemma 3 1B pretrained. [hf:google/gemma-3-1b-pt]

Dense decoder, 5:1 local:global attention pattern (5 sliding-window layers per
1 full-attention layer), MQA (kv=1), head_dim=256 (explicit: 4 heads x 256 =
1024 != d_model), 262144-token SentencePiece vocab, SwiGLU.

sliding_window=512 per the HF config (4x128 — MXU-tile aligned). Because only
1 layer in 6 keeps a full cache, long_500k decode is natively sub-quadratic in
aggregate cache memory: 26 layers -> 5 global x 512k + 21 local x 512.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    mlp_gated=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    pattern=("local", "local", "local", "local", "local", "attn"),
    sliding_window=512,
    ffn_kind="dense",
    long_context="native",
    source="hf:google/gemma-3-1b-pt",
)
