"""Architecture and input-shape configuration.

Every assigned architecture is expressed as an :class:`ArchConfig`. The model
builder (``repro.models``) consumes only this dataclass, so new architectures
are pure config additions.

Block model
-----------
A network is a stack of ``n_layers`` blocks. Each block has a *mixer* (the
sequence-mixing half) and an *ffn* (the channel-mixing half):

  mixer ∈ {"attn" (full causal), "local" (sliding-window attn),
           "rglru" (RG-LRU linear recurrence), "mamba" (Mamba-1 SSM)}
  ffn   ∈ {"dense", "moe", "none"}

``pattern`` gives the repeating unit of mixer kinds (e.g. gemma3's
``("local",)*5 + ("attn",)``); homogeneous stacks use a length-1 pattern.
Encoder-decoder models additionally set ``enc_layers > 0`` (the encoder is a
non-causal homogeneous attention stack; decoder blocks gain cross-attention).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

MIXER_KINDS = ("attn", "local", "rglru", "mamba")
FFN_KINDS = ("dense", "moe", "none")

# Pad vocab so it is MXU-tile aligned and divisible by the model mesh axis.
VOCAB_PAD_MULTIPLE = 128


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Static architecture description (full-size, dry-run only)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int                  # GQA kv heads (0 for attention-free)
    d_ff: int                        # dense FFN hidden (per-expert hidden for MoE)
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp_gated: bool = True           # SwiGLU-style gated MLP vs plain 2-matrix MLP
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # --- layer pattern -----------------------------------------------------
    pattern: Tuple[str, ...] = ("attn",)
    ffn_kind: str = "dense"
    sliding_window: int = 0          # window for "local" mixers

    # --- encoder-decoder ---------------------------------------------------
    enc_layers: int = 0              # >0 => enc-dec; n_layers is decoder depth

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_top_k: int = 0
    n_shared_experts: int = 0        # always-on shared experts (Moonlight)
    dense_residual: bool = False     # parallel dense FFN next to routed (Arctic)
    residual_d_ff: int = 0           # hidden of the dense-residual FFN
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM / RG-LRU ------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    rglru_width: int = 0             # 0 -> d_model

    # --- modality frontend (stub: precomputed embeddings) -------------------
    frontend: Optional[str] = None   # None | "audio" | "vision"
    frontend_tokens: int = 0         # patches / frames consumed per example

    # --- long-context (long_500k) handling ----------------------------------
    # "native"      : the base pattern is already sub-quadratic (ssm / hybrid /
    #                 local:global) — run long_500k as-is.
    # "sw_variant"  : base arch is pure full attention; long_500k runs a
    #                 sliding-window variant (window=lc_window, global layer
    #                 every lc_global_every) — flagged in EXPERIMENTS.md.
    long_context: str = "sw_variant"
    lc_window: int = 4096
    lc_global_every: int = 8

    # --- provenance ---------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ utils
    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.pattern:
            for m in self.pattern:
                assert m in MIXER_KINDS, m
        assert self.ffn_kind in FFN_KINDS, self.ffn_kind
        if self.n_experts:
            assert self.experts_top_k > 0

    @property
    def vocab_padded(self) -> int:
        return pad_to(self.vocab_size, VOCAB_PAD_MULTIPLE)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(m in ("rglru", "mamba") for m in self.pattern)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    def mixer_kinds(self, n_layers: Optional[int] = None) -> Tuple[str, ...]:
        """Per-layer mixer kinds for a stack of ``n_layers`` (default full)."""
        n = self.n_layers if n_layers is None else n_layers
        reps = math.ceil(n / len(self.pattern))
        return (self.pattern * reps)[:n]

    # --------------------------------------------------------------- counting
    def param_count(self) -> Dict[str, int]:
        """Analytic parameter counts (used for MODEL_FLOPS and memory maths)."""
        d, dff, hd = self.d_model, self.d_ff, self.head_dim
        counts: Dict[str, int] = {}
        counts["embed"] = self.vocab_padded * d
        counts["head"] = 0 if self.tie_embeddings else self.vocab_padded * d

        def attn_params() -> int:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o

        def dense_ffn(hidden: int) -> int:
            return (3 if self.mlp_gated else 2) * d * hidden

        def mixer_params(kind: str) -> int:
            if kind in ("attn", "local"):
                return attn_params()
            if kind == "mamba":
                di, s = self.d_inner, self.ssm_state
                in_proj = d * 2 * di
                conv = di * self.ssm_conv
                xbcdt = di * (2 * s + (di // 16)) + (di // 16) * di
                out = di * d
                return in_proj + conv + xbcdt + out + 2 * di
            if kind == "rglru":
                w = self.rglru_width or d
                conv = w * self.ssm_conv
                return 2 * d * w + w * d + conv + 3 * w + 2 * (w // 8) * w
            raise ValueError(kind)

        def ffn_params() -> int:
            if self.ffn_kind == "none":
                return 0
            if self.ffn_kind == "dense":
                return dense_ffn(dff)
            routed = self.n_experts * (3 if self.mlp_gated else 2) * d * dff
            router = d * self.n_experts
            shared = self.n_shared_experts * dense_ffn(dff)
            resid = dense_ffn(self.residual_d_ff) if self.dense_residual else 0
            return routed + router + shared + resid

        layers = 0
        for kind in self.mixer_kinds():
            layers += mixer_params(kind) + ffn_params() + 2 * d  # two norms
        if self.is_encdec:
            enc = self.enc_layers * (attn_params() + dense_ffn(dff) + 2 * d)
            cross = self.n_layers * (attn_params() + d)          # cross-attn+norm
            layers += enc + cross
        counts["layers"] = layers
        counts["final_norm"] = d
        counts["total"] = sum(counts.values())
        return counts

    def active_param_count(self) -> int:
        """Active params per token (= total for dense; router top-k for MoE)."""
        if not self.n_experts:
            return self.param_count()["total"]
        full = self.param_count()["total"]
        d, dff = self.d_model, self.d_ff
        per_expert = (3 if self.mlp_gated else 2) * d * dff
        inactive = (self.n_experts - self.experts_top_k) * per_expert
        return full - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, **over: Any) -> ArchConfig:
    """A smoke-test-sized variant of the same family (2 layers, d<=512, <=4 experts).

    Keeps the mixer pattern (truncated), GQA ratio, gating, MoE/SSM structure.
    """
    d = min(cfg.d_model, 256)
    n_heads = max(1, min(cfg.n_heads, 4))
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1)) if cfg.n_heads else 1
    n_kv = max(1, n_heads // ratio) if cfg.n_heads else 0
    upd: Dict[str, Any] = dict(
        name=cfg.name + "-reduced",
        n_layers=max(2, len(cfg.pattern)) if len(cfg.pattern) > 1 else 2,
        d_model=d,
        n_heads=n_heads if cfg.n_heads else 0,
        n_kv_heads=n_kv,
        head_dim=(d // n_heads) if cfg.n_heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        enc_layers=2 if cfg.is_encdec else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_top_k=min(cfg.experts_top_k, 2) if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        residual_d_ff=min(cfg.residual_d_ff, 256) if cfg.dense_residual else 0,
        rglru_width=min(cfg.rglru_width, 256) if cfg.rglru_width else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        lc_window=256,
        frontend_tokens=min(cfg.frontend_tokens, 16),
    )
    upd.update(over)
    return dataclasses.replace(cfg, **upd)
