"""moonshot-v1-16b-a3b — Moonshot Moonlight-16B-A3B. [hf:moonshotai/Moonlight-16B-A3B]

DeepSeek-V3-style MoE decoder: 48 layers, 64 routed experts top-6 plus 2
always-on shared experts, per-expert SwiGLU hidden 1408, MHA 16 heads
(kv=16) head_dim=128, vocab 163840.

Simplification noted in DESIGN.md: Moonlight's first dense layer is modeled
as MoE like the rest (uniform scan stack); its MLA attention is modeled as
standard MHA per the assignment table (16H kv=16).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    mlp_gated=True,
    norm="rmsnorm",
    pattern=("attn",),
    ffn_kind="moe",
    n_experts=64,
    experts_top_k=6,
    n_shared_experts=2,
    long_context="sw_variant",
    source="hf:moonshotai/Moonlight-16B-A3B",
)
