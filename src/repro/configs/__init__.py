from repro.configs.base import (  # noqa: F401
    ArchConfig,
    INPUT_SHAPES,
    ShapeSpec,
    pad_to,
    reduced,
)
from repro.configs.registry import (  # noqa: F401
    ARCHITECTURES,
    ASSIGNED,
    SURVEY_DEMO,
    get_config,
    get_reduced,
    get_shape,
)
