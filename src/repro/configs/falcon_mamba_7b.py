"""falcon-mamba-7b — TII Falcon-Mamba 7B. [arXiv:2410.05355]

Pure Mamba-1 SSM stack: 64 attention-free blocks, d_model=4096, expand=2
(d_inner=8192), ssm_state=16, conv width 4, RMSNorm, vocab 65024. Each block
is mixer-only (Mamba-1 has no separate FFN half).

Decode is O(1)-state recurrent -> long_500k is native.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    norm="rmsnorm",
    pattern=("mamba",),
    ffn_kind="none",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    long_context="native",
    source="arXiv:2410.05355 (Falcon Mamba)",
)
