"""qwen3-moe-30b-a3b — Qwen3-30B-A3B. [hf:Qwen/Qwen3-30B-A3B]

MoE decoder: 48 layers, every FFN is a 128-expert top-8 router with per-expert
SwiGLU hidden 768. GQA 32q/4kv with explicit head_dim=128 (q width 4096 !=
d_model=2048 — matches the HF config). Vocab 151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    mlp_gated=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    pattern=("attn",),
    ffn_kind="moe",
    n_experts=128,
    experts_top_k=8,
    long_context="sw_variant",
    source="hf:Qwen/Qwen3-30B-A3B",
)
