"""Sharding-spec rules: shape-divisibility invariants for every assigned arch."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, get_config, get_reduced
from repro.models import init_params
from repro.sharding.specs import (
    batch_axes,
    leaf_param_spec,
    paged_state_specs,
    param_specs,
    pool_kv_spec,
)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", ASSIGNED)
def test_specs_divide_shapes(arch):
    """Every sharded dim must be divisible by its mesh axes — the invariant
    that makes the 40-way dry-run lower."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, shapes, MESH)

    def check(path, leaf, spec):
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= MESH.shape[a]
            assert leaf.shape[i] % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        check, shapes, specs,
    )


@pytest.mark.parametrize("arch", ["gemma3-1b", "recurrentgemma-2b", "arctic-480b"])
def test_head_guard_replicates_odd_head_counts(arch):
    """4, 10 and 56 heads don't divide 16: wq/wo must stay replicated."""
    cfg = get_config(arch)
    spec = leaf_param_spec(("stack", "g0", "p0", "mixer", "wq"),
                           (cfg.n_layers, cfg.d_model, cfg.n_heads * cfg.head_dim),
                           cfg, 16)
    assert spec == P(None, None, None)


def test_moe_experts_shard_over_model():
    cfg = get_config("qwen3-moe-30b-a3b")
    spec = leaf_param_spec(("stack", "g0", "p0", "ffn", "w_up"),
                           (48, 128, 2048, 768), cfg, 16)
    assert spec[1] == "model"


def test_embed_sharded_head_sharded():
    cfg = get_config("granite-8b")
    assert leaf_param_spec(("embed", "table"), (49152, 4096), cfg, 16) == P("model", None)
    assert leaf_param_spec(("head", "w"), (4096, 49152), cfg, 16) == P(None, "model")


def test_pool_kv_spec_shards_head_axis_or_replicates():
    """Paged KV pools shard their kv-head axis (dim -2) over `model` when
    the head count divides, and fall back to replication otherwise (MQA)."""
    gqa = get_config("moonshot-v1-16b-a3b")      # 16 kv heads
    assert pool_kv_spec(gqa, 5, 2) == P(None, None, None, "model", None)
    full = get_config("granite-8b")              # 8 kv heads at full size...
    red = get_reduced("granite-8b")              # ...but 1 when reduced (MQA)
    assert red.n_kv_heads == 1
    assert pool_kv_spec(red, 5, 2) == P(None, None, None, None, None)
    assert pool_kv_spec(full, 5, 3) == P(None, None, None, None, None)


def test_paged_state_specs_tables_replicated():
    """Block tables / lengths stay replicated — page ids are global, only
    the head slices of their contents are sharded."""
    import numpy as np

    cfg = get_reduced("moonshot-v1-16b-a3b")
    state_shape = {
        "caches": [{"p0": {
            "kp": jnp.zeros((2, 9, 8, cfg.n_kv_heads, cfg.head_dim)),
            "vp": jnp.zeros((2, 9, 8, cfg.n_kv_heads, cfg.head_dim)),
        }}],
        "tables": jnp.zeros((4, 8), jnp.int32),
        "lengths": jnp.zeros((4,), jnp.int32),
    }
    specs = paged_state_specs(cfg, state_shape, FakeMesh({"data": 1, "model": 2}))
    assert specs["caches"][0]["p0"]["kp"] == P(None, None, None, "model", None)
    assert specs["tables"] == P(None, None)
    assert specs["lengths"] == P(None)


def test_batch_axes_divisibility():
    assert batch_axes(MESH, 256) == ("data",)
    assert batch_axes(MESH3, 256) == ("pod", "data")
    assert batch_axes(MESH3, 1) == ()          # long_500k: batch unshardable
    assert batch_axes(MESH3, 2) == ("pod",)
