"""Optimizer unit tests: convergence on a quadratic + 8-bit Adam parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam8bit, adamw, apply_updates, get, lamb, lars, sgd
from repro.optim.base import Schedule, clip_by_global_norm, global_norm
from repro.optim.lowbit import state_bytes


def quadratic_problem(seed=0, d=64):
    rng = np.random.RandomState(seed)
    A = rng.randn(d, d).astype(np.float32)
    A = A @ A.T / d + np.eye(d, dtype=np.float32)
    b = rng.randn(d).astype(np.float32)
    A, b = jnp.asarray(A), jnp.asarray(b)

    def loss(params):
        x = params["x"]
        return 0.5 * x @ A @ x - b @ x

    x_star = jnp.linalg.solve(A, b)
    return loss, {"x": jnp.zeros(d)}, x_star


@pytest.mark.parametrize(
    "opt,steps",
    [
        (sgd(5e-2, momentum=0.9), 400),
        (adamw(5e-2), 500),
        (lars(2e-1, weight_decay=0.0, trust_coef=0.1), 600),
        (lamb(5e-2, weight_decay=0.0), 600),
    ],
    ids=["sgd", "adamw", "lars", "lamb"],
)
def test_converges_on_quadratic(opt, steps):
    loss, params, x_star = quadratic_problem()
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state

    for _ in range(steps):
        params, state = step(params, state)
    err = float(jnp.linalg.norm(params["x"] - x_star) / jnp.linalg.norm(x_star))
    assert err < 0.05, err


def test_adam8bit_tracks_adamw():
    """8-bit Adam should track f32 Adam closely on a noisy regression."""
    rng = np.random.RandomState(1)
    W = jnp.asarray(rng.randn(128, 64).astype(np.float32))
    params8 = {"w": jnp.zeros((128, 64))}
    params32 = {"w": jnp.zeros((128, 64))}

    def loss(p, x, y):
        return jnp.mean((x @ p["w"].T - y) ** 2)

    o8, o32 = adam8bit(1e-2), adamw(1e-2)
    s8, s32 = o8.init(params8), o32.init(params32)
    step8 = jax.jit(
        lambda p, s, x, y: _apply(o8, loss, p, s, x, y)
    )
    step32 = jax.jit(
        lambda p, s, x, y: _apply(o32, loss, p, s, x, y)
    )
    for i in range(60):
        x = jnp.asarray(rng.randn(32, 64).astype(np.float32))
        y = x @ W.T
        params8, s8 = step8(params8, s8, x, y)
        params32, s32 = step32(params32, s32, x, y)
    l8 = float(loss(params8, x, y))
    l32 = float(loss(params32, x, y))
    assert l8 < 1.5 * l32 + 1e-3, (l8, l32)
    rel = float(
        jnp.linalg.norm(params8["w"] - params32["w"])
        / (jnp.linalg.norm(params32["w"]) + 1e-9)
    )
    assert rel < 0.15, rel


def _apply(opt, loss, p, s, x, y):
    g = jax.grad(loss)(p, x, y)
    upd, s = opt.update(g, s, p)
    return apply_updates(p, upd), s


def test_adam8bit_state_is_4x_smaller():
    params = {"w": jnp.zeros((512, 512))}
    s8 = adam8bit(1e-3).init(params)
    s32 = adamw(1e-3).init(params)
    b8, b32 = state_bytes(s8["slots"]), state_bytes({"m": s32["m"], "v": s32["v"]})
    assert b8 < 0.3 * b32, (b8, b32)  # 8-bit + scales ~ 0.26x


def test_schedule_linear_scaling_and_warmup():
    sched = Schedule(base_lr=1e-3, warmup_steps=10, total_steps=100,
                     base_batch=256, global_batch=1024, kind="constant")
    assert abs(float(sched(9)) - 4e-3) < 1e-9          # warmed up, 4x scaled
    assert float(sched(0)) == pytest.approx(4e-3 * 0.1)


def test_clip_by_global_norm():
    g = {"a": jnp.ones(100) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) == pytest.approx(100.0)


def test_get_registry():
    for name in ["sgd", "adamw", "lars", "lamb", "adam8bit"]:
        opt = get(name, 1e-3)
        state = opt.init({"w": jnp.zeros((4096,))})
        assert state is not None
