"""Async serving front-end: tick loop, streaming, QoS, cancel, backpressure.

The engine guarantees batched==alone token identity, and the front-end can
only change WHEN ticks happen — so every test here pins the async layer to
the isolated-run oracle: streamed tokens match the alone run exactly, a
cancelled stream is a PREFIX of the alone run, trace replay is
tick-deterministic (including its cancel/QoS/backpressure paths), and the
pool always drains.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import Runtime, init_params
from repro.serve import (
    AsyncFrontend,
    EngineConfig,
    ReplicatedServeEngine,
    ServeEngine,
    TraceRequest,
    poisson_trace,
    replay_trace,
)
from repro.train.serve import generate

pytestmark = pytest.mark.frontend

RT = Runtime(dtype=jnp.float32, chunk_q=32)


@pytest.fixture(scope="module")
def gstate():
    cfg = get_reduced("granite-8b")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _alone(cfg, params, prompt, max_new):
    out, _ = generate(
        cfg, params, {"tokens": jnp.asarray(np.asarray(prompt)[None])}, RT,
        max_new,
    )
    return np.asarray(out[0])


def _ecfg(**kw):
    base = dict(max_slots=2, page_size=8, num_pages=17, max_len=32,
                inner_steps=4)
    base.update(kw)
    return EngineConfig(**base)


def test_async_streaming_matches_alone(gstate):
    """Background-driven front-end, staggered submits (one arriving while
    another is mid-stream): every request's streamed tokens equal its
    isolated run."""
    cfg, params = gstate
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (5, 9, 12)]
    eng = ServeEngine(cfg, params, RT, _ecfg())

    async def scenario():
        async with AsyncFrontend(eng) as fe:
            h0 = await fe.submit(prompts[0], 8)
            got = 0
            async for _tok in fe.stream(h0):
                got += 1
                if got == 2:
                    break
            # mid-stream arrival: h0 is still decoding
            h1 = await fe.submit(prompts[1], 6)
            h2 = await fe.submit(prompts[2], 5)
            await fe.result(h0)
            await fe.result(h1)
            await fe.result(h2)
            return h0, h1, h2

    h0, h1, h2 = asyncio.run(scenario())
    for h, p, m in ((h0, prompts[0], 8), (h1, prompts[1], 6),
                    (h2, prompts[2], 5)):
        assert h.done == "complete" and len(h.tokens) == m
        np.testing.assert_array_equal(
            np.asarray(h.tokens, np.int32), _alone(cfg, params, p, m)
        )
    assert eng.pool.pages_in_use == 0


def _mixed_trace(cfg, rng):
    """Small hand-rolled trace exercising QoS, cancel, and backpressure."""
    lens = (5, 9, 6, 12, 7, 8)
    arrive = (0, 0, 1, 3, 3, 6)
    qos = ("interactive", "batch", "interactive", "interactive",
           "batch", "interactive")
    cancel = (0, 0, 2, 0, 0, 3)
    return [
        TraceRequest(
            arrive_tick=a,
            tokens=rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32),
            max_new=m,
            qos=q,
            cancel_after=c,
        )
        for a, s, m, q, c in zip(arrive, lens, (8, 6, 9, 7, 6, 8), qos,
                                 cancel)
    ]


def test_replay_trace_deterministic_with_cancel_and_qos(gstate):
    """Two replays of the same trace on fresh engines are tick-identical
    (admission, cancels, deferrals are functions of the trace alone), the
    completed outputs equal the alone runs, and cancelled streams are
    prefixes of theirs."""
    cfg, params = gstate
    trace = _mixed_trace(cfg, np.random.RandomState(19))

    def one():
        eng = ServeEngine(cfg, params, RT, _ecfg(max_queue=2))
        records, fe = asyncio.run(replay_trace(eng, trace))
        return eng, records, fe

    eng_a, recs_a, _ = one()
    eng_b, recs_b, _ = one()
    for ra, rb in zip(recs_a, recs_b):
        for k in ("status", "first_tick", "done_tick", "deferred_ticks",
                  "n_tokens"):
            assert ra[k] == rb[k], (k, ra, rb)
        np.testing.assert_array_equal(ra["tokens"], rb["tokens"])

    n_cancelled = 0
    for tr, rec in zip(trace, recs_a):
        alone = _alone(cfg, params, tr.tokens, tr.max_new)
        if rec["status"] == "complete":
            np.testing.assert_array_equal(rec["tokens"], alone)
        else:
            assert rec["status"] == "cancelled"
            n_cancelled += 1
            n = len(rec["tokens"])
            assert tr.cancel_after <= n < tr.max_new
            np.testing.assert_array_equal(rec["tokens"], alone[:n])
    assert n_cancelled == sum(1 for t in trace if t.cancel_after)
    assert eng_a.stats["cancelled"] == n_cancelled
    assert eng_a.pool.pages_in_use == 0
    assert eng_b.pool.pages_in_use == 0


def test_qos_interactive_served_before_earlier_batch(gstate):
    """One slot, engine busy: a batch request submitted BEFORE an
    interactive one must still be admitted after it (strict tier
    priority)."""
    cfg, params = gstate
    rng = np.random.RandomState(5)
    p0, pb, pi = (rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
                  for _ in range(3))
    eng = ServeEngine(cfg, params, RT, _ecfg(max_slots=1, num_pages=9))
    fe = AsyncFrontend(eng)
    h0 = fe.try_submit(p0, 6)
    fe.tick()                      # h0 occupies the only slot
    hb = fe.try_submit(pb, 4, qos="batch")
    hi = fe.try_submit(pi, 4, qos="interactive")
    ticks = 0
    while eng.busy:
        fe.tick()
        ticks += 1
        assert ticks < 100
    eng.run_finalize()
    assert h0.done == hb.done == hi.done == "complete"
    assert hi.first_tick < hb.first_tick   # tier beats submit order
    np.testing.assert_array_equal(
        np.asarray(hi.tokens, np.int32), _alone(cfg, params, pi, 4)
    )
    np.testing.assert_array_equal(
        np.asarray(hb.tokens, np.int32), _alone(cfg, params, pb, 4)
    )


def test_backpressure_queuefull_then_async_retry(gstate):
    """At max_queue the sync path reports backpressure (None) and the
    async submit() waits for a slot instead of failing."""
    cfg, params = gstate
    rng = np.random.RandomState(7)
    p0, p1, p2 = (rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
                  for _ in range(3))
    eng = ServeEngine(cfg, params, RT,
                      _ecfg(max_slots=1, num_pages=9, max_queue=1))

    async def scenario():
        async with AsyncFrontend(eng) as fe:
            h0 = await fe.submit(p0, 6)
            # admission only happens at a tick, so the queue may still be
            # full here; the sync probe reports that as None ...
            if fe.try_submit(p1, 4) is None:
                deferred = True
                h1 = await fe.submit(p1, 4)      # ... and the async path
            else:                                 # waits it out
                deferred = False
                h1 = fe.handles[max(fe.handles)]
            h2 = await fe.submit(p2, 4)
            await fe.result(h0)
            await fe.result(h1)
            await fe.result(h2)
            return deferred, (h0, h1, h2)

    deferred, handles = asyncio.run(scenario())
    assert deferred                # max_queue=1: the probe really did defer
    for h, p, m in zip(handles, (p0, p1, p2), (6, 4, 4)):
        assert h.done == "complete"
        np.testing.assert_array_equal(
            np.asarray(h.tokens, np.int32), _alone(cfg, params, p, m)
        )
    assert eng.pool.pages_in_use == 0


def test_cancel_queued_and_inflight(gstate):
    """Cancelling a QUEUED request yields zero tokens; cancelling an
    IN-FLIGHT one frees its pages mid-decode and the delivered stream is a
    prefix of the alone run."""
    cfg, params = gstate
    rng = np.random.RandomState(11)
    p0, p1 = (rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
              for _ in range(2))
    eng = ServeEngine(cfg, params, RT, _ecfg(max_slots=1, num_pages=9))
    fe = AsyncFrontend(eng)
    h0 = fe.try_submit(p0, 10)
    h1 = fe.try_submit(p1, 6)
    assert fe.cancel(h1)                 # still queued: nothing delivered
    fe.tick()                            # h0 admitted + first chunk
    assert h1.done == "cancelled" and h1.tokens == []
    assert len(h0.tokens) > 0
    assert fe.cancel(h0)                 # in-flight: frees pages mid-decode
    assert h0.done == "cancelled"
    assert not eng.busy
    assert eng.pool.pages_in_use == 0
    assert eng.stats["cancelled"] == 2
    n = len(h0.tokens)
    assert 0 < n < 10
    np.testing.assert_array_equal(
        np.asarray(h0.tokens, np.int32), _alone(cfg, params, p0, 10)[:n]
    )
    eng.run_finalize()


def test_replicated_engine_through_frontend(gstate):
    """The front-end drives ReplicatedServeEngine through the same tick
    API: a replayed trace completes with alone-identical outputs on a
    single-replica (mesh=None) instance."""
    cfg, params = gstate
    rng = np.random.RandomState(13)
    trace = poisson_trace(
        rng, 5, rate=0.8, vocab=cfg.vocab_size, prompt_range=(4, 10),
        new_range=(4, 8),
    )
    eng = ReplicatedServeEngine(cfg, params, RT, _ecfg(max_queue=4),
                                mesh=None)
    records, fe = asyncio.run(replay_trace(eng, trace))
    assert all(r["status"] == "complete" for r in records)
    for tr, rec in zip(trace, records):
        np.testing.assert_array_equal(
            rec["tokens"], _alone(cfg, params, tr.tokens, tr.max_new)
        )
    assert eng.stats["run_completed"] == len(trace)
    assert all(e.pool.pages_in_use == 0 for e in eng.engines)
