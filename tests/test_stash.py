"""Activation-stash subsystem (core.stash): codecs, accounting, executors.

Property tests (hypothesis, via the optional shim): random pytrees
round-trip through every backend — raw/host bit-exactly, int8 within the
blockwise |err| <= scale/2 bound — and byte accounting is exact against
the buffers ``init`` actually allocates. Executor tests run the offload
action-vector executor and the host-driven pipeline runner against plain
``jax.grad`` oracles. Planner tests cover the stash-aware ParallelPlan:
host-mode degree constraint, activation-budget validation, and the
auto_plan raw -> fp8 escalation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp_compat import hypothesis, st

from repro.core.stash import (
    HostStash,
    QuantStash,
    RawStash,
    get_backend,
    normalize_stash,
)

jax.config.update("jax_enable_x64", False)


def _tree_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def _random_tree(rng, dtype=jnp.float32):
    shapes = [(3, 7), (257,), (2, 2, 130)]
    return {
        f"leaf{i}": jnp.asarray(
            rng.randn(*s).astype(np.float32) * 10 ** rng.randint(-2, 3),
            dtype,
        )
        for i, s in enumerate(shapes)
    }


def _struct(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree
    )


# ------------------------------------------------------------- round trips
def test_normalize_stash():
    assert normalize_stash("") == "raw"
    assert normalize_stash("bf16") == "raw"
    assert normalize_stash("fp8") == "fp8"
    with pytest.raises(ValueError):
        normalize_stash("zstd")


@hypothesis.given(st.integers(0, 50), st.integers(0, 5))
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_raw_roundtrip_bitexact(seed, slot):
    rng = np.random.RandomState(seed)
    tree = _random_tree(rng)
    b = RawStash()
    state = b.init(7, _struct(tree))
    got = b.get(b.put(state, slot, tree), slot, _struct(tree))
    for a, g in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(g))


@hypothesis.given(st.integers(0, 50))
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_int8_error_bound(seed):
    """Blockwise symmetric int8: elementwise |err| <= scale/2 of the
    element's 256-block (scale = blockwise absmax / 127)."""
    rng = np.random.RandomState(seed)
    tree = _random_tree(rng)
    b = QuantStash("int8")
    state = b.init(2, _struct(tree))
    got = b.get(b.put(state, 1, tree), 1, _struct(tree))
    for a, g in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        flat = np.asarray(a, np.float32).reshape(-1)
        out = np.asarray(g, np.float32).reshape(-1)
        pad = (-len(flat)) % b.block
        fp = np.pad(flat, (0, pad)).reshape(-1, b.block)
        scale = np.abs(fp).max(axis=1, keepdims=True) / 127.0
        bound = np.repeat(scale / 2 + 1e-7, b.block, axis=1).reshape(-1)
        assert np.all(np.abs(out - flat) <= bound[: len(flat)])


@hypothesis.given(st.integers(0, 50), st.sampled_from(["int8", "fp8"]))
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_byte_accounting(seed, storage):
    """slot_bytes/state_bytes are EXACT: raw == sum of leaf nbytes; quant
    == the measured size of the code+scale buffers init allocates."""
    rng = np.random.RandomState(seed)
    tree = _random_tree(rng)
    struct = _struct(tree)
    raw = RawStash()
    assert raw.slot_bytes(struct) == _tree_bytes(tree)
    assert raw.state_bytes(5, struct) == _tree_bytes(raw.init(5, struct))
    q = QuantStash(storage)
    measured = _tree_bytes(jax.eval_shape(lambda: q.init(5, struct)))
    assert q.state_bytes(5, struct) == measured
    assert q.slot_bytes(struct) < raw.slot_bytes(struct)


def test_fp8_roundtrip_close():
    rng = np.random.RandomState(0)
    tree = _random_tree(rng)
    b = QuantStash("fp8")
    got = b.get(b.put(b.init(1, _struct(tree)), 0, tree), 0, _struct(tree))
    for a, g in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        a, g = np.asarray(a, np.float64), np.asarray(g, np.float64)
        denom = np.abs(a).max() + 1e-12
        assert np.abs(a - g).max() / denom < 0.07   # e4m3 blockwise


def test_ste_roundtrip_matches_put_get_and_passes_grads():
    """backend.roundtrip forward is bitwise the stash perturbation (what a
    put-then-get returns); its gradient is identity (straight-through)."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(2, 300).astype(np.float32))
    b = QuantStash("int8")
    struct = jax.ShapeDtypeStruct(x.shape, x.dtype)
    via_state = b.get(b.put(b.init(1, struct), 0, x), 0, struct)
    via_rt = b.roundtrip(x)
    np.testing.assert_array_equal(np.asarray(via_state), np.asarray(via_rt))
    g = jax.grad(lambda v: jnp.sum(jnp.sin(b.roundtrip(v))))(x)
    expect = jnp.cos(b.roundtrip(x))     # d/dx sin(rt(x)) with STE
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), rtol=1e-6)


def test_quant_stash_traced_slots_under_scan():
    """put/get with TRACED slot indices inside lax.scan — the in-pipeline
    usage (slots come from int32 tick tables)."""
    rng = np.random.RandomState(1)
    xs = jnp.asarray(rng.randn(4, 2, 300).astype(np.float32))
    b = QuantStash("fp8")
    struct = jax.ShapeDtypeStruct(xs.shape[1:], xs.dtype)
    slots = jnp.asarray([2, 0, 1, 2], jnp.int32)

    @jax.jit
    def run(xs):
        state0 = b.init(3, struct)

        def step(state, inp):
            slot, x = inp
            state = b.put(state, slot, x)
            return state, b.get(state, slot, struct)

        return jax.lax.scan(step, state0, (slots, xs))[1]

    out = run(xs)
    ref = jnp.stack([b.roundtrip(x) for x in xs])
    # jit fusion may round differently than the eager reference — equality
    # is at float precision, not bitwise, across compilation regimes
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------- host stash
def test_host_stash_evicts_and_restores_bitexact():
    rng = np.random.RandomState(2)
    trees = [_random_tree(rng) for _ in range(4)]
    b = HostStash(window=2)
    state = b.init(4, None)
    for i, t in enumerate(trees):
        state = b.put(state, i, t)
    stats = b.stats()
    assert stats["puts"] == 4 and stats["evictions"] == 2
    assert stats["host_bytes_high_water"] == 2 * _tree_bytes(trees[0])
    for i, t in enumerate(trees):        # 0,1 from host; 2,3 from window
        got = b.get(state, i, None)
        for a, g in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(g))
    stats = b.stats()
    assert stats["host_hits"] == 2 and stats["window_hits"] == 2
    # device-resident accounting: only the window counts
    struct = _struct(trees[0])
    assert b.state_bytes(4, struct) == 2 * b.slot_bytes(struct)


def test_host_stash_slot_reuse_drops_stale_copy():
    b = HostStash(window=1)
    state = b.init(2, None)
    state = b.put(state, 0, jnp.ones(4))
    state = b.put(state, 1, jnp.zeros(4))        # evicts slot 0 to host
    state = b.put(state, 0, jnp.full(4, 7.0))    # reuse must drop stale 0
    np.testing.assert_array_equal(np.asarray(b.get(state, 0, None)),
                                  np.full(4, 7.0))


# --------------------------------------------------- offload-chain executor
def test_offload_chain_grads_matches_oracle():
    """Executing a keep/offload/recompute action vector reproduces plain
    jax.grad over the same segment chain (host round-trips are bit-exact,
    recompute replays are the same f32 ops)."""
    from repro.core.offload import offload_chain_grads

    rng = np.random.RandomState(0)
    n, d = 5, 8
    params = [jnp.asarray(rng.randn(d, d).astype(np.float32) * 0.3)
              for _ in range(n)]
    x0 = jnp.asarray(rng.randn(2, d).astype(np.float32))

    def seg(p, x):
        return jnp.tanh(x @ p)

    def loss_fn(y):
        return jnp.sum(y * y)

    def full(ps, x):
        for p in ps:
            x = seg(p, x)
        return loss_fn(x)

    ref_loss, ref_grads = jax.value_and_grad(full)(params, x0)
    actions = ["keep", "offload", "recompute", "offload", "recompute"]
    loss, grads, dx0, stats = offload_chain_grads(
        [seg] * n, params, x0, actions, loss_fn, host_window=1
    )
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-6)
    for g, r in zip(grads, ref_grads):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-5,
                                   atol=1e-6)
    assert stats["replayed_segments"] > 0
    assert stats["evictions"] > 0        # window=1 forces host traffic


# ------------------------------------------------------- host-driven runner
def _toy_pipeline(P, M, L, d, seed=0):
    rng = np.random.RandomState(seed)
    stage_params = {"w": jnp.asarray(rng.randn(L, d, d).astype(np.float32) * 0.3)}
    shared = {"emb": jnp.asarray(rng.randn(d, d).astype(np.float32) * 0.3)}
    mbs = jnp.asarray(rng.randn(M, 2, d).astype(np.float32))

    def first_fn(sh, mb):
        return mb @ sh["emb"]

    def stage_fn(sp, x):
        def body(h, w):
            return jnp.tanh(h @ w), jnp.zeros((), jnp.float32)
        y, aux = jax.lax.scan(body, x, sp["w"])
        return y, jnp.sum(aux)

    def last_fn(sh, y, mb):
        loss = jnp.sum((y - mb) ** 2)
        return loss, {"xent": loss}

    return stage_params, shared, mbs, first_fn, stage_fn, last_fn


@pytest.mark.parametrize("stash", ["raw", "host"])
@pytest.mark.parametrize("schedule", ["1f1b", "gpipe"])
def test_pipeline_grads_host_matches_oracle(stash, schedule):
    """The eager host-driven runner reproduces jax.grad of the sequential
    model — with HostStash (window=1, forcing evictions) bit-identically
    to RawStash."""
    from repro.core.pipeline import pipeline_grads_host, tick_table

    P, M, L, d = 2, 4, 4, 6
    stage_params, shared, mbs, first_fn, stage_fn, last_fn = _toy_pipeline(
        P, M, L, d
    )
    table = tick_table(schedule, P, M)
    x_struct = jax.ShapeDtypeStruct((2, d), jnp.float32)
    backend = get_backend(stash, host_window=1)
    loss, metrics, gstack, gshared = pipeline_grads_host(
        first_fn, stage_fn, last_fn, stage_params, shared, mbs,
        table=table, x_struct=x_struct,
        metrics_struct={"xent": jax.ShapeDtypeStruct((), jnp.float32)},
        stash=backend,
    )

    def full(sp, sh):
        total = jnp.zeros((), jnp.float32)
        for m in range(M):
            x = first_fn(sh, mbs[m])
            y, _ = stage_fn(sp, x)
            l, _ = last_fn(sh, y, mbs[m])
            total = total + l
        return total

    ref_loss, (ref_sp, ref_sh) = jax.value_and_grad(full, argnums=(0, 1))(
        stage_params, shared
    )
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gstack["w"]),
                               np.asarray(ref_sp["w"]), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gshared["emb"]),
                               np.asarray(ref_sh["emb"]), rtol=1e-4, atol=1e-6)
    if stash == "host":
        stats = backend.stats()
        assert stats["evictions"] > 0 and stats["host_hits"] > 0


def test_pipeline_grads_host_raw_vs_host_bitexact():
    from repro.core.pipeline import pipeline_grads_host, tick_table

    P, M, L, d = 2, 4, 4, 6
    args = _toy_pipeline(P, M, L, d)
    stage_params, shared, mbs, first_fn, stage_fn, last_fn = args
    table = tick_table("1f1b", P, M)
    x_struct = jax.ShapeDtypeStruct((2, d), jnp.float32)
    kw = dict(table=table, x_struct=x_struct,
              metrics_struct={"xent": jax.ShapeDtypeStruct((), jnp.float32)})
    outs = {}
    for stash in ("raw", "host"):
        outs[stash] = pipeline_grads_host(
            first_fn, stage_fn, last_fn, stage_params, shared, mbs,
            stash=get_backend(stash, host_window=1), **kw,
        )
    for a, b in zip(jax.tree.leaves(outs["raw"]), jax.tree.leaves(outs["host"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_grads_rejects_host_backend():
    from repro.core.pipeline import pipeline_grads

    with pytest.raises(ValueError, match="host-driven"):
        pipeline_grads(None, None, None, None, None, None,
                       mesh=None, table=None, x_struct=None,
                       metrics_struct=None, stage_specs=None, mb_specs=None,
                       stash=get_backend("host"))


# ------------------------------------------------------------ plan plumbing
def _tiny_cfg():
    from repro.configs import SURVEY_DEMO, reduced

    return reduced(SURVEY_DEMO, n_layers=4, d_model=128, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=512)


def test_plan_stash_validation():
    from repro.core.partitioner import ParallelPlan

    cfg = _tiny_cfg()
    ParallelPlan(pp=2, microbatches=4, stash="fp8").validate(cfg)
    with pytest.raises(ValueError, match="not in"):
        ParallelPlan(pp=2, microbatches=4, stash="zstd").validate(cfg)
    with pytest.raises(ValueError, match="host-driven"):
        ParallelPlan(dp=2, pp=2, microbatches=4, stash="host").validate(cfg)
    ParallelPlan(pp=2, microbatches=4, stash="host").validate(cfg)


def test_plan_stash_report_and_budget():
    from repro.core.partitioner import ParallelPlan

    cfg = _tiny_cfg()
    base = ParallelPlan(pp=2, microbatches=4)
    kw = dict(global_batch=8, seq_len=64, itemsize=4)
    raw = base.stash_report(cfg, **kw)
    assert raw["backend"] == "raw"
    assert raw["n_act_slots"] == 2               # min(P, M) for 1f1b
    assert raw["capacity_factor"] == 1.0
    import dataclasses

    fp8 = dataclasses.replace(base, stash="fp8").stash_report(cfg, **kw)
    assert fp8["act_bytes"] < raw["act_bytes"]
    assert fp8["raw_act_bytes"] == raw["act_bytes"]
    # per-SLOT compression beats 1.8x; whole-state factor is diluted by the
    # uncompressed cotangent slot
    assert raw["bytes_per_slot"] / fp8["bytes_per_slot"] >= 1.8
    # byte-split and total accounting
    assert raw["device_bytes"] == raw["act_bytes"]
    assert raw["host_bytes"] == 0
    assert raw["transient_bytes"] > 0            # k = 2 layers/stage live
    assert raw["total_bytes"] == raw["act_bytes"] + raw["transient_bytes"]
    # gpipe holds M act slots; the host stash windows 2 and spills the rest
    host = dataclasses.replace(base, stash="host", schedule="gpipe").stash_report(
        cfg, **kw
    )
    raw_gp = dataclasses.replace(base, schedule="gpipe").stash_report(cfg, **kw)
    assert host["host_bytes"] > 0                # spilled slots land on host
    assert host["device_bytes"] < raw_gp["device_bytes"]
    full = dataclasses.replace(base, remat="full").stash_report(cfg, **kw)
    assert full["transient_bytes"] < raw["transient_bytes"]
    cot = dataclasses.replace(base, stash="fp8", stash_cot=True).stash_report(
        cfg, **kw
    )
    assert cot["act_bytes"] < fp8["act_bytes"]   # cot slots compressed too
    # the budget gate runs on total_bytes (slots + within-stage transient)
    budget = (raw["total_bytes"] + fp8["total_bytes"]) // 2
    with pytest.raises(ValueError, match="exceeds budget"):
        base.validate(cfg, act_budget=budget, **kw)
    dataclasses.replace(base, stash="fp8").validate(
        cfg, act_budget=budget, **kw
    )


def test_auto_plan_stash_escalation():
    import dataclasses

    from repro.core.partitioner import ParallelPlan, auto_plan

    cfg = _tiny_cfg()
    kw = dict(global_batch=8, seq_len=64, itemsize=4)
    base = ParallelPlan(pp=2, microbatches=4)
    raw = base.stash_report(cfg, **kw)
    fp8c = dataclasses.replace(base, stash="fp8", stash_cot=True).stash_report(
        cfg, **kw
    )
    fp8c_full = dataclasses.replace(
        base, stash="fp8", stash_cot=True, remat="full"
    ).stash_report(cfg, **kw)
    # rung 2 (compress slots + cotangents, no remat) fits here
    budget = (raw["total_bytes"] + fp8c["total_bytes"]) // 2
    plan = auto_plan(cfg, 2, microbatches=4, tp=1, max_dp=1,
                     stash="raw", act_budget=budget, **kw)
    assert plan.stash == "fp8" and plan.stash_cot    # escalated raw -> fp8
    assert plan.remat == "none"                      # ...without paying remat
    assert "stash=fp8" in plan.describe()
    # only the last rung (compression + full remat) fits this one
    budget = (fp8c["total_bytes"] + fp8c_full["total_bytes"]) // 2
    plan = auto_plan(cfg, 2, microbatches=4, tp=1, max_dp=1,
                     stash="raw", act_budget=budget, **kw)
    assert plan.stash == "fp8" and plan.remat == "full"
    with pytest.raises(ValueError, match="no stash/remat rung fits"):
        auto_plan(cfg, 2, microbatches=4, tp=1, max_dp=1,
                  stash="raw", act_budget=1000, **kw)
    # an ample budget keeps the requested backend
    plan = auto_plan(cfg, 2, microbatches=4, tp=1, max_dp=1,
                     stash="raw", act_budget=raw["total_bytes"], **kw)
    assert plan.stash == "raw" and plan.remat == "none"


def test_stash_state_specs():
    from jax.sharding import PartitionSpec as P

    from repro.sharding.specs import stash_state_specs

    class _Mesh:
        shape = {"data": 1, "model": 1, "pipe": 4}

    state = {
        "codes": jax.ShapeDtypeStruct((4, 3, 2, 256), jnp.int8),
        "scales": jax.ShapeDtypeStruct((4, 3, 2), jnp.float32),
        "slot_axis_only": jax.ShapeDtypeStruct((3, 8), jnp.float32),
    }
    specs = stash_state_specs(state, _Mesh())
    assert specs["codes"] == P("pipe", None, None, None)
    assert specs["scales"] == P("pipe", None, None)   # shards WITH codes
    assert specs["slot_axis_only"] == P(None, None)

    class _Mesh2D:
        shape = {"data": 2, "model": 2}

    specs = stash_state_specs(state, _Mesh2D())
    assert specs["codes"] == P(None, None, None, None)


# ------------------------------------------------------------ roofline math
def test_roofline_stash_bytes():
    from repro.roofline.analysis import (
        predicted_pipeline_stash_bytes,
        predicted_stash_capacity_factor,
        stash_bytes_per_slot,
    )

    assert stash_bytes_per_slot(8192, "raw", 2) == 16384
    assert stash_bytes_per_slot(8192, "host", 2) == 16384
    assert stash_bytes_per_slot(8192, "fp8", 2) == 8192 + 32 * 4
    assert stash_bytes_per_slot(100, "int8", 4) == 256 + 4   # pads to 1 block
    assert predicted_stash_capacity_factor(8192, "fp8", 2) >= 1.8
    assert predicted_stash_capacity_factor(8192, "int8", 4) >= 3.6
    # closed form == the real backend's accounting on a same-size struct
    struct = jax.ShapeDtypeStruct((8192,), jnp.bfloat16)
    for name in ("raw", "int8", "fp8"):
        assert get_backend(name).slot_bytes(struct) == stash_bytes_per_slot(
            8192, name, 2
        )
    # pipeline state: act slots at stash width + cot slots native; host
    # keeps only the device window
    assert predicted_pipeline_stash_bytes(100, 4, 1, "raw", 4) == 5 * 400
    assert predicted_pipeline_stash_bytes(100, 4, 1, "host", 4,
                                          host_window=2) == 3 * 400
    # cot_stash prices cotangent slots at the codec width
    assert predicted_pipeline_stash_bytes(
        100, 4, 1, "fp8", 4, cot_stash="fp8"
    ) == 5 * (256 + 4)
    from repro.roofline.analysis import (
        predicted_stage_transient_bytes,
        predicted_stash_host_bytes,
    )

    # host spill: slots beyond the window, native width; 0 off-host
    assert predicted_stash_host_bytes(100, 4, "host", 4, host_window=2) == 2 * 400
    assert predicted_stash_host_bytes(100, 4, "host", 4, host_window=8) == 0
    assert predicted_stash_host_bytes(100, 4, "fp8", 4) == 0
    # within-stage transient: k live layers, collapsed to 1 by full remat
    assert predicted_stage_transient_bytes(100, 3, "none", 4) == 3 * 400
    assert predicted_stage_transient_bytes(100, 3, "full", 4) == 400
