"""Model-internals unit + property tests: RoPE, masks, MoE dispatch, stacks."""
from _hyp_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.models.attention import attention_apply, init_attention
from repro.models.layers import norm_apply, rope_apply
from repro.models.moe import capacity, init_moe, moe_apply
from repro.models.stack import build_segments, layer_specs, param_groups


# ------------------------------------------------------------------- RoPE
def test_rope_preserves_norm():
    x = jnp.asarray(np.random.RandomState(0).randn(2, 8, 4, 64), jnp.float32)
    pos = jnp.arange(8)[None].repeat(2, 0)
    out = rope_apply(x, pos, 1e4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 1, 1, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 1, 64), jnp.float32)

    def dot_at(m, n):
        qm = rope_apply(q, jnp.array([[m]]), 1e4)
        kn = rope_apply(k, jnp.array([[n]]), 1e4)
        return float(jnp.sum(qm * kn))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), abs=1e-4)
    assert dot_at(0, 0) == pytest.approx(dot_at(25, 25), abs=1e-4)


# -------------------------------------------------------------- attention
def test_causal_mask_no_future_leak():
    """Changing future tokens must not change past outputs."""
    d, H, Kv, hd, S = 32, 2, 1, 16, 16
    p = init_attention(jax.random.PRNGKey(0), d, H, Kv, hd)
    rng = np.random.RandomState(0)
    x1 = rng.randn(1, S, d).astype(np.float32)
    x2 = x1.copy()
    x2[:, 10:] += 5.0
    o1, _ = attention_apply(p, jnp.asarray(x1), n_heads=H, n_kv=Kv, head_dim=hd,
                            theta=1e4, chunk_q=8)
    o2, _ = attention_apply(p, jnp.asarray(x2), n_heads=H, n_kv=Kv, head_dim=hd,
                            theta=1e4, chunk_q=8)
    np.testing.assert_allclose(np.asarray(o1[:, :10]), np.asarray(o2[:, :10]),
                               atol=1e-5)


def test_sliding_window_ignores_distant_past():
    d, H, Kv, hd, S, W = 32, 2, 1, 16, 64, 8
    p = init_attention(jax.random.PRNGKey(1), d, H, Kv, hd)
    rng = np.random.RandomState(0)
    x1 = rng.randn(1, S, d).astype(np.float32)
    x2 = x1.copy()
    x2[:, :40] += 3.0  # beyond the window of the last 16 positions
    kw = dict(n_heads=H, n_kv=Kv, head_dim=hd, theta=1e4, window=W, chunk_q=16)
    o1, _ = attention_apply(p, jnp.asarray(x1), **kw)
    o2, _ = attention_apply(p, jnp.asarray(x2), **kw)
    np.testing.assert_allclose(np.asarray(o1[:, 56:]), np.asarray(o2[:, 56:]),
                               atol=1e-5)


def test_chunked_equals_unchunked():
    d, H, Kv, hd, S = 32, 4, 2, 16, 64
    p = init_attention(jax.random.PRNGKey(2), d, H, Kv, hd)
    x = jnp.asarray(np.random.RandomState(2).randn(2, S, d), jnp.float32)
    kw = dict(n_heads=H, n_kv=Kv, head_dim=hd, theta=1e4)
    o1, _ = attention_apply(p, x, chunk_q=S + 1, **kw)   # single chunk
    o2, _ = attention_apply(p, x, chunk_q=16, **kw)      # 4 chunks
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


# -------------------------------------------------------------------- MoE
@hypothesis.given(
    seed=st.integers(0, 20), top_k=st.integers(1, 4), E=st.sampled_from([4, 8])
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_moe_capacity_conservation(seed, top_k, E):
    """Every token's combine weight mass is <= 1 (dropped slots lose mass,
    never gain); output is zero for tokens whose every slot dropped."""
    d, dff, T = 32, 16, 64
    p = init_moe(jax.random.PRNGKey(seed), d, dff, E, gated=True)
    x = jnp.asarray(np.random.RandomState(seed).randn(1, T, d), jnp.float32)
    out, aux = moe_apply(p, x, top_k=top_k, capacity_factor=1.0, gated=True)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.99  # load-balance loss >= 1 at optimum E*sum(f*p)


def test_moe_uniform_router_balanced_no_drops():
    """With capacity_factor >= E/topk... a generous capacity, no drops: the
    output must equal the dense mixture computed directly."""
    d, dff, E, k, T = 16, 8, 4, 2, 32
    p = init_moe(jax.random.PRNGKey(0), d, dff, E, gated=False)
    x = jnp.asarray(np.random.RandomState(3).randn(1, T, d), jnp.float32)
    out, _ = moe_apply(p, x, top_k=k, capacity_factor=float(E), gated=False)

    # dense reference: full softmax-topk mixture
    logits = x.reshape(T, d) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / vals.sum(-1, keepdims=True)
    ref = np.zeros((T, d), np.float32)
    xt = np.asarray(x.reshape(T, d))
    for t in range(T):
        for j in range(k):
            e = int(idx[t, j])
            h = jax.nn.silu(xt[t] @ p["w_up"][e]) @ p["w_down"][e]
            ref[t] += float(vals[t, j]) * np.asarray(h)
    np.testing.assert_allclose(np.asarray(out.reshape(T, d)), ref, atol=1e-4)


def test_capacity_formula():
    assert capacity(1024, 2, 8, 1.25) == 320
    assert capacity(1, 1, 128, 1.0) == 1


# ------------------------------------------------------------------ stacks
def test_param_groups_recurrentgemma():
    cfg = get_config("recurrentgemma-2b")
    groups = param_groups(cfg)
    assert groups[0] == (("rglru", "rglru", "attn"), 8)
    assert groups[1] == (("rglru", "rglru"), 1)


def test_segments_gemma3_runtime_pattern():
    cfg = get_config("gemma3-1b")
    specs = layer_specs(cfg, seq_len=1024)
    segs = build_segments(cfg, specs)
    assert len(segs) == 2
    assert len(segs[0].unit_specs) == 6 and segs[0].repeats == 4
    assert len(segs[1].unit_specs) == 2 and segs[1].repeats == 1
    # 5 local + 1 global inside the unit
    kinds = [s.kind for s in segs[0].unit_specs]
    assert kinds == ["local"] * 5 + ["attn"]


def test_segments_sw_variant_long_context():
    cfg = get_config("granite-34b")
    specs = layer_specs(cfg, seq_len=524_288, long_variant=True)
    segs = build_segments(cfg, specs)
    assert segs[0].repeats == 11 and len(segs[0].unit_specs) == 8
    kinds = [s.kind for s in segs[0].unit_specs]
    assert kinds == ["local"] * 7 + ["attn"]
    assert specs[7].cache_len == 524_288          # global layer: full cache
    assert specs[0].cache_len == cfg.lc_window    # local layer: window cache


def test_norm_apply_layernorm_and_rmsnorm():
    x = jnp.asarray(np.random.RandomState(0).randn(4, 32) * 3 + 1, jnp.float32)
    out_ln = norm_apply({"scale": jnp.ones(32), "bias": jnp.zeros(32)}, x, "layernorm")
    np.testing.assert_allclose(np.asarray(out_ln).mean(-1), 0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_ln).std(-1), 1, atol=1e-2)
    out_rms = norm_apply({"scale": jnp.ones(32)}, x, "rmsnorm")
    rms = np.sqrt((np.asarray(out_rms) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1, atol=1e-3)
