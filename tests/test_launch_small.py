"""Launch-path integration on a small (2x2) mesh via subprocess:
reduced archs x all four shape kinds must lower + compile + RUN a step.

This is the executable twin of the 512-device dry-run: same build_train /
build_prefill / build_decode code, real numerics on 4 fake devices.
"""
import os
import subprocess
import sys
import textwrap

from _subproc import REPO_ROOT, subprocess_env

import pytest

pytestmark = pytest.mark.multidevice


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_reduced, ShapeSpec
    import repro.configs.registry as registry
    from repro.launch.train import build_decode, build_prefill, build_train
    from repro.train import TrainConfig
    from repro.optim import get as get_opt
    from repro.train import make_state

    ARCH = "{arch}"
    cfg = get_reduced(ARCH)
    registry.ARCHITECTURES[cfg.name] = cfg
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    tc = TrainConfig(precision="f32", remat="full", zero_stage={zero})

    # ---- train ----
    shape = ShapeSpec("t", 64, 8, "train")
    jitted, (s_struct, b_struct) = build_train(cfg.name, mesh, tc, shape)
    state = make_state(cfg, get_opt(tc.optimizer, tc.lr), tc)
    state = jax.tree.map(lambda x, st: jax.device_put(x, st.sharding), state, s_struct)
    rng = np.random.RandomState(0)
    batch = {{
        "tokens": rng.randint(0, cfg.vocab_size, (8, 64)).astype(np.int32),
        "labels": rng.randint(0, cfg.vocab_size, (8, 64)).astype(np.int32),
    }}
    if cfg.frontend is not None:
        batch["frontend_embeds"] = rng.randn(8, cfg.frontend_tokens, cfg.d_model).astype(np.float32)
    batch = jax.tree.map(lambda v, st: jax.device_put(jnp.asarray(v), st.sharding), batch, b_struct)
    state2, metrics = jitted(state, batch)   # donates `state`
    loss1 = float(metrics["loss"])
    state3, metrics = jitted(state2, batch)  # donates `state2`
    assert np.isfinite(loss1) and np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) < loss1 + 1.0

    # ---- prefill ----
    pshape = ShapeSpec("p", 64, 4, "prefill")
    jit_p, (p_struct, pb_struct) = build_prefill(cfg.name, mesh, pshape, tc)
    params = jax.tree.map(lambda x, st: jax.device_put(x, st.sharding),
                          state3["params"], p_struct)
    pb = {{"tokens": batch["tokens"][:4]}}
    if cfg.frontend is not None:
        pb["frontend_embeds"] = batch["frontend_embeds"][:4]
    pb = jax.tree.map(lambda v, st: jax.device_put(jnp.asarray(v), st.sharding), pb, pb_struct)
    logits = jit_p(params, pb)
    assert np.isfinite(np.asarray(logits)).all()

    # ---- decode ----
    dshape = ShapeSpec("d", 64, 4, "decode")
    jit_d, (pd_struct, c_struct, t_struct) = build_decode(cfg.name, mesh, dshape, tc)
    cache = jax.tree.map(lambda st: jnp.zeros(st.shape, st.dtype), c_struct)
    cache = jax.tree.map(lambda x, st: jax.device_put(x, st.sharding), cache, c_struct)
    tok = jax.device_put(jnp.zeros((4,), jnp.int32), t_struct.sharding)
    logits, new_cache = jit_d(params, cache, tok)
    assert np.isfinite(np.asarray(logits)).all()
    print("LAUNCH_OK", ARCH)
    """
)


@pytest.mark.parametrize(
    "arch,zero",
    [
        ("granite-8b", 1),
        ("gemma3-1b", 3),
        ("qwen3-moe-30b-a3b", 2),
        ("falcon-mamba-7b", 1),
        ("recurrentgemma-2b", 0),
        ("seamless-m4t-medium", 1),
        ("phi-3-vision-4.2b", 3),
    ],
)
def test_launch_small_mesh(arch, zero):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(arch=arch, zero=zero)],
        capture_output=True, text=True, timeout=1200,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert f"LAUNCH_OK {arch}" in r.stdout
