"""Roofline HLO parser: synthetic-HLO unit tests."""
import pytest

from repro.roofline.analysis import (
    _shape_bytes,
    analyze,
    collective_bytes,
    dot_flops,
    loop_scaling_factor,
    _split_computations,
    _multipliers,
)

HLO = """\
HloModule test

%while_body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add.1
  %d = f32[128,512]{1,0} dot(f32[128,256]{1,0} %ar, f32[256,512]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
}

%while_cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %ag = f32[64,1024]{1,0} all-gather(f32[16,1024]{1,0} %g), replica_groups={{0,1,2,3}}, dimensions={0}
  %w = (s32[], f32[128,256]) while((s32[], f32[128,256]) %init), condition=%while_cond.1, body=%while_body.1
  %d2 = f32[8,8]{1,0} dot(f32[8,4]{1,0} %p, f32[4,8]{1,0} %q), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[4]") == 8
    assert _shape_bytes("(s32[], f32[2,2])") == 4 + 16
    assert _shape_bytes("pred[]") == 1


def test_computation_split_and_trip_count():
    comps = _split_computations(HLO)
    assert "while_body.1" in comps and "main" in comps
    mult = _multipliers(comps, trip_hint=99)
    assert mult["while_body.1"] == 12  # from the cond constant, not the hint


def test_collective_bytes_loop_multiplied():
    stats = collective_bytes(HLO, n_devices=4, trip_hint=1)
    ar_once = 2 * 128 * 256 * 4 * (3 / 4)  # 2x size x (g-1)/g
    assert stats.bytes_by_kind["all-reduce"] == pytest.approx(ar_once * 12)
    ag = 64 * 1024 * 4 * (3 / 4)
    assert stats.bytes_by_kind["all-gather"] == pytest.approx(ag)
    assert stats.count_by_kind["all-reduce"] == 12


def test_dot_flops_and_loop_factor():
    comps = _split_computations(HLO)
    once = dot_flops(comps, {})
    body_dot = 2 * 128 * 512 * 256
    entry_dot = 2 * 8 * 8 * 4
    assert once == pytest.approx(body_dot + entry_dot)
    mult = _multipliers(comps, 1)
    many = dot_flops(comps, mult)
    assert many == pytest.approx(12 * body_dot + entry_dot)
    factor = loop_scaling_factor(HLO, 1)
    assert factor == pytest.approx(many / once)


def test_analyze_end_to_end():
    r = analyze(
        arch="a", shape="s", mesh_name="single", n_devices=4,
        cost={"flops": 1e12, "bytes accessed": 1e9},
        hlo=HLO, trip_hint=12, model_flops=4e13,
    )
    assert r.loop_factor > 1
    assert r.t_compute > 0 and r.t_memory > 0 and r.t_collective > 0
    assert r.dominant in ("compute", "memory", "collective")
    # trivial consistency: terms recompute from fields
    assert r.t_compute == pytest.approx(r.flops / 197e12)


def test_fusion_calls_inherit_multiplier():
    hlo = """\
%fused_computation.1 (p: f32[64,64]) -> f32[64,64] {
  %d = f32[64,64]{1,0} dot(f32[64,64]{1,0} %a, f32[64,64]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%wbody (p: s32[]) -> s32[] {
  %f = f32[64,64]{1,0} fusion(f32[64,64]{1,0} %x), kind=kLoop, calls=%fused_computation.1
}

%wcond (p: s32[]) -> pred[] {
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %m (a: f32[64,64]) -> f32[64,64] {
  %w = s32[] while(s32[] %init), condition=%wcond, body=%wbody
}
"""
    comps = _split_computations(hlo)
    mult = _multipliers(comps, 1)
    assert mult.get("fused_computation.1") == 7
