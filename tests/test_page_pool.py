"""Property-based tests for the serve page-pool allocator.

Invariants under arbitrary admit/append/fork/evict sequences: no page is
leaked or double-assigned, the null page is never handed out, the high-water
mark respects the budget (the pool raises instead of overcommitting), and
freed pages are reusable.

Quantized pools add a device-side invariant: the per-(page-slot, head) f32
scale buffers share the page id with their codes, so COW copies must move
codes + scales together and freeing a page frees its scales (the next writer
overwrites both)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import hypothesis, st

from repro.serve.pool import PagePool, PoolExhausted


def test_alloc_append_free_roundtrip():
    pool = PagePool(num_pages=9, page_size=4)
    a = pool.alloc(6)          # 2 pages
    b = pool.alloc(4)          # 1 page
    assert pool.pages_in_use == 3
    assert sorted(pool.seq_pages(a) + pool.seq_pages(b)) == [1, 2, 3]
    pool.append(a, 3)          # 6 -> 9 tokens: 3 pages
    assert len(pool.seq_pages(a)) == 3
    pool.free(a)
    assert pool.pages_in_use == 1
    c = pool.alloc(16)         # reuses a's freed pages
    assert len(pool.seq_pages(c)) == 4
    pool.check()


def test_exhaustion_raises_without_leaking():
    pool = PagePool(num_pages=4, page_size=2)   # budget 3
    a = pool.alloc(4)
    with pytest.raises(PoolExhausted):
        pool.alloc(4)
    pool.check()
    assert pool.pages_in_use == 2
    with pytest.raises(PoolExhausted):
        pool.append(a, 5)      # needs 3 more pages, 1 free
    pool.check()
    pool.free(a)
    assert pool.pages_in_use == 0
    assert pool.high_water == 2


def test_fork_shares_then_copies_on_write():
    pool = PagePool(num_pages=9, page_size=4)
    a = pool.alloc(6)          # pages [1, 2], tail half-filled
    b = pool.fork(a)
    assert pool.seq_pages(b) == pool.seq_pages(a)
    assert pool.pages_in_use == 2          # fully shared
    pool.append(b, 1)          # writes into shared partial tail -> COW
    copies = pool.drain_copies()
    assert len(copies) == 1 and copies[0][0] == pool.seq_pages(a)[-1]
    assert pool.seq_pages(b)[-1] != pool.seq_pages(a)[-1]
    assert pool.seq_pages(b)[0] == pool.seq_pages(a)[0]  # full page shared
    pool.check()
    # full tail page: fork then append allocates without copying
    c = pool.alloc(4)
    d = pool.fork(c)
    pool.append(d, 1)
    assert pool.drain_copies() == []
    assert pool.seq_pages(d)[0] == pool.seq_pages(c)[0]
    pool.check()


def test_fork_free_order_independent():
    pool = PagePool(num_pages=5, page_size=2)
    a = pool.alloc(4)
    b = pool.fork(a)
    pool.free(a)               # b still holds the pages
    assert pool.pages_in_use == 2
    pool.free(b)
    assert pool.pages_in_use == 0
    pool.check()


def test_truncate_releases_tail_pages():
    """The speculative-decoding rollback verb: shrink a reservation to the
    committed token count, releasing exactly the pages past it."""
    pool = PagePool(num_pages=9, page_size=4)
    a = pool.alloc(15)                 # 4 pages
    keep = pool.seq_pages(a)[:2]
    pool.truncate(a, 7)                # -> 2 pages
    assert pool.seq_pages(a) == keep
    assert pool.pages_in_use == 2
    pool.truncate(a, 7)                # idempotent
    pool.truncate(a, 12)               # growing is not truncate's job: no-op
    assert pool.seq_pages(a) == keep
    pool.ensure(a, 12)                 # the grow verb re-extends
    assert len(pool.seq_pages(a)) == 3
    pool.truncate(a, 0)                # floor 1 token, like alloc
    assert len(pool.seq_pages(a)) == 1
    pool.check()
    # COW safety: truncating a fork releases only the fork's refs; shared
    # pages survive for the other sequence
    b = pool.alloc(8)
    c = pool.fork(b)
    pool.truncate(c, 1)
    assert len(pool.seq_pages(b)) == 2
    pool.free(b)
    pool.free(c)
    pool.free(a)
    pool.check()
    assert pool.pages_in_use == 0


@hypothesis.given(
    st.lists(
        st.tuples(st.integers(0, 4), st.integers(1, 9)),
        min_size=1, max_size=60,
    )
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_pool_invariants_under_random_ops(ops):
    """ops: (verb, amount) with verb 0=alloc 1=append 2=free 3=fork
    4=truncate (the spec-decode rollback verb); the amount doubles as the
    token count / live-sequence selector."""
    pool = PagePool(num_pages=8, page_size=3)   # budget 7
    live = []
    for verb, n in ops:
        try:
            if verb == 0:
                live.append(pool.alloc(n))
            elif verb == 1 and live:
                pool.append(live[n % len(live)], n)
            elif verb == 2 and live:
                pool.free(live.pop(n % len(live)))
            elif verb == 3 and live:
                live.append(pool.fork(live[n % len(live)]))
            elif verb == 4 and live:
                pool.truncate(live[n % len(live)], n - 1)
        except PoolExhausted:
            pass                                # refusal must not corrupt
        pool.check()
        assert pool.high_water <= pool.budget
        assert 0 <= pool.pages_in_use <= pool.budget
    for sid in live:
        pool.free(sid)
    pool.check()
    assert pool.pages_in_use == 0               # nothing leaked
    # freed pages are reusable: the whole budget is allocatable again
    full = pool.alloc(pool.budget * pool.page_size)
    assert len(pool.seq_pages(full)) == pool.budget
    pool.check()


@hypothesis.given(st.integers(1, 40), st.integers(1, 6))
@hypothesis.settings(max_examples=40, deadline=None)
def test_pages_for_matches_alloc(n_tokens, page_size):
    pool = PagePool(num_pages=64, page_size=page_size)
    sid = pool.alloc(n_tokens)
    assert len(pool.seq_pages(sid)) == pool.pages_for(n_tokens)
    assert pool.pages_for(n_tokens) * page_size >= n_tokens


# ------------------------------------------------- quantized pool state
@hypothesis.given(
    st.lists(
        st.tuples(st.integers(0, 3), st.integers(1, 9)),
        min_size=1, max_size=30,
    )
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_quantized_pool_scales_track_pages(ops):
    """Device-side shadow of the allocator workload under an int8 pool: the
    scale buffers are indexed by the same page ids as the codes, so (a)
    every live sequence's pages carry exactly the scales its writer stamped,
    (b) a COW copy moves codes AND scales, and (c) freed pages' scales are
    simply overwritten by the next writer — freeing a page frees its scales.
    """
    from repro.kernels.paged_attention import quant
    from repro.models.attention import init_paged_kv_cache

    page, n_kv, hd = 3, 2, 4
    pool = PagePool(num_pages=8, page_size=page)
    cache = init_paged_kv_cache(8, page, n_kv, hd, jnp.float32,
                                kv_dtype="int8")
    assert set(cache) == {"kp", "vp", "ksc", "vsc"}
    assert cache["kp"].dtype == jnp.int8
    assert cache["ksc"].shape == (8, page, n_kv)
    expected = {}            # page id -> stamped scale value

    def stamp(sid, pages):
        """Write the constant row ``sid + 1`` into each page: absmax is
        exact so the int8 round trip is lossless and the scale is known."""
        val = float(sid % 5 + 1)
        x = jnp.full((len(pages), page, n_kv, hd), val, jnp.float32)
        codes, scales = quant.kv_quantize(x, cache["kp"].dtype)
        idx = jnp.asarray(pages)
        cache["kp"] = cache["kp"].at[idx].set(codes)
        cache["ksc"] = cache["ksc"].at[idx].set(scales)
        for p in pages:
            expected[p] = val / 127.0

    live = {}
    for verb, n in ops:
        try:
            if verb == 0:
                sid = pool.alloc(n)
                live[sid] = None
                stamp(sid, pool.seq_pages(sid))
            elif verb == 1 and live:
                sid = list(live)[n % len(live)]
                before = set(pool.seq_pages(sid))
                pool.append(sid, n)
                for src, dst in pool.drain_copies():   # COW: move both
                    for key in ("kp", "ksc"):
                        cache[key] = cache[key].at[dst].set(cache[key][src])
                    expected[dst] = expected[src]
                stamp(sid, [p for p in pool.seq_pages(sid)
                            if p not in before])
            elif verb == 2 and live:
                sid = list(live)[n % len(live)]
                del live[sid]
                pool.free(sid)
            elif verb == 3 and live:
                sid = list(live)[n % len(live)]
                live[pool.fork(sid)] = None
        except PoolExhausted:
            pass
        pool.check()
        ksc = np.asarray(cache["ksc"])
        for sid in live:
            for p in pool.seq_pages(sid):
                np.testing.assert_allclose(
                    ksc[p], expected[p], rtol=1e-6,
                    err_msg=f"page {p} of sid {sid}: scales drifted",
                )
    # the null page's scales stay zero: it dequantizes to exact zeros
    assert np.all(np.asarray(cache["ksc"])[0] == 0.0)
    for sid in list(live):
        pool.free(sid)
    pool.check()
    assert pool.pages_in_use == 0


def test_kv_quant_roundtrip_error_bounds():
    """quantize -> dequantize obeys the per-row analytic bounds, and the
    per-(token, head) symmetric scheme is RMS-comparable to the blockwise
    dynamic-map reference tier (``kernels.blockwise_quant.ref``) on the
    same heavy-tailed data — both are 8-bit absmax-scaled codes."""
    from repro.kernels.blockwise_quant.ref import dequantize_ref, quantize_ref
    from repro.kernels.paged_attention import quant

    rng = np.random.RandomState(0)
    x = jnp.asarray(
        rng.randn(64, 8, 16) * np.exp(rng.randn(64, 8, 16)), jnp.float32
    )
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)

    # int8: round-to-nearest at scale = absmax/127 -> |err| <= scale/2
    c8, s8 = quant.kv_quantize(x, jnp.int8)
    err8 = jnp.abs(x - quant.kv_dequantize(c8, s8))
    assert bool(jnp.all(err8 <= s8[..., None] * 0.5 + 1e-7))

    # fp8 e4m3: 3 mantissa bits -> relative half-ulp 2^-4 of the row max
    cf, sf = quant.kv_quantize(x, jnp.float8_e4m3fn)
    errf = jnp.abs(x - quant.kv_dequantize(cf, sf))
    assert bool(jnp.all(errf <= absmax / 14.0 + 1e-7))

    # zero rows are exact (null-page semantics): scale 0, codes 0
    z = jnp.zeros((2, 3, 16), jnp.float32)
    for dt in (jnp.int8, jnp.float8_e4m3fn):
        cz, sz = quant.kv_quantize(z, dt)
        assert bool(jnp.all(sz == 0))
        assert bool(jnp.all(quant.kv_dequantize(cz, sz) == 0))

    # RMS comparability with the blockwise dynamic-map tier
    flat = x.reshape(-1)
    n = flat.shape[0] - flat.shape[0] % 256
    idx, sc = quantize_ref(flat[:n])
    err_blk = jnp.abs(flat[:n] - dequantize_ref(idx, sc))
    rms = lambda e: float(jnp.sqrt(jnp.mean(e**2)))  # noqa: E731
    ratio = rms(err8) / max(rms(err_blk), 1e-12)
    assert 0.25 < ratio < 4.0, ratio


# ------------------------------------------------- radix prefix workloads
@hypothesis.given(
    st.lists(
        st.tuples(st.integers(0, 4), st.integers(0, 2 ** 16)),
        min_size=1, max_size=80,
    )
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_pool_invariants_under_radix_workload(ops):
    """Fork-heavy adopt/insert/evict/free interleavings through the prefix
    cache: no page is ever leaked or double-freed, the cache's retains and
    the sequences' refs always reconcile (``pool.check``), adopted pages
    can never be evicted out from under a live sequence, and the high-water
    mark respects the budget.

    verbs: 0 = match+adopt+extend a prompt, 1 = retire (insert prompt pages
    into the radix tree, free the sequence), 2 = free without inserting,
    3 = evict_until(n), 4 = append to a live sequence. Prompts draw from a
    3-symbol alphabet so shared prefixes (and node splits) are common.
    """
    from repro.serve.prefix import PrefixCache

    page = 2
    pool = PagePool(num_pages=12, page_size=page)   # budget 11: real pressure
    cache = PrefixCache(pool)
    live = []   # (sid, prompt)

    def mkprompt(seed):
        rng = [(seed >> (2 * i)) % 3 for i in range(8)]
        n = 3 + seed % 6
        return [1 + r for r in rng[:n]]

    for verb, arg in ops:
        try:
            if verb == 0:
                prompt = mkprompt(arg)
                C, pages = cache.match(prompt, max_tokens=len(prompt) - 1)
                sid = pool.adopt(pages, C) if C else pool.alloc(len(prompt))
                if C:
                    try:
                        pool.ensure(sid, len(prompt))
                    except PoolExhausted:
                        pool.free(sid)      # all-or-nothing admission
                        raise
                live.append((sid, prompt))
            elif verb == 1 and live:
                sid, prompt = live.pop(arg % len(live))
                n_full = len(prompt) // page
                cache.insert(prompt, pool.seq_pages(sid)[:n_full])
                pool.free(sid)
            elif verb == 2 and live:
                sid, _ = live.pop(arg % len(live))
                pool.free(sid)
            elif verb == 3:
                cache.evict_until(1 + arg % 4)
            elif verb == 4 and live:
                sid, _ = live[arg % len(live)]
                pool.append(sid, 1 + arg % 3)
        except PoolExhausted:
            pass                                # refusal must not corrupt
        pool.check()
        cache.check()
        assert pool.high_water <= pool.budget
        # a page referenced by any live sequence is never on the free list
        # (checked inside pool.check) and never evictable:
        for sid, _ in live:
            for p in pool.seq_pages(sid):
                assert pool.refcount(p) >= 1
    for sid, _ in live:
        pool.free(sid)
    pool.check()
    cache.check()
    cache.evict_until(pool.budget)
    assert pool.pages_in_use == 0               # nothing leaked
    full = pool.alloc(pool.budget * pool.page_size)
    assert len(pool.seq_pages(full)) == pool.budget
    pool.check()
