"""Remat solvers: optimality vs brute force + policy sanity (+ hypothesis)."""
from _hyp_compat import hypothesis, st
import pytest

from repro.core.remat_solver import (
    RematPlan,
    binomial,
    brute_force,
    dtr_scores,
    dynprog_het,
    periodic,
    simulate,
)


def test_simulate_no_checkpoints_baseline():
    # only segment 0 checkpointed: backward replays the whole chain once
    extra, peak = simulate(8, [0])
    assert extra == 8
    assert peak == 8  # replaying the single span stores everything


def test_simulate_all_checkpoints():
    extra, peak = simulate(8, range(8))
    assert extra == 8  # each span of length 1 replays its own segment
    assert peak == 8


def test_periodic_reduces_peak():
    full = simulate(16, [0])[1]
    plan = periodic(16, budget=4)
    assert plan.peak_memory < full
    assert plan.extra_forwards >= 16  # recompute cost paid


@pytest.mark.parametrize("n,budget", [(6, 2), (8, 3), (10, 4)])
def test_dynprog_matches_bruteforce(n, budget):
    t = [1.0 + 0.3 * (i % 3) for i in range(n)]
    a = [1.0 + 0.5 * ((i + 1) % 2) for i in range(n)]
    mem = budget + 2.0
    bf = brute_force(n, mem, t, a)
    dp = dynprog_het(t, a, mem)
    assert dp.peak_memory <= mem + 1e-9
    assert dp.extra_forwards <= bf.extra_forwards + 1e-9, (dp, bf)


def test_binomial_beats_or_ties_periodic_uniform():
    for n, m in [(12, 3), (16, 4), (24, 4)]:
        b = binomial(n, m)
        p = periodic(n, m)
        # compare at equal achieved memory
        if b.peak_memory <= p.peak_memory:
            assert b.extra_forwards <= p.extra_forwards


def test_dtr_keeps_expensive_segments():
    t = [10.0, 1.0, 1.0, 10.0, 1.0, 1.0]
    a = [1.0] * 6
    plan = dtr_scores(t, a, keep=3)
    assert 3 in plan.checkpoints  # expensive segment stays resident
    assert 0 in plan.checkpoints


@hypothesis.given(
    n=st.integers(2, 9),
    seed=st.integers(0, 100),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_property_simulate_monotone_memory(n, seed):
    """Adding a checkpoint never increases replay time; peak memory respects
    the stored-checkpoint lower bound."""
    import random

    rng = random.Random(seed)
    t = [1.0 + rng.random() for _ in range(n)]
    a = [1.0 + rng.random() for _ in range(n)]
    cps = sorted(rng.sample(range(n), rng.randint(1, n)))
    if 0 not in cps:
        cps = [0] + cps
    extra, peak = simulate(n, cps, t, a)
    assert peak >= max(a)  # at least one span activation resident
    # adding every checkpoint reduces replay to sum(t)
    extra_all, _ = simulate(n, range(n), t, a)
    assert extra_all <= extra + 1e-9


@hypothesis.given(st.integers(4, 20), st.integers(2, 6))
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_binomial_cost_matches_recurrence(n, m):
    from repro.core.remat_solver import _opt_cost

    # REVOLVE closed form for m=1: l(l-1)/2
    assert _opt_cost(n, 1) == n * (n - 1) // 2
    # monotone in budget
    assert _opt_cost(n, m + 1) <= _opt_cost(n, m)
