"""Sharded serving: tensor-parallel paged decode over the (data, model) mesh.

Subprocess tests on forced host devices (the in-process jax backend is
already locked to one CPU device):

* TP=2 / 2x2 engines must produce TOKEN-IDENTICAL output to the TP=1
  engine for the same requests — the sharded decode is a layout change,
  not a numerics change the sampler can see.
* The KV pool's per-device bytes must shrink by the model-axis factor
  (kv-head axis sharding, ``sharding.specs.pool_kv_spec``).
* The Pallas paged kernel runs inside shard_map on per-shard head slices.
* ``ReplicatedServeEngine`` routes work to every data replica and matches
  the single engine.
* MQA families (kv heads don't divide TP) fall back to a replicated pool
  and still serve correctly.
* Quantized (int8) pools shard codes AND scale buffers over the model
  axis: token-identical to the single-device int8 engine, per-device
  bytes halved exactly.
"""
import subprocess
import sys
import textwrap

import pytest

from _subproc import REPO_ROOT, subprocess_env

pytestmark = pytest.mark.multidevice

HEADER = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_reduced
    from repro.launch.mesh import make_serve_mesh, replica_submeshes
    from repro.models import Runtime, init_params
    from repro.serve import EngineConfig, ReplicatedServeEngine, ServeEngine

    cfg = get_reduced("{arch}")
    rt = Runtime(dtype=jnp.float32, chunk_q=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (5, 11, 17, 8)]
    max_news = [9, 4, 12, 7]
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                        inner_steps=4)

    def run_engine(mesh, ec=ecfg):
        eng = ServeEngine(cfg, params, rt.replace(mesh=mesh), ec)
        rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
        out = eng.run()
        return eng, [out[r] for r in rids]
    """
)

TP_SCRIPT = HEADER.format(arch="moonshot-v1-16b-a3b") + textwrap.dedent(
    """
    eng1, out1 = run_engine(None)
    eng2, out2 = run_engine(make_serve_mesh(1, 2))
    eng4, out4 = run_engine(make_serve_mesh(2, 2))
    for a, b, c in zip(out1, out2, out4):
        np.testing.assert_array_equal(a, b)   # TP=2 == TP=1, every token
        np.testing.assert_array_equal(a, c)   # 2x2 mesh (data-replicated)
    b1 = eng1.kv_pool_bytes_per_device()
    b2 = eng2.kv_pool_bytes_per_device()
    assert b1 == 2 * b2, (b1, b2)             # kv-head shard halves KV/chip
    assert b2 == eng4.kv_pool_bytes_per_device()
    for eng in (eng1, eng2, eng4):
        eng.pool.check()
        assert eng.pool.pages_in_use == 0
    print("TP_OK", b1, b2)
    """
)

KERNEL_SCRIPT = HEADER.format(arch="moonshot-v1-16b-a3b") + textwrap.dedent(
    """
    ek = EngineConfig(max_slots=1, page_size=8, num_pages=9, max_len=32,
                      inner_steps=2, use_kernel=True)
    prompts, max_news = prompts[:1], [4]
    _, out_oracle = run_engine(None, EngineConfig(
        max_slots=1, page_size=8, num_pages=9, max_len=32, inner_steps=2))
    _, out_kernel = run_engine(make_serve_mesh(1, 2), ek)
    np.testing.assert_array_equal(out_oracle[0], out_kernel[0])
    print("KERNEL_SHARDED_OK")
    """
)

REPLICA_SCRIPT = HEADER.format(arch="moonshot-v1-16b-a3b") + textwrap.dedent(
    """
    rep = ReplicatedServeEngine(cfg, params, rt, ecfg,
                                mesh=make_serve_mesh(2, 2))
    assert len(rep.engines) == 2
    rids = [rep.submit(p, m) for p, m in zip(prompts, max_news)]
    out = rep.run()
    assert all(n > 0 for n in rep.stats["replica_requests"]), (
        rep.stats["replica_requests"])        # least-loaded: both replicas used
    _, alone = run_engine(None)
    for rid, want in zip(rids, alone):
        np.testing.assert_array_equal(out[rid], want)
    assert set(rep.stats["ttft_s"]) == set(rids)
    assert rep.stats["kv_pool_bytes_per_device"] > 0
    print("REPLICA_OK", rep.stats["replica_requests"])
    """
)

PREFIX_SCRIPT = HEADER.format(arch="moonshot-v1-16b-a3b") + textwrap.dedent(
    """
    # radix prefix cache + chunked prefill under TP: cache hits and chunk
    # scheduling are host-side and topology-blind, so TP=2 must be token-
    # identical to TP=1 on both the cold and the all-hit warm pass
    shared = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [np.concatenate([shared, t]) for t in (
        rng.randint(0, cfg.vocab_size, (5,)).astype(np.int32),
        rng.randint(0, cfg.vocab_size, (9,)).astype(np.int32),
        rng.randint(0, cfg.vocab_size, (3,)).astype(np.int32),
    )]
    max_news = [6, 4, 7]
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                        inner_steps=4, prefix_cache=True, prefill_chunk=4)

    def run_prefix(mesh):
        eng = ServeEngine(cfg, params, rt.replace(mesh=mesh), ecfg)
        rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
        cold = eng.run()
        rids2 = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
        warm = eng.run()
        eng.pool.check(); eng.prefix.check()
        return eng, [cold[r] for r in rids], [warm[r] for r in rids2]

    e1, cold1, warm1 = run_prefix(None)
    e2, cold2, warm2 = run_prefix(make_serve_mesh(1, 2))
    for a, b, c, d in zip(cold1, warm1, cold2, warm2):
        np.testing.assert_array_equal(a, b)   # cold == warm (reuse exact)
        np.testing.assert_array_equal(a, c)   # TP=1 == TP=2 cold
        np.testing.assert_array_equal(a, d)   # TP=1 == TP=2 warm
    assert e1.stats["prefix_hits"] >= 4 and e2.stats["prefix_hits"] >= 4
    assert e1.stats["prefix_hits"] == e2.stats["prefix_hits"]
    print("PREFIX_SHARDED_OK", e2.stats["prefix_hits"])
    """
)

QUANT_SCRIPT = HEADER.format(arch="moonshot-v1-16b-a3b") + textwrap.dedent(
    """
    # int8 KV pool under TP=2: the scale buffers shard their kv-head axis
    # alongside the code pools (sharding.specs.pool_scale_spec), so the
    # sharded engine is token-identical to the single-device int8 engine
    # and per-device pool bytes (codes + scales) halve exactly
    eq = EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                      inner_steps=4, kv_dtype="int8")
    eng1, out1 = run_engine(None, eq)
    eng2, out2 = run_engine(make_serve_mesh(1, 2), eq)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a, b)   # TP=2 == TP=1 at int8
    b1 = eng1.kv_pool_bytes_per_device()
    b2 = eng2.kv_pool_bytes_per_device()
    assert b1 == 2 * b2, (b1, b2)             # codes AND scales shard
    leaves = jax.tree.leaves(eng2._dev["caches"])
    assert any(l.dtype == jnp.int8 for l in leaves)      # quantized pool
    assert any(l.ndim == 4 and l.dtype == jnp.float32    # (R, N, page, Kv)
               for l in leaves)                          # scale buffers
    for eng in (eng1, eng2):
        eng.pool.check()
        assert eng.pool.pages_in_use == 0
    print("QUANT_SHARDED_OK", b1, b2)
    """
)

MQA_SCRIPT = HEADER.format(arch="granite-8b") + textwrap.dedent(
    """
    assert cfg.n_kv_heads == 1                # MQA: heads can't divide TP=2
    eng1, out1 = run_engine(None)
    eng2, out2 = run_engine(make_serve_mesh(1, 2))
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a, b)
    # pool falls back to replication: same bytes on every device
    assert eng1.kv_pool_bytes_per_device() == eng2.kv_pool_bytes_per_device()
    print("MQA_FALLBACK_OK")
    """
)


SPEC_SCRIPT = HEADER.format(arch="moonshot-v1-16b-a3b") + textwrap.dedent(
    """
    # speculative decoding under TP=2: drafting (host-side ngram lookup)
    # and the batched verify pass are layout-blind, so the spec engine at
    # TP=2 must be token-identical to spec-off at TP=1 — the strongest
    # form of the "drafting changes speed, never tokens" claim
    base = rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
    prompts = [np.tile(base, 4)] + prompts[:3]
    max_news = [10, 9, 4, 12]
    es = EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                      inner_steps=4, spec_tokens=3)
    eng0, out0 = run_engine(None)                       # spec-off TP=1
    eng1, out1 = run_engine(None, es)                   # spec-on  TP=1
    eng2, out2 = run_engine(make_serve_mesh(1, 2), es)  # spec-on  TP=2
    for a, b, c in zip(out0, out1, out2):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
    assert eng2.stats["spec_verify_calls"] > 0
    assert eng1.stats["spec_accepted_tokens"] == (
        eng2.stats["spec_accepted_tokens"])   # same ticks, same commits
    for eng in (eng1, eng2):
        eng.pool.check()
        assert eng.pool.pages_in_use == 0
    print("SPEC_SHARDED_OK", eng2.stats["spec_accepted_tokens"])
    """
)


def _run(script, marker):
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=1200,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert marker in r.stdout, r.stdout[-2000:]


def test_tp_engine_token_identical_and_kv_bytes_halved():
    _run(TP_SCRIPT, "TP_OK")


def test_paged_kernel_inside_shard_map_matches_oracle():
    _run(KERNEL_SCRIPT, "KERNEL_SHARDED_OK")


def test_replicated_engine_routes_and_matches_single():
    _run(REPLICA_SCRIPT, "REPLICA_OK")


def test_mqa_family_falls_back_to_replicated_pool():
    _run(MQA_SCRIPT, "MQA_FALLBACK_OK")


def test_quantized_pool_token_identical_and_bytes_halved_under_tp():
    _run(QUANT_SCRIPT, "QUANT_SHARDED_OK")


def test_prefix_cache_and_chunked_prefill_token_identical_under_tp():
    _run(PREFIX_SCRIPT, "PREFIX_SHARDED_OK")


def test_speculative_decoding_token_identical_under_tp():
    _run(SPEC_SCRIPT, "SPEC_SHARDED_OK")
