"""Plan-based remat granularity: trajectory-identical, memory-smaller."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SURVEY_DEMO, reduced
from repro.core.remat import period_from_plan
from repro.core.remat_solver import periodic
from repro.models import Runtime, init_params, loss_fn

CFG = reduced(SURVEY_DEMO, n_layers=8, d_model=128, n_heads=4, n_kv_heads=2,
              d_ff=256, vocab_size=512)


def grads_at(remat, period):
    rt = Runtime(dtype=jnp.float32, remat=remat, remat_period=period)
    params = init_params(CFG, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(
            np.random.RandomState(0).randint(0, 512, (2, 64)), jnp.int32),
        "labels": jnp.asarray(
            np.random.RandomState(1).randint(0, 512, (2, 64)), jnp.int32),
    }
    g = jax.jit(jax.grad(lambda p: loss_fn(CFG, p, batch, rt)[0]))(params)
    return g


def test_period_grads_identical():
    g1 = grads_at("none", 1)
    for period in (2, 4):
        g2 = grads_at("full", period)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-6)


def test_period_memory_matches_solver_cost_model():
    """Compiled temp bytes follow the remat_solver simulate() model:
    peak = stored checkpoints + in-flight recompute span. With small d_model
    the span term dominates, so temp grows with the period but every
    checkpointed variant stays far below remat=none (measured here:
    none 71 MiB > full@4 45 > full@2 36 > full@1 12)."""
    def temp_for(remat, period):
        rt = Runtime(dtype=jnp.float32, remat=remat, remat_period=period)
        params = init_params(CFG, jax.random.PRNGKey(0))
        batch = {
            "tokens": jnp.zeros((4, 128), jnp.int32),
            "labels": jnp.zeros((4, 128), jnp.int32),
        }
        c = jax.jit(
            jax.grad(lambda p: loss_fn(CFG, p, batch, rt)[0])
        ).lower(params).compile()
        return float(c.memory_analysis().temp_size_in_bytes)

    t_none = temp_for("none", 1)
    t1, t2, t4 = temp_for("full", 1), temp_for("full", 2), temp_for("full", 4)
    assert t1 < t2 < t4 < t_none, (t1, t2, t4, t_none)  # span-dominated regime


def test_period_from_plan():
    plan = periodic(16, budget=4)
    assert period_from_plan(plan) == 4
    plan1 = periodic(8, budget=8)
    assert period_from_plan(plan1) == 1
