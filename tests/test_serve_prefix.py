"""Prefix-cache subsystem: radix tree over the page pool + chunked prefill.

* Radix structure: page-aligned edges, node splitting on divergence, LRU
  eviction of idle leaves only (adopted pages are pinned by refcount).
* Engine correctness: with caching on, every request's output is token-
  identical to a cold-cache run and to running alone — the paged-prefill
  path computes bit-identical logits for a given row regardless of chunk
  offsets or what else is cached (fixed-width pool gathers, per-row
  reductions), so reuse can never change tokens.
* Chunked prefill: prompt chunks interleave with the decode batch in one
  jitted step (decode keeps advancing while a long prompt prefills) and
  an idle engine takes the prefill-only step (no decode-scan tax on TTFT).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import Runtime, init_params
from repro.serve import EngineConfig, PrefixCache, PagePool, ServeEngine
from repro.train.serve import generate

RT = Runtime(dtype=jnp.float32, chunk_q=32)


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_reduced(name)
            cache[name] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
        return cache[name]

    return get


# ------------------------------------------------------------- radix unit
def test_radix_match_insert_split_roundtrip():
    pool = PagePool(num_pages=33, page_size=4)
    cache = PrefixCache(pool)
    toks = list(range(12))
    sid = pool.alloc(12)
    assert cache.insert(toks, pool.seq_pages(sid)) == 3
    pool.free(sid)
    pool.check(), cache.check()

    # full-prefix match, capped below the prompt end
    C, pages = cache.match(toks + [99], max_tokens=12)
    assert C == 12 and len(pages) == 3
    # cap leaves the last token uncached
    C, _ = cache.match(toks, max_tokens=11)
    assert C == 8

    # divergence inside the second page -> only the first page matches
    C, pages = cache.match(list(range(4)) + [77, 78], max_tokens=6)
    assert C == 4 and len(pages) == 1

    # insert a diverging prompt: the shared first page gets its own node
    # (split), the tail a sibling — pages of the shared page are NOT
    # duplicated
    div = list(range(4)) + [50, 51, 52, 53]
    sid2 = pool.alloc(8)
    new = cache.insert(div, pool.seq_pages(sid2))
    assert new == 1                      # only the diverging page is new
    pool.free(sid2)
    cache.check(), pool.check()
    C, _ = cache.match(div, max_tokens=8)
    assert C == 8


def test_radix_adoption_pins_pages_against_eviction():
    pool = PagePool(num_pages=9, page_size=4)
    cache = PrefixCache(pool)
    sid = pool.alloc(8)
    cache.insert(list(range(8)), pool.seq_pages(sid))
    pool.free(sid)
    C, pages = cache.match(list(range(8)), max_tokens=8)
    adopted = pool.adopt(pages, C)
    # everything is pinned by the adopter: nothing evictable
    assert cache.evictable_pages() == 0
    assert cache.evict_until(2) == 0
    pool.free(adopted)
    assert cache.evictable_pages() == 2
    assert cache.evict_until(2) == 2
    pool.check(), cache.check()
    assert pool.pages_in_use == 0


def test_radix_lru_evicts_least_recently_used_leaf():
    pool = PagePool(num_pages=17, page_size=2)
    cache = PrefixCache(pool)
    prompts = [[1, 2, 10, 11], [1, 2, 20, 21], [1, 2, 30, 31]]
    for p in prompts:
        sid = pool.alloc(4)
        cache.insert(p, pool.seq_pages(sid))
        pool.free(sid)
    cache.check()
    # touch the first two; the third leaf is now LRU
    cache.match(prompts[0], max_tokens=4)
    cache.match(prompts[1], max_tokens=4)
    cache.evict_until(1)
    assert cache.match(prompts[2], max_tokens=4)[0] == 2  # tail gone
    assert cache.match(prompts[0], max_tokens=4)[0] == 4  # survivors intact
    assert cache.match(prompts[1], max_tokens=4)[0] == 4
    pool.check(), cache.check()


# ------------------------------------------ engine: cold == warm == alone
def _engine_alone(cfg, params, ecfg, prompt, max_new):
    eng = ServeEngine(cfg, params, RT, ecfg)
    rid = eng.submit(prompt, max_new)
    return eng.run()[rid]


def _dense_alone(cfg, params, prompt, max_new):
    out, _ = generate(
        cfg, params, {"tokens": jnp.asarray(prompt[None])}, RT, max_new
    )
    return np.asarray(out[0])


FAMILIES = [
    "granite-8b",           # dense full attention (paged + prefix path)
    "gemma3-1b",            # sliding-window (paged + prefix path)
    "falcon-mamba-7b",      # SSM -> dense fallback, cache bypassed
    "recurrentgemma-2b",    # RG-LRU -> dense fallback, cache bypassed
    "seamless-m4t-medium",  # enc-dec -> dense fallback, cache bypassed
    "phi-3-vision-4.2b",    # vision prefix -> legacy prefill, cache bypassed
]


@pytest.mark.parametrize("name", FAMILIES)
def test_prefix_cache_on_is_token_identical_all_families(arch_state, name):
    """Acceptance: caching on => outputs identical to a cold-cache run and
    to running alone, for every family. Paged attention families exercise
    real hits; fallback/vision families must bypass the cache unchanged."""
    cfg, params = arch_state(name)
    rng = np.random.RandomState(7)
    shared = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [
        np.concatenate(
            [shared, rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)]
        )
        for s in (5, 9, 3)
    ]
    max_news = [6, 4, 5]
    fes = [
        rng.randn(cfg.frontend_tokens, cfg.d_model).astype(np.float32)
        if cfg.frontend is not None else None
        for _ in prompts
    ]
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                        inner_steps=4, prefix_cache=True, prefill_chunk=4)
    eng = ServeEngine(cfg, params, RT, paged=None, engine=ecfg)
    rids = [
        eng.submit(p, m, frontend_embeds=fe)
        for p, m, fe in zip(prompts, max_news, fes)
    ]
    cold = eng.run()
    # warm: identical resubmission must reproduce the cold outputs exactly
    rids2 = [
        eng.submit(p, m, frontend_embeds=fe)
        for p, m, fe in zip(prompts, max_news, fes)
    ]
    warm = eng.run()
    for r1, r2, p, m, fe in zip(rids, rids2, prompts, max_news, fes):
        np.testing.assert_array_equal(cold[r1], warm[r2], err_msg=name)
        if fe is None:
            alone = _engine_alone(cfg, params, ecfg, p, m)
            np.testing.assert_array_equal(cold[r1], alone, err_msg=name)
    if eng.paged and cfg.frontend is None:
        assert eng.stats["prefix_hits"] >= len(prompts)   # warm pass hits
        eng.pool.check()
        eng.prefix.check()
        eng.prefix.clear()
        assert eng.pool.pages_in_use == 0
    else:
        # fallback/vision: the radix path must not have engaged
        assert eng.stats.get("prefix_lookups", 0) == 0


def test_prefix_cache_matches_dense_generate(arch_state):
    """Cross-path anchor: on these shapes the paged-prefill path is bit-
    identical to the dense prefill, so cache-on engine output == the dense
    generate used by every other serving test."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(0)
    shared = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [
        np.concatenate(
            [shared, rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)]
        )
        for s in (5, 9, 3, 7)
    ]
    max_news = [6, 4, 8, 5]
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                        inner_steps=4, prefix_cache=True, prefill_chunk=4)
    eng = ServeEngine(cfg, params, RT, ecfg)
    for _ in range(2):                      # cold pass, then all-hit pass
        rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
        out = eng.run()
        for rid, p, m in zip(rids, prompts, max_news):
            np.testing.assert_array_equal(
                out[rid], _dense_alone(cfg, params, p, m), err_msg=f"{rid}"
            )
    assert eng.stats["prefix_hits"] >= len(prompts)


def test_chunked_prefill_without_cache_is_exact(arch_state):
    """prefill_chunk alone (no radix tree): chunk-interleaved prefill must
    not change any output token, and the engine reports its chunk count."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (17, 11, 23)]
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                        inner_steps=4, prefill_chunk=8)
    eng = ServeEngine(cfg, params, RT, ecfg)
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(out[rid], _dense_alone(cfg, params, p, 5))
    assert eng.prefix is None
    assert eng.stats["prefill_chunks"] == sum(-(-len(p) // 8) for p in prompts)
    assert eng.pool.pages_in_use == 0


def test_decode_advances_while_long_prompt_prefills(arch_state):
    """The fused step's point: a decoding slot keeps emitting while another
    slot's long prompt goes through chunk-by-chunk."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(11)
    short = rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
    long = rng.randint(0, cfg.vocab_size, (40,)).astype(np.int32)
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                        inner_steps=2, prefill_chunk=8)
    eng = ServeEngine(cfg, params, RT, ecfg)
    r_short = eng.submit(short, 20)
    r_long = eng.submit(long, 4)
    out = eng.run()
    np.testing.assert_array_equal(out[r_short], _dense_alone(cfg, params, short, 20))
    np.testing.assert_array_equal(out[r_long], _dense_alone(cfg, params, long, 4))
    # the long prompt needed 5 chunks; the short request was decoding the
    # whole time, so its tokens landed across multiple fused steps
    assert eng.stats["prefill_chunks"] >= 5


def test_prefix_cache_under_eviction_pressure(arch_state):
    """Optimistic admission + a pool too small for everything: engine
    preemption and cache LRU eviction interleave, outputs stay exact, and
    nothing leaks."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(4)
    shared = rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
    prompts = [
        np.concatenate(
            [shared, rng.randint(0, cfg.vocab_size, (2,)).astype(np.int32)]
        )
        for _ in range(3)
    ]
    max_news = [24, 16, 12]
    ecfg = EngineConfig(max_slots=2, page_size=4, num_pages=14, max_len=48,
                        inner_steps=4, policy="optimistic",
                        prefix_cache=True, prefill_chunk=4)
    eng = ServeEngine(cfg, params, RT, ecfg)
    rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    out = eng.run()
    for rid, p, m in zip(rids, prompts, max_news):
        np.testing.assert_array_equal(
            out[rid], _dense_alone(cfg, params, p, m), err_msg=f"rid={rid}"
        )
    eng.pool.check()
    eng.prefix.check()
    eng.prefix.clear()
    assert eng.pool.pages_in_use == 0


def test_engine_reuse_and_stats_accumulate(arch_state):
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(9)
    p = rng.randint(0, cfg.vocab_size, (18,)).astype(np.int32)
    ecfg = EngineConfig(max_slots=1, page_size=8, num_pages=17, max_len=32,
                        inner_steps=2, prefix_cache=True)
    eng = ServeEngine(cfg, params, RT, ecfg)
    r0 = eng.submit(p, 4)
    o0 = eng.run()
    assert eng.stats["prefix_lookups"] == 1 and eng.stats["prefix_hits"] == 0
    r1 = eng.submit(p, 4)
    o1 = eng.run()
    np.testing.assert_array_equal(o0[r0], o1[r1])
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_cached_tokens"] == 16   # 2 full pages
    assert eng.stats["ttft_s"][r1] < 10.0            # sanity: recorded
