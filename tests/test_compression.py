"""Gradient compression: loopback correctness, EF accumulation, bytes, and
multi-device sync via subprocess shard_map (8 fake devices)."""
import subprocess
import sys
import textwrap

from _subproc import REPO_ROOT, subprocess_env

from _hyp_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (
    PowerSGD,
    QSGD,
    SignEF,
    TopK,
    init_state,
    sync,
    wire_bytes_dense,
)

PARAMS = {"w": jnp.zeros((64, 64)), "b": jnp.zeros((8,))}


def grads_like(seed=0, scale=1.0):
    rng = np.random.RandomState(seed)
    return {
        "w": jnp.asarray(rng.randn(64, 64).astype(np.float32)) * scale,
        "b": jnp.asarray(rng.randn(8).astype(np.float32)),
    }


@pytest.mark.parametrize(
    "method", [TopK(0.05), QSGD(8), SignEF(), PowerSGD(rank=8)],
    ids=["topk", "qsgd", "sign", "powersgd"],
)
def test_loopback_reasonable_approximation(method):
    g = grads_like()
    st_ = init_state(method, PARAMS)
    ghat, st2, nbytes = sync(method, g, st_, axis_name=None)
    # small leaf rides psum untouched
    np.testing.assert_allclose(np.asarray(ghat["b"]), np.asarray(g["b"]))
    # compressed leaf correlates with the true gradient
    a = np.asarray(ghat["w"]).ravel()
    b = np.asarray(g["w"]).ravel()
    cos = (a @ b) / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12)
    assert cos > 0.3, cos
    assert float(nbytes) < wire_bytes_dense(g)


def test_qsgd_high_bits_near_exact():
    g = grads_like()
    ghat, _, _ = sync(QSGD(8), g, None, axis_name=None)
    rel = np.linalg.norm(np.asarray(ghat["w"] - g["w"])) / np.linalg.norm(
        np.asarray(g["w"])
    )
    assert rel < 0.05, rel


def test_error_feedback_accumulates_dropped_mass():
    """With EF, repeatedly compressing the SAME gradient must converge:
    sum of transmitted approximations -> the true gradient direction."""
    method = TopK(0.02)
    g = grads_like(3)
    state = init_state(method, PARAMS)
    acc = jnp.zeros_like(g["w"])
    for _ in range(60):
        ghat, state, _ = sync(method, g, state, axis_name=None)
        acc = acc + ghat["w"]
    # mean transmitted ~ g after enough rounds (EF theorem)
    mean = np.asarray(acc / 60)
    rel = np.linalg.norm(mean - np.asarray(g["w"])) / np.linalg.norm(
        np.asarray(g["w"])
    )
    assert rel < 0.35, rel


def test_powersgd_rank_recovers_lowrank_gradient():
    u = np.random.RandomState(0).randn(64, 4).astype(np.float32)
    v = np.random.RandomState(1).randn(4, 64).astype(np.float32)
    g = {"w": jnp.asarray(u @ v), "b": jnp.zeros(8)}
    method = PowerSGD(rank=8)
    state = init_state(method, {"w": g["w"], "b": g["b"]})
    ghat = g
    for _ in range(3):  # a few power iterations via repeated sync
        ghat, state, _ = sync(method, g, state, axis_name=None)
    rel = np.linalg.norm(np.asarray(ghat["w"] - g["w"])) / np.linalg.norm(
        np.asarray(g["w"])
    )
    assert rel < 0.05, rel


def test_bytes_accounting_ordering():
    g = grads_like()
    dense = wire_bytes_dense(g)
    got = {}
    for m in [TopK(0.01), QSGD(8), SignEF(), PowerSGD(4)]:
        st_ = init_state(m, PARAMS)
        _, _, b = sync(m, g, st_, axis_name=None)
        got[m.name] = float(b)
    # topk@1% sends ~1% of elements (8B each) — below even 1-bit sign
    assert got["topk"] < got["sign"] < got["qsgd"] < dense
    assert got["qsgd"] < dense / 3.9  # ~4x from f32->int8
    assert got["powersgd"] < dense / 4


@hypothesis.given(
    ratio=st.floats(0.01, 0.5), seed=st.integers(0, 20), scale=st.floats(1e-3, 1e3)
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_topk_never_increases_norm(ratio, seed, scale):
    g = grads_like(seed, scale)
    method = TopK(ratio)
    ghat, _, _ = sync(method, g, init_state(method, PARAMS), axis_name=None)
    assert float(jnp.linalg.norm(ghat["w"])) <= float(jnp.linalg.norm(g["w"])) * 1.001


MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.compression import TopK, QSGD, init_state, sync

    mesh = jax.make_mesh((8,), ("data",))
    g_global = jnp.asarray(np.random.RandomState(0).randn(8, 64, 64), jnp.float32)

    def per_shard(g):   # g: (1, 64, 64) local shard
        grads = {"w": g[0]}
        ghat, _, _ = sync(QSGD(8), grads, None, axis_name="data")
        return ghat["w"][None]

    fn = jax.jit(shard_map(per_shard, mesh=mesh,
        in_specs=P("data"), out_specs=P("data"), check_vma=False))
    out = np.asarray(fn(g_global))
    want = np.asarray(jnp.mean(g_global, 0))
    for i in range(8):
        rel = np.linalg.norm(out[i] - want) / np.linalg.norm(want)
        assert rel < 0.05, rel
    # all shards agree (it was a collective mean)
    assert np.allclose(out[0], out[7], atol=1e-5)
    print("MULTIDEV_OK")
    """
)


@pytest.mark.multidevice
def test_multidevice_compressed_sync_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIDEV_OK" in r.stdout
