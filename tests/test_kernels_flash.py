"""Flash-attention kernel: shape/dtype/window sweeps vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernel

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def make_qkv(B, S, Kv, G, hd, dtype=jnp.float32, seed=0):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, S, Kv, G, hd), dtype) * (hd**-0.5)
    k = jnp.asarray(rng.randn(B, S, Kv, hd), dtype)
    v = jnp.asarray(rng.randn(B, S, Kv, hd), dtype)
    return q, k, v


SHAPES = [
    (1, 128, 1, 1, 64),    # MQA single head
    (2, 256, 2, 4, 32),    # GQA
    (1, 512, 4, 1, 128),   # MHA-ish, MXU-aligned head_dim
]


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s) for s in SHAPES])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_ref(shape, causal):
    q, k, v = make_qkv(*shape)
    out = flash_attention(q, k, v, causal, 0)
    ref = flash_attention_ref(q, k, v, causal=causal, window=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [32, 128, 250])
def test_sliding_window(window):
    q, k, v = make_qkv(1, 256, 2, 2, 32, seed=3)
    out = flash_attention(q, k, v, True, window)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_bf16_tolerance():
    q, k, v = make_qkv(1, 128, 2, 2, 64, dtype=jnp.bfloat16, seed=5)
    out = flash_attention(q, k, v, True, 0)
    ref = flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=True,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=2e-2, rtol=2e-2
    )


def test_gradients_match_ref():
    q, k, v = make_qkv(1, 128, 1, 2, 32, seed=7)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, 0) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, causal=True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)


def test_model_attention_kernel_path_matches_ref_path():
    """attention_apply(use_kernel=True) == attention_apply(use_kernel=False)."""
    from repro.models.attention import attention_apply, init_attention

    d, H, Kv, hd, S = 64, 4, 2, 16, 128
    p = init_attention(jax.random.PRNGKey(0), d, H, Kv, hd)
    x = jnp.asarray(np.random.RandomState(1).randn(2, S, d), jnp.float32)
    out_ref, _ = attention_apply(
        p, x, n_heads=H, n_kv=Kv, head_dim=hd, theta=1e4, chunk_q=32
    )
    out_ker, _ = attention_apply(
        p, x, n_heads=H, n_kv=Kv, head_dim=hd, theta=1e4, use_kernel=True
    )
    np.testing.assert_allclose(
        np.asarray(out_ker), np.asarray(out_ref), atol=3e-5, rtol=3e-5
    )
