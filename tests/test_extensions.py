"""Tests for the beyond-baseline extensions: pipeline partitioners,
4-bit optimizer + GradScale, 1-bit Adam."""
from _hyp_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.partitioner import (
    brute_force_partition,
    dp_pp_search,
    dynprog_partition,
    heuristic_partition,
    layer_costs_from_config,
)
from repro.optim import adamw, apply_updates
from repro.optim.lowbit4 import adam4bit, dynamic_map_4bit, dequantize4, quantize4
from repro.optim.onebit import onebit_adam


# ------------------------------------------------------------- partitioner
@pytest.mark.parametrize("seed,P", [(0, 2), (1, 3), (2, 4)])
def test_dynprog_partition_optimal(seed, P):
    rng = np.random.RandomState(seed)
    costs = (0.5 + rng.rand(12)).tolist()
    dp = dynprog_partition(costs, P)
    bf = brute_force_partition(costs, P)
    assert dp.bottleneck == pytest.approx(bf.bottleneck)
    assert dp.n_stages == P


def test_dynprog_beats_heuristic_on_heterogeneous():
    costs = [1.0] * 8 + [5.0] * 2 + [1.0] * 6   # hot tail segment
    dp = dynprog_partition(costs, 4)
    he = heuristic_partition(costs, 4)
    assert dp.bottleneck <= he.bottleneck


def test_partition_covers_all_layers():
    cfg = get_config("recurrentgemma-2b")
    costs = layer_costs_from_config(cfg)
    assert len(costs) == cfg.n_layers
    part = dynprog_partition(costs, 8)
    assert part.boundaries[0] == 0 and part.boundaries[-1] == cfg.n_layers
    assert sum(part.stage_costs) == pytest.approx(sum(costs))


def test_dp_pp_search_prefers_dp_for_uniform_small():
    # with generous microbatches, deep pipelines pay fill bubble: dp should win
    costs = [1.0] * 8
    choice = dp_pp_search(costs, n_devices=8, microbatches=4)
    assert choice.dp >= choice.pp


@hypothesis.given(st.integers(0, 30), st.integers(2, 5))
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_partition_bottleneck_bounds(seed, P):
    rng = np.random.RandomState(seed)
    costs = (0.1 + rng.rand(14)).tolist()
    part = dynprog_partition(costs, P)
    assert part.bottleneck >= sum(costs) / P - 1e-9     # averaging lower bound
    assert part.bottleneck >= max(costs) - 1e-9         # single-layer bound
    assert part.bottleneck <= sum(costs)                # single-stage bound


# ------------------------------------------------------------ 4-bit optim
def test_4bit_map_properties():
    m = dynamic_map_4bit()
    assert m.shape == (16,) and np.all(np.diff(m) >= 0)
    assert m.max() == 1.0 and 0.0 in m


def test_4bit_roundtrip_bounded():
    x = jnp.asarray(np.random.RandomState(0).randn(256 * 8), jnp.float32)
    c, s = quantize4(x)
    xr = dequantize4(c, s)
    assert int(c.max()) <= 15
    rel = float(jnp.sqrt(jnp.mean((x - xr) ** 2)) / jnp.sqrt(jnp.mean(x**2)))
    assert rel < 0.20, rel   # 4-bit dynamic map on gaussians: ~15% rms


def test_adam4bit_tracks_adamw():
    rng = np.random.RandomState(1)
    W = jnp.asarray(rng.randn(128, 64).astype(np.float32))
    p4 = {"w": jnp.zeros((128, 64))}
    p32 = {"w": jnp.zeros((128, 64))}

    def loss(p, x, y):
        return jnp.mean((x @ p["w"].T - y) ** 2)

    o4, o32 = adam4bit(1e-2), adamw(1e-2)
    s4, s32 = o4.init(p4), o32.init(p32)

    @jax.jit
    def step(p, s, x, y, which):
        g = jax.grad(loss)(p, x, y)
        upd, s = (o4 if which else o32).update(g, s, p)
        return apply_updates(p, upd), s

    step4 = jax.jit(lambda p, s, x, y: _apply(o4, loss, p, s, x, y))
    step32 = jax.jit(lambda p, s, x, y: _apply(o32, loss, p, s, x, y))
    for i in range(50):
        x = jnp.asarray(rng.randn(32, 64).astype(np.float32))
        y = x @ W.T
        p4, s4 = step4(p4, s4, x, y)
        p32, s32 = step32(p32, s32, x, y)
    l4, l32 = float(loss(p4, x, y)), float(loss(p32, x, y))
    assert l4 < 3.0 * l32 + 1e-2, (l4, l32)


def _apply(opt, loss, p, s, x, y):
    g = jax.grad(loss)(p, x, y)
    upd, s = opt.update(g, s, p)
    return apply_updates(p, upd), s


# ------------------------------------------------------------ 1-bit adam
def test_onebit_adam_loopback_converges():
    rng = np.random.RandomState(2)
    W = jnp.asarray(rng.randn(96, 48).astype(np.float32))
    p1 = {"w": jnp.zeros((96, 48))}

    def loss(p, x, y):
        return jnp.mean((x @ p["w"].T - y) ** 2)

    opt = onebit_adam(2e-2, warmup_steps=20)
    s = opt.init(p1)
    step = jax.jit(lambda p, s, x, y: _apply(opt, loss, p, s, x, y))
    losses = []
    for i in range(120):
        x = jnp.asarray(rng.randn(32, 48).astype(np.float32))
        y = x @ W.T
        p1, s = step(p1, s, x, y)
        losses.append(float(loss(p1, x, y)))
    assert losses[-1] < 0.25 * losses[0], (losses[0], losses[-1])
    # compression phase actually engaged
    assert int(s["step"]) > 20
    assert float(jnp.abs(jax.tree.leaves(s["ef"])[0]).sum()) > 0
