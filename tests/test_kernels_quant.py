"""Fused stash codec: Pallas-vs-jnp bitwise parity + overlapped host runner.

The fused kernels (kernels.blockwise_quant.stash_quantize_pallas /
stash_dequantize_pallas) must produce BITWISE-identical codes and scales to
the jnp reference (kernels.paged_attention.kv_quantize on flat blocks) —
that identity is what lets PR 9's grad-accuracy suite stand for the fused
path unchanged. Comparisons run against the JITTED reference: XLA CPU's
eager-mode division can differ from its jitted division by 1 ulp, and the
pipeline codec always executes under jit.

Also here: the hypothesis property that the prefetching host runner
(pipeline_grads_host lookahead > 0, HostStash poll/prefetch) is
bitwise-equal to the eager runner over random 1F1B/GPipe tick tables.
"""
from functools import partial

from _hyp_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernel

from repro.kernels.blockwise_quant.ops import (
    STASH_BLOCK,
    stash_dequantize,
    stash_quantize,
)

SHAPES = [(3, 7), (257,), (2, 2, 130), (64, 256), (33, 77)]


def _bits(a) -> np.ndarray:
    """Raw storage bytes — bitwise comparison that works for fp8/bf16."""
    return np.asarray(a).view(np.uint8)


def _quant_pair(x, storage):
    """(jitted jnp reference, pallas-interpret) quantizations of ``x``."""
    ref = jax.jit(partial(stash_quantize, storage=storage))(x)
    fused = jax.jit(
        partial(stash_quantize, storage=storage, backend="pallas")
    )(x)
    return ref, fused


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("storage", ["int8", "fp8"])
def test_stash_quantize_fused_bitwise_parity(shape, dtype, storage):
    rng = np.random.RandomState(hash((shape, storage)) % 2**31)
    x = jnp.asarray(rng.randn(*shape) * 3, dtype)
    (cr, sr), (cp, sp) = _quant_pair(x, storage)
    assert cp.dtype == cr.dtype and sp.dtype == sr.dtype
    np.testing.assert_array_equal(_bits(cp), _bits(cr))
    np.testing.assert_array_equal(_bits(sp), _bits(sr))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("storage", ["int8", "fp8"])
def test_stash_dequantize_fused_bitwise_parity(shape, dtype, storage):
    rng = np.random.RandomState(hash((shape, storage)) % 2**31 + 1)
    x = jnp.asarray(rng.randn(*shape), dtype)
    codes, scales = jax.jit(partial(stash_quantize, storage=storage))(x)
    ref = jax.jit(
        partial(stash_dequantize, shape=shape, dtype=dtype)
    )(codes, scales)
    fused = jax.jit(
        partial(stash_dequantize, shape=shape, dtype=dtype, backend="pallas")
    )(codes, scales)
    assert fused.shape == tuple(shape) and fused.dtype == jnp.dtype(dtype)
    np.testing.assert_array_equal(_bits(fused), _bits(ref))


def test_stash_fused_zeros_and_pad_blocks():
    # all-zero blocks quantize to scale 0 / code 0 on both paths, and the
    # pad tail (100 -> 256) plus pad rows (1 -> tile multiple) drop cleanly
    x = jnp.zeros(100, jnp.float32)
    (cr, sr), (cp, sp) = _quant_pair(x, "int8")
    np.testing.assert_array_equal(np.asarray(cp), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(sp), np.zeros_like(sp))
    back = stash_dequantize(cp, sp, (100,), jnp.float32, backend="pallas")
    np.testing.assert_array_equal(np.asarray(back), np.zeros(100))


@hypothesis.given(
    seed=st.integers(0, 50),
    n=st.integers(1, 4 * STASH_BLOCK + 3),
    storage=st.sampled_from(["int8", "fp8"]),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_property_stash_fused_parity(seed, n, storage):
    x = jnp.asarray(np.random.RandomState(seed).randn(n), jnp.float32)
    (cr, sr), (cp, sp) = _quant_pair(x, storage)
    np.testing.assert_array_equal(_bits(cp), _bits(cr))
    np.testing.assert_array_equal(_bits(sp), _bits(sr))


@pytest.mark.parametrize("storage", ["int8", "fp8"])
def test_quant_stash_backend_fused_put_get_identical(storage):
    """QuantStash(codec_backend='pallas') stores and returns the same bits
    as the jnp-ref backend on a real slot tree."""
    from repro.core.stash import QuantStash

    rng = np.random.RandomState(7)
    struct = jax.ShapeDtypeStruct((2, 5, 33), jnp.bfloat16)
    value = jnp.asarray(rng.randn(2, 5, 33), jnp.bfloat16)
    out = {}
    for backend_name in ("ref", "pallas"):
        b = QuantStash(storage, codec_backend=backend_name)
        state = jax.jit(
            lambda v: b.put(b.init(3, struct), 1, v)
        )(value)
        out[backend_name] = (
            state,
            jax.jit(lambda s: b.get(s, 1, struct))(state),
            jax.jit(b.roundtrip)(value),
        )
    for a, r in zip(jax.tree.leaves(out["pallas"]), jax.tree.leaves(out["ref"])):
        np.testing.assert_array_equal(_bits(a), _bits(r))


# --------------------------------------------- overlapped host runner parity
@hypothesis.given(
    seed=st.integers(0, 20),
    schedule=st.sampled_from(["1f1b", "gpipe"]),
    m_extra=st.integers(0, 2),
    lookahead=st.integers(1, 4),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_property_host_prefetch_bitwise_equals_eager(
    seed, schedule, m_extra, lookahead
):
    """Prefetching host runner == eager runner, bit for bit, over random
    tick tables — prefetch is a pure residency hint."""
    from test_stash import _toy_pipeline

    from repro.core.pipeline import pipeline_grads_host, tick_table
    from repro.core.stash import get_backend

    P, M, L, d = 2, 2 + m_extra, 4, 6
    stage_params, shared, mbs, first_fn, stage_fn, last_fn = _toy_pipeline(
        P, M, L, d, seed=seed
    )
    table = tick_table(schedule, P, M)
    kw = dict(
        table=table,
        x_struct=jax.ShapeDtypeStruct((2, d), jnp.float32),
        metrics_struct={"xent": jax.ShapeDtypeStruct((), jnp.float32)},
    )
    outs, backends = {}, {}
    for la in (0, lookahead):
        backends[la] = get_backend("host", host_window=1)
        outs[la] = pipeline_grads_host(
            first_fn, stage_fn, last_fn, stage_params, shared, mbs,
            stash=backends[la], lookahead=la, **kw,
        )
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[lookahead])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    eager, over = backends[0].stats(), backends[lookahead].stats()
    # identical access patterns; the lookahead only converts stalls to hits
    assert over["gets"] == eager["gets"]
    assert over["host_hits"] == eager["host_hits"]
    assert eager["prefetch_hits"] == 0
    if eager["host_hits"]:
        assert over["prefetch_hits"] > 0
        assert over["stalled_gets"] < eager["stalled_gets"]
