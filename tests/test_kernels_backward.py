"""Fused backward kernels vs oracle gradients.

Covers the three fused paths of the backward tier:
  * flash-attention dq/dk/dv (GQA group sizes, causal, sliding window,
    non-multiple-of-block sequence lengths)
  * fused RMSNorm dx/dscale
  * chunked cross-entropy head (loss + grads vs the dense oracle, plus a
    jaxpr-level assertion that (B, S, V) logits are never materialized)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernel

from repro.kernels.chunked_ce import chunked_ce
from repro.kernels.chunked_ce.ref import chunked_ce_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


# ------------------------------------------------------------ flash attention
def _qkv_cot(B, S, Kv, G, hd, seed=0):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, S, Kv, G, hd), jnp.float32) * hd**-0.5
    k = jnp.asarray(rng.randn(B, S, Kv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Kv, hd), jnp.float32)
    cot = jnp.asarray(rng.randn(B, S, Kv, G, hd), jnp.float32)
    return q, k, v, cot


# (B, S, Kv, G, hd, causal, window): GQA sweep, causal on/off, sliding
# windows, and sequence lengths that are not block multiples.
FA_CASES = [
    (1, 128, 2, 1, 32, True, 0),     # MHA-style, block-aligned
    (2, 64, 1, 4, 16, True, 0),      # MQA, group accumulation over G=4
    (1, 128, 2, 2, 32, False, 0),    # non-causal
    (1, 128, 2, 2, 16, True, 32),    # sliding window
    (1, 130, 2, 2, 16, True, 0),     # non-multiple-of-block S
    (1, 250, 1, 2, 16, True, 64),    # non-multiple S + window
]


@pytest.mark.parametrize("case", FA_CASES, ids=[str(c) for c in FA_CASES])
def test_flash_attention_grads_match_ref(case):
    B, S, Kv, G, hd, causal, window = case
    q, k, v, cot = _qkv_cot(B, S, Kv, G, hd, seed=sum(case[:5]))

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal, window) * cot)

    def f_ref(q, k, v):
        return jnp.sum(
            flash_attention_ref(q, k, v, causal=causal, window=window) * cot
        )

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gk, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4,
            err_msg=f"d{name} mismatch for {case}",
        )


def test_flash_attention_bf16_grads_close():
    q, k, v, cot = _qkv_cot(1, 128, 2, 2, 32, seed=9)
    qb, kb, vb = (a.astype(jnp.bfloat16) for a in (q, k, v))
    gk = jax.grad(
        lambda q_, k_, v_: jnp.sum(
            flash_attention(q_, k_, v_, True, 0).astype(jnp.float32) * cot
        ),
        argnums=(0, 1, 2),
    )(qb, kb, vb)
    gr = jax.grad(
        lambda q_, k_, v_: jnp.sum(
            flash_attention_ref(q_, k_, v_, causal=True) * cot
        ),
        argnums=(0, 1, 2),
    )(q, k, v)
    for a, b in zip(gk, gr):
        assert a.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b), atol=5e-2, rtol=5e-2
        )


# ---------------------------------------------------------------- rmsnorm bwd
@pytest.mark.parametrize("shape", [(8, 128), (2, 300, 64), (1, 7, 96)])
def test_rmsnorm_fused_bwd_matches_ref(shape):
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(*shape), jnp.float32)
    s = jnp.asarray(rng.rand(shape[-1]) + 0.5, jnp.float32)
    cot = jnp.asarray(rng.randn(*shape), jnp.float32)
    gk = jax.grad(
        lambda x_, s_: jnp.sum(rmsnorm(x_, s_) * cot), argnums=(0, 1)
    )(x, s)
    gr = jax.grad(
        lambda x_, s_: jnp.sum(rmsnorm_ref(x_, s_) * cot), argnums=(0, 1)
    )(x, s)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4
        )


# ------------------------------------------------------------------ chunked CE
def _ce_problem(B=2, S=48, d=16, V=1000, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(B, S, d), jnp.float32)
    w = jnp.asarray(rng.randn(V, d), jnp.float32) * 0.1
    labels = jnp.asarray(rng.randint(0, V, (B, S)), jnp.int32)
    return x, w, labels


@pytest.mark.parametrize("chunk", [128, 256, 1000, 4096])
def test_chunked_ce_matches_dense(chunk):
    x, w, labels = _ce_problem()

    def loss(ce):
        def f(x_, w_):
            ll, logz = ce(x_, w_)
            return jnp.mean(logz - ll) + 1e-4 * jnp.mean(logz**2)

        return f

    lc = loss(lambda x_, w_: chunked_ce(x_, w_, labels, chunk))
    lr = loss(lambda x_, w_: chunked_ce_ref(x_, w_, labels))
    np.testing.assert_allclose(float(lc(x, w)), float(lr(x, w)), rtol=1e-5)
    gc = jax.grad(lc, argnums=(0, 1))(x, w)
    gr = jax.grad(lr, argnums=(0, 1))(x, w)
    for a, b in zip(gc, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4
        )


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for item in vals:
                inner = getattr(item, "jaxpr", item)
                if hasattr(inner, "eqns"):
                    yield from _iter_eqns(inner)


def _max_intermediate_size(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    sizes = [0]
    for eqn in _iter_eqns(jaxpr.jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "size"):
                sizes.append(int(aval.size))
    return max(sizes)


def test_chunked_ce_backward_never_materializes_logits():
    """No intermediate in the chunked fwd+bwd reaches (B, S, V) size."""
    B, S, d, V, chunk = 2, 64, 16, 1024, 128
    x, w, labels = _ce_problem(B, S, d, V)
    full = B * S * V

    def loss_c(x_, w_):
        ll, logz = chunked_ce(x_, w_, labels, chunk)
        return jnp.mean(logz - ll)

    def loss_d(x_, w_):
        ll, logz = chunked_ce_ref(x_, w_, labels)
        return jnp.mean(logz - ll)

    chunked_max = _max_intermediate_size(jax.grad(loss_c, (0, 1)), x, w)
    dense_max = _max_intermediate_size(jax.grad(loss_d, (0, 1)), x, w)
    assert dense_max >= full  # the oracle DOES materialize logits
    assert chunked_max < full, (chunked_max, full)
    # largest chunked intermediate is the (B, S, chunk) tile or the (V, d)
    # weight grad, whichever is bigger
    assert chunked_max <= max(B * S * chunk, V * d)


def test_chunked_ce_respects_masked_label_convention():
    """Masked (-1) labels are clipped by the caller; grads stay finite."""
    x, w, labels = _ce_problem(seed=4)
    labels = labels.at[:, ::3].set(-1)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)

    def loss(x_, w_):
        ll, logz = chunked_ce(x_, w_, safe, 256)
        return jnp.sum((logz - ll) * mask) / jnp.maximum(mask.sum(), 1.0)

    g = jax.grad(loss, argnums=(0, 1))(x, w)
    for a in g:
        assert np.isfinite(np.asarray(a)).all()


# ------------------------------------------------------- end-to-end train step
def test_fused_backward_train_step_matches_baseline():
    from repro.configs import SURVEY_DEMO, reduced
    from repro.data import DataPipeline
    from repro.optim import get as get_opt
    from repro.train import TrainConfig, make_state, make_train_step

    tiny = reduced(SURVEY_DEMO, n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, d_ff=128, vocab_size=512)

    def losses(tc, steps=2):
        opt = get_opt(tc.optimizer, 1e-3)
        state = make_state(tiny, opt, tc, seed=0)
        step = make_train_step(tiny, opt, tc)
        data = DataPipeline(tiny, batch_size=4, seq_len=64, seed=0)
        out = []
        try:
            for _ in range(steps):
                batch = {k: jnp.asarray(v) for k, v in next(data).items()}
                state, m = step(state, batch)
                out.append(float(m["loss"]))
        finally:
            data.close()
        return out

    base = losses(TrainConfig())
    fused = losses(TrainConfig(fused_backward=True))
    np.testing.assert_allclose(base, fused, rtol=2e-4, atol=2e-4)
