"""Serve engine + serving-path fixes.

* Oracle equivalence: greedy decode reproduces the teacher-forced full-
  forward argmax token-for-token across architecture families (ring-buffer
  attention, SSM, RG-LRU, enc-dec cross-attention, vision prefix) and across
  the paged vs dense cache paths.
* Continuous batching: each request's engine output is identical to running
  that request alone (including under eviction pressure and through the
  Pallas kernel path).
* Fixes: compile-cache no-retrace regression; finfo-min vocab masking in
  ``sample_token`` over float dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import hypothesis, st

from repro.configs import get_reduced
from repro.models import Runtime, forward, init_params
from repro.serve import (
    EngineConfig,
    ReplicaRouter,
    ServeEngine,
    paged_supported,
)
from repro.serve.sampling import sample_token
from repro.train.serve import generate

RT = Runtime(dtype=jnp.float32, chunk_q=32)


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_reduced(name)
            cache[name] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
        return cache[name]

    return get


def make_batch(cfg, B, S, key=0):
    rng = np.random.RandomState(key)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    }
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return batch


# --------------------------------------------------- oracle equivalence
FAMILIES = [
    "granite-8b",           # dense full attention
    "gemma3-1b",            # sliding-window ring buffers
    "falcon-mamba-7b",      # recurrent SSM (dense fallback family)
    "recurrentgemma-2b",    # RG-LRU hybrid (dense fallback family)
    "seamless-m4t-medium",  # enc-dec cross-attention (dense fallback family)
    "phi-3-vision-4.2b",    # vision-prefix decode
]


@pytest.mark.parametrize("name", FAMILIES)
def test_greedy_decode_matches_teacher_forced_argmax(arch_state, name):
    """Greedy generation == argmax chain of the full (teacher-forced)
    forward at every step — validates every family's cache path."""
    cfg, params = arch_state(name)
    B, S, M = 2, 9, 5
    batch = make_batch(cfg, B, S, key=11)
    tokens, _ = generate(cfg, params, batch, RT, max_new_tokens=M)
    assert tokens.shape == (B, M)

    full = dict(batch, tokens=jnp.concatenate(
        [batch["tokens"], tokens], axis=1))
    logits, _ = forward(cfg, params, full, RT)
    off = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    for i in range(M):
        expect = jnp.argmax(
            logits[:, off + S - 1 + i, : cfg.vocab_size], axis=-1
        )
        np.testing.assert_array_equal(
            np.asarray(tokens[:, i]), np.asarray(expect), err_msg=f"step {i}"
        )


@pytest.mark.parametrize("name", ["granite-8b", "gemma3-1b"])
def test_paged_path_matches_dense_path(arch_state, name):
    cfg, params = arch_state(name)
    batch = make_batch(cfg, B=2, S=10, key=3)
    dense, _ = generate(cfg, params, batch, RT, max_new_tokens=6)
    paged, stats = generate(cfg, params, batch, RT, max_new_tokens=6,
                            paged=True)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))
    assert set(stats["ttft_s"]) == {0, 1} and set(stats["kv_bytes"]) == {0, 1}


def test_paged_supported_matrix():
    assert paged_supported(get_reduced("granite-8b"))
    assert paged_supported(get_reduced("gemma3-1b"))
    assert paged_supported(get_reduced("phi-3-vision-4.2b"))
    assert not paged_supported(get_reduced("falcon-mamba-7b"))
    assert not paged_supported(get_reduced("recurrentgemma-2b"))
    assert not paged_supported(get_reduced("seamless-m4t-medium"))
    with pytest.raises(ValueError):
        ServeEngine(
            get_reduced("falcon-mamba-7b"), params=None, rt=RT, paged=True
        )


# --------------------------------------------------- continuous batching
def _run_alone(cfg, params, prompt, max_new):
    out, _ = generate(
        cfg, params, {"tokens": jnp.asarray(prompt[None])}, RT, max_new
    )
    return np.asarray(out[0])


def test_continuous_batching_matches_alone(arch_state):
    """Variable-length staggered requests through 2 slots: every request's
    output must equal its isolated run, and the pool must drain."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(0)
    prompts = [
        rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
        for s in (5, 11, 17, 8)
    ]
    max_news = [9, 4, 12, 7]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                     inner_steps=4),
    )
    rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    out = eng.run()
    for rid, p, m in zip(rids, prompts, max_news):
        assert out[rid].shape == (m,)
        np.testing.assert_array_equal(
            out[rid], _run_alone(cfg, params, p, m), err_msg=f"rid={rid}"
        )
    eng.pool.check()
    assert eng.pool.pages_in_use == 0
    assert set(eng.stats["ttft_s"]) == set(rids)
    assert all(b > 0 for b in eng.stats["kv_bytes"].values())


def test_engine_sliding_window_family(arch_state):
    cfg, params = arch_state("gemma3-1b")
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (7, 13)]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                     inner_steps=3),
    )
    rids = [eng.submit(p, 6) for p in prompts]
    out = eng.run()
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, p, 6))


def test_engine_eviction_under_pressure_stays_exact(arch_state):
    """Optimistic admission: both requests start at one page and grow past
    the combined budget, so the engine must preempt the YOUNGEST
    (evict+requeue, FIFO fairness) and still produce exact outputs."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
               for _ in range(2)]
    max_news = [24, 16]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=4, num_pages=10, max_len=48,
                     inner_steps=4, policy="optimistic"),
    )
    rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    out = eng.run()
    assert eng.stats.get("evictions", 0) > 0
    for rid, p, m in zip(rids, prompts, max_news):
        np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, p, m))
    eng.pool.check()
    assert eng.pool.pages_in_use == 0


def test_engine_bucketed_prefill_exact_and_bounded_compiles(arch_state):
    """prefill_bucket pads prompts to a shared shape (bounding XLA prefill
    compiles to max_len/bucket programs) without changing any output token."""
    from repro.serve import dense

    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (3, 6, 5, 7)]          # all bucket up to length 8
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                     inner_steps=4, prefill_bucket=8),
    )
    before = dense.CACHE_BUILDS
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    # 4 distinct prompt lengths share ONE bucketed prefill program
    assert dense.CACHE_BUILDS - before == 1
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, p, 5))


@pytest.mark.parametrize("n_kv", [1, 2, 4])
def test_engine_bucketed_prefill_exact_across_head_layouts(n_kv):
    """Bucketed-prefill exactness is head-layout-agnostic: the causal mask
    hides pad positions identically for MQA (kv=1), GQA (kv=2, groups of
    2), and MHA (kv=4). Each layout's bucketed engine output must equal its
    exact-shape alone run."""
    cfg = get_reduced("granite-8b", n_kv_heads=n_kv)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (3, 6, 5)]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                     inner_steps=4, prefill_bucket=8),
    )
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    for rid, p in zip(rids, prompts):
        tokens, _ = generate(
            cfg, params, {"tokens": jnp.asarray(p[None])}, RT, 5
        )
        np.testing.assert_array_equal(
            out[rid], np.asarray(tokens[0]), err_msg=f"n_kv={n_kv}"
        )


def test_engine_warns_on_moe_bucketed_or_chunked_prefill(arch_state):
    """The documented fallback: MoE expert capacity counts pad/chunk
    tokens, so bucketed / chunked prefill is not guaranteed token-exact
    for MoE families — the engine says so instead of silently differing."""
    cfg, params = arch_state("qwen3-moe-30b-a3b")
    with pytest.warns(UserWarning, match="expert capacity"):
        ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=1, page_size=8, num_pages=17, max_len=32,
                         prefill_bucket=8),
        )
    with pytest.warns(UserWarning, match="expert capacity"):
        ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=1, page_size=8, num_pages=17, max_len=32,
                         prefill_chunk=4),
        )
    # no warning for exact-shape non-chunked serving
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=1, page_size=8, num_pages=17, max_len=32),
        )


def test_engine_bucketed_prefill_exact_past_sliding_window(arch_state):
    """Regression: right-padding a prompt past a local layer's window must
    not ring-evict real in-window tokens from the prefill cache — the
    engine prefills with full (un-windowed) caches for the page pool."""
    cfg, params = arch_state("gemma3-1b")
    assert cfg.sliding_window == 64
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab_size, (66,)).astype(np.int32)
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=1, page_size=16, num_pages=13, max_len=96,
                     inner_steps=3, prefill_bucket=16),  # pads 66 -> 80 > 64
    )
    rid = eng.submit(prompt, 4)
    out = eng.run()
    np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, prompt, 4))


def test_engine_pallas_kernel_path(arch_state):
    """End-to-end decode through the Pallas paged kernel (interpret mode on
    CPU) must match the jnp-oracle engine path."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    outs = {}
    for use_kernel in (False, True):
        eng = ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=1, page_size=8, num_pages=9, max_len=16,
                         inner_steps=2, use_kernel=use_kernel),
        )
        rid = eng.submit(prompt, 3)
        outs[use_kernel] = eng.run()[rid]
    np.testing.assert_array_equal(outs[True], outs[False])


def test_engine_dense_fallback_family(arch_state):
    cfg, params = arch_state("falcon-mamba-7b")
    rng = np.random.RandomState(6)
    prompts = [rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
               for _ in range(2)]
    eng = ServeEngine(cfg, params, RT, EngineConfig(max_slots=2))
    assert not eng.paged
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    batch = {"tokens": jnp.asarray(np.stack(prompts))}
    expect, _ = generate(cfg, params, batch, RT, 5)
    for b, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], np.asarray(expect[b]))
    assert set(eng.stats["ttft_s"]) == set(rids)


def test_engine_reusable_across_runs(arch_state):
    """submit()/run() a second time on the same engine: only the new
    request's output is returned and per-run stats stay sane."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(2)]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=17, max_len=32,
                     inner_steps=4),
    )
    r0 = eng.submit(prompts[0], 5)
    out0 = eng.run()
    assert set(out0) == {r0}
    r1 = eng.submit(prompts[1], 5)
    out1 = eng.run()
    assert set(out1) == {r1}
    assert eng.stats["decode_tokens"] == 4 and eng.stats["tokens_per_s"] > 0
    np.testing.assert_array_equal(
        out1[r1], _run_alone(cfg, params, prompts[1], 5)
    )
    eng.pool.check()


def test_engine_rejects_oversized_request(arch_state):
    cfg, params = arch_state("granite-8b")
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=1, page_size=4, num_pages=5, max_len=64),
    )
    with pytest.raises(ValueError):
        eng.submit(np.zeros(40, np.int32), 20)   # > pool budget


# ------------------------------------------------------- sharded serving
def test_replica_router_least_loaded_deterministic():
    """Least-loaded routing over caller-supplied loads, lowest index on
    ties; routed counts accumulate per replica."""
    r = ReplicaRouter(3)
    assert r.route([0, 0, 0]) == 0        # all tied -> lowest index
    assert r.route([100, 0, 0]) == 1
    assert r.route([100, 10, 0]) == 2
    assert r.route([100, 10, 10]) == 1    # 1 and 2 tied -> lowest index
    assert r.route([100, 15, 10]) == 2
    assert r.route([0, 15, 11]) == 0      # drained replica is emptiest
    assert r.routed == [2, 2, 2]


def test_engine_outstanding_tokens_tracks_queue_and_pool(arch_state):
    """The load measure the router balances on: queued tokens before run,
    zero after the pool drains."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(17)
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=1, page_size=8, num_pages=9, max_len=16,
                     inner_steps=2),
    )
    assert eng.outstanding_tokens == 0
    eng.submit(prompt, 4)
    assert eng.outstanding_tokens == len(prompt) + 4
    eng.run()
    assert eng.outstanding_tokens == 0


def test_engine_trivial_mesh_matches_unsharded(arch_state):
    """A 1x1 mesh exercises the whole sharded code path (placement, specs,
    shard_map guards) on one device and must change nothing."""
    import jax as _jax

    cfg, params = arch_state("granite-8b")
    mesh = _jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.RandomState(21)
    prompt = rng.randint(0, cfg.vocab_size, (7,)).astype(np.int32)
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=17, max_len=32,
                        inner_steps=4)
    outs = {}
    for key, rt in (("plain", RT), ("mesh", RT.replace(mesh=mesh))):
        eng = ServeEngine(cfg, params, rt, ecfg)
        rid = eng.submit(prompt, 6)
        outs[key] = eng.run()[rid]
        assert eng.kv_pool_bytes_per_device() > 0
    np.testing.assert_array_equal(outs["plain"], outs["mesh"])


# ----------------------------------------------------- retrace regression
def test_generate_does_not_retrace_on_same_shapes(arch_state):
    from repro.serve import dense

    cfg, params = arch_state("granite-8b")
    batch = make_batch(cfg, B=2, S=19, key=8)   # unique shape for this test
    before = dense.CACHE_BUILDS
    generate(cfg, params, batch, RT, max_new_tokens=4)
    cold = dense.CACHE_BUILDS - before
    assert cold == 2                             # prefill + decode loop
    generate(cfg, params, batch, RT, max_new_tokens=4)
    assert dense.CACHE_BUILDS - before == cold   # cache hit: no rebuild

    total = 19 + 4
    bkey = dense.batch_shape_key(batch)
    prefill_fn = dense.compiled_prefill(cfg, RT, bkey, total)
    loop_fn = dense.compiled_decode_loop(cfg, RT, bkey, total, 4, 0.0)
    for fn in (prefill_fn, loop_fn):             # jax.jit miss counters
        if hasattr(fn, "_cache_size"):
            assert fn._cache_size() == 1, "second call retraced"


# ------------------------------------------------------- sample_token fix
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_sample_token_finfo_masking_over_dtypes(dtype):
    """Padded-vocab masking must use the dtype's finfo min — a hard-coded
    -1e30 overflows fp16 (and exceeds bf16 resolution tricks)."""
    vocab, padded = 5, 8
    logits = jnp.full((2, padded), 10.0, dtype)
    logits = logits.at[:, vocab:].set(20.0)      # padding ids look best
    tok = sample_token(logits, jax.random.PRNGKey(0), 0.0, vocab)
    assert np.asarray(tok).max() < vocab
    for seed in range(5):
        tok = sample_token(logits, jax.random.PRNGKey(seed), 1.0, vocab)
        assert np.asarray(tok).max() < vocab, "sampled a padded id"
    masked = jnp.where(
        jnp.arange(padded) < vocab, logits, jnp.finfo(dtype).min
    )
    assert bool(jnp.all(jnp.isfinite(masked) | (masked == jnp.finfo(dtype).min)))


@hypothesis.given(st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=25, deadline=None)
def test_zero_temperature_equals_argmax(seed):
    rng = np.random.RandomState(seed % (2**31 - 1))
    vocab = 11
    logits = jnp.asarray(rng.randn(3, 16), jnp.float32)
    tok = sample_token(logits, jax.random.PRNGKey(seed), 0.0, vocab)
    expect = jnp.argmax(logits[:, :vocab], axis=-1)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(expect))
