"""Serve engine + serving-path fixes.

* Oracle equivalence: greedy decode reproduces the teacher-forced full-
  forward argmax token-for-token across architecture families (ring-buffer
  attention, SSM, RG-LRU, enc-dec cross-attention, vision prefix) and across
  the paged vs dense cache paths.
* Continuous batching: each request's engine output is identical to running
  that request alone (including under eviction pressure and through the
  Pallas kernel path).
* Fixes: compile-cache no-retrace regression; finfo-min vocab masking in
  ``sample_token`` over float dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import hypothesis, st

from repro.configs import get_reduced
from repro.models import Runtime, forward, init_params
from repro.serve import (
    EngineConfig,
    ReplicaRouter,
    ServeEngine,
    paged_supported,
)
from repro.serve.sampling import sample_token
from repro.train.serve import generate

RT = Runtime(dtype=jnp.float32, chunk_q=32)


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_reduced(name)
            cache[name] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
        return cache[name]

    return get


def make_batch(cfg, B, S, key=0):
    rng = np.random.RandomState(key)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    }
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return batch


# --------------------------------------------------- oracle equivalence
FAMILIES = [
    "granite-8b",           # dense full attention
    "gemma3-1b",            # sliding-window ring buffers
    "falcon-mamba-7b",      # recurrent SSM (dense fallback family)
    "recurrentgemma-2b",    # RG-LRU hybrid (dense fallback family)
    "seamless-m4t-medium",  # enc-dec cross-attention (dense fallback family)
    "phi-3-vision-4.2b",    # vision-prefix decode
]


@pytest.mark.parametrize("name", FAMILIES)
def test_greedy_decode_matches_teacher_forced_argmax(arch_state, name):
    """Greedy generation == argmax chain of the full (teacher-forced)
    forward at every step — validates every family's cache path."""
    cfg, params = arch_state(name)
    B, S, M = 2, 9, 5
    batch = make_batch(cfg, B, S, key=11)
    tokens, _ = generate(cfg, params, batch, RT, max_new_tokens=M)
    assert tokens.shape == (B, M)

    full = dict(batch, tokens=jnp.concatenate(
        [batch["tokens"], tokens], axis=1))
    logits, _ = forward(cfg, params, full, RT)
    off = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    for i in range(M):
        expect = jnp.argmax(
            logits[:, off + S - 1 + i, : cfg.vocab_size], axis=-1
        )
        np.testing.assert_array_equal(
            np.asarray(tokens[:, i]), np.asarray(expect), err_msg=f"step {i}"
        )


@pytest.mark.parametrize("name", ["granite-8b", "gemma3-1b"])
def test_paged_path_matches_dense_path(arch_state, name):
    cfg, params = arch_state(name)
    batch = make_batch(cfg, B=2, S=10, key=3)
    dense, _ = generate(cfg, params, batch, RT, max_new_tokens=6)
    paged, stats = generate(cfg, params, batch, RT, max_new_tokens=6,
                            paged=True)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))
    assert set(stats["ttft_s"]) == {0, 1} and set(stats["kv_bytes"]) == {0, 1}


def test_paged_supported_matrix():
    assert paged_supported(get_reduced("granite-8b"))
    assert paged_supported(get_reduced("gemma3-1b"))
    assert paged_supported(get_reduced("phi-3-vision-4.2b"))
    assert not paged_supported(get_reduced("falcon-mamba-7b"))
    assert not paged_supported(get_reduced("recurrentgemma-2b"))
    assert not paged_supported(get_reduced("seamless-m4t-medium"))
    with pytest.raises(ValueError):
        ServeEngine(
            get_reduced("falcon-mamba-7b"), params=None, rt=RT, paged=True
        )


# --------------------------------------------------- continuous batching
def _run_alone(cfg, params, prompt, max_new):
    out, _ = generate(
        cfg, params, {"tokens": jnp.asarray(prompt[None])}, RT, max_new
    )
    return np.asarray(out[0])


def test_continuous_batching_matches_alone(arch_state):
    """Variable-length staggered requests through 2 slots: every request's
    output must equal its isolated run, and the pool must drain."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(0)
    prompts = [
        rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
        for s in (5, 11, 17, 8)
    ]
    max_news = [9, 4, 12, 7]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                     inner_steps=4),
    )
    rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    out = eng.run()
    for rid, p, m in zip(rids, prompts, max_news):
        assert out[rid].shape == (m,)
        np.testing.assert_array_equal(
            out[rid], _run_alone(cfg, params, p, m), err_msg=f"rid={rid}"
        )
    eng.pool.check()
    assert eng.pool.pages_in_use == 0
    assert set(eng.stats["ttft_s"]) == set(rids)
    assert all(b > 0 for b in eng.stats["kv_bytes"].values())


def test_engine_sliding_window_family(arch_state):
    cfg, params = arch_state("gemma3-1b")
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (7, 13)]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                     inner_steps=3),
    )
    rids = [eng.submit(p, 6) for p in prompts]
    out = eng.run()
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, p, 6))


def test_engine_eviction_under_pressure_stays_exact(arch_state):
    """Optimistic admission: both requests start at one page and grow past
    the combined budget, so the engine must preempt the YOUNGEST
    (evict+requeue, FIFO fairness) and still produce exact outputs."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
               for _ in range(2)]
    max_news = [24, 16]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=4, num_pages=10, max_len=48,
                     inner_steps=4, policy="optimistic"),
    )
    rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    out = eng.run()
    assert eng.stats.get("evictions", 0) > 0
    for rid, p, m in zip(rids, prompts, max_news):
        np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, p, m))
    eng.pool.check()
    assert eng.pool.pages_in_use == 0


def test_engine_bucketed_prefill_exact_and_bounded_compiles(arch_state):
    """prefill_bucket pads prompts to a shared shape (bounding XLA prefill
    compiles to max_len/bucket programs) without changing any output token."""
    from repro.serve import dense

    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (3, 6, 5, 7)]          # all bucket up to length 8
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                     inner_steps=4, prefill_bucket=8),
    )
    before = dense.CACHE_BUILDS
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    # 4 distinct prompt lengths share ONE bucketed prefill program
    assert dense.CACHE_BUILDS - before == 1
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, p, 5))


@pytest.mark.parametrize("n_kv", [1, 2, 4])
def test_engine_bucketed_prefill_exact_across_head_layouts(n_kv):
    """Bucketed-prefill exactness is head-layout-agnostic: the causal mask
    hides pad positions identically for MQA (kv=1), GQA (kv=2, groups of
    2), and MHA (kv=4). Each layout's bucketed engine output must equal its
    exact-shape alone run."""
    cfg = get_reduced("granite-8b", n_kv_heads=n_kv)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (3, 6, 5)]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=33, max_len=64,
                     inner_steps=4, prefill_bucket=8),
    )
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    for rid, p in zip(rids, prompts):
        tokens, _ = generate(
            cfg, params, {"tokens": jnp.asarray(p[None])}, RT, 5
        )
        np.testing.assert_array_equal(
            out[rid], np.asarray(tokens[0]), err_msg=f"n_kv={n_kv}"
        )


def test_engine_warns_on_moe_bucketed_or_chunked_prefill(arch_state):
    """The documented fallback: MoE expert capacity counts pad/chunk
    tokens, so bucketed / chunked prefill is not guaranteed token-exact
    for MoE families — the engine says so instead of silently differing."""
    cfg, params = arch_state("qwen3-moe-30b-a3b")
    with pytest.warns(UserWarning, match="expert capacity"):
        ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=1, page_size=8, num_pages=17, max_len=32,
                         prefill_bucket=8),
        )
    with pytest.warns(UserWarning, match="expert capacity"):
        ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=1, page_size=8, num_pages=17, max_len=32,
                         prefill_chunk=4),
        )
    # no warning for exact-shape non-chunked serving
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=1, page_size=8, num_pages=17, max_len=32),
        )


def test_engine_bucketed_prefill_exact_past_sliding_window(arch_state):
    """Regression: right-padding a prompt past a local layer's window must
    not ring-evict real in-window tokens from the prefill cache — the
    engine prefills with full (un-windowed) caches for the page pool."""
    cfg, params = arch_state("gemma3-1b")
    assert cfg.sliding_window == 64
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab_size, (66,)).astype(np.int32)
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=1, page_size=16, num_pages=13, max_len=96,
                     inner_steps=3, prefill_bucket=16),  # pads 66 -> 80 > 64
    )
    rid = eng.submit(prompt, 4)
    out = eng.run()
    np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, prompt, 4))


def test_engine_pallas_kernel_path(arch_state):
    """End-to-end decode through the Pallas paged kernel (interpret mode on
    CPU) must match the jnp-oracle engine path."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    outs = {}
    for use_kernel in (False, True):
        eng = ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=1, page_size=8, num_pages=9, max_len=16,
                         inner_steps=2, use_kernel=use_kernel),
        )
        rid = eng.submit(prompt, 3)
        outs[use_kernel] = eng.run()[rid]
    np.testing.assert_array_equal(outs[True], outs[False])


def test_engine_dense_fallback_family(arch_state):
    cfg, params = arch_state("falcon-mamba-7b")
    rng = np.random.RandomState(6)
    prompts = [rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
               for _ in range(2)]
    eng = ServeEngine(cfg, params, RT, EngineConfig(max_slots=2))
    assert not eng.paged
    rids = [eng.submit(p, 5) for p in prompts]
    out = eng.run()
    batch = {"tokens": jnp.asarray(np.stack(prompts))}
    expect, _ = generate(cfg, params, batch, RT, 5)
    for b, rid in enumerate(rids):
        np.testing.assert_array_equal(out[rid], np.asarray(expect[b]))
    assert set(eng.stats["ttft_s"]) == set(rids)


def test_engine_reusable_across_runs(arch_state):
    """submit()/run() a second time on the same engine: only the new
    request's output is returned and per-run stats stay sane."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(2)]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=17, max_len=32,
                     inner_steps=4),
    )
    r0 = eng.submit(prompts[0], 5)
    out0 = eng.run()
    assert set(out0) == {r0}
    r1 = eng.submit(prompts[1], 5)
    out1 = eng.run()
    assert set(out1) == {r1}
    assert eng.stats["decode_tokens"] == 4 and eng.stats["tokens_per_s"] > 0
    np.testing.assert_array_equal(
        out1[r1], _run_alone(cfg, params, prompts[1], 5)
    )
    eng.pool.check()


def test_engine_rejects_oversized_request(arch_state):
    cfg, params = arch_state("granite-8b")
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=1, page_size=4, num_pages=5, max_len=64),
    )
    with pytest.raises(ValueError):
        eng.submit(np.zeros(40, np.int32), 20)   # > pool budget


# ----------------------------------------------------- quantized KV pool
PAGED_FAMILIES = ["granite-8b", "gemma3-1b", "phi-3-vision-4.2b"]
DENSE_FAMILIES = ["falcon-mamba-7b", "recurrentgemma-2b", "seamless-m4t-medium"]


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("name", PAGED_FAMILIES)
def test_quantized_pool_batched_equals_alone(arch_state, name, kv_dtype):
    """Quantize-once-per-write keeps pool bytes independent of batch
    composition, so the engine's batched==alone token identity must hold at
    every kv_dtype (the quantized trajectory may differ from bf16's — the
    guarantee is internal consistency at a FIXED pool dtype)."""
    cfg, params = arch_state(name)
    rng = np.random.RandomState(31)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in (5, 11, 8)]
    fes = [
        rng.randn(cfg.frontend_tokens, cfg.d_model).astype(np.float32)
        if cfg.frontend is not None else None
        for _ in prompts
    ]

    def run(reqs, fe_list, slots):
        eng = ServeEngine(
            cfg, params, RT,
            EngineConfig(max_slots=slots, page_size=8, num_pages=33,
                         max_len=64, inner_steps=4, kv_dtype=kv_dtype),
        )
        rids = [eng.submit(p, 6, frontend_embeds=fe)
                for p, fe in zip(reqs, fe_list)]
        out = eng.run()
        eng.pool.check()
        assert eng.pool.pages_in_use == 0
        return [out[r] for r in rids]

    batched = run(prompts, fes, slots=2)
    for i, (p, fe) in enumerate(zip(prompts, fes)):
        alone = run([p], [fe], slots=1)[0]
        np.testing.assert_array_equal(
            batched[i], alone, err_msg=f"{name} {kv_dtype} req {i}"
        )


def _paged_step_logits(cfg, params, prompt, kv_dtype, steps, teacher=None):
    """Admission-path harness: prefill -> write_prefill_to_pool -> paged
    decode steps, returning per-step logits (teacher-forced when given)."""
    from repro.models import decode_step_paged, init_paged_state, prefill
    from repro.models.stack import write_prefill_to_pool

    rt = RT.replace(kv_dtype=kv_dtype)
    page = 8
    prompt_total = len(prompt) + (
        cfg.frontend_tokens if cfg.frontend == "vision" else 0
    )
    max_len = -(-(prompt_total + steps) // page) * page
    P = max_len // page
    state = init_paged_state(
        cfg, 1, rt, num_pages=P + 1, page_size=page, max_len=max_len
    )
    table_row = jnp.arange(1, P + 1, dtype=jnp.int32)
    batch = {"tokens": jnp.asarray(prompt[None])}
    if cfg.frontend is not None:
        rngf = np.random.RandomState(1)
        batch["frontend_embeds"] = jnp.asarray(
            rngf.randn(1, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    logits, pstate = prefill(
        cfg, params, batch, rt, max_len=prompt_total + steps, full_cache=True
    )
    state["caches"] = write_prefill_to_pool(
        state["caches"], pstate["caches"], table_row, page
    )
    state["tables"] = table_row[None]
    state["lengths"] = jnp.asarray([prompt_total], jnp.int32)
    logs, toks = [logits[0]], []
    for i in range(steps):
        tok = (int(jnp.argmax(logs[-1][: cfg.vocab_size]))
               if teacher is None else teacher[i])
        toks.append(tok)
        lg, state = decode_step_paged(
            cfg, params, state, jnp.asarray([tok]), rt, max_len
        )
        logs.append(lg[0])
    return logs, toks


@pytest.mark.parametrize("kv_dtype,rel_tol", [("int8", 0.04), ("fp8", 0.15)])
@pytest.mark.parametrize("name", PAGED_FAMILIES)
def test_quantized_pool_logit_error_within_tolerance(
    arch_state, name, kv_dtype, rel_tol
):
    """Teacher-forced decode over a quantized pool stays within a measured
    max-logit-error tolerance of the native pool (measured ~0.012 relative
    for int8, ~0.045 for fp8 across these families; asserted at ~3x margin).
    The prefill logits themselves are quantization-free (native ring cache),
    so step 0 must be exact — only pool-reading decode steps may drift."""
    cfg, params = arch_state(name)
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab_size, (11,)).astype(np.int32)
    ref, toks = _paged_step_logits(cfg, params, prompt, "", steps=5)
    got, _ = _paged_step_logits(
        cfg, params, prompt, kv_dtype, steps=5, teacher=toks
    )
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(got[0]))
    scale = max(float(jnp.max(jnp.abs(lg))) for lg in ref)
    err = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in zip(ref[1:], got[1:])
    )
    assert 0.0 < err <= rel_tol * scale, (err, scale)


@pytest.mark.parametrize("name", DENSE_FAMILIES)
def test_quantized_kv_dtype_noop_on_dense_fallback(arch_state, name):
    """Dense-fallback families never touch the page pool: a kv_dtype on the
    engine config must change nothing (dense compiles are shared via
    ``serve.dense._dense_rt`` stripping the field before cache keying)."""
    cfg, params = arch_state(name)
    rng = np.random.RandomState(37)
    prompts = [rng.randint(0, cfg.vocab_size, (7,)).astype(np.int32)
               for _ in range(2)]
    fe_list = [
        rng.randn(cfg.frontend_tokens, cfg.d_model).astype(np.float32)
        if (cfg.frontend is not None or cfg.is_encdec) else None
        for _ in prompts
    ]
    outs = {}
    for kv_dtype in ("", "int8"):
        eng = ServeEngine(
            cfg, params, RT, EngineConfig(max_slots=2, kv_dtype=kv_dtype)
        )
        assert not eng.paged
        rids = [eng.submit(p, 5, frontend_embeds=fe)
                for p, fe in zip(prompts, fe_list)]
        out = eng.run()
        outs[kv_dtype] = [out[r] for r in rids]
    for a, b in zip(outs[""], outs["int8"]):
        np.testing.assert_array_equal(a, b)


# ------------------------------------- latency accounting (TTFT origin)
def _submit_then_wait(eng, prompts, max_new, wait_s=0.05):
    """Submit everything, sit in the queue for wait_s, then drain. With
    TTFT measured from SUBMIT (the fix), every request's TTFT must include
    that wait; the old admit-origin accounting would report only prefill."""
    import time as _time

    rids = [eng.submit(p, max_new) for p in prompts]
    _time.sleep(wait_s)
    out = eng.run()
    return rids, out


@pytest.mark.parametrize("variant", ["legacy", "chunked", "dense"])
def test_ttft_origin_is_submit_on_every_path(arch_state, variant):
    """Regression for the TTFT accounting bug: the legacy whole-prompt
    prefill, the chunked-prefill path, and the dense fallback all timed
    TTFT from admission, hiding queue wait. All three must now span
    submit -> first token (>= the induced queue wait) and keep prefill
    compute in the separate prefill_s."""
    name = "falcon-mamba-7b" if variant == "dense" else "granite-8b"
    cfg, params = arch_state(name)
    rng = np.random.RandomState(23)
    prompts = [rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)
               for s in ((6, 6) if variant == "dense" else (6, 11))]
    if variant == "legacy":
        ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=17,
                            max_len=32, inner_steps=4)
    elif variant == "chunked":
        ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=17,
                            max_len=32, inner_steps=4, prefix_cache=True,
                            prefill_chunk=8)
    else:
        ecfg = EngineConfig(max_slots=2)
    eng = ServeEngine(cfg, params, RT, ecfg)
    rids, _ = _submit_then_wait(eng, prompts, 4, wait_s=0.05)
    s = eng.stats
    for rid in rids:
        assert s["ttft_s"][rid] >= 0.05, (variant, rid, s["ttft_s"])
        assert 0 < s["prefill_s"][rid] < s["ttft_s"][rid]


def test_ttft_includes_queue_wait_ordering(arch_state):
    """One slot, co-submitted requests: each later admission's TTFT must
    grow by the time spent waiting behind its predecessors."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(29)
    prompts = [rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(3)]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=1, page_size=8, num_pages=9, max_len=16,
                     inner_steps=4),
    )
    rids, _ = _submit_then_wait(eng, prompts, 4, wait_s=0.0)
    ttfts = [eng.stats["ttft_s"][r] for r in rids]
    assert ttfts[0] < ttfts[1] < ttfts[2], ttfts


def test_preempt_readmit_ttft_spans_original_submit(arch_state):
    """Preemption pressure driven through the external step() loop: the
    evicted-and-readmitted request's recomputed TTFT still originates at
    its original submit (>= the pre-run queue wait), and outputs stay
    exact."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
               for _ in range(2)]
    max_news = [24, 16]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=4, num_pages=10, max_len=48,
                     inner_steps=4, policy="optimistic"),
    )
    import time as _time

    rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    _time.sleep(0.05)
    eng.run_begin()
    steps = 0
    while eng.busy:
        assert eng.step()["busy"]
        steps += 1
        assert steps < 200
    out = eng.run_finalize()
    assert eng.stats.get("evictions", 0) > 0
    for rid, p, m in zip(rids, prompts, max_news):
        np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, p, m))
        assert eng.stats["ttft_s"][rid] >= 0.05
    eng.pool.check()


def test_engine_per_run_stats_are_per_run(arch_state):
    """A second submit/run cycle reports ITS OWN completion count and mean
    TTFT — regression for readers that averaged the accumulated per-rid
    ttft_s dict across runs."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(3)]
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=2, page_size=8, num_pages=17, max_len=32,
                     inner_steps=4),
    )
    r0, r1 = eng.submit(prompts[0], 5), eng.submit(prompts[1], 5)
    eng.run()
    assert eng.stats["run_completed"] == 2
    r2 = eng.submit(prompts[2], 5)
    eng.run()
    s = eng.stats
    assert s["run_completed"] == 1
    assert s["decode_tokens"] == 4 and s["tokens_per_s"] > 0
    # the run mean covers ONLY this run's rid, not the accumulated dict
    assert s["run_mean_ttft_s"] == pytest.approx(s["ttft_s"][r2])
    assert len(s["ttft_s"]) == 3      # the dict does accumulate (by design)


def test_capacity_budget_never_overspends(arch_state):
    """Regression: the null page was not charged, so num_pages * page_bytes
    could exceed pool_bytes by one page. The sized pool (null page
    included) must now fit the budget whenever the budget can hold at
    least one usable page — and the Capacity result's own byte accounting
    must agree with the pool pricing rule."""
    from repro.serve.pool import kv_page_bytes

    cfg, _ = arch_state("granite-8b")
    page = 8
    page_b = kv_page_bytes(page, cfg.n_kv_heads, cfg.head_dim,
                           cfg.n_layers, "bf16")
    pages_per_req = 40 // page                 # horizon 24+12 -> max_len 40
    # smallest budget that holds one request + the null page, then larger
    # ones; below that floor budget sizing still returns 1 slot by
    # design (documented), so the no-overspend contract starts here
    floor = (1 + pages_per_req) * page_b
    for budget in (floor, 150_000, 200_000, 400_000, 1_000_000):
        cap = EngineConfig.capacity(
            24, 12, pool_bytes=budget, cfg=cfg, page_size=page,
            kv_dtype="bf16",
        )
        assert cap.page_bytes == page_b
        assert cap.pool_bytes == cap.num_pages * page_b <= budget, (
            budget, cap.num_pages,
        )
        assert cap.num_pages >= 1 + pages_per_req
        assert cap.pages_per_request == pages_per_req
        ecfg = cap.engine(inner_steps=4)
        assert (ecfg.max_slots, ecfg.num_pages, ecfg.kv_dtype) == (
            cap.slots, cap.num_pages, "bf16",
        )


def test_capacity_api_validation(arch_state):
    cfg, _ = arch_state("granite-8b")
    with pytest.raises(ValueError, match="exactly one"):
        EngineConfig.capacity(24, 12)
    with pytest.raises(ValueError, match="exactly one"):
        EngineConfig.capacity(24, 12, slots=2, pool_bytes=10**6, cfg=cfg)
    with pytest.raises(ValueError, match="needs cfg"):
        EngineConfig.capacity(24, 12, pool_bytes=10**6)
    # slots mode without cfg: geometry exact, byte fields report 0
    cap = EngineConfig.capacity(24, 12, slots=3, page_size=8, headroom=2.0)
    assert cap.max_len == 40 and cap.pages_per_request == 5
    assert cap.num_pages == 1 + 3 * 5 * 2
    assert cap.bytes_per_token == cap.page_bytes == cap.pool_bytes == 0


def test_replicated_submit_is_transactional(arch_state):
    """Regression: ReplicaRouter.route() was committed before the inner
    submit could raise, leaking a phantom request onto the replica's load.
    An oversized submit must leave router counts AND rid numbering
    untouched, and the engine must keep serving afterwards."""
    from repro.serve import ReplicatedServeEngine

    cfg, params = arch_state("granite-8b")
    ecfg = EngineConfig(max_slots=1, page_size=4, num_pages=5, max_len=64,
                        inner_steps=4)
    eng = ReplicatedServeEngine(cfg, params, RT, ecfg, mesh=None)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(40, np.int32), 20)    # > pool budget
    assert eng.router.routed == [0]
    assert eng._next_rid == 0
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    rid = eng.submit(prompt, 4)
    out = eng.run()
    np.testing.assert_array_equal(out[rid], _run_alone(cfg, params, prompt, 4))


# ------------------------------------------------------- sharded serving
def test_replica_router_least_loaded_deterministic():
    """Least-loaded routing over caller-supplied loads, lowest index on
    ties; routed counts accumulate per replica."""
    r = ReplicaRouter(3)
    assert r.route([0, 0, 0]) == 0        # all tied -> lowest index
    assert r.route([100, 0, 0]) == 1
    assert r.route([100, 10, 0]) == 2
    assert r.route([100, 10, 10]) == 1    # 1 and 2 tied -> lowest index
    assert r.route([100, 15, 10]) == 2
    assert r.route([0, 15, 11]) == 0      # drained replica is emptiest
    assert r.routed == [2, 2, 2]


def test_engine_outstanding_tokens_tracks_queue_and_pool(arch_state):
    """The load measure the router balances on: queued tokens before run,
    zero after the pool drains."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(17)
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    eng = ServeEngine(
        cfg, params, RT,
        EngineConfig(max_slots=1, page_size=8, num_pages=9, max_len=16,
                     inner_steps=2),
    )
    assert eng.outstanding_tokens == 0
    eng.submit(prompt, 4)
    assert eng.outstanding_tokens == len(prompt) + 4
    eng.run()
    assert eng.outstanding_tokens == 0


def test_engine_trivial_mesh_matches_unsharded(arch_state):
    """A 1x1 mesh exercises the whole sharded code path (placement, specs,
    shard_map guards) on one device and must change nothing."""
    import jax as _jax

    cfg, params = arch_state("granite-8b")
    mesh = _jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.RandomState(21)
    prompt = rng.randint(0, cfg.vocab_size, (7,)).astype(np.int32)
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=17, max_len=32,
                        inner_steps=4)
    outs = {}
    for key, rt in (("plain", RT), ("mesh", RT.replace(mesh=mesh))):
        eng = ServeEngine(cfg, params, rt, ecfg)
        rid = eng.submit(prompt, 6)
        outs[key] = eng.run()[rid]
        assert eng.kv_pool_bytes_per_device() > 0
    np.testing.assert_array_equal(outs["plain"], outs["mesh"])


# ----------------------------------------------------- retrace regression
def test_generate_does_not_retrace_on_same_shapes(arch_state):
    from repro.serve import dense

    cfg, params = arch_state("granite-8b")
    batch = make_batch(cfg, B=2, S=19, key=8)   # unique shape for this test
    before = dense.CACHE_BUILDS
    generate(cfg, params, batch, RT, max_new_tokens=4)
    cold = dense.CACHE_BUILDS - before
    assert cold == 2                             # prefill + decode loop
    generate(cfg, params, batch, RT, max_new_tokens=4)
    assert dense.CACHE_BUILDS - before == cold   # cache hit: no rebuild

    total = 19 + 4
    bkey = dense.batch_shape_key(batch)
    prefill_fn = dense.compiled_prefill(cfg, RT, bkey, total)
    loop_fn = dense.compiled_decode_loop(cfg, RT, bkey, total, 4, 0.0)
    for fn in (prefill_fn, loop_fn):             # jax.jit miss counters
        if hasattr(fn, "_cache_size"):
            assert fn._cache_size() == 1, "second call retraced"


# ------------------------------------------------------- sample_token fix
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_sample_token_finfo_masking_over_dtypes(dtype):
    """Padded-vocab masking must use the dtype's finfo min — a hard-coded
    -1e30 overflows fp16 (and exceeds bf16 resolution tricks)."""
    vocab, padded = 5, 8
    logits = jnp.full((2, padded), 10.0, dtype)
    logits = logits.at[:, vocab:].set(20.0)      # padding ids look best
    tok = sample_token(logits, jax.random.PRNGKey(0), 0.0, vocab)
    assert np.asarray(tok).max() < vocab
    for seed in range(5):
        tok = sample_token(logits, jax.random.PRNGKey(seed), 1.0, vocab)
        assert np.asarray(tok).max() < vocab, "sampled a padded id"
    masked = jnp.where(
        jnp.arange(padded) < vocab, logits, jnp.finfo(dtype).min
    )
    assert bool(jnp.all(jnp.isfinite(masked) | (masked == jnp.finfo(dtype).min)))


@hypothesis.given(st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=25, deadline=None)
def test_zero_temperature_equals_argmax(seed):
    rng = np.random.RandomState(seed % (2**31 - 1))
    vocab = 11
    logits = jnp.asarray(rng.randn(3, 16), jnp.float32)
    tok = sample_token(logits, jax.random.PRNGKey(seed), 0.0, vocab)
    expect = jnp.argmax(logits[:, :vocab], axis=-1)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(expect))
