"""Speculative decoding over the paged engine.

* Verify-pass identity: ``verify_step_paged`` at T=1 IS one
  ``decode_step_paged`` — bitwise, logits AND every written pool leaf,
  including the int8/fp8 quantized pools (the quantize-once-per-write
  bytes must not depend on which path wrote them).
* At T=k the batched rows reproduce k sequential decode steps up to
  argmax (token-exact); raw logits drift ~1e-6 from XLA's row-count-
  dependent GEMM accumulation order, so the float check is a tight
  allclose, not bitwise. Token identity of the committed stream is what
  the engine guarantee rests on, and that is exact.
* Engine: spec-on greedy output is token-identical to spec-off and to
  each request alone, for both drafter kinds (ngram and paired-model,
  including the self-draft full-accept extreme), under optimistic-policy
  eviction with ``PagePool.truncate`` rollback, and mixed with the
  prefix cache + chunked prefill (ngram only).
* Drafting never changes tokens, only speed — so every identity test
  doubles as a rejection-rollback test wherever acceptance < 1.
* Config gating: the ValueErrors that keep unsupported mode combinations
  out of ``ServeEngine.__init__`` (dense-fallback families among them —
  which is how the non-paged half of the family matrix is covered here).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import reduced
from repro.models import (
    Runtime,
    decode_step_paged,
    init_paged_state,
    init_params,
    prefill,
    verify_step_paged,
)
from repro.models.stack import write_prefill_to_pool
from repro.serve import EngineConfig, ServeEngine
from repro.serve.spec import ngram_draft, paired_drafter_cfg
from repro.train.serve import generate

RT = Runtime(dtype=jnp.float32, chunk_q=32)

PAGED_FAMILIES = ["granite-8b", "gemma3-1b", "phi-3-vision-4.2b"]


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_reduced(name)
            cache[name] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
        return cache[name]

    return get


# ------------------------------------------------ verify-pass identity
def _prefilled_state(cfg, params, prompt, kv_dtype, horizon):
    """Paged state with the prompt written to the pool (the admission
    path: prefill -> write_prefill_to_pool), plus the pending token."""
    rt = RT.replace(kv_dtype=kv_dtype)
    page = 8
    prompt_total = len(prompt) + (
        cfg.frontend_tokens if cfg.frontend == "vision" else 0
    )
    max_len = -(-(prompt_total + horizon) // page) * page
    P = max_len // page
    state = init_paged_state(
        cfg, 1, rt, num_pages=P + 1, page_size=page, max_len=max_len
    )
    table_row = jnp.arange(1, P + 1, dtype=jnp.int32)
    batch = {"tokens": jnp.asarray(prompt[None])}
    if cfg.frontend is not None:
        rngf = np.random.RandomState(1)
        batch["frontend_embeds"] = jnp.asarray(
            rngf.randn(1, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    logits, pstate = prefill(
        cfg, params, batch, rt, max_len=prompt_total + horizon,
        full_cache=True,
    )
    state["caches"] = write_prefill_to_pool(
        state["caches"], pstate["caches"], table_row, page
    )
    state["tables"] = table_row[None]
    state["lengths"] = jnp.asarray([prompt_total], jnp.int32)
    tok0 = int(jnp.argmax(logits[0, : cfg.vocab_size]))
    return state, tok0, rt, max_len


# phi-3-vision carries ~4e-6 accumulation drift even at T=1: XLA fuses
# its decode-step GEMMs differently from the T-dim verify GEMMs. Argmax
# is still exact there; the bitwise half of the claim holds for the
# text-only paged families.
BITWISE_T1 = {"granite-8b", "gemma3-1b"}


@pytest.mark.parametrize("kv_dtype", ["", "int8", "fp8"])
@pytest.mark.parametrize("name", PAGED_FAMILIES)
def test_verify_at_t1_is_decode_step_bitwise(arch_state, name, kv_dtype):
    """T=1 verify == one decode step: identical argmax everywhere, and
    bitwise-identical logits AND pool leaves (codes and scales when
    quantized) for the families where XLA emits the same GEMM schedule.
    This is the base case of the spec-tick determinism argument — a
    draft-free tick degenerates to ordinary decode exactly."""
    cfg, params = arch_state(name)
    rng = np.random.RandomState(13)
    prompt = rng.randint(0, cfg.vocab_size, (11,)).astype(np.int32)
    state, tok0, rt, max_len = _prefilled_state(
        cfg, params, prompt, kv_dtype, horizon=8
    )

    lg_d, st_d = decode_step_paged(
        cfg, params, state, jnp.asarray([tok0]), rt, max_len
    )
    lg_v, st_v = verify_step_paged(
        cfg, params, state, jnp.asarray([[tok0]], jnp.int32),
        jnp.asarray([1], jnp.int32), rt, max_len,
    )
    assert int(jnp.argmax(lg_d[0, : cfg.vocab_size])) == int(
        jnp.argmax(lg_v[0, 0, : cfg.vocab_size])
    )
    if name in BITWISE_T1:
        np.testing.assert_array_equal(
            np.asarray(lg_d[0]), np.asarray(lg_v[0, 0])
        )
        for leaf_d, leaf_v in zip(
            jax.tree.leaves(st_d["caches"]), jax.tree.leaves(st_v["caches"])
        ):
            np.testing.assert_array_equal(
                np.asarray(leaf_d), np.asarray(leaf_v)
            )
    else:
        np.testing.assert_allclose(
            np.asarray(lg_d[0]), np.asarray(lg_v[0, 0]),
            rtol=1e-4, atol=1e-4,
        )
    # decode advances lengths; verify leaves the commit to the caller
    assert int(st_d["lengths"][0]) == int(state["lengths"][0]) + 1
    assert int(st_v["lengths"][0]) == int(state["lengths"][0])


@pytest.mark.parametrize(
    "name,kv_dtype",
    [(n, "") for n in PAGED_FAMILIES]
    + [("granite-8b", "int8"), ("granite-8b", "fp8")],
)
def test_verify_at_tk_matches_sequential_decode(arch_state, name, kv_dtype):
    """One T=k verify pass over the target's own greedy chain reproduces
    k sequential decode steps: argmax token-exact at every row (the
    committed stream), logits within batched-GEMM accumulation noise."""
    cfg, params = arch_state(name)
    k = 4
    rng = np.random.RandomState(17)
    prompt = rng.randint(0, cfg.vocab_size, (9,)).astype(np.int32)
    state, tok0, rt, max_len = _prefilled_state(
        cfg, params, prompt, kv_dtype, horizon=k + 2
    )

    seq_logits, toks, st = [], [tok0], state
    for _ in range(k):
        lg, st = decode_step_paged(
            cfg, params, st, jnp.asarray([toks[-1]]), rt, max_len
        )
        seq_logits.append(np.asarray(lg[0]))
        toks.append(int(jnp.argmax(lg[0, : cfg.vocab_size])))

    lg_v, _ = verify_step_paged(
        cfg, params, state, jnp.asarray([toks[:k]], jnp.int32),
        jnp.asarray([k], jnp.int32), rt, max_len,
    )
    for j in range(k):
        assert int(jnp.argmax(lg_v[0, j, : cfg.vocab_size])) == toks[j + 1], j
        np.testing.assert_allclose(
            np.asarray(lg_v[0, j]), seq_logits[j], rtol=1e-4, atol=1e-4,
            err_msg=f"row {j}",
        )


# ---------------------------------------------------- engine identity
def _run_alone(cfg, params, prompt, max_new):
    out, _ = generate(
        cfg, params, {"tokens": jnp.asarray(prompt[None])}, RT, max_new
    )
    return np.asarray(out[0])


def _drive(cfg, params, ecfg, prompts, max_news, **kw):
    eng = ServeEngine(cfg, params, RT, ecfg, **kw)
    rids = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    out = eng.run()
    return eng, [np.asarray(out[r]) for r in rids]


def _spec_prompts(cfg):
    """Staggered lengths plus one cyclic prompt (the identity claims hold
    at ANY acceptance rate, so a near-zero-acceptance full-vocab workload
    is the harshest rejection exercise)."""
    rng = np.random.RandomState(2)
    base = rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
    prompts = [
        np.tile(base, 4),                                       # cyclic
        rng.randint(0, cfg.vocab_size, (11,)).astype(np.int32),
        rng.randint(0, cfg.vocab_size, (17,)).astype(np.int32),
        rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32),
    ]
    return prompts, [10, 6, 9, 8]


ECFG = dict(max_slots=2, page_size=8, num_pages=33, max_len=64,
            inner_steps=4)


def test_spec_ngram_token_identical_and_counters(arch_state):
    """Anchored binary-vocab scenario (the bench's trick): a vocab-2
    random-init model's greedy stream falls into short cycles, so the
    prompt-lookup drafter provably lands hits — the accept counters are
    non-zero, not just well-formed. Full-vocab ngram identity (where every
    draft is junk and must be rejected) is covered by the rollback and
    prefix-cache tests below."""
    base_cfg, _ = arch_state("granite-8b")
    cfg = reduced(base_cfg, name="granite-8b-bin", vocab_size=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, 2, (n,)).astype(np.int32) for n in (12, 7, 15)]
    max_news = [24, 16, 20]
    _, off = _drive(cfg, params, EngineConfig(**ECFG), prompts, max_news)
    eng, on = _drive(
        cfg, params, EngineConfig(spec_tokens=3, **ECFG), prompts, max_news
    )
    for i, (a, b) in enumerate(zip(off, on)):
        np.testing.assert_array_equal(a, b, err_msg=f"req {i}")
        np.testing.assert_array_equal(
            a, _run_alone(cfg, params, prompts[i], max_news[i]),
            err_msg=f"req {i} alone",
        )
    s = eng.stats
    assert s["spec_verify_calls"] > 0
    assert s["spec_drafted_tokens"] > 0
    # the cyclic greedy stream guarantees some prompt-lookup hits land
    assert s["spec_accepted_tokens"] > 0
    assert 0.0 < s["spec_accept_rate"] <= 1.0
    # every verify commits at least the target's own next token
    assert s["spec_accepted_per_verify"] >= 1.0
    # fewer ticks than tokens: the whole point of the multi-token commit
    total = sum(len(o) for o in on)
    assert s["spec_verify_calls"] < total, (s["spec_verify_calls"], total)
    eng.pool.check()
    assert eng.pool.pages_in_use == 0


def test_spec_model_drafter_token_identical(arch_state):
    """Paired 1-layer drafter with its own random init: mostly-rejected
    drafts (the rejection path), yet the committed stream is exactly the
    target's greedy stream."""
    cfg, params = arch_state("granite-8b")
    dcfg = paired_drafter_cfg(cfg)
    dparams = init_params(dcfg, jax.random.PRNGKey(1))
    prompts, max_news = _spec_prompts(cfg)
    _, off = _drive(cfg, params, EngineConfig(**ECFG), prompts, max_news)
    eng, on = _drive(
        cfg, params,
        EngineConfig(spec_tokens=3, spec_drafter="model", **ECFG),
        prompts, max_news, draft_params=dparams, draft_cfg=dcfg,
    )
    for i, (a, b) in enumerate(zip(off, on)):
        np.testing.assert_array_equal(a, b, err_msg=f"req {i}")
    assert eng.stats["spec_verify_calls"] > 0
    eng.pool.check()
    assert eng.pool.pages_in_use == 0


def test_spec_self_draft_accepts_nearly_everything(arch_state):
    """Drafter == target: every draft token IS the target argmax, so only
    the per-request remaining-token cap can reject — acceptance must be
    near 1 and each verify must commit multiple tokens. Exercises the
    full-accept catch-up path (drafter one token behind after k+1
    commits) that partial acceptance never reaches. Longer max_news than
    ``_spec_prompts`` so the tail-cap rejections amortize below 20%."""
    cfg, params = arch_state("granite-8b")
    prompts, _ = _spec_prompts(cfg)
    max_news = [22, 18, 21, 20]
    eng, on = _drive(
        cfg, params,
        EngineConfig(spec_tokens=3, spec_drafter="model", **ECFG),
        prompts, max_news, draft_params=params, draft_cfg=cfg,
    )
    _, off = _drive(cfg, params, EngineConfig(**ECFG), prompts, max_news)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    s = eng.stats
    assert s["spec_accept_rate"] > 0.8, s["spec_accept_rate"]
    assert s["spec_accepted_per_verify"] > 2.0, s["spec_accepted_per_verify"]


def test_spec_rollback_under_optimistic_eviction(arch_state):
    """Optimistic policy + tiny pool: eviction mid-decode AND per-tick
    ``PagePool.truncate`` rewinds of over-reserved draft capacity. The
    rollback must be invisible in the tokens and leave the pool clean."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
               for _ in range(2)]
    max_news = [24, 16]
    tight = dict(max_slots=2, page_size=4, num_pages=10, max_len=48,
                 inner_steps=4, policy="optimistic")
    _, off = _drive(cfg, params, EngineConfig(**tight), prompts, max_news)
    eng, on = _drive(
        cfg, params, EngineConfig(spec_tokens=3, **tight), prompts, max_news
    )
    assert eng.stats.get("evictions", 0) > 0
    for i, (a, b) in enumerate(zip(off, on)):
        np.testing.assert_array_equal(a, b, err_msg=f"req {i}")
        np.testing.assert_array_equal(
            a, _run_alone(cfg, params, prompts[i], max_news[i])
        )
    eng.pool.check()
    assert eng.pool.pages_in_use == 0


def test_spec_with_prefix_cache_and_chunked_prefill(arch_state):
    """ngram drafting composes with the radix prefix cache and chunked
    prefill: spec ticks interleave with mid-prefill ticks (which fall
    back to the ordinary chunk path) without changing a token."""
    cfg, params = arch_state("granite-8b")
    rng = np.random.RandomState(6)
    sys_prompt = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [
        np.concatenate([sys_prompt,
                        rng.randint(0, cfg.vocab_size, (s,)).astype(np.int32)])
        for s in (5, 3, 7)
    ]
    max_news = [8, 10, 6]
    mode = dict(prefix_cache=True, prefill_chunk=8, **ECFG)
    _, off = _drive(cfg, params, EngineConfig(**mode), prompts, max_news)
    eng, on = _drive(
        cfg, params, EngineConfig(spec_tokens=3, **mode), prompts, max_news
    )
    for i, (a, b) in enumerate(zip(off, on)):
        np.testing.assert_array_equal(a, b, err_msg=f"req {i}")
    assert eng.stats["spec_verify_calls"] > 0
    eng.pool.check()
    # retired prompts stay resident in the radix cache by design; spec
    # drafting must not leak pages beyond what the cache accounts for
    assert eng.pool.pages_in_use == eng.prefix.pages_cached()
    eng.prefix.clear()
    assert eng.pool.pages_in_use == 0


# ------------------------------------------------------------- gating
def test_spec_config_gating(arch_state):
    cfg, params = arch_state("granite-8b")
    vis_cfg, vis_params = arch_state("phi-3-vision-4.2b")
    spec = dict(spec_tokens=3, **ECFG)
    # dense-fallback families have no paged verify path
    for name in ("falcon-mamba-7b", "recurrentgemma-2b",
                 "seamless-m4t-medium"):
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(get_reduced(name), None, RT, EngineConfig(**spec))
    with pytest.raises(ValueError, match="temperature"):
        ServeEngine(cfg, params, RT,
                    EngineConfig(temperature=0.7, **spec))
    with pytest.raises(ValueError, match="spec_drafter"):
        ServeEngine(cfg, params, RT,
                    EngineConfig(spec_drafter="medusa", **spec))
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeEngine(cfg, params, RT,
                    EngineConfig(spec_drafter="model", prefix_cache=True,
                                 **spec))
    with pytest.raises(ValueError, match="draft_params"):
        ServeEngine(cfg, params, RT,
                    EngineConfig(spec_drafter="model", **spec))
    with pytest.raises(ValueError, match="ngram drafter"):
        ServeEngine(vis_cfg, vis_params, RT,
                    EngineConfig(spec_drafter="model", **spec),
                    draft_params=vis_params, draft_cfg=vis_cfg)


# ----------------------------------------------------------- drafters
def test_ngram_draft_prompt_lookup():
    # continuation after the earlier occurrence of the final 3-gram
    ctx = np.array([7, 1, 2, 3, 9, 8, 1, 2, 3], np.int32)
    np.testing.assert_array_equal(ngram_draft(ctx, k=2), [9, 8])
    # k truncation; a continuation that runs off the end cycles the tail
    np.testing.assert_array_equal(ngram_draft(ctx, k=1), [9])
    np.testing.assert_array_equal(
        ngram_draft(np.array([1, 2, 3, 1, 2, 3], np.int32), k=5),
        [1, 2, 3, 1, 2],
    )
    # longest n wins: a 2-gram match beats a more recent 1-gram match
    ctx = np.array([1, 2, 5, 4, 2, 9, 1, 2], np.int32)
    np.testing.assert_array_equal(ngram_draft(ctx, k=1), [5])
    # most recent occurrence wins at equal n
    ctx = np.array([1, 2, 5, 0, 1, 2, 8, 0, 1, 2], np.int32)
    np.testing.assert_array_equal(ngram_draft(ctx, k=1), [8])
    # periodic tail extension: on the period-2 stream the nearest match
    # sits 2 tokens from the end — blind truncation would propose only
    # [0, 1] and cap every accepted run at one period
    ctx = np.tile(np.array([0, 1], np.int32), 5)
    np.testing.assert_array_equal(ngram_draft(ctx, k=3), [0, 1, 0])
    # no repeat -> empty proposal (draft-free verify tick)
    assert ngram_draft(np.array([1, 2, 3, 4], np.int32), k=3).size == 0
    assert ngram_draft(np.array([5], np.int32), k=3).size == 0
    assert ngram_draft(np.array([1, 2, 1, 2], np.int32), k=0).size == 0


def test_paired_drafter_cfg_contract():
    from repro.serve import paged_supported

    cfg = get_reduced("granite-8b")
    dcfg = paired_drafter_cfg(cfg)
    assert dcfg.n_layers == 1
    assert dcfg.vocab_size == cfg.vocab_size     # draft tokens ARE target ids
    assert dcfg.family == cfg.family
    assert dcfg.name == cfg.name + "-draft"
    assert paged_supported(dcfg)
    assert paired_drafter_cfg(cfg, n_layers=2).n_layers == 2
