"""3D (data x model x pipe) training subprocess suite.

Covers the executable-pipeline acceptance bar: 3D meshes train with losses
matching the single-device step, 1F1B gradients are bitwise-equal to GPipe
on anchored shapes (with the O(P)-vs-O(M) activation-slot gap), and a
checkpoint saved under one ParallelPlan restores into a different
(dp, tp, pp) layout (reshard-on-load).
"""
import subprocess
import sys
import textwrap

from _subproc import REPO_ROOT, subprocess_env

import pytest

pytestmark = pytest.mark.multidevice

PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import SURVEY_DEMO, ShapeSpec, reduced
    import repro.configs.registry as registry
    from repro.core.partitioner import ParallelPlan
    from repro.data import DataPipeline
    from repro.launch.mesh import make_train_mesh
    from repro.launch.train import build_train, build_train_pipeline
    from repro.optim import get as get_opt
    from repro.train import TrainConfig, make_state, make_train_step

    TINY = reduced(SURVEY_DEMO, n_layers=4, d_model=128, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=512)
    registry.ARCHITECTURES[TINY.name] = TINY
    B, SEQ, M = 8, 32, 4
    tc = TrainConfig(precision="f32", remat="none", log_every=1)
    opt = get_opt(tc.optimizer, tc.lr)

    def batches(steps, seed=0):
        data = DataPipeline(TINY, batch_size=B, seq_len=SEQ, seed=seed)
        out = [{k: np.asarray(v) for k, v in dict(next(data)).items()}
               for _ in range(steps)]
        data.close()
        return out

    def put(tree, structs):
        return jax.tree.map(
            lambda v, st: jax.device_put(jnp.asarray(v), st.sharding),
            tree, structs)
    """
)


def run(script: str, marker: str, timeout: int = 900) -> None:
    r = subprocess.run(
        [sys.executable, "-c", PRELUDE + textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout,
        env=subprocess_env(), cwd=REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert marker in r.stdout, r.stdout[-2000:]


def test_3d_losses_match_single_device():
    """2x1x2 / 1x2x2 / 2x2x2 plans track the single-device trajectory."""
    run(
        """
        STEPS = 5
        BATCHES = batches(STEPS)
        step1 = make_train_step(TINY, opt, tc)
        state1 = make_state(TINY, opt, tc)
        ref = []
        for b in BATCHES:
            state1, m = step1(state1, {k: jnp.asarray(v) for k, v in b.items()})
            ref.append(float(m["loss"]))
        for (dp, tp, pp) in [(2, 1, 2), (1, 2, 2), (2, 2, 2)]:
            plan = ParallelPlan(dp=dp, tp=tp, pp=pp, microbatches=M,
                                schedule="1f1b").validate(TINY)
            mesh = make_train_mesh(dp, tp, pp)
            jitted, (s_struct, b_struct) = build_train_pipeline(
                TINY.name, mesh, plan, tc, ShapeSpec("t", SEQ, B, "train"))
            state = put(make_state(TINY, opt, tc), s_struct)
            losses = []
            for b in BATCHES:
                state, m = jitted(state, put(dict(b), b_struct))
                losses.append(float(m["loss"]))
            np.testing.assert_allclose(losses, ref, rtol=2e-3, atol=2e-4)
            print(f"{dp}x{tp}x{pp} ok", losses[-1])
        print("LOSSES_3D_OK")
        """,
        "LOSSES_3D_OK",
    )


def test_1f1b_matches_gpipe_bitwise():
    """Same params/batch: 1F1B grads == GPipe grads exactly, O(P) slots."""
    run(
        """
        from repro.core.pipeline import tick_table
        PP, MM = 2, 8   # M >= 2*P: the memory gap regime
        t1, tg = tick_table("1f1b", PP, MM), tick_table("gpipe", PP, MM)
        assert t1.n_act_slots < tg.n_act_slots, (t1.n_act_slots, tg.n_act_slots)
        assert t1.n_act_slots == min(PP, MM) and tg.n_act_slots == MM

        BATCH = batches(1)[0]
        outs = {}
        for sched in ("gpipe", "1f1b"):
            plan = ParallelPlan(dp=2, tp=2, pp=PP, microbatches=MM,
                                schedule=sched).validate(TINY)
            mesh = make_train_mesh(2, 2, PP)
            jitted, (s_struct, b_struct) = build_train_pipeline(
                TINY.name, mesh, plan, tc, ShapeSpec("t", SEQ, B, "train"))
            state = put(make_state(TINY, opt, tc), s_struct)
            new_state, m = jitted(state, put(dict(BATCH), b_struct))
            outs[sched] = (
                jax.tree.map(np.asarray, new_state["params"]),
                float(m["loss"]), float(m["grad_norm"]),
            )
        assert outs["gpipe"][1] == outs["1f1b"][1], "loss not bitwise equal"
        assert outs["gpipe"][2] == outs["1f1b"][2], "grad_norm not bitwise equal"
        ga, gb = outs["gpipe"][0], outs["1f1b"][0]
        for (pa, a), (pb, bb) in zip(
            jax.tree_util.tree_flatten_with_path(ga)[0],
            jax.tree_util.tree_flatten_with_path(gb)[0],
        ):
            np.testing.assert_array_equal(a, bb, err_msg=str(pa))
        print("BITWISE_OK")
        """,
        "BITWISE_OK",
    )


def test_checkpoint_reshard_on_load():
    """Save under one plan, restore into another (dp, tp, pp) and into the
    2D trainer; both continue with identical step outputs."""
    run(
        """
        import tempfile
        from repro.checkpoint import restore_resharded, save

        shape = ShapeSpec("t", SEQ, B, "train")
        BATCHES = batches(3)

        plan_a = ParallelPlan(dp=2, tp=1, pp=2, microbatches=M).validate(TINY)
        mesh_a = make_train_mesh(2, 1, 2)
        jit_a, (sa_struct, ba_struct) = build_train_pipeline(
            TINY.name, mesh_a, plan_a, tc, shape)
        state = put(make_state(TINY, opt, tc), sa_struct)
        state, _ = jit_a(state, put(dict(BATCHES[0]), ba_struct))

        with tempfile.TemporaryDirectory() as d:
            save(d, 1, jax.tree.map(np.asarray, state))

            # restore into a different 3D plan
            plan_b = ParallelPlan(dp=1, tp=2, pp=2, microbatches=M).validate(TINY)
            mesh_b = make_train_mesh(1, 2, 2)
            jit_b, (sb_struct, bb_struct) = build_train_pipeline(
                TINY.name, mesh_b, plan_b, tc, shape)
            state_b = restore_resharded(d, sb_struct)
            for la, lb in zip(jax.tree.leaves(state), jax.tree.leaves(state_b)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

            # and into the plain 2D pjit trainer
            mesh_c = jax.make_mesh((2, 2), ("data", "model"))
            jit_c, (sc_struct, bc_struct) = build_train(
                TINY.name, mesh_c, tc, shape)
            state_c = restore_resharded(d, sc_struct)

            state_b, mb_ = jit_b(state_b, put(dict(BATCHES[1]), bb_struct))
            state_c, mc_ = jit_c(state_c, put(dict(BATCHES[1]), bc_struct))
            np.testing.assert_allclose(
                float(mb_["loss"]), float(mc_["loss"]), rtol=2e-3)
        print("RESHARD_OK")
        """,
        "RESHARD_OK",
    )
