"""Distributed selective scan (sequence-parallel Mamba) == local scan.

Runs on 4 simulated devices in a subprocess; asserts the sharded scan's
outputs and gradients match the single-device reference."""
import os
import subprocess
import sys
import textwrap

import pytest

from _subproc import REPO_ROOT, subprocess_env


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.ssm import init_mamba, mamba_apply, mamba_apply_seqpar

    mesh = jax.make_mesh((1, 4), ("data", "model"))
    d, di, s, K = 32, 64, 8, 4
    p = init_mamba(jax.random.PRNGKey(0), d, di, s, K)
    x = jnp.asarray(np.random.RandomState(1).randn(2, 64, d), jnp.float32)

    ref = mamba_apply(p, x)
    par = jax.jit(lambda p, x: mamba_apply_seqpar(
        p, x, mesh=mesh, batch_axes=(), ))(p, x)
    np.testing.assert_allclose(np.asarray(par), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)

    gr = jax.grad(lambda p_: jnp.sum(mamba_apply(p_, x) ** 2))(p)
    # note: jit required — eager shard_map linearization hits a sharding-
    # override assertion in jax 0.8.2 (production path is always jitted)
    gp = jax.jit(jax.grad(lambda p_: jnp.sum(mamba_apply_seqpar(
        p_, x, mesh=mesh, batch_axes=()) ** 2)))(p)
    for k in gr:
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(gr[k]),
                                   rtol=5e-3, atol=5e-4), k
    # RG-LRU distributed scan
    from repro.models.rglru import init_rglru, rglru_apply, rglru_apply_seqpar
    pr = init_rglru(jax.random.PRNGKey(2), 32, 64, 4)
    ref = rglru_apply(pr, x)
    par = jax.jit(lambda p_, x_: rglru_apply_seqpar(
        p_, x_, mesh=mesh, batch_axes=()))(pr, x)
    np.testing.assert_allclose(np.asarray(par), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    gr = jax.grad(lambda p_: jnp.sum(rglru_apply(p_, x) ** 2))(pr)
    gp = jax.jit(jax.grad(lambda p_: jnp.sum(rglru_apply_seqpar(
        p_, x, mesh=mesh, batch_axes=()) ** 2)))(pr)
    for k in gr:
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(gr[k]),
                                   rtol=5e-3, atol=5e-4)
    print("SEQPAR_OK")
    """
)


@pytest.mark.multidevice
def test_seqpar_matches_local():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SEQPAR_OK" in r.stdout
