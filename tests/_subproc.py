"""Shared env for tests that spawn jax subprocesses on simulated devices."""
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def subprocess_env():
    """Inherit the environment (JAX_PLATFORMS etc. — a bare env hangs jax
    backend probing on CPU containers); scripts set their own XLA_FLAGS."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    return env
