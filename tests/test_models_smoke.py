"""Per-architecture smoke tests on REDUCED variants (CPU, 1 device).

For every assigned architecture: instantiate a reduced config of the same
family (<=2-ish layers, d_model<=256, <=4 experts), run one forward and one
train step (grad + SGD update), and assert output shapes + finiteness.
Decode smoke: prefill a short prompt then decode a few tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_reduced
from repro.models import Runtime, decode_step, forward, init_params, loss_fn, prefill

RT = Runtime(dtype=jnp.float32, chunk_q=32)


def make_batch(cfg, B=2, S=32, key=0):
    rng = np.random.RandomState(key)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_reduced(name)
            params = init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_shapes_and_finite(arch_state, name):
    cfg, params = arch_state(name)
    batch = make_batch(cfg)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b, RT))(params, batch)
    S = batch["tokens"].shape[1] + (
        cfg.frontend_tokens if cfg.frontend == "vision" else 0
    )
    assert logits.shape == (2, S, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step_no_nans(arch_state, name):
    cfg, params = arch_state(name)
    batch = make_batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: loss_fn(cfg, q, b, RT), has_aux=True
        )(p)
        new_p = jax.tree.map(lambda w, g: w - 1e-3 * g, p, grads)
        return loss, new_p

    loss, new_params = step(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    leaves = jax.tree.leaves(new_params)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), name
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), leaves)
    )
    assert changed, name


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_then_decode(arch_state, name):
    cfg, params = arch_state(name)
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S)
    logits, state = jax.jit(lambda p, b: prefill(cfg, p, b, RT))(params, batch)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()

    total = S + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    logits, state = jax.jit(
        lambda p, b: prefill(cfg, p, b, RT, max_len=total + 4)
    )(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32) % cfg.vocab_size
    step = jax.jit(lambda p, s, t: decode_step(cfg, p, s, t, RT, seq_len=total + 4))
    for _ in range(3):
        logits, state = step(params, state, tok)
        assert logits.shape == (B, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits)).all(), name
        tok = jnp.argmax(logits, -1).astype(jnp.int32) % cfg.vocab_size


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the forward logits (dense arch)."""
    cfg = get_reduced("granite-8b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 12
    batch = make_batch(cfg, B=B, S=S, key=3)
    full_logits, _ = forward(cfg, params, batch, RT)

    pre = {k: (v[:, :4] if v.ndim > 1 else v) for k, v in batch.items()}
    logits, state = prefill(cfg, params, pre, RT, max_len=S)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, 3]), rtol=2e-4, atol=2e-4
    )
    for t in range(4, S):
        tok = batch["tokens"][:, t]
        logits, state = decode_step(cfg, params, state, tok, RT, seq_len=S)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-4,
            err_msg=f"t={t}",
        )
