"""Optional-hypothesis shim so the tier-1 suite runs on a bare interpreter.

Test modules do ``from _hyp_compat import hypothesis, st`` instead of a hard
``import hypothesis``. When hypothesis is installed the real module is passed
through and the property tests run; when it is missing only the
``@hypothesis.given`` tests skip (with an importorskip-style reason) while
the rest of the module still collects and runs.
"""
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Placeholder strategy factory: args are never drawn, only displayed."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None

            return strategy

    class _Hypothesis:
        def given(self, *args, **kwargs):
            return pytest.mark.skip(
                reason="could not import 'hypothesis' (property test)"
            )

        def settings(self, *args, **kwargs):
            return lambda fn: fn

    hypothesis = _Hypothesis()
    st = _Strategies()
