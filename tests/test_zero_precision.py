"""ZeRO spec algebra + mixed-precision/loss-scaling unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.precision import (
    PrecisionPolicy,
    init_scale_state,
    scale_loss,
    unscale_and_check,
)
from repro.core.zero import add_axis_to_spec, memory_per_device, overlay


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})


def test_add_axis_prefers_largest_divisible_dim():
    spec = add_axis_to_spec(P(None, "model"), (4096, 1024), MESH)
    assert spec == P("data", "model")


def test_add_axis_skips_sharded_and_indivisible():
    # dim0 already sharded; dim1 not divisible by 16
    spec = add_axis_to_spec(P("model", None), (512, 100), MESH)
    assert spec == P("model", None)


def test_add_axis_leaves_small_tensors_replicated():
    assert add_axis_to_spec(P(None), (7,), MESH) == P(None)


def test_overlay_stages():
    specs = {"w": P(None, "model"), "b": P(None)}
    shapes = {
        "w": jax.ShapeDtypeStruct((4096, 1024), jnp.float32),
        "b": jax.ShapeDtypeStruct((1024,), jnp.float32),
    }
    for stage, (p_sharded, g_sharded, o_sharded) in {
        0: (False, False, False),
        1: (False, False, True),
        2: (False, True, True),
        3: (True, True, True),
    }.items():
        p, g, o = overlay(stage, specs, shapes, MESH)
        assert (p["w"] == P("data", "model")) == p_sharded
        assert (g["w"] == P("data", "model")) == g_sharded
        assert (o["w"] == P("data", "model")) == o_sharded


def test_memory_per_device_monotone():
    last = None
    for stage in range(4):
        m = memory_per_device(8e9, MESH, stage, tp_shard=16)
        total = sum(m.values())
        if last is not None:
            assert total <= last
        last = total
    # stage3 with dp=16: everything /16
    m3 = memory_per_device(8e9, MESH, 3, tp_shard=16)
    m0 = memory_per_device(8e9, MESH, 0, tp_shard=16)
    assert sum(m3.values()) == pytest.approx(sum(m0.values()) / 16)


# ---------------------------------------------------------------- precision
def test_fp16_scale_halves_on_nonfinite():
    pol = PrecisionPolicy.fp16()
    st = init_scale_state(pol)
    grads = {"w": jnp.array([jnp.inf, 1.0])}
    g, st2, finite = unscale_and_check(grads, st, pol)
    assert not bool(finite)
    assert float(st2["scale"]) == float(st["scale"]) / 2


def test_fp16_scale_grows_after_interval():
    pol = PrecisionPolicy(compute_dtype=jnp.float16, use_loss_scaling=True,
                          growth_interval=3, init_scale=8.0)
    st = init_scale_state(pol)
    grads = {"w": jnp.ones(4)}
    for i in range(3):
        g, st, finite = unscale_and_check(grads, st, pol)
        assert bool(finite)
    assert float(st["scale"]) == 16.0
    assert int(st["good_steps"]) == 0


def test_unscale_restores_magnitude():
    pol = PrecisionPolicy.fp16()
    st = init_scale_state(pol)
    loss = jnp.array(2.0)
    scaled = scale_loss(loss, st)
    assert float(scaled) == 2.0 * pol.init_scale
    g, _, _ = unscale_and_check({"w": jnp.ones(2) * pol.init_scale}, st, pol)
    np.testing.assert_allclose(np.asarray(g["w"]), np.ones(2))


def test_bf16_no_scaling():
    pol = PrecisionPolicy.bf16()
    st = init_scale_state(pol)
    assert float(st["scale"]) == 1.0
    g, st2, finite = unscale_and_check({"w": jnp.ones(2)}, st, pol)
    assert bool(finite) and float(st2["scale"]) == 1.0
