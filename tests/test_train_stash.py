"""Quantized/host activation-stash training suite (subprocess, forced devices).

The grad-accuracy regression bar for the stash subsystem: int8/fp8 slot
compression perturbs gradients by a bounded relative error against the
raw-stash oracle on an anchored 2-stage arch, short loss curves track the
raw run, and quantized-stash training is deterministic — the same seed
yields a bitwise-identical loss stream, across TP and pipe degrees.
"""
import subprocess
import sys
import textwrap

from _subproc import REPO_ROOT, subprocess_env

import pytest

pytestmark = pytest.mark.multidevice

PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import SURVEY_DEMO, ShapeSpec, reduced
    import repro.configs.registry as registry
    from repro.core.partitioner import ParallelPlan
    from repro.data import DataPipeline
    from repro.launch.mesh import make_train_mesh
    from repro.launch.train import build_train_pipeline
    from repro.optim import get as get_opt
    from repro.train import TrainConfig, make_state

    TINY = reduced(SURVEY_DEMO, n_layers=4, d_model=128, n_heads=4,
                   n_kv_heads=2, d_ff=256, vocab_size=512)
    registry.ARCHITECTURES[TINY.name] = TINY
    B, SEQ, M = 8, 32, 4
    shape = ShapeSpec("t", SEQ, B, "train")

    def batches(steps, seed=0):
        data = DataPipeline(TINY, batch_size=B, seq_len=SEQ, seed=seed)
        out = [{k: np.asarray(v) for k, v in dict(next(data)).items()}
               for _ in range(steps)]
        data.close()
        return out

    def put(tree, structs):
        return jax.tree.map(
            lambda v, st: jax.device_put(jnp.asarray(v), st.sharding),
            tree, structs)

    def pipe_losses(stash, dims, BATCHES, tc=None, state_np=None,
                    stash_cot=False):
        dp, tp, pp = dims
        tc = tc or TrainConfig(precision="f32", log_every=1, stash=stash)
        opt = get_opt(tc.optimizer, tc.lr)
        plan = ParallelPlan(dp=dp, tp=tp, pp=pp, microbatches=M,
                            schedule="1f1b", stash=stash,
                            stash_cot=stash_cot).validate(TINY)
        mesh = make_train_mesh(dp, tp, pp)
        jitted, (s_struct, b_struct) = build_train_pipeline(
            TINY.name, mesh, plan, tc, shape)
        init = state_np if state_np is not None else make_state(TINY, opt, tc)
        state = put(init, s_struct)
        losses = []
        for b in BATCHES:
            state, m = jitted(state, put(dict(b), b_struct))
            losses.append(float(m["loss"]))
        return losses, jax.tree.map(np.asarray, state)
    """
)


def run(script: str, marker: str, timeout: int = 900) -> None:
    r = subprocess.run(
        [sys.executable, "-c", PRELUDE + textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout,
        env=subprocess_env(), cwd=REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert marker in r.stdout, r.stdout[-2000:]


def test_quant_stash_grad_accuracy():
    """One SGD step from a shared init on the anchored 2-stage arch: the
    param delta is -lr * grad (momentum buffer starts at 0, clip disabled),
    so comparing deltas bounds the stash's relative GRADIENT error against
    the raw oracle. fp8 (e4m3, ~2 mantissa bits) sits well above int8."""
    run(
        """
        tc = TrainConfig(precision="f32", optimizer="sgd", lr=1e-3,
                         grad_clip=1e9, log_every=1)
        opt = get_opt(tc.optimizer, tc.lr)
        # numpy copy: the jitted step donates its state arg, so a device
        # state could not be re-put for the second and third backends
        state0 = jax.tree.map(np.asarray, make_state(TINY, opt, tc))
        p0 = state0["params"]
        BATCH = batches(1)

        def delta(stash):
            _, state = pipe_losses(stash, (1, 1, 2), BATCH, tc=tc,
                                   state_np=state0)
            return jax.tree.map(lambda a, b: a - b, state["params"], p0)

        d_raw = delta("raw")
        flat = lambda t: np.concatenate(
            [np.asarray(l).ravel() for l in jax.tree.leaves(t)])
        ref = flat(d_raw)
        assert np.linalg.norm(ref) > 0
        bounds = {"int8": 0.05, "fp8": 0.20}
        for stash in ("int8", "fp8"):
            err = np.linalg.norm(flat(delta(stash)) - ref) / np.linalg.norm(ref)
            print(f"{stash} rel grad err {err:.4f}")
            assert err < bounds[stash], (stash, err)
            assert err > 0   # the perturbation is real, not a no-op
        print("GRAD_ACC_OK")
        """,
        "GRAD_ACC_OK",
    )


def test_quant_cotangent_grad_accuracy():
    """Same one-SGD-step param-delta technique, isolating the COTANGENT
    codec: raw-cotangent quantized-slot runs vs stash_cot=True runs at the
    same activation stash. Compressing cotangents adds its own bounded
    gradient perturbation on top of the slot codec's (the bwd stream is
    quantized once per stage hop), and it must be a real perturbation, not
    a no-op."""
    run(
        """
        tc = TrainConfig(precision="f32", optimizer="sgd", lr=1e-3,
                         grad_clip=1e9, log_every=1)
        opt = get_opt(tc.optimizer, tc.lr)
        state0 = jax.tree.map(np.asarray, make_state(TINY, opt, tc))
        p0 = state0["params"]
        BATCH = batches(1)

        def delta(stash, stash_cot):
            _, state = pipe_losses(stash, (1, 1, 2), BATCH, tc=tc,
                                   state_np=state0, stash_cot=stash_cot)
            return jax.tree.map(lambda a, b: a - b, state["params"], p0)

        flat = lambda t: np.concatenate(
            [np.asarray(l).ravel() for l in jax.tree.leaves(t)])
        ref = flat(delta("raw", False))
        assert np.linalg.norm(ref) > 0
        bounds = {"int8": 0.08, "fp8": 0.30}
        for stash in ("int8", "fp8"):
            act_only = flat(delta(stash, False))
            both = flat(delta(stash, True))
            err = np.linalg.norm(both - ref) / np.linalg.norm(ref)
            print(f"{stash}+cot rel grad err {err:.4f}")
            assert err < bounds[stash], (stash, err)
            # cot compression is a real extra perturbation over act-only
            assert np.linalg.norm(both - act_only) > 0
        print("COT_GRAD_ACC_OK")
        """,
        "COT_GRAD_ACC_OK",
    )


def test_quant_stash_loss_tracking():
    """Short training curves: int8/fp8 stash losses track the raw-stash
    run within a few percent at every step (no divergence)."""
    run(
        """
        STEPS = 6
        BATCHES = batches(STEPS)
        ref, _ = pipe_losses("raw", (1, 1, 2), BATCHES)
        for stash, rtol in (("int8", 0.02), ("fp8", 0.05)):
            losses, _ = pipe_losses(stash, (1, 1, 2), BATCHES)
            np.testing.assert_allclose(losses, ref, rtol=rtol)
            print(stash, "tracks:", losses[-1], "vs raw", ref[-1])
        print("TRACKING_OK")
        """,
        "TRACKING_OK",
    )


def test_quant_stash_determinism():
    """Same seed -> bitwise-identical loss stream under a quantized stash,
    at both (1,1,2) and the TP-sharded (1,2,2) degrees."""
    run(
        """
        BATCHES = batches(4)
        for dims in ((1, 1, 2), (1, 2, 2)):
            a, _ = pipe_losses("fp8", dims, BATCHES)
            b, _ = pipe_losses("fp8", dims, BATCHES)
            assert a == b, (dims, a, b)
            print("deterministic at", dims, a)
        print("DETERMINISM_OK")
        """,
        "DETERMINISM_OK",
    )
