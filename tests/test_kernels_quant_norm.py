"""Blockwise-quant + rmsnorm kernels: sweeps vs oracles (+ hypothesis)."""
from _hyp_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernel

from repro.kernels.blockwise_quant import dequantize, quantize
from repro.kernels.blockwise_quant.ref import dequantize_ref, dynamic_map, quantize_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


# ------------------------------------------------------------ blockwise quant
def test_dynamic_map_properties():
    m = dynamic_map()
    assert m.shape == (256,)
    assert np.all(np.diff(m) >= 0)
    assert m.max() == 1.0 and 0.0 in m
    assert abs(m.min()) > 0.99


@pytest.mark.parametrize("n", [256 * 64, 256 * 64 * 4])
@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e4])
def test_quant_kernel_matches_ref_sweep(n, scale):
    x = jnp.asarray(np.random.RandomState(0).randn(n), jnp.float32) * scale
    cp, sp, _ = quantize(x, backend="pallas")
    cr, sr, _ = quantize(x, backend="ref")
    np.testing.assert_array_equal(np.asarray(cp), np.asarray(cr))
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr))


def test_quant_roundtrip_error_bound():
    x = jnp.asarray(np.random.RandomState(1).randn(256 * 64), jnp.float32)
    c, s, n = quantize(x)
    xr = dequantize(c, s, n, x.shape)
    rel = float(jnp.sqrt(jnp.mean((x - xr) ** 2)) / jnp.sqrt(jnp.mean(x**2)))
    assert rel < 0.02, rel  # dynamic 8-bit: ~1% rms


def test_quant_handles_zeros_and_padding():
    x = jnp.zeros(100)  # needs padding to tile multiple; all-zero block
    c, s, n = quantize(x)
    xr = dequantize(c, s, n, x.shape)
    np.testing.assert_array_equal(np.asarray(xr), np.zeros(100))


@hypothesis.given(
    seed=st.integers(0, 50),
    logscale=st.floats(-6, 6),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_quant_scale_equivariant(seed, logscale):
    """quantize(a*x) has codes == quantize(x) (per-block absmax normalizes)."""
    a = float(10.0**logscale)
    x = jnp.asarray(np.random.RandomState(seed).randn(256 * 64), jnp.float32)
    c1, s1, _ = quantize(x)
    c2, s2, _ = quantize(x * a)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1) * a, rtol=1e-5)


@hypothesis.given(seed=st.integers(0, 50))
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_dequant_bounded_by_scale(seed):
    x = jnp.asarray(np.random.RandomState(seed).randn(256 * 64), jnp.float32)
    c, s, n = quantize(x)
    xr = np.asarray(dequantize(c, s, n, x.shape)).reshape(-1, 256)
    assert (np.abs(xr) <= np.asarray(s)[:, None] + 1e-6).all()


# ----------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("shape", [(4, 128), (2, 7, 256), (1, 300, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(*shape), dtype)
    s = jnp.asarray(rng.rand(shape[-1]) + 0.5, jnp.float32)
    out = rmsnorm(x, s)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=1e-5 if dtype == jnp.float32 else 1e-2, rtol=1e-5 if dtype == jnp.float32 else 1e-2,
    )


def test_rmsnorm_grads_match_ref():
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(8, 128), jnp.float32)
    s = jnp.asarray(rng.rand(128) + 0.5, jnp.float32)
    gk = jax.grad(lambda x_, s_: jnp.sum(rmsnorm(x_, s_) ** 2), argnums=(0, 1))(x, s)
    gr = jax.grad(lambda x_, s_: jnp.sum(rmsnorm_ref(x_, s_) ** 2), argnums=(0, 1))(x, s)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


@hypothesis.given(seed=st.integers(0, 30), rows=st.integers(1, 17))
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_rmsnorm_row_norm(seed, rows):
    """With unit scale, every row of the output has RMS ~ 1."""
    x = jnp.asarray(np.random.RandomState(seed).randn(rows, 64) * 3, jnp.float32)
    out = np.asarray(rmsnorm(x, jnp.ones(64)))
    rms = np.sqrt((out**2).mean(-1))
    np.testing.assert_allclose(rms, np.ones(rows), atol=1e-3)
