"""Paged decode-attention kernel vs the jnp oracle, and the oracle vs a
dense gather-free computation. Sweeps GQA group sizes, sliding windows,
non-page-multiple request lengths, and explicit interpret mode. The
prefill-kernel sweeps at the bottom cover the chunked-prefill sibling:
chunk-length queries, ragged valid rows, nonzero start offsets (cached
prefixes), and the T=1 decode degeneration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernel

from repro.kernels.paged_attention import (
    paged_attention,
    paged_attention_ref,
    paged_prefill_attention,
    paged_prefill_attention_ref,
)
from repro.kernels.paged_attention.kernel import (
    paged_attention_kernel,
    paged_prefill_attention_kernel,
)


def make_case(B, Kv, G, hd, page, N, P, lengths, seed=0):
    """Random pool + per-request block tables covering ``lengths`` tokens."""
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, Kv, G, hd), jnp.float32) * (hd**-0.5)
    kp = jnp.asarray(rng.randn(N, page, Kv, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(N, page, Kv, hd), jnp.float32)
    # carve disjoint page runs out of 1..N-1 (page 0 = null)
    tables = np.zeros((B, P), np.int32)
    nxt = 1
    for b, L in enumerate(lengths):
        n = -(-L // page)
        assert nxt + n <= N
        tables[b, :n] = np.arange(nxt, nxt + n)
        nxt += n
    return q, kp, vp, jnp.asarray(tables), jnp.asarray(lengths, jnp.int32)


CASES = [
    # (B, Kv, G, hd, page, N, P, lengths)  — lengths off page multiples
    (1, 1, 1, 32, 8, 8, 4, [13]),          # MQA
    (3, 2, 4, 32, 8, 32, 4, [13, 27, 5]),  # GQA
    (2, 4, 2, 64, 16, 16, 4, [64, 33]),    # exact + off multiple
    (2, 2, 8, 32, 4, 32, 8, [1, 31]),      # single-token request
]


@pytest.mark.parametrize("case", CASES, ids=[str(c[:4]) for c in CASES])
@pytest.mark.parametrize("window", [0, 6])
def test_kernel_matches_ref(case, window):
    B, Kv, G, hd, page, N, P, lengths = case
    q, kp, vp, tables, lens = make_case(B, Kv, G, hd, page, N, P, lengths)
    out = paged_attention(q, kp, vp, tables, lens, window=window,
                          use_kernel=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens, window=window)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5
    )


def test_kernel_interpret_mode_explicit():
    q, kp, vp, tables, lens = make_case(2, 2, 2, 32, 8, 16, 4, [9, 20], seed=3)
    out = paged_attention_kernel(q, kp, vp, tables, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5
    )


def test_ref_matches_dense_attention():
    """The oracle's block-table gather == attending over the contiguous
    sequence the pages encode."""
    B, Kv, G, hd, page, N, P = 2, 2, 2, 16, 8, 16, 4
    lengths = [11, 26]
    q, kp, vp, tables, lens = make_case(B, Kv, G, hd, page, N, P, lengths,
                                        seed=7)
    out = paged_attention_ref(q, kp, vp, tables, lens)
    for b, L in enumerate(lengths):
        k = np.asarray(kp)[np.asarray(tables)[b]].reshape(-1, Kv, hd)[:L]
        v = np.asarray(vp)[np.asarray(tables)[b]].reshape(-1, Kv, hd)[:L]
        scores = np.einsum("kgh,skh->kgs", np.asarray(q)[b], k)
        w = jax.nn.softmax(jnp.asarray(scores), axis=-1)
        expect = np.einsum("kgs,skh->kgh", np.asarray(w), v)
        np.testing.assert_allclose(
            np.asarray(out)[b], expect, atol=2e-5, rtol=2e-5
        )


def test_null_page_padding_is_masked():
    """Garbage in null-page / padding table entries must not leak into any
    request within its valid length."""
    q, kp, vp, tables, lens = make_case(2, 2, 2, 16, 8, 16, 4, [9, 12], seed=1)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    kp2 = kp.at[0].set(1e3)  # poison the null page
    vp2 = vp.at[0].set(-1e3)
    out = paged_attention(q, kp2, vp2, tables, lens, use_kernel=True)
    ref2 = paged_attention_ref(q, kp2, vp2, tables, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref2),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ref2), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_window_equals_full_when_covering():
    q, kp, vp, tables, lens = make_case(1, 2, 2, 16, 8, 8, 4, [14], seed=2)
    full = paged_attention_ref(q, kp, vp, tables, lens, window=0)
    wide = paged_attention_ref(q, kp, vp, tables, lens, window=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(wide),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------- quantized pool gather
def _quantize_case(q, kp, vp, kv_dtype):
    from repro.kernels.paged_attention import quant

    store = quant.kv_storage_dtype(kv_dtype, q.dtype)
    kc, ks = quant.kv_quantize(kp, store)
    vc, vs = quant.kv_quantize(vp, store)
    return kc, vc, ks, vs


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("case", CASES[:2], ids=[str(c[:4]) for c in CASES[:2]])
def test_quantized_kernel_matches_quantized_ref(case, kv_dtype):
    """Fused in-gather dequant inside the Pallas kernel == dequantizing in
    the jnp oracle: both read the same codes + scales, so they must agree
    to kernel tolerance (the quantization error itself cancels out)."""
    B, Kv, G, hd, page, N, P, lengths = case
    q, kp, vp, tables, lens = make_case(B, Kv, G, hd, page, N, P, lengths)
    kc, vc, ks, vs = _quantize_case(q, kp, vp, kv_dtype)
    out = paged_attention(q, kc, vc, tables, lens, k_scale=ks, v_scale=vs,
                          use_kernel=True)
    ref = paged_attention_ref(q, kc, vc, tables, lens, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5
    )
    # and the quantized result is close to (but not identical with) exact
    exact = paged_attention_ref(q, kp, vp, tables, lens)
    drift = float(jnp.max(jnp.abs(ref - exact)))
    assert 0.0 < drift < 0.5, drift


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_prefill_kernel_matches_quantized_ref(kv_dtype):
    B, T, Kv, G, hd, page, N, P = 2, 4, 2, 2, 32, 8, 16, 4
    starts, qlens = [0, 5], [4, 3]
    q, kp, vp, tbl, st, ln = make_prefill_case(
        B, T, Kv, G, hd, page, N, P, starts, qlens
    )
    kc, vc, ks, vs = _quantize_case(q, kp, vp, kv_dtype)
    out = paged_prefill_attention(
        q, kc, vc, tbl, st, ln, k_scale=ks, v_scale=vs, use_kernel=True
    )
    ref = paged_prefill_attention_ref(
        q, kc, vc, tbl, st, ln, k_scale=ks, v_scale=vs
    )
    for b in range(B):
        np.testing.assert_allclose(
            np.asarray(out)[b, : qlens[b]], np.asarray(ref)[b, : qlens[b]],
            atol=2e-5, rtol=2e-5,
        )


def test_quantized_null_page_dequantizes_to_zero():
    """Zero-initialized scales make the null page read as exact zeros no
    matter what garbage codes it holds — padding masking stays intact."""
    from repro.kernels.paged_attention import quant

    q, kp, vp, tables, lens = make_case(2, 2, 2, 16, 8, 16, 4, [9, 12], seed=1)
    kc, vc, ks, vs = _quantize_case(q, kp, vp, "int8")
    kc = kc.at[0].set(127)                   # poison null-page codes
    vc = vc.at[0].set(-127)
    ks = ks.at[0].set(0.0)                   # null page: scale stays zero
    vs = vs.at[0].set(0.0)
    ref = paged_attention_ref(q, kc, vc, tables, lens, k_scale=ks, v_scale=vs)
    out = paged_attention(q, kc, vc, tables, lens, k_scale=ks, v_scale=vs,
                          use_kernel=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    assert bool(jnp.all(jnp.isfinite(ref)))
    # dequant of zero-scale pages is exactly zero (not NaN/Inf)
    assert bool(jnp.all(quant.kv_dequantize(kc[0], ks[0]) == 0.0))


# -------------------------------------------------- chunked prefill kernel
def make_prefill_case(B, T, Kv, G, hd, page, N, P, starts, qlens, seed=0):
    """Pool + block tables covering each request's start + T positions."""
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, T, Kv, G, hd), jnp.float32) * (hd ** -0.5)
    kp = jnp.asarray(rng.randn(N, page, Kv, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(N, page, Kv, hd), jnp.float32)
    tables = np.zeros((B, P), np.int32)
    nxt = 1
    for b in range(B):
        n = -(-(starts[b] + T) // page)
        assert nxt + n <= N and n <= P
        tables[b, :n] = np.arange(nxt, nxt + n)
        nxt += n
    return (
        q, kp, vp, jnp.asarray(tables),
        jnp.asarray(starts, jnp.int32), jnp.asarray(qlens, jnp.int32),
    )


PREFILL_CASES = [
    # (B, T, Kv, G, hd, page, N, P, starts, qlens)
    (1, 8, 1, 4, 32, 4, 24, 12, [0], [8]),       # MQA, cold chunk
    (2, 4, 2, 2, 32, 8, 16, 4, [0, 5], [4, 3]),  # GQA, offset + ragged
    (1, 8, 1, 4, 32, 4, 24, 12, [13], [6]),      # mid-prompt chunk
    (3, 4, 2, 4, 16, 8, 32, 4, [0, 9, 17], [4, 2, 1]),  # mixed depths
]


@pytest.mark.parametrize(
    "case", PREFILL_CASES, ids=[str(c[:4]) for c in PREFILL_CASES]
)
@pytest.mark.parametrize("window", [0, 6])
def test_prefill_kernel_matches_ref(case, window):
    B, T, Kv, G, hd, page, N, P, starts, qlens = case
    q, kp, vp, tbl, st, ln = make_prefill_case(
        B, T, Kv, G, hd, page, N, P, starts, qlens
    )
    out = paged_prefill_attention(
        q, kp, vp, tbl, st, ln, window=window, use_kernel=True
    )
    ref = paged_prefill_attention_ref(q, kp, vp, tbl, st, ln, window=window)
    for b in range(B):   # padded rows (t >= q_len) are garbage by contract
        np.testing.assert_allclose(
            np.asarray(out)[b, : qlens[b]], np.asarray(ref)[b, : qlens[b]],
            atol=2e-5, rtol=2e-5,
        )


def test_prefill_kernel_interpret_mode_explicit():
    q, kp, vp, tbl, st, ln = make_prefill_case(
        2, 4, 2, 2, 32, 8, 16, 4, [3, 11], [4, 2], seed=3
    )
    out = paged_prefill_attention_kernel(q, kp, vp, tbl, st, ln,
                                         interpret=True)
    ref = paged_prefill_attention_ref(q, kp, vp, tbl, st, ln)
    for b, L in enumerate([4, 2]):
        np.testing.assert_allclose(
            np.asarray(out)[b, :L], np.asarray(ref)[b, :L],
            atol=2e-5, rtol=2e-5,
        )


def test_prefill_t1_degenerates_to_decode():
    """A one-row chunk at position p is exactly a decode step at length
    p + 1 — the two oracles (and thus both kernels) must agree."""
    q, kp, vp, tbl, st, ln = make_prefill_case(
        2, 1, 2, 2, 16, 8, 16, 4, [6, 11], [1, 1], seed=7
    )
    pre = paged_prefill_attention_ref(q, kp, vp, tbl, st, ln)
    dec = paged_attention_ref(q[:, 0], kp, vp, tbl, st + 1)
    np.testing.assert_allclose(
        np.asarray(pre[:, 0]), np.asarray(dec), atol=1e-6, rtol=1e-6
    )


def test_prefill_causality_ignores_future_garbage():
    """Keys beyond each row's own position — including stale garbage in
    allocated-but-unwritten page slots — must not leak into any valid row."""
    q, kp, vp, tbl, st, ln = make_prefill_case(
        1, 4, 2, 2, 16, 8, 16, 4, [2], [4], seed=5
    )
    ref = paged_prefill_attention_ref(q, kp, vp, tbl, st, ln)
    # poison every pool position at kpos > last query position
    last = 2 + 4 - 1
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    for j in range(np.asarray(tbl).shape[1]):
        pid = int(np.asarray(tbl)[0, j])
        for s in range(kp2.shape[1]):
            if j * kp2.shape[1] + s > last and pid != 0:
                kp2[pid, s] = 1e3
                vp2[pid, s] = -1e3
    out = paged_prefill_attention(
        q, jnp.asarray(kp2), jnp.asarray(vp2), tbl, st, ln, use_kernel=True
    )
    np.testing.assert_allclose(
        np.asarray(out)[0], np.asarray(ref)[0], atol=2e-5, rtol=2e-5
    )
